"""Elastic coordinator: heartbeats, failure detection, rescale plans,
coordinator takeover (PWFComb lease)."""

import time

from repro.runtime.elastic import ElasticCoordinator


def test_heartbeat_and_plan():
    co = ElasticCoordinator(4, heartbeat_timeout=0.5)
    plan = co.heartbeat(1, step=10)
    assert plan.dp_size == 4
    assert plan.data_shards[1] == 1


def test_failure_detection_and_rescale():
    co = ElasticCoordinator(4, heartbeat_timeout=0.05)
    for h in (0, 1, 2):
        co.heartbeat(h, step=5)
    time.sleep(0.08)
    for h in (0, 1, 2):
        co.heartbeat(h, step=6)
    failed = co.detect_failures()
    assert failed == [3]
    plan = co.rescale(committed_step=5, failed=failed)
    assert plan.hosts == (0, 1, 2)
    assert plan.dp_size == 3
    assert plan.restore_step == 5
    assert plan.epoch == 1
    # shard indices are dense 0..n-1
    assert sorted(plan.data_shards.values()) == [0, 1, 2]


def test_straggler_detection_by_progress():
    co = ElasticCoordinator(3, heartbeat_timeout=10.0)
    co.heartbeat(0, step=20)
    co.heartbeat(1, step=20)
    co.heartbeat(2, step=3)        # alive but far behind
    assert co.stragglers() == [2]


def test_join_after_rescale():
    co = ElasticCoordinator(2, heartbeat_timeout=0.05)
    co.heartbeat(0, 1)
    time.sleep(0.08)
    co.heartbeat(0, 2)
    plan = co.rescale(committed_step=1)
    assert plan.hosts == (0,)
    co.join(1)                     # host comes back
    co.heartbeat(1, 0)
    plan = co.rescale(committed_step=2)
    assert plan.hosts == (0, 1)
    assert plan.epoch == 2


def test_coordinator_takeover_lease():
    co = ElasticCoordinator(3, heartbeat_timeout=10.0, lease_s=0.05)
    co.heartbeat(0, 1)             # coordinator alive
    assert not co.take_over_coordination(2)
    time.sleep(0.08)               # lease lapses
    assert co.coordinator_lease_expired()
    assert co.take_over_coordination(2)
    assert co.coordinator_host == 2
    # second takeover attempt immediately fails (SC semantics)
    assert not co.take_over_coordination(1)
