"""Elastic coordinator: heartbeats, failure detection, rescale plans,
coordinator takeover (PWFComb lease)."""

import time

from repro.runtime.elastic import ElasticCoordinator


def test_heartbeat_and_plan():
    co = ElasticCoordinator(4, heartbeat_timeout=0.5)
    plan = co.heartbeat(1, step=10)
    assert plan.dp_size == 4
    assert plan.data_shards[1] == 1


def test_failure_detection_and_rescale():
    co = ElasticCoordinator(4, heartbeat_timeout=0.05)
    for h in (0, 1, 2):
        co.heartbeat(h, step=5)
    time.sleep(0.08)
    for h in (0, 1, 2):
        co.heartbeat(h, step=6)
    failed = co.detect_failures()
    assert failed == [3]
    plan = co.rescale(committed_step=5, failed=failed)
    assert plan.hosts == (0, 1, 2)
    assert plan.dp_size == 3
    assert plan.restore_step == 5
    assert plan.epoch == 1
    # shard indices are dense 0..n-1
    assert sorted(plan.data_shards.values()) == [0, 1, 2]


def test_straggler_detection_by_progress():
    co = ElasticCoordinator(3, heartbeat_timeout=10.0)
    co.heartbeat(0, step=20)
    co.heartbeat(1, step=20)
    co.heartbeat(2, step=3)        # alive but far behind
    assert co.stragglers() == [2]


def test_join_after_rescale():
    co = ElasticCoordinator(2, heartbeat_timeout=0.05)
    co.heartbeat(0, 1)
    time.sleep(0.08)
    co.heartbeat(0, 2)
    plan = co.rescale(committed_step=1)
    assert plan.hosts == (0,)
    co.join(1)                     # host comes back
    co.heartbeat(1, 0)
    plan = co.rescale(committed_step=2)
    assert plan.hosts == (0, 1)
    assert plan.epoch == 2


def test_leave_and_alive_hosts():
    co = ElasticCoordinator(3, heartbeat_timeout=10.0)
    assert co.alive_hosts() == [0, 1, 2]
    co.leave(1)                    # voluntary scale-down: immediate
    assert co.alive_hosts() == [0, 2]
    plan = co.rescale(committed_step=7)
    assert plan.hosts == (0, 2)
    assert plan.restore_step == 7
    co.join(1)
    assert co.alive_hosts() == [0, 1, 2]
    plan = co.rescale(committed_step=7)
    assert plan.hosts == (0, 1, 2)


def test_elastic_leave_join_shm_fleet():
    """join/leave against REAL fork()ed workers on the shm backend: a
    departed worker serves nothing while the survivors carry the whole
    wave; rejoin restores it (the fleet applies coordinator plans as
    per-shard active worker sets)."""
    from repro.fleet import Fleet, FleetConfig

    cfg = FleetConfig(n_shards=2, workers_per_shard=2, n_clients=8,
                      seed=11)
    with Fleet(cfg) as f:
        res = f.run_wave(f.make_wave(16, rate_rps=4000.0))
        assert sum(len(r.latencies) for r in res.values()) == 16

        plan = f.leave(1, 1)           # shard 1 loses worker tid 1
        assert f.host_id(1, 1) not in plan.hosts
        assert f.shards[1].active_tids == [0]
        assert f.shards[0].active_tids == [0, 1]

        res = f.run_wave(f.make_wave(16, rate_rps=4000.0))
        assert sum(len(r.latencies) for r in res.values()) == 16
        # the departed worker ran an empty schedule: served nothing
        by_tid = {r.tid: r for r in res[1].reports}
        assert not by_tid[1].latencies
        assert by_tid[1].ops_done == 0

        plan2 = f.join(1, 1)           # elastic scale-up
        assert plan2.epoch == plan.epoch + 1
        assert f.host_id(1, 1) in plan2.hosts
        assert f.shards[1].active_tids == [0, 1]
        res = f.run_wave(f.make_wave(16, rate_rps=4000.0))
        assert sum(len(r.latencies) for r in res.values()) == 16


def test_coordinator_takeover_lease():
    co = ElasticCoordinator(3, heartbeat_timeout=10.0, lease_s=0.05)
    co.heartbeat(0, 1)             # coordinator alive
    assert not co.take_over_coordination(2)
    time.sleep(0.08)               # lease lapses
    assert co.coordinator_lease_expired()
    assert co.take_over_coordination(2)
    assert co.coordinator_host == 2
    # second takeover attempt immediately fails (SC semantics)
    assert not co.take_over_coordination(1)
