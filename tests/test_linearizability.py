"""Durable-linearizability crash sweeps through the history checker
(tests/checker.py) — the machine-checked counterpart of the paper's
durable-linearizability claims, on BOTH backends:

  * multiprocess (shm): 4 fork()ed workers drive rich (blob-heap)
    payloads against 2-segment ShmNVM structures while a shared crash
    countdown halts the machine mid-workload; workers report their
    in-flight ops and ``recover(inflight=...)`` replays them.  Every
    tentpole path — blob codec publication, per-segment rings, the
    serving/checkpoint structures — runs under the checker here.
  * threads: the staged announce/perform harness crashes inside
    combining rounds serving N announced requests (the only way to
    enumerate in-round crash points deterministically in one process).

Sizes are tuned for 2-core CI runners: the sweeps are many small
commands against one long-lived runtime/pool, not many runtimes.
"""

import random

import pytest

from repro.api import CombiningRuntime
from repro.core import SimulatedCrash

from checker import HistoryChecker, check_ckpt, check_log

#: (countdown, rng seed) cases; 24 for the serving/checkpoint rows (the
#: acceptance gate) and a 12-case prefix for the matrix cells
CASES_24 = [(cd, seed) for seed in (1, 2, 3)
            for cd in (2, 3, 5, 7, 9, 11, 15, 21)]
CASES_12 = CASES_24[:12]

MP_CELLS = [("queue", "pbcomb"), ("queue", "pwfcomb"),
            ("stack", "pbcomb"), ("stack", "pwfcomb"),
            ("heap", "pbcomb"), ("heap", "pwfcomb")]

_DRAIN_OP = {"queue": "dequeue", "stack": "pop", "heap": "delete_min"}


def _drain_all(rt, obj):
    """Quiescent post-recovery drain through a parent-process handle:
    the structure's own remove op until empty — for a queue this IS the
    FIFO order, for a stack the LIFO residue, for a heap the sorted
    stream the heap-order check wants."""
    fn = rt.attach(0).invoker(obj, _DRAIN_OP[obj.kind], arity=0)
    out = []
    while True:
        v = fn()
        if v is None:
            break
        out.append(v)
    return out


# --------------------------------------------------------------------- #
# multiprocess sweeps                                                   #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("kind,protocol", MP_CELLS)
def test_mp_crash_sweep_matrix(kind, protocol):
    """queue/stack/heap x pbcomb/pwfcomb under 4 real processes, rich
    blob values, 2-segment NVM, crashes swept across countdowns."""
    rt = CombiningRuntime(n_threads=4, backend="shm", segments=2)
    chk = HistoryChecker(kind)
    try:
        obj = rt.make(kind, protocol)
        pool = rt.spawn_workers(4)
        for case_i, (cd, seed) in enumerate(CASES_12):
            rt.nvm.arm_crash(cd, random.Random(seed))
            res = pool.run_pairs(obj, 5, collect=True, rich=True,
                                 index_base=case_i * 5)
            chk.extend_pool(res)
            if res.crashed:
                replies = rt.recover(inflight=res.inflight)
                chk.apply_replay(res.inflight, replies)
            else:
                rt.nvm.disarm_crash()
        # one full machine crash + recovery, then the quiescent drain
        rt.crash(random.Random(99))
        rt.recover()
        chk.check(_drain_all(rt, obj))
    finally:
        rt.close()


@pytest.mark.parametrize("protocol", ["pbcomb", "pwfcomb",
                                      "lock-direct"])
def test_mp_crash_sweep_serving(protocol):
    """The serving-over-shm row under the checker: 24 crash cases of
    workers RECORDing rich responses into one shared log.  lock-direct
    rides along: RECORD is idempotent and its (seq, response) pair
    shares a cache line, so even the non-detectable baseline must keep
    the log exact — what the gate's floor row relies on."""
    gen_len = 6
    rt = CombiningRuntime(n_threads=4, backend="shm", segments=2)
    chk = HistoryChecker("log")
    try:
        log = rt.make("log", protocol, n_clients=4)
        pool = rt.spawn_workers(4)
        base = 0
        for cd, seed in CASES_24:
            rt.nvm.arm_crash(cd, random.Random(seed))
            res = pool.run_serving(log, 3, gen_len=gen_len,
                                   seq_base=base, collect=True)
            chk.extend_pool(res)
            if res.crashed:
                replies = rt.recover(inflight=res.inflight)
                chk.apply_replay(res.inflight, replies)
            else:
                rt.nvm.disarm_crash()
            base += 3
        rt.crash(random.Random(7))
        rt.recover()
        check_log(chk.events, log.snapshot(), gen_len)
    finally:
        rt.close()


@pytest.mark.parametrize("protocol", ["pbcomb", "pwfcomb",
                                      "lock-direct"])
def test_mp_crash_sweep_checkpoint(protocol):
    """The checkpoint-over-shm row under the checker: 24 crash cases of
    workers persisting multi-word shard payloads; the durable
    (step, payload) pair must stay atomic and cover every ack."""
    words = 12
    rt = CombiningRuntime(n_threads=4, backend="shm", segments=2)
    chk = HistoryChecker("ckpt")
    try:
        ck = rt.make("ckpt", protocol)
        pool = rt.spawn_workers(4)
        base = 0
        for cd, seed in CASES_24:
            rt.nvm.arm_crash(cd, random.Random(seed))
            res = pool.run_checkpoint(ck, 3, payload_words=words,
                                      step_base=base, collect=True)
            chk.extend_pool(res)
            if res.crashed:
                replies = rt.recover(inflight=res.inflight)
                chk.apply_replay(res.inflight, replies)
            else:
                rt.nvm.disarm_crash()
            base += 3
        rt.crash(random.Random(13))
        rt.recover()
        check_ckpt(chk.events, ck.snapshot(), words)
    finally:
        rt.close()


def test_mp_mixed_segments_under_checker():
    """Serving AND checkpoint in one 2-segment runtime (the bench's
    mixed row): both histories stay linearizable through interleaved
    crashes, and each structure's psyncs accounted on its own device."""
    gen_len, words = 6, 8
    rt = CombiningRuntime(n_threads=4, backend="shm", segments=2)
    log_chk, ck_chk = HistoryChecker("log"), HistoryChecker("ckpt")
    try:
        log = rt.make("log", "pbcomb", n_clients=4)
        ck = rt.make("ckpt", "pbcomb")
        assert rt.segment_stats()["placement"] == \
            {"log/pbcomb": 0, "ckpt/pbcomb": 1}
        pool = rt.spawn_workers(4)
        base = 0
        for cd, seed in CASES_24[:8]:
            rt.nvm.arm_crash(cd, random.Random(seed))
            res = pool.run_serving(log, 2, gen_len=gen_len,
                                   seq_base=base, collect=True)
            log_chk.extend_pool(res)
            if res.crashed:
                log_chk.apply_replay(
                    res.inflight, rt.recover(inflight=res.inflight))
            else:
                rt.nvm.disarm_crash()
            res = pool.run_checkpoint(ck, 2, payload_words=words,
                                      step_base=base, collect=True)
            ck_chk.extend_pool(res)
            if res.crashed:
                ck_chk.apply_replay(
                    res.inflight, rt.recover(inflight=res.inflight))
            base += 2
        rt.crash(random.Random(5))
        rt.recover()
        check_log(log_chk.events, log.snapshot(), gen_len)
        check_ckpt(ck_chk.events, ck.snapshot(), words)
        segs = rt.nvm.segment_counters()
        assert len(segs) == 2
        assert all(s["psync"] > 0 for s in segs), segs
    finally:
        rt.close()


# --------------------------------------------------------------------- #
# thread-backend sweeps (staged in-round crash points)                  #
# --------------------------------------------------------------------- #
_STAGE_OPS = {"queue": ("enqueue", "dequeue"),
              "stack": ("push", "pop"),
              "heap": ("insert", "delete_min")}

_PAD = "thread-blob-pad-" * 2


@pytest.mark.parametrize("kind,protocol", MP_CELLS)
def test_thread_crash_sweep_matrix(kind, protocol):
    """The same checker over the thread backend: each case stages a
    combining round serving N announced requests and crashes inside it
    (announce/perform + armed countdown), alternating add and remove
    rounds."""
    n = 3
    rt = CombiningRuntime(n_threads=n)
    chk = HistoryChecker(kind)
    obj = rt.make(kind, protocol)
    handles = [rt.attach(p) for p in range(n)]
    add_op, rem_op = _STAGE_OPS[kind]
    idx = [0] * n
    for case_i, (cd, seed) in enumerate(CASES_12):
        adding = case_i % 2 == 0
        args = {}
        for p in range(n):
            if adding:
                args[p] = (p, idx[p], _PAD)
                idx[p] += 1
                handles[p].announce(obj, add_op, args[p])
            else:
                args[p] = None
                handles[p].announce(obj, rem_op)
        rt.arm_crash(cd, random.Random(seed))
        op = add_op if adding else rem_op
        try:
            ret = handles[0].perform(obj)
            chk.extend(0, [(op, args[0], ret)])
        except SimulatedCrash:
            pass
        rt.nvm.disarm_crash()       # late countdowns must not fire in
        replies = rt.recover()      # the replay below
        for p in range(n):
            key = (obj.name, p)
            if key in replies:
                chk.extend(p, [(op, args[p], replies[key])])
    chk.check(_drain_all(rt, obj))
