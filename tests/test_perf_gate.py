"""The CI perf gate (benchmarks/perf_gate.py) and the atomic bench-JSON
writer: the machinery that turns the deterministic modeled columns into
a real regression gate.
"""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import perf_gate
from benchmarks.common import atomic_write_json


def _row(name, m_us=1.0, m_pwb=1.0, m_psync=0.25, profile="optane"):
    return {"name": name, "us_per_op": 42.0, "pwbs_per_op": 1.0,
            "psyncs_per_op": 1.0, "modeled_us_per_op": m_us,
            "modeled_pwbs_per_op": m_pwb, "modeled_psyncs_per_op": m_psync,
            "profile": profile}


def _doc(rows):
    return {"schema": "bench.v2", "tag": "t", "quick": True,
            "profile": "optane", "rows": rows}


BASE = _doc([_row("matrix/queue/pbcomb"),
             _row("matrix/stack/dfc", m_us=2.0, m_pwb=3.5, m_psync=0.25),
             _row("checkpoint/naive", profile=None)])


def test_gate_passes_on_identical_docs():
    failures, warnings, table = perf_gate.compare(BASE, BASE)
    assert failures == []
    assert warnings == []
    assert len(table) == 2 + 2          # header + separator + 2 gated rows


def test_gate_fails_on_injected_psync_regression():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][0]["modeled_psyncs_per_op"] += 1.0
    failures, _w, _t = perf_gate.compare(BASE, cur)
    assert len(failures) == 1
    assert "psyncs/op regressed" in failures[0]


def test_gate_zero_tolerance_on_pwb_counter():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][1]["modeled_pwbs_per_op"] += 0.001    # any growth fails
    failures, _w, _t = perf_gate.compare(BASE, cur)
    assert len(failures) == 1 and "pwbs/op regressed" in failures[0]


def test_counter_improvement_warns_but_passes():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][0]["modeled_psyncs_per_op"] = 0.125
    failures, warnings, _t = perf_gate.compare(BASE, cur)
    assert failures == []
    assert any("improved" in w for w in warnings)


def test_modeled_us_tolerance_band():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][0]["modeled_us_per_op"] = 1.05       # +5% < 10% tol
    failures, _w, _t = perf_gate.compare(BASE, cur, modeled_us_tol=0.10)
    assert failures == []
    cur["rows"][0]["modeled_us_per_op"] = 1.25       # +25% > 10% tol
    failures, _w, _t = perf_gate.compare(BASE, cur, modeled_us_tol=0.10)
    assert len(failures) == 1 and "modeled_us_per_op regressed" in failures[0]


def test_zero_baseline_does_not_mask_regressions():
    base = _doc([_row("matrix/x", m_us=0.0)])       # rounds to 0.000
    cur = _doc([_row("matrix/x", m_us=50.0)])
    failures, _w, _t = perf_gate.compare(base, cur)
    assert len(failures) == 1 and "modeled_us_per_op regressed" in failures[0]
    same = _doc([_row("matrix/x", m_us=0.0)])
    failures, _w, _t = perf_gate.compare(base, same)
    assert failures == []


def test_lost_row_fails_new_row_warns():
    cur = json.loads(json.dumps(BASE))
    dropped = cur["rows"].pop(0)
    cur["rows"].append(_row("matrix/heap/pbcomb"))
    failures, warnings, _t = perf_gate.compare(BASE, cur)
    assert any(dropped["name"] in f and "missing" in f for f in failures)
    assert any("matrix/heap/pbcomb" in w for w in warnings)


def test_unmodeled_rows_are_not_gated():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][2]["us_per_op"] = 9999.0       # wall drift on null-profile
    failures, warnings, _t = perf_gate.compare(BASE, cur)
    assert failures == [] and warnings == []


def test_check_identical_detects_any_modeled_drift():
    assert perf_gate.check_identical(BASE, BASE) == []
    cur = json.loads(json.dumps(BASE))
    cur["rows"][0]["modeled_us_per_op"] += 1e-3
    bad = perf_gate.check_identical(BASE, cur)
    assert len(bad) == 1 and "modeled_us_per_op" in bad[0]


def test_main_exit_codes_and_summary(tmp_path):
    base_p = tmp_path / "base.json"
    cur_p = tmp_path / "cur.json"
    summary = tmp_path / "summary.md"
    base_p.write_text(json.dumps(BASE))
    cur = json.loads(json.dumps(BASE))
    cur_p.write_text(json.dumps(cur))
    assert perf_gate.main([str(base_p), str(cur_p),
                           "--summary", str(summary)]) == 0
    assert "Perf gate" in summary.read_text()
    cur["rows"][0]["modeled_psyncs_per_op"] += 1.0   # injected regression
    cur_p.write_text(json.dumps(cur))
    assert perf_gate.main([str(base_p), str(cur_p)]) == 1
    # determinism mode
    assert perf_gate.main(["--identical", str(base_p), str(base_p)]) == 0
    assert perf_gate.main(["--identical", str(base_p), str(cur_p)]) == 1


# ------------------------------------------------------------------ #
# Atomic --json writes (crash mid-suite must not clobber results)    #
# ------------------------------------------------------------------ #
def test_atomic_write_json_round_trip(tmp_path):
    p = tmp_path / "BENCH_x.json"
    atomic_write_json(str(p), {"ok": 1})
    assert json.loads(p.read_text()) == {"ok": 1}


def test_atomic_write_preserves_existing_on_failure(tmp_path):
    p = tmp_path / "BENCH_x.json"
    p.write_text('{"good": true}')
    with pytest.raises(TypeError):
        atomic_write_json(str(p), {"bad": object()})   # unserializable
    assert json.loads(p.read_text()) == {"good": True}  # intact
    assert list(tmp_path.iterdir()) == [p]              # no temp litter
