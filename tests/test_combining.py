"""PBComb / PWFComb: linearizability under threads, detectable recovery
under exhaustive crash-point sweeps (paper Sections 3-4)."""

import random
import threading

import pytest

try:                                   # optional dep: `pip install .[test]`
    from hypothesis import given, settings, strategies as st
except ImportError:                    # property tests skip below
    given = settings = st = None

from repro.core import (NVM, AtomicFloatObject, FetchAddObject, PBComb,
                        PWFComb, SimulatedCrash)
from repro.core.pbcomb import RequestRec

N = 6
OPS = 150


def _run_threads(obj, op):
    results = [[] for _ in range(N)]

    def worker(p):
        seq = 0
        rng = random.Random(p)
        for _ in range(OPS):
            seq += 1
            results[p].append(op(p, seq))
            for _ in range(rng.randint(0, 30)):   # paper's local work
                pass
    ts = [threading.Thread(target=worker, args=(p,)) for p in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return results


@pytest.mark.parametrize("proto", [PBComb, PWFComb])
def test_faa_linearizable(proto):
    """k FAA(1) ops must return exactly {0..k-1} (each value once) and
    leave the counter at k — any interleaving violating atomicity breaks
    this."""
    nvm = NVM()
    c = proto(nvm, N, FetchAddObject())
    results = _run_threads(c, lambda p, seq: c.op(p, "FAA", 1, seq))
    flat = sorted(v for vs in results for v in vs)
    assert flat == list(range(N * OPS))


@pytest.mark.parametrize("proto", [PBComb, PWFComb])
def test_atomicfloat(proto):
    nvm = NVM()
    c = proto(nvm, N, AtomicFloatObject())
    _run_threads(c, lambda p, seq: c.op(p, "MUL", 1.0000001, seq))
    # state survived and is the product of all multiplications
    if proto is PBComb:
        final = nvm.read(c._st_base(c._mindex()))
    else:
        final = nvm.read(c._base(c.S.load()))
    assert abs(final - 1.0000001 ** (N * OPS)) < 1e-6


@pytest.mark.parametrize("proto", [PBComb, PWFComb])
def test_combining_persistence_cost(proto):
    """P1: persistence instructions per combining ROUND, not per request
    — with 1 thread issuing k ops, pwbs/op is a small constant; psyncs
    equal rounds."""
    nvm = NVM()
    c = proto(nvm, 2, FetchAddObject())
    for seq in range(1, 51):
        c.op(0, "FAA", 1, seq)
    assert nvm.counters["psync"] == 50            # one per round here
    assert nvm.counters["pwb"] <= 50 * 6


@pytest.mark.parametrize("proto", [PBComb, PWFComb])
@pytest.mark.parametrize("crash_at", range(8))
@pytest.mark.parametrize("drain_seed", [None, 1, 2, 3])
def test_detectable_recovery_crash_sweep(proto, crash_at, drain_seed):
    """Crash at every persistence instruction inside a combining round
    serving 4 requests; after recovery every request must have been
    applied EXACTLY once with the right response (detectability)."""
    nvm = NVM()
    c = proto(nvm, 4, FetchAddObject(), **(
        {} if proto is PBComb else {"backoff": False}))
    seqs = [0] * 4
    seqs[0] += 1
    assert c.op(0, "FAA", 1, seqs[0]) == 0
    for p in range(4):
        seqs[p] += 1
        c.request[p] = RequestRec("FAA", 1, 1 - c.request[p].activate, 1)
    rng = random.Random(drain_seed) if drain_seed else None
    nvm.arm_crash(crash_at, rng)
    try:
        c._perform_request(1)
    except SimulatedCrash:
        pass
    nvm.disarm_crash()
    c.reset_volatile()
    rets = {p: c.recover(p, "FAA", 1, seqs[p]) for p in range(4)}
    if proto is PBComb:
        final = nvm.read(c._st_base(c._mindex()))
    else:
        final = nvm.read(c._base(c.S.load()))
    assert final == 5                              # 1 + 4, exactly once each
    assert sorted(rets.values()) == [1, 2, 3, 4]


if st is not None:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 12), st.integers(0, 2 ** 31 - 1),
           st.integers(2, 5))
    def test_property_pbcomb_crash_anywhere(crash_at, seed, n_active):
        """Randomized crash points/drains: post-recovery state is always
        the initial value plus each announced request applied exactly
        once."""
        nvm = NVM()
        c = PBComb(nvm, n_active, FetchAddObject())
        seqs = [1] * n_active
        for p in range(n_active):
            c.request[p] = RequestRec("FAA", 1, 1, 1)
        nvm.arm_crash(crash_at, random.Random(seed))
        try:
            c._perform_request(0)
        except SimulatedCrash:
            pass
        nvm.disarm_crash()
        c.reset_volatile()
        rets = {p: c.recover(p, "FAA", 1, seqs[p]) for p in range(n_active)}
        final = nvm.read(c._st_base(c._mindex()))
        assert final == n_active
        assert sorted(rets.values()) == list(range(n_active))
else:
    def test_property_pbcomb_crash_anywhere():
        pytest.importorskip("hypothesis")


def test_pbcomb_combiner_crash_then_repeat_crash_in_recovery():
    """Recovery functions must themselves be re-invocable after a crash
    during recovery (paper Section 2)."""
    nvm = NVM()
    c = PBComb(nvm, 2, FetchAddObject())
    c.request[0] = RequestRec("FAA", 1, 1, 1)
    nvm.arm_crash(1, random.Random(7))
    try:
        c._perform_request(0)
    except SimulatedCrash:
        pass
    c.reset_volatile()
    # crash again during the recovery's re-execution
    nvm.arm_crash(2, random.Random(8))
    try:
        c.recover(0, "FAA", 1, 1)
    except SimulatedCrash:
        pass
    nvm.disarm_crash()
    c.reset_volatile()
    ret = c.recover(0, "FAA", 1, 1)
    assert ret == 0
    assert nvm.read(c._st_base(c._mindex())) == 1
