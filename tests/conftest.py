import os
import sys

# Tests run against the real single CPU device — never the 512-device
# dry-run environment (which only repro.launch.dryrun may create).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
