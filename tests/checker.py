"""Durable-linearizability history checker for crash-sweep runs.

The mp worker pool already journals everything a checker needs, per the
paper's system-support contract:

  * every COMPLETED op, in per-thread program order, with its response
    (``WorkerReport.results`` — recorded the moment the op returns, so
    an op acked before a crash is in the journal even when the crash
    lands one op later);
  * every IN-FLIGHT op at a crash (``PoolResult.inflight`` — the
    ``(obj, tid, op, args, seq)`` records recovery replays);
  * the replayed responses (``runtime.recover(inflight=...)``).

``HistoryChecker`` accumulates those into one history per structure —
across any number of pool commands, crashes and recoveries — and checks
it against the structure's sequential specification plus durability:

  exact-once   every acked add appears exactly once among successful
               removals + the final state; every successful removal
               returns something that was actually added (all kinds).
  FIFO         (queue) for each (consumer, producer) pair the removed
               indices are strictly increasing — a FIFO queue can never
               show one consumer producer-P values out of enqueue order
               — the final drain is per-producer increasing, and no
               remaining value precedes a removed one from the same
               producer.
  LIFO         (stack) the final drain (top first) is per-producer
               DECREASING: a stack's residue holds each producer's
               survivors newest-on-top.
  heap-order   (heap) a quiescent post-recovery drain is non-decreasing
               and equals the surviving multiset.

A replayed in-flight op is appended at the TAIL of its thread's
journal: its linearization point lies after every completion the same
thread observed (program order), which is exactly where recovery
replays it.

Pair-workload values carry their producer and per-producer index
(``repro.api.mp.rich_value`` tuples, or ``producer * BASE + index``
ints), so the order checks need no global clock — only per-thread
program order, which the journal preserves.

Serving/checkpoint rows get their own checks (``check_log`` /
``check_ckpt``): last-record equality with recomputable response
content (a torn blob would fail the content equation) and checkpoint
step/payload atomicity + monotone durability.  Fleet histories — where
any worker serves any client — use ``check_fleet_log`` instead of
``check_log``.
"""

from __future__ import annotations

from collections import Counter, defaultdict
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.api.mp import checkpoint_payload, serving_response

PRODUCER_BASE = 1_000_000

ADD_OPS = {"enqueue", "push", "insert"}
REM_OPS = {"dequeue", "pop", "delete_min"}
_ACKS = ("ACK", True)


def producer_index(value: Any) -> Tuple[int, int]:
    """(producer, per-producer index) of a pairs-workload value."""
    if isinstance(value, tuple):
        return value[0], value[1]
    return divmod(value, PRODUCER_BASE)


def _acked(ret: Any) -> bool:
    return any(ret is a or ret == a for a in _ACKS)


def replay_banner(scenario_class: str, seed: int, cell: str,
                  backend: str) -> str:
    """The (scenario class, seed, cell, backend) replay tuple plus the
    one copy-pasteable command that reproduces it — every fuzz-driven
    checker failure carries this, so a red CI run is a local repro."""
    return (f"replay: (class={scenario_class} seed={seed:#018x} "
            f"cell={cell} backend={backend})\n"
            f"rerun:  PYTHONPATH=src python -m repro.fuzz run "
            f"--cls {scenario_class} --seed {seed:#018x} "
            f"--cell {cell} --backend {backend}")


def _fail(header: str, failures: List[str],
          replay: Optional[str]) -> None:
    lines = [f"  - {f}" for f in failures]
    if replay:
        lines += [f"  {ln}" for ln in replay.splitlines()]
    raise AssertionError(header + "\n" + "\n".join(lines))


class HistoryChecker:
    """Accumulates one structure's multi-crash history; ``check`` raises
    AssertionError listing every violated invariant.

    ``replay``: optional replay banner (``replay_banner``) appended to
    every failure message — the fuzz harness threads its (class, seed,
    cell, backend) tuple through here so a red run prints its own repro
    command.

    Partial-failure verdicts: a history where some effects are
    legitimately UNKNOWN — a killed worker whose journal never arrived,
    or an in-flight op on a non-detectable protocol whose pre-crash
    effect may have landed before the at-least-once replay — is checked
    against a relaxed exact-once: ``note_lost`` / ``note_at_least_once``
    register the allowance (each registered add may appear at most once
    beyond its acked count; each registered remove may have consumed at
    most one acked add without an ack).  Anything beyond the registered
    allowance still fails."""

    def __init__(self, kind: str, replay: Optional[str] = None) -> None:
        self.kind = kind
        self.replay = replay
        self.events: Dict[int, List[Tuple[str, Any, Any]]] = \
            defaultdict(list)
        #: values whose addition is UNKNOWN (may appear 0 or 1 extra
        #: time each) — killed-worker adds, at-least-once replayed adds
        self.maybe_added: Counter = Counter()
        #: number of removals whose ack is UNKNOWN — each may have
        #: consumed one acked add without appearing in the journal
        self.lost_removes = 0

    # ------------- journal construction -------------------------------- #
    def extend(self, tid: int, results) -> None:
        if results:
            self.events[tid].extend(results)

    def extend_pool(self, pool_result) -> None:
        for rep in pool_result.reports:
            self.extend(rep.tid, rep.results)

    def apply_replay(self, inflight, replies: Dict[Tuple[str, int], Any]
                     ) -> None:
        """Append each replayed in-flight op to its thread's journal."""
        for name, tid, op, args, _seq in inflight:
            key = (name, tid)
            if key in replies:
                self.extend(tid, [(op, args, replies[key])])

    # ------------- partial-failure allowances --------------------------- #
    def note_lost(self, records: Iterable[Tuple[str, Any, Any]]) -> None:
        """Register ``(op, arg, ret)`` records whose outcome is LOST —
        e.g. a killed worker's journal (acked to clients that died with
        it) and its in-flight ops.  Use journal triples; for raw
        in-flight records pass ``(op, args, None)``."""
        for op, arg, _ret in records:
            if op in ADD_OPS:
                self.maybe_added[self._add_value(arg)] += 1
            elif op in REM_OPS:
                self.lost_removes += 1

    def note_at_least_once(self, inflight) -> None:
        """Register replayed in-flight ``(obj, tid, op, args, seq)``
        records of a NON-detectable protocol (durable-ms, the lock
        baselines): recovery RE-EXECUTES them, so a pre-crash effect
        that already landed shows up once more than the journal acked
        — the documented at-least-once allowance."""
        for _name, _tid, op, args, _seq in inflight:
            if op in ADD_OPS:
                self.maybe_added[self._add_value(args)] += 1
            elif op in REM_OPS:
                self.lost_removes += 1

    @staticmethod
    def _add_value(arg: Any) -> Any:
        """The stored value of an add op's args: pair workloads invoke
        ``enqueue(value)`` where value may itself be a rich tuple — the
        journal's arg IS the value (mp workers journal it that way)."""
        return arg

    # ------------- derived multisets ----------------------------------- #
    def added(self) -> Counter:
        return Counter(arg for evs in self.events.values()
                       for op, arg, ret in evs
                       if op in ADD_OPS and _acked(ret))

    def removed(self) -> Counter:
        return Counter(ret for evs in self.events.values()
                       for op, _arg, ret in evs
                       if op in REM_OPS and ret is not None)

    # ------------- checks ----------------------------------------------- #
    def check(self, final_state: Iterable[Any]) -> None:
        """``final_state``: queue snapshot (head first), stack snapshot
        (top first), or a heap's quiescent drain (delete_min until
        empty)."""
        final = list(final_state)
        failures = []
        added, removed = self.added(), self.removed()
        remaining = Counter(final)

        if added != removed + remaining:
            lost = added - (removed + remaining)
            conjured = (removed + remaining) - added
            # partial-failure allowances: each registered maybe-add
            # excuses ONE surplus appearance of that value; each
            # registered lost remove excuses ONE missing value
            excess = conjured - self.maybe_added
            n_lost = sum(lost.values())
            if excess:
                failures.append(
                    f"exact-once violated: duplicated-or-conjured="
                    f"{dict(excess)} (beyond the "
                    f"{sum(self.maybe_added.values())} registered "
                    "partial-failure adds)")
            if n_lost > self.lost_removes:
                failures.append(
                    f"exact-once violated: lost={dict(lost)} "
                    f"({n_lost} values for {self.lost_removes} "
                    "registered lost removes)")

        if self.kind == "queue":
            failures += self._check_fifo(final, removed)
        elif self.kind == "stack":
            failures += self._check_lifo(final)
        elif self.kind == "heap":
            failures += self._check_heap(final)

        if failures:
            _fail(f"{self.kind} history violates durable "
                  "linearizability:", failures, self.replay)

    def _by_producer(self, values) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = defaultdict(list)
        for v in values:
            prod, idx = producer_index(v)
            out[prod].append(idx)
        return out

    def _check_fifo(self, final, removed) -> List[str]:
        failures = []
        # per (consumer, producer): removed indices strictly increasing.
        # A registered maybe-add (at-least-once duplicate) excuses one
        # re-sighting of that value — a duplicated enqueue legitimately
        # hands the same (producer, index) to a consumer twice.
        excuse = Counter(self.maybe_added)
        for tid, evs in self.events.items():
            seen: Dict[int, int] = {}
            for op, _arg, ret in evs:
                if op not in REM_OPS or ret is None:
                    continue
                prod, idx = producer_index(ret)
                if idx <= seen.get(prod, -1):
                    if excuse[ret] > 0:
                        excuse[ret] -= 1
                    else:
                        failures.append(
                            f"consumer {tid} saw producer {prod} index "
                            f"{idx} after index {seen[prod]} "
                            "(FIFO inversion)")
                seen[prod] = max(seen.get(prod, -1), idx)
        # order scope: values with a registered partial-failure
        # allowance have UNKNOWN multiplicity and may legitimately sit
        # at either of two positions — exclude them from the positional
        # checks (exact-once above still bounds their counts)
        scoped_final = self._order_scope(final)
        scoped_removed = self._order_scope(removed.elements())
        # final drain per producer increasing
        for prod, idxs in self._by_producer(scoped_final).items():
            if idxs != sorted(idxs):
                failures.append(
                    f"remaining values of producer {prod} out of FIFO "
                    f"order: {idxs}")
        # nothing remaining may precede a removed value (same producer)
        max_removed = {p: max(i) for p, i in
                       self._by_producer(scoped_removed).items()}
        for prod, idxs in self._by_producer(scoped_final).items():
            if prod in max_removed and min(idxs) < max_removed[prod]:
                failures.append(
                    f"producer {prod}: index {min(idxs)} still queued "
                    f"although index {max_removed[prod]} was dequeued")
        return failures

    def _order_scope(self, values) -> List[Any]:
        if not self.maybe_added:
            return list(values)
        return [v for v in values if v not in self.maybe_added]

    def _check_lifo(self, final) -> List[str]:
        failures = []
        for prod, idxs in self._by_producer(
                self._order_scope(final)).items():
            if idxs != sorted(idxs, reverse=True):
                failures.append(
                    f"stack residue of producer {prod} not "
                    f"newest-on-top: {idxs}")
        return failures

    def _check_heap(self, final) -> List[str]:
        if final != sorted(final):
            return [f"heap drain not non-decreasing: {final[:10]}..."]
        return []


# --------------------------------------------------------------------- #
# serving / checkpoint histories                                        #
# --------------------------------------------------------------------- #
def check_log(checker_events: Dict[int, List[Tuple[str, Any, Any]]],
              snapshot: List[Tuple[int, Any]], gen_len: int,
              replay: Optional[str] = None) -> None:
    """Durable response log history check.

    Per client: acked seqs strictly increase (program order), the final
    logged (seq, response) equals the client's LAST acked-or-replayed
    record, and the response content equals the deterministic toy
    generation for that seq — a torn blob publication (new seq with old
    or partial response bytes) fails the content equation.  The
    seq/response pair itself cannot tear: both words share one cache
    line and the object writes response before seq."""
    failures = []
    last: Dict[int, int] = {}
    for tid, evs in checker_events.items():
        prev = 0
        for op, arg, _ret in evs:
            if op != "record":
                continue
            client, seq = arg[0], arg[1]
            if client != tid:
                failures.append(f"worker {tid} recorded for {client}")
            if seq <= prev:
                failures.append(
                    f"client {tid} acked seq {seq} after {prev}")
            prev = seq
        if prev:
            last[tid] = prev
    for client, want_seq in last.items():
        got_seq, got_resp = snapshot[client]
        if got_seq != want_seq:
            failures.append(
                f"client {client}: durable seq {got_seq} != last "
                f"acked/replayed {want_seq} (lost or phantom record)")
        elif got_resp != serving_response(client, want_seq, gen_len):
            failures.append(
                f"client {client}: durable response content wrong for "
                f"seq {want_seq} (torn payload?): {got_resp!r}")
    if failures:
        _fail("serving log history violates durable linearizability:",
              failures, replay)


def check_fleet_log(checker_events: Dict[int, List[Tuple[str, Any, Any]]],
                    snapshot: List[Tuple[int, Any]],
                    gen_len: int, replay: Optional[str] = None) -> None:
    """Durable response log check for FLEET histories.

    Weaker than ``check_log`` by design: in the fleet any worker may
    serve any client (requests are dequeued from the shard ingress), so
    neither client==tid nor per-journal seq monotonicity holds, and the
    log's last-writer-wins RECORD means the durable seq is not
    necessarily the client's maximum acked seq when two workers raced.
    What MUST hold per client:

      * every acked/replayed record's response equals the deterministic
        toy generation for its (client, seq) — content equation over
        the whole history;
      * the durable (seq, response) pair is either the initial (0,
        None) or some acked-or-replayed record — a pair nobody wrote is
        a phantom (and a torn publication fails the content equation,
        since response is written before seq on one cache line).

    ``__batch__`` journal entries (replayed ``invoke_many`` RECORD_MANY
    batches — the openloop completion path) are expanded into their
    individual records."""
    failures = []
    acked: Dict[int, set] = defaultdict(set)

    def one(arg, ret):
        client, seq = arg[0], arg[1]
        want = serving_response(client, seq, gen_len)
        if ret != want:
            failures.append(
                f"client {client} seq {seq}: acked response content "
                f"wrong (torn payload?): {ret!r}")
        acked[client].add(seq)

    for _tid, evs in checker_events.items():
        for op, arg, ret in evs:
            if op == "record":
                one(arg, ret)
            elif op == "__batch__":
                for (bop, barg, _seq), bret in zip(arg, ret):
                    if bop == "record":
                        one(barg, bret)
    for client, (got_seq, got_resp) in enumerate(snapshot):
        if got_seq == 0:
            if got_resp is not None:
                failures.append(
                    f"client {client}: durable response without a seq")
            continue
        if got_seq not in acked[client]:
            failures.append(
                f"client {client}: durable seq {got_seq} was never "
                f"acked or replayed (phantom record)")
        elif got_resp != serving_response(client, got_seq, gen_len):
            failures.append(
                f"client {client}: durable response content wrong for "
                f"seq {got_seq} (torn payload?): {got_resp!r}")
    if failures:
        _fail("fleet log history violates durable linearizability:",
              failures, replay)


def check_ckpt(checker_events: Dict[int, List[Tuple[str, Any, Any]]],
               snapshot: Dict[str, Any], payload_words: int,
               replay: Optional[str] = None) -> None:
    """Checkpoint cell history check: the durable (step, payload) pair
    is atomic (payload carries its own step — a torn pair fails the
    equation), the payload content matches its writer's deterministic
    shard, and the durable step covers every acked persist (response r
    means state >= r was durable at the ack)."""
    failures = []
    step, payload = snapshot["step"], snapshot["payload"]
    max_acked = 0
    for _tid, evs in checker_events.items():
        for op, _arg, ret in evs:
            if op == "persist" and isinstance(ret, int):
                max_acked = max(max_acked, ret)
    if step:
        if not isinstance(payload, dict) or payload.get("step") != step:
            failures.append(
                f"durable payload/step torn: step={step} "
                f"payload={payload!r}")
        else:
            want = checkpoint_payload(payload["writer"], step,
                                      payload_words)
            if payload.get("shard") != want["shard"]:
                failures.append(
                    f"durable shard content wrong for step {step} "
                    f"writer {payload['writer']}")
    if step < max_acked:
        failures.append(
            f"durable step {step} < max acked persist {max_acked} "
            "(acked checkpoint lost)")
    if failures:
        _fail("checkpoint history violates durable linearizability:",
              failures, replay)
