"""Shared-memory atomics layer (core/shm.py): value codec, lock-striped
CAS emulation under real cross-process contention, and the ShmNVM's
crash/replay equivalence with the in-thread backend.

These tests fork real processes (the whole point of the layer); sizes
are kept small so the suite stays fast on 2-core CI runners.
"""

import multiprocessing
import random
import threading

import pytest

from repro.api import CombiningRuntime
from repro.core import NVM, SimulatedCrash
from repro.core.shm import (ShmAtomicInt, ShmAtomicRef, ShmBackend,
                            ShmMutex, ShmNVM, decode, encode)

CTX = multiprocessing.get_context("fork")


@pytest.fixture
def be():
    b = ShmBackend(data_words=1 << 12, aux_i64=1 << 12, ring_i64=1 << 14)
    yield b
    b.close()


# --------------------------------------------------------------------- #
# value codec                                                           #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("value", [
    0, 1, -1, 2**62, -(2**62), None, True, False, 1.5, -0.0, 3.14159,
    "", "ACK", "ENQ", "HDELETEMIN", "sixteen-bytes-xy"])
def test_codec_round_trip(value):
    out = decode(*encode(value))
    assert out == value and type(out) is type(value)


def test_codec_rejects_out_of_domain():
    with pytest.raises(TypeError):
        encode((1, 2))                    # tuples don't fit a word
    with pytest.raises(TypeError):
        encode("seventeen bytes!!")       # > 16 utf-8 bytes
    with pytest.raises(TypeError):
        encode(2**64)                     # > int64


def test_shm_nvm_word_domain(be):
    nvm = ShmNVM(1 << 12, backend=be)
    addr = nvm.alloc(8)
    values = [7, None, "ACK", True, 2.5, -3]
    nvm.write_range(addr, values)
    assert nvm.read_range(addr, len(values)) == values
    nvm.pwb(addr, len(values))
    nvm.psync()
    assert [nvm.durable_read(addr + i) for i in range(len(values))] \
        == values


# --------------------------------------------------------------------- #
# cross-process CAS contention                                          #
# --------------------------------------------------------------------- #
def _cas_worker(a, n, done_q):
    ok = 0
    for _ in range(n):
        while True:                       # CAS-increment retry loop
            v = a.load()
            if a.cas(v, v + 1):
                ok += 1
                break
    done_q.put(ok)


def test_atomic_int_cas_contention_across_processes(be):
    n_procs, n_incr = 4, 400
    a = ShmAtomicInt(be, 0)
    q = CTX.SimpleQueue()
    procs = [CTX.Process(target=_cas_worker, args=(a, n_incr, q))
             for _ in range(n_procs)]
    for p in procs:
        p.start()
    total = sum(q.get() for _ in procs)
    for p in procs:
        p.join()
    # every CAS-increment that reported success is visible exactly once
    assert total == n_procs * n_incr
    assert a.load() == n_procs * n_incr


def _faa_worker(a, n):
    for _ in range(n):
        a.fetch_add(1)


def test_atomic_int_fetch_add_across_processes(be):
    a = ShmAtomicInt(be, 0)
    procs = [CTX.Process(target=_faa_worker, args=(a, 500))
             for _ in range(4)]
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    assert a.load() == 2000


def _sc_worker(ref, n, done_q):
    wins = 0
    for _ in range(n):
        val, ver = ref.ll()
        if ref.sc(ver, val + 1):
            wins += 1
    done_q.put(wins)


def test_atomic_ref_sc_versioning_across_processes(be):
    nvm = ShmNVM(1 << 12, backend=be)
    mirror_addr = nvm.alloc(1)
    ref = ShmAtomicRef(be, 0, mirror=(nvm, mirror_addr))
    q = CTX.SimpleQueue()
    procs = [CTX.Process(target=_sc_worker, args=(ref, 300, q))
             for _ in range(4)]
    for p in procs:
        p.start()
    wins = sum(q.get() for _ in procs)
    for p in procs:
        p.join()
    # SC semantics: value advanced exactly once per successful SC, and
    # the NVM mirror (written inside the SC) matches the final value —
    # the lost-link-class guarantee the DurableMSQueue fix relies on
    assert ref.load() == wins
    assert nvm.read(mirror_addr) == wins


def _mutex_worker(m, cell, n):
    for _ in range(n):
        with m:
            cell.value = cell.value + 1   # non-atomic read-modify-write


def test_mutex_excludes_across_processes(be):
    m = ShmMutex(be._ctx)
    cell = be.cell(0)
    procs = [CTX.Process(target=_mutex_worker, args=(m, cell, 300))
             for _ in range(4)]
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    assert cell.value == 1200             # no lost updates under the lock


def test_mutex_reset_releases_dead_holder(be):
    m = ShmMutex(be._ctx)
    assert m.acquire(False)
    # holder "died" without releasing; reset forces one free permit
    m.reset()
    assert m.acquire(False)
    m.release()
    m.reset()                             # reset of a free mutex: still one
    assert m.acquire(False)
    assert not m.acquire(False)
    m.release()


# --------------------------------------------------------------------- #
# replay equivalence vs the in-thread backend                           #
# --------------------------------------------------------------------- #
def _scripted_run(backend, crash_after, protocol, segments=1):
    """Deterministic single-process op script with an armed crash;
    returns (trace, replayed responses, post-recovery snapshot)."""
    rt = CombiningRuntime(n_threads=2, backend=backend, nvm_words=1 << 16,
                          segments=segments if backend == "shm" else 1)
    try:
        obj = rt.make("queue", protocol)
        bound = [rt.attach(p).bind(obj) for p in range(2)]
        rt.nvm.arm_crash(crash_after)
        trace = []
        try:
            for i in range(12):
                trace.append(("enq", bound[i % 2].enqueue(i)))
                if i % 3 == 2:
                    trace.append(("deq", bound[(i + 1) % 2].dequeue()))
        except SimulatedCrash:
            trace.append("CRASH")
        replay = rt.recover()
        return trace, sorted(replay.items()), obj.snapshot()
    finally:
        rt.close()


@pytest.mark.parametrize("protocol",
                         ["pbcomb", "pwfcomb", "lock-undo", "durable-ms"])
@pytest.mark.parametrize("crash_after", [3, 7, 11, 16, 25, 999])
def test_replay_equivalence_threads_vs_shm(protocol, crash_after):
    """The shm NVM must be indistinguishable from the in-thread one for
    a deterministic schedule: same responses, same crash point, same
    replayed recovery responses, same post-recovery state."""
    assert _scripted_run("threads", crash_after, protocol) \
        == _scripted_run("shm", crash_after, protocol)


@pytest.mark.parametrize("crash_after", [5, 11, 999])
def test_replay_equivalence_multisegment(crash_after):
    """A 2-segment shm NVM is indistinguishable from the single-DIMM
    thread NVM for a deterministic schedule: the segment striping moves
    write-backs onto per-segment rings/devices without changing any
    observable response, crash point, or machine-wide counter."""
    assert _scripted_run("threads", crash_after, "pbcomb") \
        == _scripted_run("shm", crash_after, "pbcomb", segments=2)


def test_counters_match_threads_vs_shm():
    """pwb/pfence/psync arithmetic is identical across backends (the
    shm discrete path mirrors the fused sentences' counter math)."""
    def counters(backend):
        rt = CombiningRuntime(n_threads=2, backend=backend,
                              nvm_words=1 << 16)
        try:
            obj = rt.make("stack", "pbcomb")
            b = rt.attach(0).bind(obj)
            for i in range(10):
                b.push(i)
            for _ in range(5):
                b.pop()
            c = rt.nvm.counters
            return {k: c[k] for k in ("pwb", "pfence", "psync")}
        finally:
            rt.close()

    assert counters("threads") == counters("shm")


def test_adversarial_crash_drain_shm():
    """crash(rng) on the shm ring: epoch-prefix drains land in the
    durable image; recovery from every cut is a consistent queue."""
    for seed in range(6):
        rt = CombiningRuntime(n_threads=2, backend="shm",
                              nvm_words=1 << 16)
        try:
            obj = rt.make("queue", "pbcomb")
            b = rt.attach(0).bind(obj)
            for i in range(8):
                b.enqueue(i)
            rt.crash(random.Random(seed))
            rt.recover()
            snap = obj.snapshot()
            # every completed enqueue was durable pre-crash: psync
            # before respond — the adversary cannot lose them
            assert snap == list(range(8))
        finally:
            rt.close()


def test_ring_spill_is_legal_early_completion():
    """Overflowing the write-back ring drains early instead of dying;
    psync/crash semantics stay correct."""
    be = ShmBackend(data_words=1 << 12, aux_i64=1 << 12,
                    ring_i64=256)           # tiny ring: a few entries
    try:
        nvm = ShmNVM(1 << 12, backend=be)
        addr = nvm.alloc(64)
        for i in range(64):
            nvm.write(addr + i, i)
            nvm.pwb(addr + i, 1)
        assert nvm.counters["ring_spills"] > 0
        nvm.psync()
        assert [nvm.durable_read(addr + i) for i in range(64)] \
            == list(range(64))
    finally:
        be.close()


def test_ring_spill_with_blob_payloads():
    """Spill-drained entries carry blob PINS, not byte copies: the
    early completion must still land the exact pinned payloads in the
    durable image."""
    be = ShmBackend(data_words=1 << 12, aux_i64=1 << 12, ring_i64=256)
    try:
        nvm = ShmNVM(1 << 12, backend=be)
        addr = nvm.alloc(32)
        vals = [("blob", i, "p" * 30) for i in range(32)]
        for i, v in enumerate(vals):
            nvm.write(addr + i, v)
            nvm.pwb(addr + i, 1)
        assert nvm.counters["ring_spills"] > 0
        nvm.psync()
        assert [nvm.durable_read(addr + i) for i in range(32)] == vals
    finally:
        be.close()


def test_segment_counters_and_placement():
    """Per-segment accounting: each structure's psyncs engage only its
    own device; machine counters stay the totals."""
    rt = CombiningRuntime(n_threads=2, backend="shm", segments=2)
    try:
        q0 = rt.make("queue", "pbcomb")     # placed on segment 0
        q1 = rt.make("queue", "pwfcomb")    # placed on segment 1
        assert rt.segment_stats()["placement"] == \
            {"queue/pbcomb": 0, "queue/pwfcomb": 1}
        b = rt.attach(0)
        b.invoke(q0, "enqueue", 1)
        segs = rt.nvm.segment_counters()
        assert segs[0]["psync"] > 0 and segs[1]["psync"] == 0
        b.invoke(q1, "enqueue", 2)
        segs = rt.nvm.segment_counters()
        assert segs[1]["psync"] > 0
        assert rt.nvm.counters["psync"] == sum(s["psync"] for s in segs)
    finally:
        rt.close()


def test_shm_rejects_profile():
    with pytest.raises(ValueError):
        CombiningRuntime(backend="shm", profile="optane")


def test_thread_backend_unchanged_by_seam():
    """The seam returns plain threading primitives for thread NVMs —
    the gated modeled trajectory runs on exactly the seed's objects."""
    nvm = NVM(1 << 12)
    assert type(nvm.backend.mutex()) is type(threading.Lock())
