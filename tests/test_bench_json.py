"""Bench pipeline smoke test: the machine-readable JSON emitter.

Runs the full benchmark suite at tiny (--quick) sizes and validates the
``bench.v2`` contract every future PR's trajectory (and the CI perf
gate) depends on:

  * every row parses with the documented keys and sane values — the
    wall-clock v1 columns plus the virtual-clock ``modeled_*`` columns
    (null only for rows without a deterministic replay);
  * combining-protocol rows (pbcomb/pwfcomb) spend at most ~one psync
    per operation — a combining ROUND issues one coalesced persist +
    one psync however many requests it serves (they drop below 1
    exactly when combining happens);
  * the fully modeled Figure 8 reproduces the paper's relative ordering
    at Optane latencies: PBComb < DFC < durable-MS.
"""

import json
import subprocess
import sys

import pytest

EPS = 0.05

V1_KEYS = {"name", "us_per_op", "pwbs_per_op", "psyncs_per_op"}
V2_KEYS = V1_KEYS | {"modeled_us_per_op", "modeled_pwbs_per_op",
                     "modeled_psyncs_per_op", "profile",
                     "degree_mean", "degree_max", "vector_apply",
                     "ring_spills", "redundant_pwbs_per_op"}


@pytest.fixture(scope="module")
def bench_doc(tmp_path_factory):
    out = tmp_path_factory.mktemp("bench") / "BENCH_smoke.json"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--quick",
         "--json", str(out)],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    return json.loads(out.read_text())


def test_schema(bench_doc):
    assert bench_doc["schema"] == "bench.v2"
    assert bench_doc["quick"] is True
    assert bench_doc["profile"] == "optane"
    rows = bench_doc["rows"]
    assert rows, "bench emitted no rows"
    names = set()
    for r in rows:
        assert set(r) == V2_KEYS, r
        assert isinstance(r["name"], str) and "/" in r["name"]
        assert r["name"] not in names, f"duplicate row {r['name']}"
        names.add(r["name"])
        assert r["us_per_op"] >= 0
        assert r["pwbs_per_op"] >= 0
        assert r["psyncs_per_op"] >= 0
        # modeled columns: all present or all null, consistently
        modeled = [r["modeled_us_per_op"], r["modeled_pwbs_per_op"],
                   r["modeled_psyncs_per_op"], r["profile"]]
        if r["profile"] is None:
            assert modeled == [None] * 4, r
        else:
            assert r["profile"] == bench_doc["profile"]
            assert all(isinstance(v, (int, float)) and v >= 0
                       for v in modeled[:3]), r
        # measured-degree columns: both set (combining rows of the
        # matrix bench) or both null; never negative
        if r["degree_mean"] is None:
            assert r["degree_max"] is None, r
        else:
            assert r["degree_mean"] >= 0 and r["degree_max"] >= 0, r
        # minimality metric comes only from --audit runs; this
        # fixture's run (the gated shape) must leave it null
        assert r["redundant_pwbs_per_op"] is None, r


def test_covers_figures_and_framework(bench_doc):
    tables = {r["name"].split("/", 1)[0] for r in bench_doc["rows"]}
    assert {"fig1_atomicfloat", "fig3_no_psync", "fig4_queues",
            "fig6_queues_no_pwb", "fig7a_stacks", "fig7b_heap",
            "fig8_modeled", "matrix", "checkpoint", "serving"} <= tables


def test_most_rows_carry_modeled_columns(bench_doc):
    """Every figure/matrix row has a deterministic modeled replay; only
    the framework rows without one (checkpoint/serving) carry nulls."""
    for r in bench_doc["rows"]:
        table = r["name"].split("/", 1)[0]
        if table.startswith("fig") or table == "matrix":
            assert r["profile"] is not None, r


def test_matrix_degree_columns(bench_doc):
    """Combining matrix rows carry the measured degree (GIL-pinned
    near 1 for these threaded runs — mp_bench is where it grows);
    per-op-persist baselines carry nulls (nothing combines)."""
    for r in bench_doc["rows"]:
        if not r["name"].startswith("matrix/"):
            continue
        proto = r["name"].rsplit("/", 1)[1]
        if proto in ("pbcomb", "pwfcomb"):
            assert r["degree_mean"] is not None, r
            assert r["degree_mean"] >= 0.9, r
            assert r["degree_max"] >= 1, r
        elif proto in ("lock-direct", "lock-undo", "durable-ms"):
            assert r["degree_mean"] is None, r


def test_vector_rounds_rows(bench_doc):
    """VectorApply seam rows: paired vector/per-op cells per (kind,
    degree), wall-only (the round body is pure volatile compute — the
    persistence columns are exactly zero and nothing is gated)."""
    for r in bench_doc["rows"]:
        if not r["name"].startswith("vector_rounds/"):
            assert r["vector_apply"] is None, r
    rows = [r for r in bench_doc["rows"]
            if r["name"].startswith("vector_rounds/")]
    if not rows:
        pytest.skip("jax unavailable: vector_rounds emitted no rows")
    names = {r["name"] for r in rows}
    for r in rows:
        _table, kind, d, side = r["name"].split("/")
        assert side in ("vector", "per-op")
        assert r["vector_apply"] is (side == "vector")
        other = "per-op" if side == "vector" else "vector"
        assert f"vector_rounds/{kind}/{d}/{other}" in names
        assert r["us_per_op"] > 0
        assert r["pwbs_per_op"] == 0.0
        assert r["psyncs_per_op"] == 0.0
        assert r["profile"] is None          # wall-only: never gated


def test_combining_rows_one_psync_per_round(bench_doc):
    """The paper's core claim, pinned as a machine check: a combining
    round costs one psync regardless of how many ops it serves."""
    comb = [r for r in bench_doc["rows"]
            if r["name"].startswith("matrix/")
            and ("pbcomb" in r["name"] or "pwfcomb" in r["name"])]
    assert len(comb) >= 4          # queue+stack x pbcomb+pwfcomb
    for r in comb:
        assert r["psyncs_per_op"] <= 1 + EPS, r
        # the modeled pass stages rounds of degree 4: exactly one psync
        # per round -> 0.25/op on the pb side; pwf dequeues may add a
        # helping psync, still O(1) per round
        assert r["modeled_psyncs_per_op"] <= 1 + EPS, r
    # PB*/PWF* figure rows ride the same protocols — same bound, with
    # one protocol-inherent exception: PWFQueue's dequeue side HELPS
    # persist the enqueue publication (pwb(S_E)+psync) before adopting
    # its tail as the durable frontier, so under a psync cost model a
    # dequeue can carry a second (helping) psync.  Still O(1) per
    # round; bound it at 2 instead of 1.
    for r in bench_doc["rows"]:
        name = r["name"]
        if name.startswith(("fig4_queues/PB", "fig4_queues/PWF",
                            "fig7a_stacks/PB", "fig7a_stacks/PWF",
                            "fig7b_heap/", "fig1_atomicfloat/PB")):
            bound = 2 if name.startswith("fig4_queues/PWFQueue") else 1
            assert r["psyncs_per_op"] <= bound + EPS, r


MP_ROW_KEYS = V2_KEYS | {"workers", "rounds", "segments",
                         "seg_psyncs_per_op"}


def _mp_row(name, workers=4, degree=3.0, psync=0.3, segs=(0.3, 0.0)):
    return {"name": name, "workers": workers, "us_per_op": 10.0,
            "pwbs_per_op": 2.0, "psyncs_per_op": psync, "rounds": 10,
            "degree_mean": degree, "degree_max": 4,
            "segments": len(segs), "seg_psyncs_per_op": list(segs),
            "ring_spills": 0, "modeled_us_per_op": None,
            "modeled_pwbs_per_op": None, "modeled_psyncs_per_op": None,
            "profile": None}


def test_mp_serving_checkpoint_cells_emit_v2_rows():
    """One tiny serving + checkpoint + mixed cell end-to-end: the
    bench.mp.v2 row contract (per-segment psync columns, ring_spills,
    nullable modeled columns) and measured combining degree > 1 on the
    serving path."""
    from benchmarks.mp_bench import (bench_checkpoint_cell,
                                     bench_mixed_cell,
                                     bench_serving_cell)
    rows = [bench_serving_cell("pbcomb", 2, 12, gen_len=4),
            bench_checkpoint_cell("pbcomb", 2, 10, payload_words=8),
            bench_mixed_cell(2, 8, 6)]
    for r in rows:
        # modeled columns + the audit metric are filled in (nullable)
        # at the main() level, not by the cell functions
        assert set(r) | {"modeled_us_per_op", "modeled_pwbs_per_op",
                         "modeled_psyncs_per_op", "profile",
                         "vector_apply", "redundant_pwbs_per_op"} \
            >= MP_ROW_KEYS - {"profile"}
        assert r["workers"] == 2
        assert r["segments"] == 2
        assert len(r["seg_psyncs_per_op"]) == 2
        assert r["ring_spills"] >= 0
        assert r["psyncs_per_op"] < 1.0          # combining amortizes
        assert (r["degree_mean"] or 0) > 1.0
    # the mixed cell engages BOTH modeled devices
    assert all(v > 0 for v in rows[2]["seg_psyncs_per_op"]), rows[2]


def test_mp_check_rows_gate():
    """The mp-smoke gate logic: passes on healthy rows, fires on low
    degree and on psync/op at-or-above the per-op-persist floor."""
    from benchmarks.mp_bench import check_rows
    healthy = [_mp_row("queue/pbcomb"), _mp_row("queue/lock-direct",
                                                degree=None, psync=1.0),
               _mp_row("stack/pbcomb"), _mp_row("heap/pbcomb"),
               _mp_row("serving/pbcomb"),
               _mp_row("serving/lock-direct", degree=None, psync=1.0),
               _mp_row("checkpoint/pbcomb"), _mp_row("mixed/pbcomb")]
    for r in healthy:
        if r["degree_mean"] is None:
            r["rounds"] = r["degree_max"] = None
    assert check_rows(healthy, workers=4) == []
    # low degree on the serving row
    bad = [dict(r) for r in healthy]
    bad[4] = dict(bad[4], degree_mean=1.2)
    assert any("serving/pbcomb" in f and "degree_mean" in f
               for f in check_rows(bad, workers=4))
    # psync/op at the measured floor
    bad = [dict(r) for r in healthy]
    bad[0] = dict(bad[0], psyncs_per_op=1.0)
    assert any("queue/pbcomb" in f and "floor" in f
               for f in check_rows(bad, workers=4))
    # checkpoint row gated against the definitional floor when no
    # per-op-persist row is present
    bad = [dict(r) for r in healthy]
    bad[6] = dict(bad[6], psyncs_per_op=1.1)
    assert any("checkpoint/pbcomb" in f
               for f in check_rows(bad, workers=4))
    # a missing gated row is itself a failure
    assert any("no serving/pbcomb row" in f
               for f in check_rows([_mp_row("queue/pbcomb")], workers=4))
    # a combining row reporting redundant persists violates minimality
    bad = [dict(r) for r in healthy]
    bad[0] = dict(bad[0], redundant_pwbs_per_op=0.5)
    assert any("queue/pbcomb" in f and "redundant" in f
               for f in check_rows(bad, workers=4))
    # ... but a per-op-persist baseline reporting some is tolerated
    ok = [dict(r) for r in healthy]
    ok[1] = dict(ok[1], redundant_pwbs_per_op=0.5)
    assert check_rows(ok, workers=4) == []
    # a combining row holding blob chunks past the structure-state
    # ceiling means response refcounts leaked
    from benchmarks.mp_bench import live_chunks_ceiling
    bad = [dict(r) for r in healthy]
    bad[4] = dict(bad[4], live_chunks=live_chunks_ceiling(4) + 1)
    assert any("serving/pbcomb" in f and "live blob chunks" in f
               for f in check_rows(bad, workers=4))
    ok = [dict(r) for r in healthy]
    ok[4] = dict(ok[4], live_chunks=live_chunks_ceiling(4))
    assert check_rows(ok, workers=4) == []


def test_fig8_reproduces_paper_ordering(bench_doc):
    """Modeled us/op at Optane latencies orders the implementations the
    way the paper's Figures 4-7 do: combining wins, DFC pays its
    per-thread announcement/response persists, per-op-persist last."""
    rows = {r["name"].split("/", 1)[1]: r for r in bench_doc["rows"]
            if r["name"].startswith("fig8_modeled/")}
    pb = rows["PBStack"]["modeled_us_per_op"]
    dfc = rows["DFCStack (flat-combining)"]["modeled_us_per_op"]
    ms = rows["DurableMSQueue (FHMP-shape)"]["modeled_us_per_op"]
    pbq = rows["PBQueue"]["modeled_us_per_op"]
    assert pb < dfc < ms
    assert pbq < ms
    # fig8 is fully modeled: wall columns mirror the modeled ones
    for r in rows.values():
        assert r["us_per_op"] == r["modeled_us_per_op"]
