"""Bench pipeline smoke test: the machine-readable JSON emitter.

Runs the full benchmark suite at tiny (--quick) sizes and validates the
``bench.v1`` contract every future PR's trajectory depends on:

  * every row parses with the documented keys and sane values;
  * combining-protocol rows (pbcomb/pwfcomb) spend at most ~one psync
    per operation — a combining ROUND issues one coalesced persist +
    one psync however many requests it serves, so per-op psyncs can
    never exceed 1 + eps (they drop below 1 exactly when combining
    happens).
"""

import json
import subprocess
import sys

import pytest

EPS = 0.05


@pytest.fixture(scope="module")
def bench_doc(tmp_path_factory):
    out = tmp_path_factory.mktemp("bench") / "BENCH_smoke.json"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--quick",
         "--json", str(out)],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    return json.loads(out.read_text())


def test_schema(bench_doc):
    assert bench_doc["schema"] == "bench.v1"
    assert bench_doc["quick"] is True
    rows = bench_doc["rows"]
    assert rows, "bench emitted no rows"
    names = set()
    for r in rows:
        assert set(r) == {"name", "us_per_op", "pwbs_per_op",
                          "psyncs_per_op"}, r
        assert isinstance(r["name"], str) and "/" in r["name"]
        assert r["name"] not in names, f"duplicate row {r['name']}"
        names.add(r["name"])
        assert r["us_per_op"] >= 0
        assert r["pwbs_per_op"] >= 0
        assert r["psyncs_per_op"] >= 0


def test_covers_figures_and_framework(bench_doc):
    tables = {r["name"].split("/", 1)[0] for r in bench_doc["rows"]}
    assert {"fig1_atomicfloat", "fig3_no_psync", "fig4_queues",
            "fig6_queues_no_pwb", "fig7a_stacks", "fig7b_heap",
            "matrix", "checkpoint", "serving"} <= tables


def test_combining_rows_one_psync_per_round(bench_doc):
    """The paper's core claim, pinned as a machine check: a combining
    round costs one psync regardless of how many ops it serves."""
    comb = [r for r in bench_doc["rows"]
            if r["name"].startswith("matrix/")
            and ("pbcomb" in r["name"] or "pwfcomb" in r["name"])]
    assert len(comb) >= 4          # queue+stack x pbcomb+pwfcomb
    for r in comb:
        assert r["psyncs_per_op"] <= 1 + EPS, r
    # PB*/PWF* figure rows ride the same protocols — same bound, with
    # one protocol-inherent exception: PWFQueue's dequeue side HELPS
    # persist the enqueue publication (pwb(S_E)+psync) before adopting
    # its tail as the durable frontier, so under a psync cost model a
    # dequeue can carry a second (helping) psync.  Still O(1) per
    # round; bound it at 2 instead of 1.
    for r in bench_doc["rows"]:
        name = r["name"]
        if name.startswith(("fig4_queues/PB", "fig4_queues/PWF",
                            "fig7a_stacks/PB", "fig7a_stacks/PWF",
                            "fig7b_heap/", "fig1_atomicfloat/PB")):
            bound = 2 if name.startswith("fig4_queues/PWFQueue") else 1
            assert r["psyncs_per_op"] <= bound + EPS, r
