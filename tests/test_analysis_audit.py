"""Persist-ordering detector (repro.analysis.audit, DESIGN.md §10).

Seeded-violation fixtures — tiny hand-written instruction sequences
that each plant exactly one class of persist-ordering bug and assert
the detector flags it AT THE OFFENDING SITE:

  * drop the pwb        -> unflushed-at-commit
  * reorder the psync   -> psync-order-race (Lamport clock proof)
  * read the raced line
    after a crash       -> post-crash-unordered-read
  * flush twice         -> redundant-pwb (the minimality metric)
  * fence an empty
    epoch               -> redundant-pfence

plus the no-false-positive direction: a textbook persist sentence
raises nothing, and the full registry matrix (every structure x every
protocol, both backends, through the same crash/recover schedule the
54-case protocol-matrix test drives) comes back clean against the
checked-in allowlist.
"""

import random
import threading

import pytest

from repro.analysis import load_allowlist
from repro.analysis import sweep as sweep_mod
from repro.analysis.audit import Finding
from repro.analysis.sweep import run_sweep, sweep_cell
from repro.core.nvm import LINE, NVM
from repro.core.shm import ShmNVM

HERE = "test_analysis_audit.py"


def _nvm():
    """Audited thread NVM with the virtual clock engaged (profile) so
    the happens-before checks run."""
    return NVM(1 << 12, profile="optane", audit=True)


def _one(findings, rule):
    hits = [f for f in findings if f.rule == rule]
    assert len(hits) == 1, findings
    return hits[0]


# --------------------------------------------------------------------- #
# seeded violations                                                     #
# --------------------------------------------------------------------- #
def test_dropped_pwb_is_unflushed_at_commit():
    nvm = _nvm()
    a = nvm.alloc(1)
    nvm.write(a, 7)            # <- the offending store (never flushed)
    nvm.psync()                # commit point
    f = _one(nvm.audit.findings, "unflushed-at-commit")
    assert f.gating
    # blamed at the WRITE site in this file, not inside the simulator
    assert f.site.startswith(HERE + ":")
    assert f.site_key == HERE + "::test_dropped_pwb_is_unflushed_at_commit"
    assert f.line == a // LINE
    # ... and it is exactly once even though psync runs again
    nvm.psync()
    assert len(nvm.audit.findings) == 1


def test_other_threads_dirty_lines_not_blamed_at_my_commit():
    """A psync only judges lines the SYNCING thread dirtied: another
    thread's in-flight store is judged at that thread's own commit."""
    nvm = _nvm()
    a, b = nvm.alloc(1), nvm.alloc(1)
    with nvm.clock.bind(1):
        nvm.write(a, 1)        # thread 1 leaves a dirty (no commit yet)
    with nvm.clock.bind(2):
        nvm.write(b, 2)
        nvm.pwb(b)
        nvm.psync()            # thread 2's commit: its own line is clean
    assert nvm.audit.findings == []
    with nvm.clock.bind(1):
        nvm.psync()            # now thread 1 commits -> flagged
    assert _one(nvm.audit.findings, "unflushed-at-commit").line == a // LINE


def test_double_flush_is_redundant_pwb():
    nvm = _nvm()
    a = nvm.alloc(1)
    nvm.write(a, 1)
    nvm.pwb(a)
    nvm.pwb(a)                 # <- same thread re-flushes, nothing new
    nvm.psync()
    nvm.pwb(a)                 # <- and again on the drained line
    aud = nvm.audit
    assert aud.redundant_pwbs == 2
    f = _one(aud.findings, "redundant-pwb")
    assert not f.gating and f.count == 2
    assert f.site.startswith(HERE + ":")
    # non-gating: the sweep would not fail on it
    assert aud.gating_findings() == []
    rep = aud.report()
    assert rep["redundant_pwbs"] == 2 and rep["gating"] == []


def test_helping_reflush_is_not_redundant():
    """Re-flushing a line LAST FLUSHED BY ANOTHER THREAD is the normal
    helping pattern (pwfcomb recovery) and must not count."""
    nvm = _nvm()
    a = nvm.alloc(1)
    with nvm.clock.bind(1):
        nvm.write(a, 1)
        nvm.pwb(a)
        nvm.psync()
    with nvm.clock.bind(2):
        nvm.pwb(a)             # helper covers the same (clean) line
    assert nvm.audit.redundant_pwbs == 0
    assert nvm.audit.findings == []


def test_empty_epoch_pfence_is_redundant():
    nvm = _nvm()
    nvm.pfence()               # <- nothing pwb'd in this epoch
    aud = nvm.audit
    assert aud.redundant_pfences == 1
    f = _one(aud.findings, "redundant-pfence")
    assert not f.gating
    assert f.site.startswith(HERE + ":")


def test_reordered_psync_is_an_order_race_and_taints_recovery():
    """Thread 1 pwbs at a large clock stamp; thread 2 — whose clock
    never caught up, i.e. NO happens-before path reaches the pwb —
    psyncs it to the durable image.  That drain is a race outcome, and
    a post-crash read of the line is flagged as consuming it."""
    nvm = _nvm()
    a = nvm.alloc(1)
    with nvm.clock.bind(1):
        # advance thread 1's clock past zero with a full sentence...
        nvm.write(a, 1)
        nvm.pwb(a)
        nvm.psync()
        assert nvm.clock.now() > 0.0
        # ...then leave a pwb in flight with stamp > 0
        nvm.write(a, 2)
        nvm.pwb(a)
    with nvm.clock.bind(2):
        assert nvm.clock.now() == 0.0
        nvm.psync()            # <- drains thread 1's pwb unordered
    f = _one(nvm.audit.findings, "psync-order-race")
    assert f.gating and f.line == a // LINE
    assert f.site_key == \
        HERE + "::test_reordered_psync_is_an_order_race_and_taints_recovery"

    nvm.crash(random.Random(7))
    nvm.read(a)                # recovery consumes the raced line
    f = _one(nvm.audit.findings, "post-crash-unordered-read")
    assert f.gating and f.line == a // LINE
    assert f.site.startswith(HERE + ":")


def test_ordered_handoff_is_not_a_race():
    """Same shape, but the syncer's clock has seen the pwb stamp
    (merge models the acquire edge): no finding."""
    nvm = _nvm()
    a = nvm.alloc(1)
    with nvm.clock.bind(1):
        nvm.write(a, 1)
        nvm.pwb(a)
        stamp = nvm.clock.now()
    with nvm.clock.bind(2):
        nvm.clock.merge(stamp + 1.0)   # happens-before edge observed
        nvm.psync()
    assert nvm.audit.findings == []


def test_rewrite_clears_the_taint():
    """A raced line that recovery REWRITES before reading is untainted:
    the race outcome was never consumed."""
    nvm = _nvm()
    a = nvm.alloc(1)
    with nvm.clock.bind(1):
        nvm.write(a, 1)
        nvm.pwb(a)
        nvm.psync()
        nvm.write(a, 2)
        nvm.pwb(a)
    with nvm.clock.bind(2):
        nvm.psync()            # race (flagged above-style)
    nvm.crash(random.Random(7))
    nvm.write(a, 0)            # recovery reinitializes the word
    nvm.read(a)
    rules = {f.rule for f in nvm.audit.findings}
    assert "post-crash-unordered-read" not in rules


# --------------------------------------------------------------------- #
# no false positives                                                    #
# --------------------------------------------------------------------- #
def test_textbook_sentence_is_clean():
    nvm = _nvm()
    a = nvm.alloc(2 * LINE)
    for i in range(4):
        nvm.write(a + i, i)
        nvm.pwb(a + i)
    nvm.pfence()
    nvm.write(a + LINE, 99)    # second epoch
    nvm.pwb(a + LINE)
    nvm.psync()
    aud = nvm.audit
    assert aud.findings == []
    assert aud.redundant_pwbs == 0 and aud.redundant_pfences == 0


def test_audit_keeps_counters_identical():
    """audit=True must not move the persistence counters (it pins
    force_discrete, whose equivalence the property tests gate)."""
    def drive(nvm):
        a = nvm.alloc(8)
        for i in range(8):
            nvm.write(a + i, i)
        nvm.pwb(a, 8)
        nvm.pfence()
        nvm.psync()
        return dict(nvm.counters)

    plain = drive(NVM(1 << 12, profile="optane"))
    audited = drive(_nvm())
    assert plain == audited


def test_reset_metrics_drops_metric_not_gating():
    nvm = _nvm()
    a = nvm.alloc(1)
    nvm.write(a, 1)
    nvm.pwb(a)
    nvm.pwb(a)                 # redundant (metric)
    b = nvm.alloc(1)
    nvm.write(b, 2)
    nvm.psync()                # unflushed-at-commit on b (gating)
    nvm.reset_counters()       # benches zero the measured window here
    aud = nvm.audit
    assert aud.redundant_pwbs == 0
    assert {f.rule for f in aud.findings} == {"unflushed-at-commit"}


# --------------------------------------------------------------------- #
# the shm NVM (flush-state classes; no clock, so no order checks)       #
# --------------------------------------------------------------------- #
def test_shm_nvm_flags_flush_state_classes():
    nvm = ShmNVM(1 << 14, audit=True)
    try:
        a, b = nvm.alloc(1), nvm.alloc(1)
        nvm.write(a, 1)
        nvm.psync()            # dropped pwb
        nvm.write(b, 2)
        nvm.pwb(b)
        nvm.pwb(b)             # double flush
        nvm.pfence()
        nvm.psync()
        aud = nvm.audit
        assert _one(aud.findings, "unflushed-at-commit").line == a // LINE
        assert aud.redundant_pwbs == 1
        rules = {f.rule for f in aud.findings}
        assert "psync-order-race" not in rules      # clockless: disabled
    finally:
        nvm.close()


def test_shm_threaded_keys_are_per_thread():
    """Without a clock the audit keys on the OS thread: another
    thread's dirty line is not blamed at this thread's commit."""
    nvm = ShmNVM(1 << 14, audit=True)
    try:
        a = nvm.alloc(1)

        def writer():
            nvm.write(a, 5)    # dirty, never committed by this thread

        t = threading.Thread(target=writer)
        t.start()
        t.join()
        nvm.psync()            # main thread's commit
        assert nvm.audit.findings == []
    finally:
        nvm.close()


# --------------------------------------------------------------------- #
# registry-matrix sweep: zero non-allowlisted findings                  #
# --------------------------------------------------------------------- #
def test_sweep_cell_reports_and_cleans_up():
    cell = sweep_cell("queue", "pbcomb", "threads",
                      rounds=2, post_crash_rounds=1)
    assert cell["error"] is None
    assert cell["ops"] == 3 * sweep_mod.N_THREADS
    assert [f for f in cell["findings"] if f.gating] == []
    assert cell["redundant_pwbs"] == 0          # paper P2, as a number


def test_sweep_cell_surfaces_driver_errors():
    cell = sweep_cell("queue", "no-such-protocol", "threads", rounds=1)
    assert cell["error"] is not None
    assert cell["findings"] == []


def test_full_matrix_sweep_no_false_positives():
    """The tentpole gate, in-process: every registry (kind, protocol)
    cell on BOTH backends through announce/invoke rounds + adversarial
    crash + recovery + snapshot + post-crash rounds — the same schedule
    shape as the 54-case protocol-matrix crash test — raises zero
    non-allowlisted gating findings, and the combining protocols
    report zero redundant persists."""
    allow = load_allowlist()
    res = run_sweep(backends=("threads", "shm"), quick=True, allow=allow)
    assert res["failures"] == 0, [
        (c["kind"], c["protocol"], c["backend"], c["error"], c["gating"])
        for c in res["cells"] if c["error"] or c["gating"]]
    from repro.api import entries
    assert len(res["cells"]) == 2 * len(list(entries()))   # both backends
    for c in res["cells"]:
        if c["protocol"] in ("pbcomb", "pwfcomb"):
            assert c["redundant_pwbs"] == 0, c


# --------------------------------------------------------------------- #
# sweep rendering + CLI plumbing (run_sweep monkeypatched: cheap)       #
# --------------------------------------------------------------------- #
def _fake_result(with_violation: bool):
    f = Finding("unflushed-at-commit", "x.py:3", "x.py::X.op", 4,
                thread=1, detail="seeded", gating=True)
    cell = {"kind": "queue", "protocol": "pbcomb", "backend": "threads",
            "ops": 12, "redundant_pwbs": 0, "redundant_pfences": 0,
            "error": None, "allowed": [],
            "gating": [f] if with_violation else []}
    return {"cells": [cell], "failures": 1 if with_violation else 0}


def test_sweep_summary_and_json_render():
    good = sweep_mod.render_summary(_fake_result(False))
    assert "No non-allowlisted violations." in good
    bad = sweep_mod.render_summary(_fake_result(True))
    assert "unflushed-at-commit" in bad and "`x.py::X.op`" in bad
    doc = sweep_mod._to_json(_fake_result(True))
    assert doc["schema"] == "analysis.sweep.v1"
    assert doc["failures"] == 1
    assert doc["cells"][0]["gating"][0]["site_key"] == "x.py::X.op"


@pytest.mark.parametrize("violation,code", [(False, 0), (True, 1)])
def test_sweep_cli_exit_codes(monkeypatch, tmp_path, capsys,
                              violation, code):
    monkeypatch.setattr(sweep_mod, "run_sweep",
                        lambda **kw: _fake_result(violation))
    out_json = tmp_path / "sweep.json"
    out_md = tmp_path / "summary.md"
    rc = sweep_mod.main(["--quick", "--backend", "threads",
                         "--json", str(out_json),
                         "--summary", str(out_md)])
    assert rc == code
    assert "Persist-ordering sweep" in capsys.readouterr().out
    assert "Matrix" in out_md.read_text()
    import json
    assert json.loads(out_json.read_text())["failures"] == (1 if code else 0)
