"""Crash-schedule fuzzer (repro.fuzz, DESIGN.md §12, docs/FUZZING.md).

Covers the whole subsystem: the kind-aware crash-point injector on the
pwb/pfence/psync tick seam, the multi-segment partial-failure crash
policy, scenario determinism (same class+seed → byte-identical result),
the checked-in corpus replaying green, seed shrinking, the checker's
partial-failure verdicts with replayable failure banners, explicit
crash-during-recover coverage on the threads backend, and the
acceptance bar: the fuzzer REDISCOVERS both seeded historical bugs
(PR 5 torn announcement, PR 4 durable-MS mirror race) within a bounded
seed budget.
"""

import dataclasses
import json
import random

import pytest

from checker import HistoryChecker, replay_banner
from repro.api import CombiningRuntime
from repro.core import NVM, SimulatedCrash
from repro.core.pbcomb import PBComb
from repro.core.shm import ShmNVM
from repro.fuzz import (CrashPointInjector, SCENARIO_CLASSES,
                        dump_entry, load_corpus, replay_corpus,
                        run_scenario, shrink_seed)
from repro.fuzz.bugs import BUG_HUNTS, SEEDED_BUGS, seeded_bug
from repro.fuzz.corpus import default_corpus_path
from repro.structures.baselines import DurableMSQueue


# --------------------------------------------------------------------- #
# crash-point injector seam                                             #
# --------------------------------------------------------------------- #
def test_injector_kind_filtering_and_one_shot():
    inj = CrashPointInjector("psync", 2)
    assert not inj.tick("pwb")          # wrong kind: not counted
    assert not inj.tick("psync")        # 1st psync of 2
    assert not inj.tick("pfence")
    assert inj.tick("psync")            # 2nd psync: fire
    assert inj.fired
    assert not inj.tick("psync")        # one-shot: never fires again


def test_injector_any_kind_counts_everything():
    inj = CrashPointInjector("any", 3)
    assert not inj.tick("pwb")
    assert not inj.tick("pfence")
    assert inj.tick("psync")


def test_injector_rejects_bad_args():
    with pytest.raises(ValueError):
        CrashPointInjector("flush", 1)
    with pytest.raises(ValueError):
        CrashPointInjector("pwb", 0)


def test_nvm_injector_fires_at_nth_kind_and_self_clears():
    nvm = NVM(256)
    a = nvm.alloc(4)
    nvm.arm_injector(CrashPointInjector("pwb", 2))
    nvm.write(a, 1)
    nvm.pwb(a, 1)                       # 1st pwb: survives
    nvm.write(a + 1, 2)
    with pytest.raises(SimulatedCrash):
        nvm.pwb(a + 1, 1)               # 2nd pwb: crash
    assert nvm._injector is None        # self-cleared on fire
    nvm.disarm_crash()
    nvm.write(a + 2, 3)
    nvm.pwb(a + 2, 1)                   # no residual crash point
    nvm.psync()


def test_injector_survives_disarm_crash():
    """disarm_crash clears the countdown but NOT the injector — the
    property that lets a scenario crash inside ``recover`` (whose
    first act is disarm_crash)."""
    nvm = NVM(256)
    a = nvm.alloc(2)
    nvm.arm_injector(CrashPointInjector("pwb", 1))
    nvm.disarm_crash()
    nvm.write(a, 1)
    with pytest.raises(SimulatedCrash):
        nvm.pwb(a, 1)
    nvm.disarm_crash()


def test_injector_disables_fused_fast_path():
    """With an injector armed the fused sentences must fall back to
    discrete instructions, else per-kind ticks are never consulted."""
    nvm = NVM(256)
    assert nvm._fast_ok()
    nvm.arm_injector(CrashPointInjector("psync", 1))
    assert not nvm._fast_ok()
    nvm.disarm_injector()
    assert nvm._fast_ok()


# --------------------------------------------------------------------- #
# multi-segment partial failure (segment loss)                          #
# --------------------------------------------------------------------- #
def test_shm_segment_loss_drops_only_lost_segment():
    """Crash with lose_segment=1: segment 0's pending write-backs all
    drain (survivor DIMMs flush), segment 1's are lost entirely."""
    nvm = ShmNVM(4096, segments=2)
    try:
        with nvm.placement(0):
            a0 = nvm.alloc(1)
        with nvm.placement(1):
            a1 = nvm.alloc(1)
        nvm.write(a0, 11)
        nvm.write(a1, 22)
        nvm.pwb(a0, 1)
        nvm.arm_crash(0, lose_segment=1)
        with pytest.raises(SimulatedCrash):
            nvm.pwb(a1, 1)              # pwb tick: both entries pending
        nvm.disarm_crash()
        assert nvm.read(a0) == 11       # survivor segment drained
        assert nvm.read(a1) == 0        # lost segment dropped (shm
        #                                 words zero-init, never 22)
    finally:
        nvm.close()


def test_shm_lose_segment_validated():
    nvm = ShmNVM(1024, segments=2)
    try:
        with pytest.raises(ValueError):
            nvm.arm_crash(1, lose_segment=2)
    finally:
        nvm.close()
    single = NVM(256)
    with pytest.raises(ValueError):
        single.arm_crash(1, lose_segment=0)


# --------------------------------------------------------------------- #
# scenario determinism + corpus                                         #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("cls", sorted(SCENARIO_CLASSES))
def test_scenario_deterministic(cls):
    a = run_scenario(cls, 7)
    b = run_scenario(cls, 7)
    assert dataclasses.asdict(a) == dataclasses.asdict(b)
    assert a.verdict == "ok", a.detail


@pytest.mark.parametrize("cls", sorted(SCENARIO_CLASSES))
def test_scenario_cell_pin_matches_derived(cls):
    """Pinning the derived cell must not disturb the RNG stream — the
    property corpus replay relies on."""
    a = run_scenario(cls, 11)
    b = run_scenario(cls, 11, cell=a.cell)
    assert dataclasses.asdict(a) == dataclasses.asdict(b)


def test_corpus_replays_green():
    """The checked-in corpus is the PR regression gate: every entry's
    verdict must reproduce exactly."""
    entries = load_corpus()
    assert entries, "tests/fuzz_corpus/corpus.jsonl is missing/empty"
    assert {e["class"] for e in entries} == set(SCENARIO_CLASSES), \
        "corpus must cover every scenario class"
    results, mismatches = replay_corpus()
    assert not mismatches, mismatches


def test_corpus_roundtrip_format():
    for e in load_corpus():
        seed = int(e["seed"], 16)
        res = run_scenario(e["class"], seed, cell=e["cell"])
        line = dump_entry(res)
        assert json.loads(line) == e
    assert default_corpus_path().endswith("corpus.jsonl")


def test_unknown_class_rejected():
    with pytest.raises(ValueError):
        run_scenario("no-such-class", 1)
    with pytest.raises(ValueError):
        run_scenario("schedule", 1, backend="shm")  # wrong backend


# --------------------------------------------------------------------- #
# shrinking                                                             #
# --------------------------------------------------------------------- #
def test_shrink_converges_to_simpler_seed():
    # synthetic oracle: fails iff bit 3 is set — minimal seed is 0x8
    evals = []

    def fails(s):
        evals.append(s)
        return bool(s & 0x8)

    out = shrink_seed(fails, 0xDEAD_BEEF_CAFE_0008, budget=200)
    assert out == 0x8
    assert len(evals) <= 200


def test_shrink_keeps_original_when_nothing_simpler():
    assert shrink_seed(lambda s: s == 0x1, 0x1, budget=32) == 0x1


# --------------------------------------------------------------------- #
# crash-during-recover (threads backend, explicit coverage)             #
# --------------------------------------------------------------------- #
def test_crash_during_recover_threads_explicit():
    """A crash landing INSIDE recover, then a second recover from the
    caller-retained records: exactly-once for a detectable protocol."""
    rt = CombiningRuntime(n_threads=2)
    try:
        obj = rt.make("queue", "pbcomb")
        h = [rt.attach(p) for p in range(2)]
        s0 = h[0].announce(obj, "enqueue", "a")
        s1 = h[1].announce(obj, "enqueue", "b")
        rt.arm_crash(2)
        with pytest.raises(SimulatedCrash):
            h[0].perform(obj)
        records = [(obj.name, 0, "enqueue", "a", s0),
                   (obj.name, 1, "enqueue", "b", s1)]
        rt.nvm.disarm_crash()
        rt.nvm.arm_injector(CrashPointInjector("any", 1))
        with pytest.raises(SimulatedCrash):
            rt.recover(inflight=records)
        rt.nvm.disarm_injector()
        rt.nvm.disarm_crash()
        replies = rt.recover(inflight=records)
        assert replies[(obj.name, 0)] in ("ACK", True)
        assert replies[(obj.name, 1)] in ("ACK", True)
        drained = obj.snapshot()
        assert sorted(drained) == ["a", "b"]    # exactly once each
    finally:
        rt.close()


def test_crash_during_recover_scenarios_exercise_the_path():
    hits = 0
    for seed in range(6):
        r = run_scenario("crash-during-recover", seed)
        assert r.verdict == "ok", r.detail
        hits += r.stats.get("recover_crashes", 0)
    assert hits > 0, "no scenario crashed inside recover in 6 seeds"


# --------------------------------------------------------------------- #
# checker partial-failure verdicts + replay banner                      #
# --------------------------------------------------------------------- #
def test_checker_lost_add_excused_once():
    x, y = (0, 0, "p"), (1, 0, "p")     # (producer, index, pad) values
    chk = HistoryChecker("queue")
    chk.extend(0, [("enqueue", x, "ACK")])
    chk.note_lost([("enqueue", y, "ACK")])      # killed worker's add
    chk.check([x, y])                           # y surfaces once: ok
    chk2 = HistoryChecker("queue")
    chk2.note_lost([("enqueue", y, "ACK")])
    with pytest.raises(AssertionError):
        chk2.check([y, y])                      # twice: beyond allowance


def test_checker_lost_remove_excuses_missing_value():
    chk = HistoryChecker("queue")
    chk.extend(0, [("enqueue", "x", "ACK")])
    chk.note_lost([("dequeue", None, None)])
    chk.check([])                               # x consumed, ack lost
    chk2 = HistoryChecker("queue")
    chk2.extend(0, [("enqueue", "x", "ACK"),
                    ("enqueue", "y", "ACK")])
    chk2.note_lost([("dequeue", None, None)])
    with pytest.raises(AssertionError):
        chk2.check([])                          # two missing, one excuse


def test_checker_failure_prints_replay_tuple():
    banner = replay_banner("schedule", 0xAB, "queue/pbcomb", "threads")
    chk = HistoryChecker("queue", replay=banner)
    chk.extend(0, [("enqueue", "x", "ACK")])
    with pytest.raises(AssertionError) as ei:
        chk.check([])
    msg = str(ei.value)
    assert "replay: (class=schedule seed=0x00000000000000ab "\
           "cell=queue/pbcomb backend=threads)" in msg
    assert "python -m repro.fuzz run --cls schedule "\
           "--seed 0x00000000000000ab" in msg


def test_partition_inflight_splits_by_tid():
    from repro.api.mp import PoolResult, WorkerReport
    res = PoolResult(wall_s=0.0, reports=[
        WorkerReport(tid=0, status="crashed",
                     inflight=[("q", 0, "enqueue", "a", 1)]),
        WorkerReport(tid=1, status="crashed",
                     inflight=[("q", 1, "dequeue", None, 4)]),
    ])
    surv, lost = res.partition_inflight({1})
    assert surv == [("q", 0, "enqueue", "a", 1)]
    assert lost == [("q", 1, "dequeue", None, 4)]


# --------------------------------------------------------------------- #
# seeded-bug rediscovery (the acceptance bar)                           #
# --------------------------------------------------------------------- #
def test_seeded_bug_flags_off_by_default():
    assert PBComb.torn_announce_bug is False
    assert DurableMSQueue.mirror_race_bug is False


def test_seeded_bug_context_restores_flag():
    with seeded_bug("torn-announce"):
        assert PBComb.torn_announce_bug is True
    assert PBComb.torn_announce_bug is False
    with pytest.raises(ValueError):
        with seeded_bug("no-such-bug"):
            pass


@pytest.mark.parametrize("bug", SEEDED_BUGS)
def test_fuzzer_rediscovers_seeded_bug(bug):
    """The calibration bar: each re-introduced historical bug must be
    found within a bounded seed budget, and the finding seed must pass
    with the bug off (it is the bug, not the harness)."""
    cls, cell = BUG_HUNTS[bug]
    budget = 32
    hit = None
    with seeded_bug(bug):
        for seed in range(budget):
            res = run_scenario(cls, seed, cell=cell)
            if res.failed:
                hit = res
                break
    assert hit is not None, \
        f"{bug} not found in {budget} seeds on {cls}/{cell}"
    clean = run_scenario(cls, hit.seed, cell=cell)
    assert clean.verdict == "ok", \
        f"seed {hit.seed:#x} fails even with {bug} off: {clean.verdict}"


def test_seeded_bugs_dont_leak_into_history():
    """Belt and braces for the fixture flags: a quick clean run of each
    hunting cell after the rediscovery tests stays green."""
    for cls, cell in BUG_HUNTS.values():
        r = run_scenario(cls, 5, cell=cell)
        assert r.verdict == "ok", r.detail


# --------------------------------------------------------------------- #
# scheduler round protocol sanity                                       #
# --------------------------------------------------------------------- #
def test_staged_scheduler_round_journal_consistent():
    from repro.fuzz.scheduler import StagedScheduler, drain_all
    rt = CombiningRuntime(n_threads=3)
    try:
        chk = HistoryChecker("queue")
        obj = rt.make("queue", "pbcomb")
        rng = random.Random(42)
        sched = StagedScheduler(rt, obj, chk, rng, 3)
        for i in range(4):
            sched.round(arm_cd=3 if i % 2 else None,
                        arm_rng=random.Random(i))
        sched.finish()      # raises on any history violation
    finally:
        rt.close()
