"""Per-architecture smoke tests (reduced same-family configs, CPU):
one forward + one train step, shape + finiteness assertions; prefill vs
full-forward consistency; feature-specific checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cells_for
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, loss_fn, param_count, prefill)
from repro.optim import make_optimizer

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _extra(cfg):
    extra = {}
    if cfg.family == "vlm":
        extra["image_embeds"] = jnp.full(
            (B, cfg.n_image_tokens, cfg.d_model), 0.01, jnp.bfloat16)
    if cfg.family == "audio":
        extra["frame_embeds"] = jnp.full(
            (B, cfg.n_audio_frames, cfg.d_model), 0.01, jnp.bfloat16)
    return extra


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_and_train_step(arch):
    cfg = ARCHS[arch].smoke()
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    extra = _extra(cfg)
    logits = forward(params, cfg, tokens, extra)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # one full train step: loss + grads + optimizer update
    batch = {"tokens": tokens, "labels": tokens, "extra": extra}
    init_fn, update_fn = make_optimizer(cfg)
    opt = init_fn(params)
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    new_params, _ = update_fn(grads, opt, params, jnp.zeros((), jnp.int32))
    moved = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32)
                                      - y.astype(jnp.float32))))
                for x, y in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params)))
    assert moved > 0.0                            # the update did something


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_prefill_decode(arch):
    cfg = ARCHS[arch].smoke()
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    extra = _extra(cfg)
    lg, state = prefill(params, cfg, tokens, extra, max_len=S + 8)
    assert lg.shape == (B, cfg.padded_vocab)
    lg2, state2 = decode_step(params, cfg, state, tokens[:, 0])
    assert lg2.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(lg2.astype(jnp.float32))))
    assert int(state2.pos) == S + 1


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-2.7b",
                                  "zamba2-2.7b", "whisper-medium"])
def test_prefill_then_decode_matches_full_forward(arch):
    """Teacher-forced decode continuation must agree with the full
    forward pass at the same positions (cache correctness)."""
    cfg = ARCHS[arch].smoke()
    params = init_params(cfg, KEY)
    T = 16
    tokens = jax.random.randint(KEY, (1, T), 0, cfg.vocab_size)
    extra = {k: v[:1] for k, v in _extra(cfg).items()}
    full = forward(params, cfg, tokens, extra).astype(jnp.float32)
    # bf16 params: the decode recurrence accumulates in a different order
    # than the chunked train path, so agreement is at bf16 resolution
    # (~0.05-0.1 at logit magnitude ~5) — exact-math agreement is covered
    # by the f32 kernel/oracle tests in test_kernels.py.
    tol = dict(atol=1.5e-1, rtol=1.5e-1)
    lg, state = prefill(params, cfg, tokens[:, :T - 2], extra,
                        max_len=T + 2)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full[:, T - 3]), **tol)
    lg, state = decode_step(params, cfg, state, tokens[:, T - 2])
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full[:, T - 2]), **tol)
    lg, state = decode_step(params, cfg, state, tokens[:, T - 1])
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full[:, T - 1]), **tol)


def test_gemma2_local_global_alternation():
    """Local (sliding-window) layers must mask distant context; check
    that truncating distant context changes nothing when ALL layers are
    local with a tiny window."""
    import dataclasses
    cfg = ARCHS["gemma2-9b"].smoke()
    cfg_local = dataclasses.replace(cfg, local_global_pattern=False,
                                    sliding_window=4, n_layers=2)
    params = init_params(cfg_local, KEY)
    T = 24
    tokens = jax.random.randint(KEY, (1, T), 0, cfg_local.vocab_size)
    out_full = forward(params, cfg_local, tokens)
    # perturb tokens far outside every window of the last position
    tokens2 = tokens.at[0, :4].set((tokens[0, :4] + 1) % cfg_local.vocab_size)
    out_pert = forward(params, cfg_local, tokens2)
    np.testing.assert_allclose(
        np.asarray(out_full[0, -1], np.float32),
        np.asarray(out_pert[0, -1], np.float32), atol=1e-3, rtol=1e-3)


def test_logit_softcap_bounds_logits():
    cfg = ARCHS["gemma2-9b"].smoke()
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    logits = forward(params, cfg, tokens).astype(jnp.float32)
    real = logits[..., :cfg.vocab_size]
    assert float(jnp.max(jnp.abs(real))) <= cfg.logit_softcap + 1e-3


def test_padded_vocab_never_wins():
    cfg = ARCHS["mamba2-2.7b"].smoke()   # vocab 256 -> already padded OK
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab_size=250)   # force padding
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    logits = forward(params, cfg, tokens)
    assert logits.shape[-1] == 256
    assert int(jnp.max(jnp.argmax(logits, -1))) < cfg.vocab_size


def test_moe_capacity_and_gates():
    """MoE: outputs finite, gradients flow to every expert weight kind,
    and with huge capacity no tokens are dropped (output differs from
    zero everywhere)."""
    cfg = ARCHS["moonshot-v1-16b-a3b"].smoke()
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens, "extra": {}}
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    g = grads["blocks"]["moe"]
    for name in ("w_gate", "w_up", "w_down", "router"):
        assert float(jnp.sum(jnp.abs(g[name].astype(jnp.float32)))) > 0


def test_loss_chunking_equivalence():
    """Chunked CE == unchunked CE."""
    import repro.models.model as M
    cfg = ARCHS["qwen3-1.7b"].smoke()
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens, "extra": {}}
    old = M.LOSS_CHUNK
    try:
        M.LOSS_CHUNK = 16
        l_chunked = float(loss_fn(params, cfg, batch))
        M.LOSS_CHUNK = 10 ** 9
        l_full = float(loss_fn(params, cfg, batch))
    finally:
        M.LOSS_CHUNK = old
    assert abs(l_chunked - l_full) < 1e-4


def test_param_counts_full_configs():
    """Full (unreduced) configs hit their published parameter scale
    (eval_shape only — nothing is materialized)."""
    expect = {
        "qwen3-14b": (13e9, 18e9),
        "command-r-35b": (28e9, 40e9),   # tied embeddings save ~2.1B
        "qwen3-1.7b": (1.5e9, 2.4e9),
        "gemma2-9b": (8e9, 11e9),
        "llama4-maverick-400b-a17b": (7.0e11, 8.5e11),
        # literal 64e x 1408ff x 48L config = 28B total (the HF "16B"
        # label reflects a shared-expert split we fold into the pool)
        "moonshot-v1-16b-a3b": (24e9, 32e9),
        "llama-3.2-vision-11b": (8e9, 12e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "zamba2-2.7b": (2.2e9, 3.4e9),
        "whisper-medium": (2.8e8, 1.2e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = ARCHS[arch]
        shape = jax.eval_shape(lambda c=cfg: init_params(c, KEY))
        n = param_count(shape)
        assert lo <= n <= hi, (arch, n)


def test_cells_for_long_context_policy():
    runnable = {a: [s.name for s, r, _ in cells_for(c) if r]
                for a, c in ARCHS.items()}
    assert "long_500k" in runnable["mamba2-2.7b"]
    assert "long_500k" in runnable["zamba2-2.7b"]
    assert "long_500k" in runnable["gemma2-9b"]
    assert "long_500k" not in runnable["qwen3-14b"]
    assert "long_500k" not in runnable["whisper-medium"]
    total = sum(len(v) for v in runnable.values())
    assert total == 33                      # 40 cells - 7 principled skips
