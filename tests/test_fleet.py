"""Sharded serving fleet (repro.fleet): router, traffic, recorder and
the Fleet orchestration — including the acceptance scenario: crash of
one shard mid-traffic, consistent-cut recovery, per-shard durable
linearizability (DESIGN.md §9)."""

import random
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from repro.fleet import (ConsistentHashRouter, Fleet, FleetConfig,
                         LatencyRecorder, burst_schedule, find_knee,
                         percentile, poisson_schedule, shard_skew,
                         trace_schedule)

from checker import HistoryChecker, check_fleet_log


# ------------------------------------------------------------------ #
# router                                                             #
# ------------------------------------------------------------------ #
def test_router_deterministic_and_total():
    r1 = ConsistentHashRouter(4, seed=3)
    r2 = ConsistentHashRouter(4, seed=3)
    keys = [f"client-{i}" for i in range(200)]
    assert [r1.shard_for(k) for k in keys] == \
        [r2.shard_for(k) for k in keys]
    groups = r1.assign(keys)
    assert sorted(groups) == [0, 1, 2, 3]
    assert sum(len(v) for v in groups.values()) == 200


def test_router_seed_changes_mapping():
    keys = [f"client-{i}" for i in range(100)]
    a = [ConsistentHashRouter(4, seed=0).shard_for(k) for k in keys]
    b = [ConsistentHashRouter(4, seed=1).shard_for(k) for k in keys]
    assert a != b


def test_router_stability_under_shard_removal():
    """Removing the last shard only moves the keys it owned — every
    other key keeps its placement (the consistent-hash property the
    per-shard logs rely on)."""
    keys = [f"client-{i}" for i in range(300)]
    big = ConsistentHashRouter(4, seed=0)
    small = ConsistentHashRouter(3, seed=0)
    moved = stayed = 0
    for k in keys:
        was = big.shard_for(k)
        now = small.shard_for(k)
        if was == 3:
            moved += 1
        else:
            assert now == was, f"{k} moved {was}->{now} gratuitously"
            stayed += 1
    assert moved and stayed


def test_router_balance():
    r = ConsistentHashRouter(4, replicas=64, seed=0)
    counts = [len(v) for v in
              r.assign(f"k{i}" for i in range(4000)).values()]
    assert shard_skew(counts) < 0.5     # replicas smooth the arcs


def test_shard_skew():
    assert shard_skew([10, 10]) == 0.0
    assert shard_skew([20, 10, 0]) == pytest.approx(1.0)
    assert shard_skew([]) == 0.0
    assert shard_skew([0, 0]) == 0.0


# ------------------------------------------------------------------ #
# traffic                                                            #
# ------------------------------------------------------------------ #
def test_poisson_schedule_seeded_and_monotone():
    a = poisson_schedule(1000.0, 50, seed=7)
    b = poisson_schedule(1000.0, 50, seed=7)
    assert a == b
    assert a == sorted(a)
    assert len(a) == 50
    assert poisson_schedule(1000.0, 50, seed=8) != a
    with pytest.raises(ValueError):
        poisson_schedule(0.0, 10, seed=0)


def test_burst_and_trace_schedules():
    assert burst_schedule(4) == [0.0] * 4
    assert trace_schedule([0.3, 0.1, 0.2]) == [0.1, 0.2, 0.3]
    with pytest.raises(ValueError):
        trace_schedule([-0.1, 0.2])


# ------------------------------------------------------------------ #
# recorder                                                           #
# ------------------------------------------------------------------ #
def test_percentile_nearest_rank():
    vals = sorted([10.0, 20.0, 30.0, 40.0])
    assert percentile(vals, 0.50) == 20.0
    assert percentile(vals, 0.99) == 40.0
    assert percentile(vals, 0.0) == 10.0
    assert percentile([], 0.5) == 0.0


def test_latency_recorder_summary():
    rec = LatencyRecorder()
    rec.add([0.001] * 99)
    rec.add([0.1])
    s = rec.summary()
    assert s["n"] == 100
    assert s["p50_us"] == pytest.approx(1000.0)
    assert s["p99_us"] == pytest.approx(1000.0)
    assert s["p999_us"] == pytest.approx(100_000.0)
    assert s["max_us"] == pytest.approx(100_000.0)
    assert LatencyRecorder().summary()["p99_us"] is None


def test_find_knee_brackets_capacity():
    p99 = {100.0: 1000.0, 200.0: 2000.0, 400.0: 50_000.0}
    k = find_knee(lambda r: {"p99_us": p99[r]}, [100.0, 200.0, 400.0],
                  p99_budget_us=10_000.0)
    assert k["last_ok_rate_rps"] == 200.0
    assert k["first_saturated_rate_rps"] == 400.0
    assert k["knee_rate_rps"] == pytest.approx((200.0 * 400.0) ** 0.5)
    assert not k["saturated_at_floor"]
    assert len(k["steps"]) == 3        # ramp stops at first saturation


def test_find_knee_edge_cases():
    k = find_knee(lambda r: {"p99_us": 1.0}, [100.0, 200.0], 10.0)
    assert k["knee_rate_rps"] is None  # never saturated
    k = find_knee(lambda r: {"p99_us": 99.0}, [100.0, 200.0], 10.0)
    assert k["saturated_at_floor"]
    assert k["knee_rate_rps"] == 100.0
    assert len(k["steps"]) == 1


# ------------------------------------------------------------------ #
# fleet end-to-end (shm worker pools)                                #
# ------------------------------------------------------------------ #
def _shard_checkers(fleet):
    return {s.index: HistoryChecker("queue") for s in fleet.shards}


def _feed(checkers, results):
    for i, res in results.items():
        checkers[i].extend_pool(res)


def _check_all(fleet, checkers):
    """Every shard's ingress FIFO/exact-once + fleet log invariants."""
    for s in fleet.shards:
        checkers[s.index].check(s.ingress.snapshot())
        check_fleet_log(checkers[s.index].events, s.log.snapshot(),
                        fleet.cfg.gen_len)


def test_fleet_open_loop_smoke():
    cfg = FleetConfig(n_shards=2, workers_per_shard=2, n_clients=8,
                      seed=5)
    with Fleet(cfg) as f:
        checkers = _shard_checkers(f)
        res = f.run_wave(f.make_wave(40, rate_rps=4000.0),
                         collect=True)
        assert sum(len(r.latencies) for r in res.values()) == 40
        assert all(lat >= 0 for r in res.values()
                   for lat in r.latencies)
        _feed(checkers, res)
        # trace-driven wave rides the same machinery
        res = f.run_wave(
            f.make_wave(10, trace=[i * 0.001 for i in range(10)]),
            collect=True)
        assert sum(len(r.latencies) for r in res.values()) == 10
        _feed(checkers, res)
        _check_all(f, checkers)
        step = f.checkpoint()
        assert f.committed_step() == step


def test_fleet_wave_determinism():
    """Same seed, same config -> identical schedules (routing, arrival
    times, client identities, seqs, deadlines)."""
    def schedules():
        cfg = FleetConfig(n_shards=2, workers_per_shard=2,
                          n_clients=8, seed=9)
        f = Fleet(cfg)          # no start(): scheduling is pure
        try:
            return f.make_wave(50, rate_rps=2000.0)
        finally:
            f.close()
    assert schedules() == schedules()


def test_fleet_shard_crash_mid_traffic_consistent_cut():
    """The acceptance scenario: one shard crashes mid-traffic, the rest
    keep serving; recovery replays the crashed shard's in-flight ops,
    the next consistent cut commits fleet-wide, and every shard's
    history stays durably linearizable."""
    cfg = FleetConfig(n_shards=2, workers_per_shard=2, n_clients=8,
                      seed=13)
    with Fleet(cfg) as f:
        checkers = _shard_checkers(f)
        _feed(checkers, f.run_wave(f.make_wave(30, rate_rps=4000.0),
                                   collect=True))
        step1 = f.checkpoint()

        f.arm_crash(0, 40, random.Random(2))
        res = f.run_wave(f.make_wave(30, rate_rps=4000.0),
                         collect=True)
        assert res[0].crashed            # shard 0 went down mid-wave
        assert not res[1].crashed        # shard 1 kept serving
        _feed(checkers, res)
        replies = f.recover_shards(res)
        assert 0 in replies
        checkers[0].apply_replay(res[0].inflight, replies[0])

        # the committed cut survives the crash of a shard subset
        assert f.committed_step() >= step1

        # traffic continues after recovery; the next cut commits
        _feed(checkers, f.run_wave(f.make_wave(30, rate_rps=4000.0),
                                   collect=True))
        step2 = f.checkpoint()
        assert step2 > step1
        assert f.committed_step() == step2
        _check_all(f, checkers)

        # the durable cut payload names its shard and step
        for s in f.shards:
            snap = s.ckpt.snapshot()
            assert snap["step"] == step2
            assert snap["payload"]["shard"] == s.index
            assert snap["payload"]["step"] == step2


def test_fleet_requires_worker_per_shard():
    cfg = FleetConfig(n_shards=2, workers_per_shard=1, n_clients=4)
    f = Fleet(cfg)
    try:
        with pytest.raises(RuntimeError):
            f.leave(1, 0)          # would empty shard 1
    finally:
        f.close()


def test_fleet_config_validation():
    with pytest.raises(ValueError):
        Fleet(FleetConfig(n_shards=2), n_shards=3)   # both forms
    f = Fleet(FleetConfig(n_shards=1, workers_per_shard=1,
                          n_clients=2))
    try:
        with pytest.raises(ValueError):
            f.make_wave(4)                           # no arrival process
        with pytest.raises(ValueError):
            f.make_wave(4, rate_rps=100.0, burst=True)
        with pytest.raises(RuntimeError):
            f.run_wave({})                           # not started
    finally:
        f.close()


# ------------------------------------------------------------------ #
# fleet_bench gates                                                  #
# ------------------------------------------------------------------ #
def _bench_doc(comb_degrees=(2.5, 2.4), comb_psync=0.4,
               floor_psync=1.0, knee=500.0, completed=None):
    def row(name, psync, degrees):
        return {"name": name, "rate_rps": None, "offered": 100,
                "completed": 100 if completed is None else completed,
                "shard_skew": 0.1, "p50_us": 1.0, "p99_us": 2.0,
                "p999_us": 3.0, "psyncs_per_op": psync,
                "pwbs_per_op": 1.0, "degree_mean": 2.0,
                "per_shard": [
                    {"shard": i, "degree_mean": d, "degree_max": 4,
                     "active_workers": 4}
                    for i, d in enumerate(degrees)]}
    return {"rows": [row("fleet/pbcomb/burst", comb_psync,
                         comb_degrees),
                     row("fleet/lock-direct/burst", floor_psync,
                         (None, None))],
            "knee": {"knee_rate_rps": knee},
            "checkpoint": {"step": 3, "committed": 3}}


def test_fleet_bench_check_passes_and_fails():
    from benchmarks.fleet_bench import check_results
    assert check_results(_bench_doc()) == []
    assert any("degree" in m for m in
               check_results(_bench_doc(comb_degrees=(2.5, 1.5))))
    assert any("floor" in m for m in
               check_results(_bench_doc(comb_psync=1.0)))
    assert any("knee" in m for m in
               check_results(_bench_doc(knee=None)))
    assert any("lost" in m for m in
               check_results(_bench_doc(completed=90)))
    doc = _bench_doc()
    doc["checkpoint"]["committed"] = 2
    assert any("cut" in m for m in check_results(doc))
