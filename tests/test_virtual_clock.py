"""Virtual-clock NVM timing engine (DESIGN.md §6).

Pins the three contracts the modeled perf trajectory rests on:

  * fused round sentences (pwb_fence / pwb_sync / commit_round) charge
    EXACTLY what their discrete-instruction fallbacks would — same
    floats, same counters, same durable image — under every profile;
  * the deterministic modeled bench pass is byte-identical across runs,
    and reproduces the paper's relative ordering (PBComb < DFC <
    durable-MS / locks) at Optane latencies;
  * Lamport clock merging: a combining round's modeled latency is the
    max over its participants, not the sum — and crash countdowns armed
    mid-round still land on durable prefixes with the clock engaged.
"""

import random
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

try:                                   # optional dep: `pip install .[test]`
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = settings = st = None

from repro.api import CombiningRuntime
from repro.core import (NVM, PROFILES, PBComb, RequestRec, SimulatedCrash,
                        VClock)
from repro.structures import PBStack

from benchmarks import modeled


# ------------------------------------------------------------------ #
# VClock unit behavior                                               #
# ------------------------------------------------------------------ #
def test_vclock_bind_advance_merge():
    clk = VClock(PROFILES["optane"])
    with clk.bind(0):
        clk.advance(100.0)
        assert clk.now() == 100.0
    with clk.bind(1):
        assert clk.now() == 0.0
        clk.merge(250.0)
        assert clk.now() == 250.0
        clk.merge(10.0)                      # merge is a max, monotone
        assert clk.now() == 250.0
    with clk.bind(0):
        assert clk.now() == 100.0            # per-logical-thread clocks
    assert clk.max_time_ns() == 250.0


def test_vclock_device_serializes():
    clk = VClock(PROFILES["optane"])
    with clk.bind(0):
        clk.sync_device(1000.0)
        assert clk.now() == 1000.0
    with clk.bind(1):
        # device busy until t=1000: this thread's psync queues behind it
        clk.sync_device(1000.0)
        assert clk.now() == 2000.0


def test_unknown_profile_rejected():
    with pytest.raises(ValueError):
        NVM(1 << 12, profile="nvram-of-theseus")


# ------------------------------------------------------------------ #
# Fused sentence == discrete fallback (cost, counters, durability)   #
# ------------------------------------------------------------------ #
def _prepared(profile, force):
    nvm = NVM(1 << 14, profile=profile)
    nvm.force_discrete = force
    base = nvm.alloc(80)
    idx = nvm.alloc(1)
    for i in range(80):
        nvm.write(base + i, i * 3 + 1)
    nvm.reset_counters()
    nvm.clock.reset()
    return nvm, base, idx


def _observe(nvm):
    return (nvm.clock.now(), dict(nvm.counters),
            [nvm.durable_read(a) for a in range(nvm._alloc_ptr)])


def _prior_traffic(nvm, base, prior):
    for off, n in prior:
        nvm.pwb(base + off, n)
    nvm.pfence()


PENDING_CASES = [None, [], [(0, 1)], [(5, 3), (40, 2)],
                 [(0, 8), (3, 9), (70, 1)]]
PRIOR_CASES = [[], [(2, 1)], [(60, 10), (0, 2)]]


@pytest.mark.parametrize("profile", sorted(PROFILES))
@pytest.mark.parametrize("pending", PENDING_CASES)
@pytest.mark.parametrize("prior", PRIOR_CASES)
def test_commit_round_fused_equals_discrete(profile, pending, prior):
    results = []
    for force in (False, True):
        nvm, base, idx = _prepared(profile, force)
        _prior_traffic(nvm, base, prior)
        pend = None if pending is None else \
            [(base + off, n) for off, n in pending]
        nvm.commit_round(base, 40, idx, 1, pending=pend)
        results.append(_observe(nvm))
    assert results[0] == results[1]          # floats bit-equal too


@pytest.mark.parametrize("profile", sorted(PROFILES))
@pytest.mark.parametrize("pending", PENDING_CASES)
def test_pwb_fence_fused_equals_discrete(profile, pending):
    results = []
    for force in (False, True):
        nvm, base, _idx = _prepared(profile, force)
        pend = None if pending is None else \
            [(base + off, n) for off, n in pending]
        nvm.pwb_fence(base, 24, pending=pend)
        results.append(_observe(nvm))
    assert results[0] == results[1]


@pytest.mark.parametrize("profile", sorted(PROFILES))
@pytest.mark.parametrize("prior", PRIOR_CASES)
def test_pwb_sync_fused_equals_discrete(profile, prior):
    results = []
    for force in (False, True):
        nvm, base, _idx = _prepared(profile, force)
        _prior_traffic(nvm, base, prior)
        nvm.pwb_sync(base + 17, 2)
        results.append(_observe(nvm))
    assert results[0] == results[1]


if st is not None:
    @settings(max_examples=40, deadline=None)
    @given(st.sampled_from(sorted(PROFILES)),
           st.lists(st.tuples(st.integers(0, 75), st.integers(1, 12)),
                    max_size=5),
           st.lists(st.tuples(st.integers(0, 75), st.integers(1, 12)),
                    max_size=3),
           st.integers(1, 60))
    def test_property_commit_round_cost_equivalence(profile, pending,
                                                    prior, state_words):
        """The satellite property: a fused commit_round's modeled cost
        equals the sum of its discrete-instruction fallback under every
        profile, for arbitrary pending/prior line traffic."""
        results = []
        for force in (False, True):
            nvm, base, idx = _prepared(profile, force)
            _prior_traffic(nvm, base, prior)
            pend = [(base + off, n) for off, n in pending]
            nvm.commit_round(base, state_words, idx, 1,
                             pending=pend or None)
            results.append(_observe(nvm))
        assert results[0] == results[1]
else:
    def test_property_commit_round_cost_equivalence():
        pytest.importorskip("hypothesis")


# ------------------------------------------------------------------ #
# Deterministic modeled pass + paper ordering                        #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("cell", [("queue", "pbcomb"),
                                  ("queue", "pwfcomb"),
                                  ("queue", "durable-ms"),
                                  ("stack", "dfc"),
                                  ("counter", "lock-undo")])
def test_modeled_cell_byte_identical(cell):
    kind, proto = cell
    assert modeled.modeled_cell(kind, proto) == \
        modeled.modeled_cell(kind, proto)


def test_modeled_fig1_byte_identical():
    for name in modeled.FIG1_IMPLS:
        assert modeled.modeled_fig1(name) == modeled.modeled_fig1(name)


def test_modeled_ordering_matches_paper():
    """The paper's headline relative ordering at Optane latencies:
    combining (PBComb) beats detectable flat combining (DFC) beats the
    per-op-persist competitors (durable MS queue, locks)."""
    pb = modeled.modeled_cell("queue", "pbcomb")
    pbs = modeled.modeled_cell("stack", "pbcomb")
    dfc = modeled.modeled_cell("stack", "dfc")
    ms = modeled.modeled_cell("queue", "durable-ms")
    ld = modeled.modeled_cell("queue", "lock-direct")
    lu = modeled.modeled_cell("queue", "lock-undo")
    assert pbs["modeled_us_per_op"] < dfc["modeled_us_per_op"]
    assert dfc["modeled_us_per_op"] < ms["modeled_us_per_op"]
    for worse in (ms, ld, lu):
        assert pb["modeled_us_per_op"] < worse["modeled_us_per_op"]
    # and the why: one psync per round vs one per op
    assert pb["modeled_psync_per_op"] < 0.5 < ms["modeled_psync_per_op"]


def test_round_latency_is_max_not_sum():
    """Three announced requests served by one round: every participant
    lands at the round's end (merge), the device is paid ONCE, and the
    makespan is far below three sequential per-op persists."""
    rt = CombiningRuntime(n_threads=3, profile="optane")
    c = rt.make("counter", "pbcomb")
    handles = [rt.attach(p) for p in range(3)]
    rt.nvm.reset_counters()
    rt.nvm.clock.reset()
    handles[1].announce(c, "fetch_add", 1)
    handles[2].announce(c, "fetch_add", 1)
    handles[0].bind(c).fetch_add(1)
    assert rt.nvm.counters["psync"] == 1         # one round, one psync
    clk = rt.nvm.clock
    prof = clk.profile
    combiner_t = clk._times[0]
    # makespan == the combiner's clock, and well under 3 discrete
    # psync round trips (what per-op persistence would charge)
    assert clk.max_time_ns() == combiner_t
    assert combiner_t < 3 * prof.psync_ns
    assert c.snapshot() == 3


# ------------------------------------------------------------------ #
# Crash countdowns with the clock engaged                            #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("crash_at", range(10))
@pytest.mark.parametrize("seed", [None, 13])
def test_crash_mid_round_durable_prefix_with_clock(crash_at, seed):
    """Arming a crash countdown forces the discrete instruction path
    (ticks land BETWEEN instructions); with a profile engaged the same
    sweep must still recover every announced op exactly once."""
    nvm = NVM(1 << 20, profile="optane")
    s = PBStack(nvm, 3)
    s.op(0, "PUSH", "base", 1)
    t_before = nvm.clock.max_time_ns()
    for p in range(3):
        s.request[p] = RequestRec("PUSH", f"v{p}",
                                  1 - s.request[p].activate, 1)
    nvm.arm_crash(crash_at, random.Random(seed) if seed else None)
    try:
        s._perform_request(0)
    except SimulatedCrash:
        pass
    nvm.disarm_crash()
    s.reset_volatile()
    seqs = {0: 2, 1: 1, 2: 1}
    rets = {p: s.recover(p, "PUSH", f"v{p}", seqs[p]) for p in range(3)}
    assert all(r == "ACK" for r in rets.values())
    content = s.drain()
    assert sorted(content[:-1]) == ["v0", "v1", "v2"]
    assert content[-1] == "base"
    # logical time is monotone through crash + recovery
    assert nvm.clock.max_time_ns() >= t_before


@pytest.mark.parametrize("crash_at", range(8))
def test_runtime_crash_recover_with_clock(crash_at):
    """Full-machine crash through the runtime/handle surface with the
    clock engaged: acknowledged prefix intact, in-flight op replayed."""
    rt = CombiningRuntime(n_threads=2, profile="dram")
    q = rt.make("queue", "pbcomb")
    b = rt.attach(0).bind(q)
    b.enqueue("a")
    b.enqueue("b")
    rt.arm_crash(crash_at, random.Random(crash_at))
    try:
        b.enqueue("c")
    except SimulatedCrash:
        pass
    rt.crash(random.Random(crash_at + 1))
    rt.recover()
    content = q.snapshot()
    assert content[:2] == ["a", "b"]
    assert all(v == "c" for v in content[2:]) and len(content) <= 3
