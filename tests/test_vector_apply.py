"""VectorApply seam (DESIGN.md §11): vectorized combining rounds are an
EXACT drop-in for the per-op simulation loop.

The contract under test is exactness-or-decline:

  * equivalence — for every array-valued registry cell that accepts
    ``vector_apply=True`` (counter/heap/log/ckpt x pbcomb/pwfcomb), the
    same staged-announcement workload produces identical responses
    (values AND types), identical structure snapshots, and identical
    NVM persistence counters with the seam on and off.  The vector
    path runs through volatile ``read_range``/``write_range`` only, so
    the modeled trajectory cannot move — the counter equality pins it;
  * engagement — the jitted kernels actually run on the vector side
    (``vector_rounds.kernel_calls()`` advances), so the equivalence is
    not vacuously tested against a permanently-declining seam;
  * decline — heterogeneous rounds, non-int payloads, bignums and other
    unpackable arguments fall back to the per-op loop rather than
    approximate (the kernel packing guards);
  * durability — a crash landing inside a vectorized round replays
    every announced request exactly once, same as the eager rounds
    (the in-flight idiom of tests/test_api_matrix.py).
"""

import random

import pytest

from repro.api import CombiningRuntime, get_adapter
from repro.core import NVM, SimulatedCrash
from repro.core.objects import (AtomicFloatObject, FetchAddObject,
                                HeapObject, ResponseLogObject)
from repro.kernels import vector_rounds

N = 4
ROUNDS = 6

#: Registry cells whose adapters accept ``vector_apply=`` (array-valued
#: structures under a combining protocol).
VECTOR_CELLS = [(k, p) for k in ("counter", "heap", "log", "ckpt")
                for p in ("pbcomb", "pwfcomb")]

#: Per-kind homogeneous round schedule (one op, every thread announces
#: it) with int-only payloads so the kernels can pack them.
_SCHED = {
    "counter": [("fetch_add", lambda p, r: 1)],
    "heap": [("insert", lambda p, r: (p * 31 + r) % 997),
             ("delete_min", None)],
    "log": [("record", lambda p, r: (p, r + 1, p * 1000 + r))],
    "ckpt": [("persist", lambda p, r: (r + 1, r))],
}


def _drive(kind, protocol, vector):
    """Run ROUNDS staged homogeneous combining rounds; every logical
    thread announces, thread 0 performs (serving the whole batch).
    Returns (responses, snapshot, persistence counters)."""
    nvm = NVM(1 << 20)
    rt = CombiningRuntime(nvm=nvm, n_threads=N)
    obj = rt.make(kind, protocol, vector_apply=vector)
    handles = [rt.attach(p) for p in range(N)]
    bound0 = handles[0].bind(obj)
    rets = []
    for r in range(ROUNDS):
        for op, argfn in _SCHED[kind]:
            for p in range(1, N):
                if argfn is None:
                    handles[p].announce(obj, op)
                else:
                    handles[p].announce(obj, op, argfn(p, r))
            fn = getattr(bound0, op)
            rets.append(fn(*(() if argfn is None else (argfn(0, r),))))
            for p in range(1, N):
                rets.append(handles[p].perform(obj))
    return rets, obj.snapshot(), dict(nvm.counters)


def _typed(values):
    """Pair every response with its concrete type: the seam must not
    swap an int for a numpy scalar (or a bool for an int)."""
    return [(type(v).__name__, v) for v in values]


@pytest.mark.parametrize("kind,protocol", VECTOR_CELLS)
def test_vector_equals_eager(kind, protocol):
    before = vector_rounds.kernel_calls()
    v_rets, v_snap, v_counters = _drive(kind, protocol, vector=True)
    engaged = vector_rounds.kernel_calls() - before
    e_rets, e_snap, e_counters = _drive(kind, protocol, vector=False)
    assert _typed(v_rets) == _typed(e_rets)
    assert v_snap == e_snap
    assert v_counters == e_counters          # modeled trajectory pinned
    if vector_rounds.available():
        # every round is homogeneous and int-valued: the kernel must
        # have served them (equivalence is not decline-vs-decline)
        assert engaged >= ROUNDS


@pytest.mark.parametrize("protocol", ["pbcomb", "pwfcomb"])
def test_heterogeneous_round_falls_back(protocol):
    """A round mixing funcs (insert + get_min map to different kernel
    funcs) must decline vectorization and still be correct."""

    def drive(vector):
        nvm = NVM(1 << 20)
        rt = CombiningRuntime(nvm=nvm, n_threads=N)
        obj = rt.make("heap", protocol, vector_apply=vector)
        handles = [rt.attach(p) for p in range(N)]
        b0 = handles[0].bind(obj)
        b0.insert(7)
        handles[1].announce(obj, "insert", 3)
        handles[2].announce(obj, "get_min")
        handles[3].announce(obj, "insert", 11)
        rets = [b0.insert(5)]
        rets += [handles[p].perform(obj) for p in (1, 2, 3)]
        return rets, obj.snapshot(), dict(nvm.counters)

    assert drive(True) == drive(False)


def test_unpackable_payloads_decline():
    """The packing guards: strings, None, bignums and floats-for-int
    slots make vector_apply return None (eager fallback), never an
    approximate batch."""
    nvm = NVM(1 << 16)
    log = ResponseLogObject(8)
    base = nvm.alloc(log.state_words)
    log.init_state(nvm, base)
    assert log.vector_apply(nvm, base, "RECORD",
                            [(0, 1, "a-string")]) is None
    assert log.vector_apply(nvm, base, "RECORD", [(0, 1, None)]) is None
    assert log.vector_apply(nvm, base, "RECORD", [(0, 1, 2 ** 70)]) is None

    ctr = FetchAddObject()
    cbase = nvm.alloc(ctr.state_words)
    ctr.init_state(nvm, cbase)
    assert ctr.vector_apply(nvm, cbase, "FAA", [2 ** 70]) is None
    assert ctr.vector_apply(nvm, cbase, "FAA", [1.5]) is None
    # wrong func for the object declines rather than misapplying
    assert ctr.vector_apply(nvm, cbase, "MUL", [2]) is None

    heap = HeapObject(16)
    hbase = nvm.alloc(heap.state_words)
    heap.init_state(nvm, hbase)
    assert heap.vector_apply(nvm, hbase, "HINSERT", ["x"]) is None


@pytest.mark.skipif(not vector_rounds.available(), reason="no jax")
def test_bool_packs_as_int():
    """The documented wrinkle: bool is an int subclass and packs as its
    int value — the batch result must still equal the eager loop."""
    nvm = NVM(1 << 16)
    ctr = FetchAddObject()
    base = nvm.alloc(ctr.state_words)
    ctr.init_state(nvm, base)
    resps = ctr.vector_apply(nvm, base, "FAA", [True, 2, True])
    assert resps == [0, 1, 3]
    assert all(type(v) is int for v in resps)
    assert nvm.read(base) == 4


@pytest.mark.skipif(not vector_rounds.available(), reason="no jax")
def test_atomicfloat_mul_round_exact():
    """The paper's AtomicFloat under the seam: the scan kernel performs
    the identical float multiplies in the identical order, so state and
    responses match the eager loop bit-for-bit."""
    args = [1.000001, 0.75, 3.5, 1.25, 0.5, 2.0] * 3
    nvm_v, nvm_e = NVM(1 << 10), NVM(1 << 10)
    obj = AtomicFloatObject()
    bv, be = nvm_v.alloc(1), nvm_e.alloc(1)
    obj.init_state(nvm_v, bv)
    obj.init_state(nvm_e, be)
    resps_v = obj.vector_apply(nvm_v, bv, "MUL", args)
    resps_e = [obj.apply(nvm_e, be, "MUL", a) for a in args]
    assert resps_v == resps_e
    assert nvm_v.read(bv) == nvm_e.read(be)


# --------------------------------------------------------------------- #
# Crash inside a vectorized round                                       #
# --------------------------------------------------------------------- #
_ANNOUNCE = {"counter": ("fetch_add", lambda p: 1),
             "heap": ("insert", lambda p: 100 + p),
             "log": ("record", lambda p: (p, 1, 10 + p)),
             "ckpt": ("persist", lambda p: (1, 7))}

CRASH_CELLS = [(k, p) for k, p in VECTOR_CELLS
               if get_adapter(k, p).detectable]


@pytest.mark.parametrize("kind,protocol", CRASH_CELLS)
@pytest.mark.parametrize("crash_at", [0, 2, 4, 6])
def test_crash_mid_vectorized_round_replays_exactly_once(kind, protocol,
                                                         crash_at):
    """Arm a crash inside the combining round that serves N announced
    requests through the vector seam; after recovery the durable state
    equals an eager crash-free run of the same workload and every
    request was applied exactly once."""
    rt = CombiningRuntime(n_threads=N)
    obj = rt.make(kind, protocol, vector_apply=True)
    handles = [rt.attach(p) for p in range(N)]
    op, argfn = _ANNOUNCE[kind]
    for p in range(N):
        handles[p].announce(obj, op, argfn(p))
    rt.arm_crash(crash_at, random.Random(13))
    rets = {}
    try:
        rets[1] = handles[1].perform(obj)
    except SimulatedCrash:
        pass
    replies = rt.recover()
    for p in range(N):
        if (obj.name, p) in replies:
            rets[p] = replies[(obj.name, p)]
    assert len(rets) == N

    # eager, crash-free reference run of the identical workload
    ref_rt = CombiningRuntime(n_threads=N)
    ref = ref_rt.make(kind, protocol, vector_apply=False)
    ref_handles = [ref_rt.attach(p) for p in range(N)]
    for p in range(1, N):
        ref_handles[p].announce(ref, op, argfn(p))
    getattr(ref_handles[0].bind(ref), op)(argfn(0))
    for p in range(1, N):
        ref_handles[p].perform(ref)
    assert obj.snapshot() == ref.snapshot()

    if kind == "counter":
        # FAA multiset linearizability: N replayed FAA(1) responses are
        # exactly {0..N-1} — a lost or doubled application breaks this
        assert sorted(rets.values()) == list(range(N))

    # structure stays usable post-recovery, vector path still on
    b = rt.attach(0).bind(obj)
    if kind == "counter":
        assert b.fetch_add(1) == N
    elif kind == "heap":
        b.insert(-1)
        assert b.get_min() == -1
    elif kind == "log":
        b.record((0, 2, 99))
        assert b.lookup(0) == (2, 99)
    else:
        b.persist((5, 55))
        assert b.latest() == (5, 55)
