"""Epoch-based memory reclamation (DESIGN.md §13).

Covers the ``EpochReclaimer`` primitive on both backends (retire →
grace → quiesce → take flow, pins, ring-overflow drops, the
never-reissue recovery rule), the PWFQueue/PWFStack integration (node
reuse under churn, reachability safety, crash-at-every-persist-point
sweeps of the quiesce protocol), the PerThreadFreeList shared-overflow
regression, the crash-robust shm segment lifecycle, and blob-heap GC
correctness under overwrite churn.
"""

import multiprocessing
import os
import random
import signal
import time
from collections import deque

import pytest

from repro.api import CombiningRuntime
from repro.core import SimulatedCrash
from repro.core.nvm import NVM
from repro.core.shm import ShmNVM
from repro.core import shm as shm_mod
from repro.fuzz.crashpoints import CrashPointInjector
from repro.persist.reclaim import EpochReclaimer
from repro.structures.nodes import NodePool, PerThreadFreeList

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # pragma: no cover - optional dependency
    given = settings = st = None

BACKENDS = ["threads", "shm"]


@pytest.fixture(params=BACKENDS)
def nvm(request):
    n = NVM(1 << 14) if request.param == "threads" else ShmNVM(1 << 14)
    yield n
    if request.param == "shm":
        n.close()


def _rt(backend, n_threads=2):
    kw = {"backend": backend}
    if backend == "shm":
        kw["segments"] = 2
    return CombiningRuntime(n_threads=n_threads, nvm_words=1 << 16, **kw)


# --------------------------------------------------------------------- #
# EpochReclaimer primitive                                              #
# --------------------------------------------------------------------- #
def test_retire_grace_quiesce_take_flow(nvm):
    rec = EpochReclaimer(nvm, n_threads=2, cap=8)
    addrs = [nvm.alloc(2) for _ in range(3)]
    for a in addrs:
        rec.retire(0, a)
    # same-epoch quiesce: nothing has aged past the grace period
    assert rec.quiesce()["freed"] == 0
    assert rec.take(0) is None
    for _ in range(EpochReclaimer.GRACE):
        rec.advance()
    out = rec.quiesce()
    assert out["freed"] == 3
    # FIFO: the free window hands nodes back in retirement order
    assert [rec.take(0) for _ in range(3)] == addrs
    assert rec.take(0) is None
    s = rec.stats()
    assert s["retired"] == 3 and s["limbo"] == 0
    assert s["free_window"] == 0 and s["reused"] == 3


def test_pin_blocks_freeing(nvm):
    rec = EpochReclaimer(nvm, n_threads=2, cap=8)
    rec.retire(0, nvm.alloc(2))
    rec.pin(1)                       # thread 1 may still hold a reference
    for _ in range(3):
        rec.advance()
    assert rec.quiesce()["freed"] == 0
    assert rec.take(0) is None
    rec.unpin(1)
    assert rec.quiesce()["freed"] == 1
    assert rec.take(0) is not None


def test_ring_overflow_drops_instead_of_clobbering(nvm):
    rec = EpochReclaimer(nvm, n_threads=1, cap=4)
    addrs = [nvm.alloc(2) for _ in range(6)]
    for a in addrs:
        rec.retire(0, a)
    s = rec.stats()
    assert s["retired"] == 4 and s["drops"] == 2
    for _ in range(EpochReclaimer.GRACE):
        rec.advance()
    rec.quiesce()
    # only the first cap entries survive; the overflow leaked, not
    # overwrote
    assert [rec.take(0) for _ in range(5)] == addrs[:4] + [None]


def test_crash_never_reissues_consumed_nodes(nvm):
    rec = EpochReclaimer(nvm, n_threads=1, cap=8)
    first = [nvm.alloc(2) for _ in range(4)]
    for a in first:
        rec.retire(0, a)
    for _ in range(EpochReclaimer.GRACE):
        rec.advance()
    rec.quiesce()
    assert rec.take(0) in first and rec.take(0) in first
    nvm.crash(random.Random(0))
    nvm.disarm_crash()
    rec.recover()
    # recovery empties the free window: entries consumed before the
    # crash (their consumption was volatile) must never come back
    assert rec.take(0) is None
    second = [nvm.alloc(2) for _ in range(3)]
    for a in second:
        rec.retire(0, a)
    for _ in range(EpochReclaimer.GRACE):
        rec.advance()
    rec.quiesce()
    reissued = [rec.take(0) for _ in range(4)]
    assert reissued == second + [None]
    assert not (set(reissued) & set(first))


# --------------------------------------------------------------------- #
# structure integration                                                 #
# --------------------------------------------------------------------- #
def _churn_queue(rt, q, handles, qm, rng, rounds):
    for _ in range(rounds):
        for p, h in enumerate(handles):
            v = rng.randrange(1 << 30)
            assert h.invoke(q, "enqueue", v) == "ACK"
            qm.append(v)
            if len(qm) > 4:
                assert h.invoke(q, "dequeue", None) == qm.popleft()


@pytest.mark.parametrize("backend", BACKENDS)
def test_queue_reuses_nodes_under_churn(backend):
    rt = _rt(backend)
    try:
        q = rt.make("queue", "pwfcomb")          # reclaim="epoch" default
        handles = [rt.attach(p) for p in range(2)]
        qm = deque()
        rng = random.Random(11)
        for _ in range(6):
            _churn_queue(rt, q, handles, qm, rng, 10)
            rt.quiesce()
        st_ = q.core.reclaim.stats()
        assert st_["reused"] > 0, st_
        assert st_["drops"] == 0
        assert q.adapter.snapshot(q.core) == list(qm)
    finally:
        rt.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_stack_epoch_reclaim_opt_in(backend):
    rt = _rt(backend)
    try:
        assert rt.make("stack", "pwfcomb").core.reclaim is None
        s = rt.make("stack", "pwfcomb", name="stack-rec", reclaim="epoch")
        handles = [rt.attach(p) for p in range(2)]
        sm = []
        rng = random.Random(13)
        for _ in range(6):
            for _ in range(10):
                for h in handles:
                    v = rng.randrange(1 << 30)
                    assert h.invoke(s, "push", v) == "ACK"
                    sm.append(v)
                    if len(sm) > 4:
                        assert h.invoke(s, "pop", None) == sm.pop()
            rt.quiesce()
        assert s.core.reclaim.stats()["reused"] > 0
        # drain is top-first; the mirror appends at the top
        assert s.adapter.snapshot(s.core) == sm[::-1]
    finally:
        rt.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_take_never_returns_reachable_node(backend):
    rt = _rt(backend)
    try:
        q = rt.make("queue", "pwfcomb")
        core, rec, nvm = q.core, q.core.reclaim, rt.nvm
        reuses = 0
        orig_take = rec.take

        def checked_take(p):
            nonlocal reuses
            addr = orig_take(p)
            if addr is not None:
                reuses += 1
                node = nvm.read(core.deq._base(core.deq.S.load()))
                while node:
                    assert node != addr, \
                        f"free window reissued reachable node {addr}"
                    nxt = nvm.read(node + 1)
                    node = nxt if type(nxt) is int else 0
            return addr

        rec.take = checked_take
        handles = [rt.attach(p) for p in range(2)]
        qm = deque()
        rng = random.Random(17)
        for _ in range(8):
            _churn_queue(rt, q, handles, qm, rng, 10)
            rt.quiesce()
        assert reuses > 0                 # the guard actually exercised
        assert q.adapter.snapshot(q.core) == list(qm)
    finally:
        rt.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_quiesce_crash_sweep(backend):
    """Crash at every persist point inside the two-stage quiesce
    protocol (injector sweep, like the fuzz crashpoint scenarios): the
    queue contents must survive, and churn + quiesce must keep working
    afterwards."""
    fired_points = 0
    nth = 1
    while True:
        rt = _rt(backend)
        try:
            q = rt.make("queue", "pwfcomb")
            handles = [rt.attach(p) for p in range(2)]
            qm = deque()
            rng = random.Random(1000 + nth)
            _churn_queue(rt, q, handles, qm, rng, 12)   # pending limbo
            rt.nvm.arm_injector(CrashPointInjector("any", nth))
            fired = False
            try:
                q.core.quiesce()
            except SimulatedCrash:
                fired = True
            if not fired:
                rt.nvm.disarm_injector()
                break
            fired_points += 1
            rt.recover()
            assert q.adapter.snapshot(q.core) == list(qm)
            _churn_queue(rt, q, handles, qm, rng, 12)
            q.core.quiesce()
            assert q.adapter.snapshot(q.core) == list(qm)
        finally:
            rt.close()
        nth += 1
    # two persist_lines + two psyncs: the sweep must have found at
    # least the stage-1 and stage-2 boundaries
    assert fired_points >= 2


# --------------------------------------------------------------------- #
# PerThreadFreeList shared-overflow regression                          #
# --------------------------------------------------------------------- #
def test_free_list_overflow_recycles_across_threads():
    """Asymmetric roles (thread 0 allocates, thread 1 frees): the pure
    per-thread scheme would allocate fresh chunks forever; the shared
    overflow bounds fresh allocation to the freeing thread's private
    cap."""
    fl = PerThreadFreeList(2, cap=8)
    nvm = NVM(1 << 14)
    pool = NodePool(nvm, 2, fl, chunk_nodes=4)
    chunk_allocs = 0
    orig = pool.chunks.alloc

    def counting(p):
        nonlocal chunk_allocs
        chunk_allocs += 1
        return orig(p)

    pool.chunks.alloc = counting
    addrs = [pool.alloc(0) for _ in range(100)]
    for a in addrs:
        pool.free(1, a)
    before = chunk_allocs
    again = [pool.alloc(0) for _ in range(100)]
    # fresh node allocations are bounded by the cap nodes stranded in
    # thread 1's private list — the pure per-thread scheme would need
    # 100 here
    assert chunk_allocs - before <= 8, chunk_allocs - before
    assert len(set(again) & set(addrs)) >= 92


# --------------------------------------------------------------------- #
# shm segment lifecycle                                                 #
# --------------------------------------------------------------------- #
def _dead_pid():
    p = multiprocessing.get_context("fork").Process(target=lambda: None)
    p.start()
    p.join()
    return p.pid


def _fake_orphan():
    """A /dev/shm psc-* file stamped with a dead owner pid."""
    path = f"/dev/shm/{shm_mod._SEG_PREFIX}{_dead_pid()}-0"
    with open(path, "wb") as f:
        f.write(b"\0" * 64)
    return path


def _orphan_child(q):
    be = shm_mod.ShmBackend(data_words=1 << 8, aux_i64=1 << 8,
                            ring_i64=1 << 10)
    q.put(be.name)
    time.sleep(60)       # parent SIGKILLs us long before this returns


@pytest.mark.skipif(not os.path.isdir("/dev/shm"), reason="no /dev/shm")
def test_reap_orphan_segments_after_sigkill():
    ctx = multiprocessing.get_context("fork")
    mq = ctx.Queue()
    p = ctx.Process(target=_orphan_child, args=(mq,))
    p.start()
    try:
        name = mq.get(timeout=30)
        assert os.path.exists(f"/dev/shm/{name}")
    finally:
        os.kill(p.pid, signal.SIGKILL)
        p.join()
    reaped = shm_mod.reap_orphan_segments()
    assert name in reaped
    assert not os.path.exists(f"/dev/shm/{name}")


@pytest.mark.skipif(not os.path.isdir("/dev/shm"), reason="no /dev/shm")
def test_reap_never_touches_live_or_foreign_segments():
    be = shm_mod.ShmBackend(data_words=1 << 8, aux_i64=1 << 8,
                            ring_i64=1 << 10)
    try:
        assert shm_mod.reap_orphan_segments() == []   # owner (us) alive
        assert os.path.exists(f"/dev/shm/{be.name}")
        # atexit sweep in a forked child must skip inherited entries
        saved = dict(shm_mod._LIVE_SEGMENTS)
        shm_mod._LIVE_SEGMENTS.clear()
        shm_mod._LIVE_SEGMENTS[be.name] = (os.getpid() + 1, be._shm)
        try:
            shm_mod._reap_at_exit()
            assert os.path.exists(f"/dev/shm/{be.name}")
        finally:
            shm_mod._LIVE_SEGMENTS.clear()
            shm_mod._LIVE_SEGMENTS.update(saved)
    finally:
        be.close()
    assert not os.path.exists(f"/dev/shm/{be.name}")


@pytest.mark.skipif(not os.path.isdir("/dev/shm"), reason="no /dev/shm")
def test_forked_child_close_keeps_parent_segment():
    be = shm_mod.ShmBackend(data_words=1 << 8, aux_i64=1 << 8,
                            ring_i64=1 << 10)
    try:
        p = multiprocessing.get_context("fork").Process(target=be.close)
        p.start()
        p.join()
        assert p.exitcode == 0
        assert os.path.exists(f"/dev/shm/{be.name}")
    finally:
        be.close()
    assert not os.path.exists(f"/dev/shm/{be.name}")


@pytest.mark.skipif(not os.path.isdir("/dev/shm"), reason="no /dev/shm")
def test_runtime_recover_reaps_orphans():
    path = _fake_orphan()
    rt = _rt("shm")
    try:
        q = rt.make("queue", "pwfcomb")
        h = rt.attach(0)
        assert h.invoke(q, "enqueue", 1) == "ACK"
        rt.crash(random.Random(0))
        rt.recover()
        assert not os.path.exists(path)
        assert q.adapter.snapshot(q.core) == [1]
    finally:
        rt.close()


# --------------------------------------------------------------------- #
# blob-heap GC under churn                                              #
# --------------------------------------------------------------------- #
def test_blob_gc_preserves_values_under_overwrite_churn():
    nvm = ShmNVM(1 << 12)
    try:
        rng = random.Random(7)
        n_slots = 12
        base = nvm.alloc(n_slots)
        mirror = {}
        for _ in range(5):
            for i in range(n_slots):
                if rng.random() < 0.7:
                    payload = bytes(rng.randrange(256)
                                    for _ in range(rng.randrange(64, 512)))
                    nvm.write(base + i, payload)
                    nvm.pwb(base + i, 1)
                    mirror[i] = payload
            nvm.psync()
            out = nvm.gc_blobs()
            assert out["moved_chunks"] >= 0
            for i, v in mirror.items():
                assert nvm.read(base + i) == v
                assert nvm.durable_read(base + i) == v
            assert nvm.blob_leak_check()["excess_rc"] == 0
    finally:
        nvm.close()


def test_gc_blobs_requires_drained_rings():
    nvm = ShmNVM(1 << 12)
    try:
        a = nvm.alloc(1)
        nvm.write(a, b"x" * 256)
        nvm.pwb(a, 1)                 # ring entry pending, no psync
        with pytest.raises(RuntimeError):
            nvm.gc_blobs()
        nvm.psync()
        nvm.gc_blobs()                # fine once drained
    finally:
        nvm.close()


if st is not None:

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.binary(min_size=0, max_size=256),
                    min_size=1, max_size=16))
    def test_blob_gc_roundtrip_property(payloads):
        nvm = ShmNVM(1 << 10)
        try:
            base = nvm.alloc(len(payloads))
            for i, v in enumerate(payloads):
                nvm.write(base + i, v)
                nvm.pwb(base + i, 1)
            nvm.psync()
            nvm.gc_blobs()
            for i, v in enumerate(payloads):
                assert nvm.read(base + i) == v
                assert nvm.durable_read(base + i) == v
            assert nvm.blob_leak_check()["excess_rc"] == 0
        finally:
            nvm.close()

else:  # pragma: no cover - hypothesis not installed

    def test_blob_gc_roundtrip_property():
        pytest.importorskip("hypothesis")
