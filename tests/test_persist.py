"""PBComb checkpointer + sharded commit: torn-checkpoint impossibility,
detectability, combining of concurrent announcements, lease takeover."""

import random
import threading

import numpy as np
import pytest

from repro.persist import staterec
from repro.persist.checkpoint import PBCombCheckpointer
from repro.persist.sharded import (NaiveShardedCheckpointer,
                                   ShardedCheckpointer)
from repro.persist.store import DirStore, MemStore


def _payload(step):
    return {"w": np.full((8, 8), float(step), np.float32),
            "step": np.asarray(step, np.int32)}


TEMPLATE = _payload(0)


def test_staterec_roundtrip():
    buf = staterec.pack(_payload(3), ["a", None], [1, 0])
    payload, rv, da = staterec.unpack(buf, TEMPLATE)
    assert int(payload["step"]) == 3
    assert rv == ["a", None] and da == [1, 0]
    np.testing.assert_array_equal(payload["w"], _payload(3)["w"])


def test_staterec_bf16_roundtrip():
    import jax.numpy as jnp
    p = {"w": jnp.ones((4, 4), jnp.bfloat16) * 1.5}
    buf = staterec.pack(p, [None], [0])
    out, _, _ = staterec.unpack(buf, p)
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.full((4, 4), 1.5, np.float32))


def test_checkpoint_announce_combine_recover():
    store = MemStore()
    ck = PBCombCheckpointer(store, 2, TEMPLATE)
    ck.initialize(_payload(0))
    ck.announce(0, _payload(5), seq=1)
    ck.announce(1, _payload(5), seq=1)
    served = ck.combine_once()
    assert served == 2
    assert store.counters["psync"] >= 1
    payload = ck.recover()
    assert int(payload["step"]) == 5
    assert ck.was_applied(0, 1) and ck.was_applied(1, 1)
    assert ck.response(0) == 1


def test_checkpoint_combining_reduces_psyncs():
    """P1: k announcements served by one round -> one psync (vs k for a
    per-announcer scheme)."""
    store = MemStore()
    ck = PBCombCheckpointer(store, 8, TEMPLATE)
    ck.initialize(_payload(0))
    base = store.counters["psync"]
    for p in range(8):
        ck.announce(p, _payload(7), seq=1)
    ck.combine_once()
    assert store.counters["psync"] - base == 1


@pytest.mark.parametrize("seed", range(12))
def test_checkpoint_crash_never_torn(seed):
    """Crash with adversarial drain at any point: recovery sees either
    the old or the new checkpoint — never a torn one — and the
    deactivate bits agree with the payload that survived."""
    store = MemStore()
    ck = PBCombCheckpointer(store, 2, TEMPLATE)
    ck.initialize(_payload(0))
    ck.announce(0, _payload(1), seq=1)
    ck.combine_once()                      # committed step 1
    ck.announce(0, _payload(2), seq=2)
    ck.announce(1, _payload(2), seq=1)
    # run the round but crash the store adversarially before/after psync:
    # emulate by doing the slot pwb + fence, then crashing mid-queue.
    rng = random.Random(seed)
    # interleave: sometimes allow full combine, sometimes crash first
    if rng.random() < 0.5:
        ck.combine_once()
    store.crash(rng)
    ck2 = PBCombCheckpointer(store, 2, TEMPLATE)
    payload = ck2.recover()
    step = int(payload["step"])
    assert step in (1, 2)
    np.testing.assert_array_equal(payload["w"],
                                  np.full((8, 8), float(step), np.float32))
    # detectability consistent with surviving payload
    if step == 2:
        assert ck2.was_applied(0, 2)
        assert ck2.response(0) == 2
    else:
        assert ck2.was_applied(0, 1)
        assert not ck2.was_applied(0, 2)


def test_checkpoint_lease_takeover():
    store = MemStore()
    ck = PBCombCheckpointer(store, 2, TEMPLATE, lease_s=0.01)
    ck.initialize(_payload(0))
    # no combiner thread running — announcer takes over after the lease
    rec = ck.announce(0, _payload(9), seq=1, wait=True, timeout=0.05)
    assert rec.done_event.is_set()
    assert int(ck.recover()["step"]) == 9


def test_nvmstore_roundtrip_and_epoch_semantics():
    """NVMStore: the Store facade over simulated NVM words — pwb stages
    (not durable), psync makes durable, crash drops the staged epoch."""
    from repro.core import NVM
    from repro.persist.store import NVMStore

    store = NVMStore(NVM(1 << 14))
    store.pwb("a", b"one")
    assert store.read("a") is None          # staged, not durable
    store.pfence()
    store.pwb("b", b"two")
    store.psync()
    assert store.read("a") == b"one" and store.read("b") == b"two"
    store.pwb("a", b"three")
    store.crash(None)                       # drain-nothing cut
    store.nvm.disarm_crash()
    assert store.read("a") == b"one"        # staged write lost
    assert store.counters["psync"] >= 1


def test_checkpointer_over_shm_nvm():
    """PBCombCheckpointer wired through a shared-memory NVM
    (``over_nvm``): slot files live in the shm blob heap, psyncs land
    on the chosen segment's device, recovery + detectability survive a
    machine crash, and a crash mid-commit leaves old-or-new (the
    torn-checkpoint impossibility, now over NVMStore)."""
    from repro.core import SimulatedCrash
    from repro.core.shm import ShmNVM

    nvm = ShmNVM(1 << 14, segments=2)
    try:
        ck = PBCombCheckpointer.over_nvm(nvm, 3, TEMPLATE, segment=1)
        ck.initialize(_payload(0))
        ck.announce(0, _payload(7), 1)
        ck.announce(1, _payload(7), 1)
        assert ck.combine_once() == 2
        assert ck.was_applied(0, 1) and ck.was_applied(1, 1)
        segs = nvm.segment_counters()
        assert segs[1]["psync"] >= 1 and segs[0]["psync"] == 0
        nvm.crash(random.Random(3))
        nvm.disarm_crash()
        payload = ck.recover()
        np.testing.assert_array_equal(payload["w"], _payload(7)["w"])
        assert ck.was_applied(0, 1) and ck.was_applied(1, 1)
        # crash mid-commit: recovery reads the index-named slot — the
        # previous checkpoint, never a torn one
        ck.announce(2, _payload(20), 1)
        nvm.arm_crash(1)
        with pytest.raises(SimulatedCrash):
            ck.combine_once()
        nvm.disarm_crash()
        payload = ck.recover()
        assert int(payload["step"]) in (7, 20)
    finally:
        nvm.close()


def test_dirstore_roundtrip(tmp_path):
    store = DirStore(str(tmp_path))
    ck = PBCombCheckpointer(store, 1, TEMPLATE)
    ck.initialize(_payload(0))
    ck.announce(0, _payload(4), seq=1)
    ck.combine_once()
    # fresh process: new objects over the same directory
    store2 = DirStore(str(tmp_path))
    ck2 = PBCombCheckpointer(store2, 1, TEMPLATE)
    payload = ck2.recover()
    assert int(payload["step"]) == 4
    assert ck2.was_applied(0, 1)


# ------------------------- sharded ----------------------------------- #
def test_sharded_commit_all_or_nothing():
    store = MemStore()
    tmpl = [_payload(0), _payload(0), _payload(0)]
    ck = ShardedCheckpointer(store, 3, tmpl)
    for h in range(3):
        ck.write_shard(h, _payload(1), step=1)
    assert ck.try_commit(1)
    # next round: only 2 of 3 hosts write, then crash
    ck.write_shard(0, _payload(2), step=2)
    ck.write_shard(1, _payload(2), step=2)
    assert not ck.try_commit(2)            # combiner refuses partial round
    store.crash(random.Random(0))
    ck2 = ShardedCheckpointer(store, 3, tmpl)
    shards, step = ck2.recover()
    assert step == 1                        # the torn round is invisible
    for s in shards:
        assert int(s["step"]) == 1


def test_sharded_takeover_commit():
    store = MemStore()
    tmpl = [_payload(0), _payload(0)]
    ck = ShardedCheckpointer(store, 2, tmpl, lease_s=0.0)
    for h in range(2):
        ck.write_shard(h, _payload(3), step=3)
    assert ck.lease_expired()
    assert ck.takeover_commit(3)           # any host commits
    _, step = ck.recover()
    assert step == 3


def test_naive_sharded_can_tear_but_is_detected():
    """The baseline (per-host psync, no combining) CAN leave hosts at
    different steps after a crash — which our recover() flags with a
    negative step.  This is the failure mode the combining design
    removes."""
    store = MemStore()
    tmpl = [_payload(0), _payload(0)]
    ck = NaiveShardedCheckpointer(store, 2, tmpl)
    ck.write_shard(0, _payload(1), step=1)
    ck.write_shard(1, _payload(1), step=1)
    ck.write_shard(0, _payload(2), step=2)   # host 1 crashes before step 2
    store.crash(random.Random(1))
    shards, step = ck.recover()
    assert step == 1 or step < 0             # either lucky or torn-detected
