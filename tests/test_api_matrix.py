"""Unified runtime/handle API: the same workload + crash/recover script
over every (kind, protocol) pair in the registry.

This is the tentpole invariant of the API: protocols are interchangeable
behind one interface, so one test body covers PBcomb, PWFcomb, the
lock/undo-log baselines, DFC and the durable MS queue — and detectable
protocols additionally get exactly-once in-flight replay checked
(FetchAdd multiset linearizability)."""

import random
import threading

import pytest

from repro.api import (CombiningRuntime, entries, get_adapter,
                       make_recoverable)
from repro.core import SimulatedCrash

N = 3
OPS = 30


def _ops_for(kind, bound, p):
    """A small mixed workload through the typed sugar."""
    if kind == "queue":
        return lambda i: (bound.enqueue(p * 100000 + i), bound.dequeue())
    if kind == "stack":
        return lambda i: (bound.push(p * 100000 + i), bound.pop())
    if kind == "heap":
        return lambda i: (bound.insert(p * 100000 + i),
                          bound.delete_min())
    if kind == "log":
        return lambda i: (bound.record((p, i + 1, ("resp", p, i + 1))),
                          bound.lookup(p))
    if kind == "ckpt":
        return lambda i: (bound.persist((i + 1, {"step": i + 1, "w": p})),
                          bound.latest())
    return lambda i: (bound.fetch_add(1), bound.read())


@pytest.mark.parametrize("kind,protocol", entries())
def test_workload_crash_recover_state_equality(kind, protocol):
    """attach -> ops -> crash -> recover -> verify, identical for every
    registry entry: post-recovery state equals the pre-crash state (all
    completed ops were made durable before returning — the repo-wide
    'respond only after psync' rule)."""
    rt = CombiningRuntime(n_threads=N)
    obj = rt.make(kind, protocol)

    def worker(p):
        step = _ops_for(kind, rt.attach(p).bind(obj), p)
        for i in range(OPS):
            step(i)

    ts = [threading.Thread(target=worker, args=(p,)) for p in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    pre = obj.snapshot()
    rt.crash(random.Random(7))               # adversarial drain
    rt.recover()                             # one call, whole machine
    assert obj.snapshot() == pre
    # the structure stays fully usable after recovery
    b = rt.attach(0).bind(obj)
    if kind == "queue":
        b.enqueue("post")
        assert "post" in obj.snapshot()
    elif kind == "stack":
        b.push("post")
        assert b.pop() == "post"
    elif kind == "heap":
        b.insert(-1)
        assert b.get_min() == -1
    elif kind == "log":
        b.record((0, 999, "post"))
        assert b.lookup(0) == (999, "post")
    elif kind == "ckpt":
        big = 10 ** 6
        b.persist((big, {"step": big, "w": 0}))
        assert b.latest() == (big, {"step": big, "w": 0})
    else:
        assert b.fetch_add(1) == pre


# exactly-once replay is claimed only where the adapter claims it (and
# announce/perform lets the test stage a multi-request round)
DETECTABLE = [e for e in entries()
              if get_adapter(*e).detectable and get_adapter(*e).can_announce]


#: per-kind announce op + per-thread args for the in-flight crash test
_ANNOUNCE = {"queue": ("enqueue", lambda p: f"v{p}"),
             "stack": ("push", lambda p: f"v{p}"),
             "heap": ("insert", lambda p: f"v{p}"),
             "counter": ("fetch_add", lambda p: 1),
             "log": ("record", lambda p: (p, 1, f"r{p}")),
             "ckpt": ("persist", lambda p: (p + 1, {"step": p + 1,
                                                    "w": p}))}


@pytest.mark.parametrize("kind,protocol", DETECTABLE)
@pytest.mark.parametrize("crash_at", [0, 2, 4, 6])
def test_inflight_crash_replay_exactly_once(kind, protocol, crash_at):
    """Crash inside a combining round serving N announced requests, then
    recover the whole machine with one call: every in-flight op applied
    exactly once (for the idempotent log/ckpt structures: exactly once
    in effect), every response correct."""
    rt = CombiningRuntime(n_threads=N)
    obj = rt.make(kind, protocol)
    handles = [rt.attach(p) for p in range(N)]
    add, argfn = _ANNOUNCE[kind]
    # a committed prefix through the normal path (container kinds)
    base = 0 if kind == "counter" else "base"
    if kind == "counter":
        assert handles[0].invoke(obj, add, 1) == 0
    elif kind in ("queue", "stack", "heap"):
        handles[0].invoke(obj, add, base)
    # N announced in-flight ops; the performing thread crashes mid-round
    for p in range(N):
        handles[p].announce(obj, add, argfn(p))
    rt.arm_crash(crash_at, random.Random(13))
    rets = {}
    try:
        # with a late crash point the round may complete: the performer's
        # response then comes from perform, everyone else's from recover
        rets[1] = handles[1].perform(obj)
    except SimulatedCrash:
        pass
    replies = rt.recover()
    for p in range(N):
        if (obj.name, p) in replies:
            rets[p] = replies[(obj.name, p)]
    assert len(rets) == N
    if kind == "counter":
        # FetchAdd multiset linearizability: the N replayed FAA(1)
        # responses are exactly {1..N} (0 went to the prefix op) and the
        # final value is N+1 — any lost or duplicated application breaks
        # this.
        assert sorted(rets.values()) == list(range(1, N + 1))
        assert obj.snapshot() == N + 1
    elif kind == "heap":
        assert all(r is True for r in rets.values())
        assert obj.snapshot() == sorted([base] + [f"v{p}"
                                                  for p in range(N)])
    elif kind == "log":
        assert rets == {p: f"r{p}" for p in range(N)}
        assert obj.snapshot() == [(1, f"r{p}") for p in range(N)]
    elif kind == "ckpt":
        # newest step wins; every response is a step >= the announcer's
        # own (monotone), and the durable pair is the max step's
        assert all(p + 1 <= rets[p] <= N for p in range(N))
        assert obj.snapshot() == {"step": N,
                                  "payload": {"step": N, "w": N - 1}}
    else:
        assert all(r == "ACK" for r in rets.values())
        content = obj.snapshot()
        assert sorted(content) == sorted([base] + [f"v{p}"
                                                   for p in range(N)])


def test_make_recoverable_standalone():
    """The one-liner factory: a fresh runtime rides along on the object."""
    q = make_recoverable("queue", "pwfcomb", n_threads=2)
    h = q.runtime.attach(0)
    bq = h.bind(q)
    bq.enqueue(1)
    bq.enqueue(2)
    assert bq.dequeue() == 1
    q.runtime.crash()
    q.runtime.recover()
    assert q.snapshot() == [2]


def test_bound_proxy_inflight_survives_recover():
    """Bound proxies capture the runtime's in-flight dict at bind time;
    recover() must clear it IN PLACE — a proxy created before a recover
    still records (and replays) ops crashed after it."""
    rt = CombiningRuntime(n_threads=1)
    q = rt.make("queue", "pbcomb")
    bq = rt.attach(0).bind(q)
    bq.enqueue("a")
    rt.crash()
    rt.recover()
    rt.arm_crash(1, random.Random(5))
    try:
        bq.enqueue("b")               # same pre-recover proxy
    except SimulatedCrash:
        pass
    replies = rt.recover()
    assert replies[(q.name, 0)] == "ACK"
    assert q.snapshot() == ["a", "b"]


def test_unknown_pair_raises():
    rt = CombiningRuntime(n_threads=2)
    with pytest.raises(ValueError, match="no recoverable implementation"):
        rt.make("queue", "dfc")
    with pytest.raises(ValueError, match="no op"):
        b = rt.make("stack", "pbcomb")
        rt.attach(0).invoke(b, "enqueue", 1)


def test_handle_seq_groups_are_per_instance():
    """The split queues keep independent enqueue/dequeue parities: a
    workload alternating unevenly between the two instances must stay
    recoverable (parity = per-instance op count mod 2)."""
    rt = CombiningRuntime(n_threads=2)
    q = rt.make("queue", "pbcomb")
    h = rt.attach(0)
    bq = h.bind(q)
    bq.enqueue("a")
    assert bq.dequeue() == "a"               # deq count 1, enq count 1
    bq.enqueue("b")                          # enq count 2
    # in-flight dequeue crashes mid-round; parity check must see the
    # *dequeue* instance's count, not the global op count
    h.announce(q, "dequeue")
    rt.arm_crash(1, random.Random(3))
    try:
        h.perform(q)
    except SimulatedCrash:
        pass
    replies = rt.recover()
    assert replies[(q.name, 0)] == "b"
    assert q.snapshot() == []


def test_invoke_many_single_round_persist():
    """The batched path: all calls of an invoke_many on a batching
    adapter land in ONE combining round (engine response-log path is
    covered end-to-end in test_serving)."""
    from repro.persist.checkpoint import (CheckpointAdapter,
                                          PBCombCheckpointer)
    from repro.persist.store import MemStore
    store = MemStore()
    ck = PBCombCheckpointer(store, 4, payload_template={})
    ck.initialize({})
    rt = CombiningRuntime(n_threads=4)
    log = rt.register("log", ck, CheckpointAdapter())
    h = rt.attach(0)
    base = store.counters["psync"]
    outs = h.invoke_many([(log, "record", c, 1, f"resp{c}")
                          for c in range(4)])
    assert outs == [f"resp{c}" for c in range(4)]
    assert store.counters["psync"] - base == 1     # one round, one psync
    assert all(ck.was_applied(c, 1) for c in range(4))
