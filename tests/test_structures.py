"""Recoverable stacks/queues/heap + baselines (paper Section 5)."""

import random
import threading

import pytest

from repro.core import NVM
from repro.structures import (DFCStack, DurableMSQueue, PBHeap, PBQueue,
                              PBStack, PWFQueue, PWFStack)

N = 5
OPS = 80


def _pairs_workload(push, pop, drain):
    pushed, popped = [[] for _ in range(N)], [[] for _ in range(N)]

    def worker(p):
        seq = 0
        rng = random.Random(p)
        for i in range(OPS):
            v = p * 100000 + i
            seq += 1
            push(p, v, seq)
            pushed[p].append(v)
            for _ in range(rng.randint(0, 25)):
                pass
            seq += 1
            r = pop(p, seq)
            if r is not None:
                popped[p].append(r)
    ts = [threading.Thread(target=worker, args=(p,)) for p in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    all_pushed = sorted(v for vs in pushed for v in vs)
    all_popped = [v for vs in popped for v in vs]
    rest = list(drain())
    assert sorted(all_popped + rest) == all_pushed      # no loss, no dup


@pytest.mark.parametrize("cls,kwargs", [
    (PBStack, {}), (PBStack, {"elimination": False}),
    (PBStack, {"recycle": False}), (PWFStack, {}),
    (PWFStack, {"elimination": False}),
])
def test_stack_no_loss_no_dup(cls, kwargs):
    nvm = NVM(1 << 21)
    s = cls(nvm, N, **kwargs)
    _pairs_workload(s.push, s.pop, s.drain)


@pytest.mark.parametrize("cls,kwargs", [
    (PBQueue, {}), (PBQueue, {"recycle": False}), (PWFQueue, {}),
])
def test_queue_no_loss_no_dup(cls, kwargs):
    nvm = NVM(1 << 21)
    q = cls(nvm, N, **kwargs)
    _pairs_workload(q.enqueue, q.dequeue, q.drain)


@pytest.mark.parametrize("cls", [PBQueue, PWFQueue, DurableMSQueue])
def test_queue_fifo(cls):
    nvm = NVM()
    q = cls(nvm, 2)
    seq = 0
    for i in range(20):
        seq += 1
        q.enqueue(0, i, seq)
    outs = []
    for _ in range(20):
        seq += 1
        outs.append(q.dequeue(0, seq))
    assert outs == list(range(20))


@pytest.mark.parametrize("cls", [PBStack, PWFStack, DFCStack])
def test_stack_lifo(cls):
    nvm = NVM()
    s = cls(nvm, 2)
    seq = 0
    for i in range(10):
        seq += 1
        if cls is DFCStack:
            s.op(0, "PUSH", i, seq)
        else:
            s.push(0, i, seq)
    outs = []
    for _ in range(10):
        seq += 1
        outs.append(s.op(0, "POP", None, seq) if cls is DFCStack
                    else s.pop(0, seq))
    assert outs == list(range(9, -1, -1))


def test_pop_empty_returns_none():
    nvm = NVM()
    s = PBStack(nvm, 2)
    assert s.pop(0, 1) is None
    q = PBQueue(nvm, 2)
    assert q.dequeue(0, 1) is None


def test_heap_sorts():
    nvm = NVM()
    h = PBHeap(nvm, 2, capacity=128)
    keys = random.Random(0).sample(range(1000), 60)
    seq = 0
    for k in keys:
        seq += 1
        h.insert(0, k, seq)
    seq += 1
    assert h.get_min(0, seq) == min(keys)
    outs = []
    for _ in keys:
        seq += 1
        outs.append(h.delete_min(0, seq))
    assert outs == sorted(keys)


def test_heap_threaded():
    nvm = NVM()
    h = PBHeap(nvm, N, capacity=N * OPS + 1)
    inserted = [[] for _ in range(N)]
    removed = [[] for _ in range(N)]

    def worker(p):
        seq = 0
        rng = random.Random(p)
        for i in range(40):
            k = rng.randint(0, 10 ** 6)
            seq += 1
            if h.insert(p, k, seq):
                inserted[p].append(k)
            seq += 1
            r = h.delete_min(p, seq)
            if r is not None:
                removed[p].append(r)
    ts = [threading.Thread(target=worker, args=(p,)) for p in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    all_in = sorted(k for ks in inserted for k in ks)
    all_out = [k for ks in removed for k in ks]
    rest = []
    seq = 10 ** 6
    while True:
        seq += 1
        r = h.delete_min(0, seq)
        if r is None:
            break
        rest.append(r)
    assert sorted(all_out + rest) == all_in


def test_stack_recycling_reuses_nodes():
    nvm = NVM()
    s = PBStack(nvm, 2, recycle=True, chunk_nodes=4)
    seq = 1
    s.push(0, 0, seq)
    first_chunk_limit = s.pool.chunks._limit[0]
    seq += 1
    s.pop(0, seq)
    for i in range(50):                      # push/pop far beyond a chunk
        seq += 1
        s.push(0, i, seq)
        seq += 1
        s.pop(0, seq)
    # recycling kept allocation inside the FIRST chunk
    assert s.pool.chunks._limit[0] == first_chunk_limit
    assert len(s.pool.recycler) >= 1


def test_queue_oldtail_guard():
    """A dequeuer never observes a value whose enqueue round has not yet
    published oldTail (single-threaded: oldTail always caught up, so
    values flow; the guard logic is exercised under threads in
    test_queue_no_loss_no_dup)."""
    nvm = NVM()
    q = PBQueue(nvm, 2)
    q.enqueue(0, "a", 1)
    assert q.old_tail != q.dummy
    assert q.dequeue(0, 2) == "a"
