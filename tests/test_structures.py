"""Recoverable stacks/queues/heap + baselines (paper Section 5).

Everything goes through the unified ``repro.api`` surface — the old
per-structure calling conventions (``s.push(p, v, seq)``) were removed
after their one-PR deprecation cycle (DESIGN.md §1).  Protocol-level
internals (node pools, ``old_tail``) are still reachable via
``obj.core`` where an invariant needs them.
"""

import random
import threading

import pytest

from repro.api import CombiningRuntime

N = 5
OPS = 80


def _make(kind, protocol, n_threads=N, **kw):
    rt = CombiningRuntime(n_threads=n_threads, nvm_words=1 << 21)
    return rt, rt.make(kind, protocol, **kw)


def _pairs_workload(rt, obj, add, rem):
    pushed, popped = [[] for _ in range(N)], [[] for _ in range(N)]

    def worker(p):
        b = rt.attach(p).bind(obj)
        addf, remf = getattr(b, add), getattr(b, rem)
        rng = random.Random(p)
        for i in range(OPS):
            v = p * 100000 + i
            addf(v)
            pushed[p].append(v)
            for _ in range(rng.randint(0, 25)):
                pass
            r = remf()
            if r is not None:
                popped[p].append(r)
    ts = [threading.Thread(target=worker, args=(p,)) for p in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    all_pushed = sorted(v for vs in pushed for v in vs)
    all_popped = [v for vs in popped for v in vs]
    rest = list(obj.snapshot())
    assert sorted(all_popped + rest) == all_pushed      # no loss, no dup


@pytest.mark.parametrize("protocol,kwargs", [
    ("pbcomb", {}), ("pbcomb", {"elimination": False}),
    ("pbcomb", {"recycle": False}), ("pwfcomb", {}),
    ("pwfcomb", {"elimination": False}),
])
def test_stack_no_loss_no_dup(protocol, kwargs):
    rt, s = _make("stack", protocol, **kwargs)
    _pairs_workload(rt, s, "push", "pop")


@pytest.mark.parametrize("protocol,kwargs", [
    ("pbcomb", {}), ("pbcomb", {"recycle": False}), ("pwfcomb", {}),
])
def test_queue_no_loss_no_dup(protocol, kwargs):
    rt, q = _make("queue", protocol, **kwargs)
    _pairs_workload(rt, q, "enqueue", "dequeue")


@pytest.mark.parametrize("protocol", ["pbcomb", "pwfcomb", "durable-ms"])
def test_queue_fifo(protocol):
    rt, q = _make("queue", protocol, n_threads=2)
    b = rt.attach(0).bind(q)
    for i in range(20):
        b.enqueue(i)
    assert [b.dequeue() for _ in range(20)] == list(range(20))


@pytest.mark.parametrize("protocol", ["pbcomb", "pwfcomb", "dfc"])
def test_stack_lifo(protocol):
    rt, s = _make("stack", protocol, n_threads=2)
    b = rt.attach(0).bind(s)
    for i in range(10):
        b.push(i)
    assert [b.pop() for _ in range(10)] == list(range(9, -1, -1))


def test_pop_empty_returns_none():
    rt, s = _make("stack", "pbcomb", n_threads=2)
    assert rt.attach(0).bind(s).pop() is None
    rt2, q = _make("queue", "pbcomb", n_threads=2)
    assert rt2.attach(0).bind(q).dequeue() is None


def test_heap_sorts():
    rt, h = _make("heap", "pbcomb", n_threads=2, capacity=128)
    b = rt.attach(0).bind(h)
    keys = random.Random(0).sample(range(1000), 60)
    for k in keys:
        b.insert(k)
    assert b.get_min() == min(keys)
    assert [b.delete_min() for _ in keys] == sorted(keys)


def test_heap_threaded():
    rt, h = _make("heap", "pbcomb", capacity=N * OPS + 1)
    inserted = [[] for _ in range(N)]
    removed = [[] for _ in range(N)]

    def worker(p):
        b = rt.attach(p).bind(h)
        rng = random.Random(p)
        for i in range(40):
            k = rng.randint(0, 10 ** 6)
            if b.insert(k):
                inserted[p].append(k)
            r = b.delete_min()
            if r is not None:
                removed[p].append(r)
    ts = [threading.Thread(target=worker, args=(p,)) for p in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    all_in = sorted(k for ks in inserted for k in ks)
    all_out = [k for ks in removed for k in ks]
    b = rt.attach(0).bind(h)
    rest = []
    while True:
        r = b.delete_min()
        if r is None:
            break
        rest.append(r)
    assert sorted(all_out + rest) == all_in


def test_stack_recycling_reuses_nodes():
    rt, s = _make("stack", "pbcomb", n_threads=2, recycle=True,
                  chunk_nodes=4)
    core = s.core
    b = rt.attach(0).bind(s)
    b.push(0)
    first_chunk_limit = core.pool.chunks._limit[0]
    b.pop()
    for i in range(50):                      # push/pop far beyond a chunk
        b.push(i)
        b.pop()
    # recycling kept allocation inside the FIRST chunk
    assert core.pool.chunks._limit[0] == first_chunk_limit
    assert len(core.pool.recycler) >= 1


def test_stack_elimination_pairs_push_pop_in_round():
    """The paper's elimination pass (Figure 7a): a round serving a
    concurrent push/pop pair matches them against each other — the pop
    returns the eliminated push's value, the stack state never changes,
    and no node is allocated or persisted for the pair."""
    rt, s = _make("stack", "pbcomb", n_threads=3)
    rt.attach(0).bind(s).push("base")
    h1, h2 = rt.attach(1), rt.attach(2)
    h1.announce(s, "push", "x")
    h2.announce(s, "pop")
    pwb_before = rt.nvm.counters["pwb"]
    assert h2.perform(s) == "x"              # eliminated pair
    assert s.snapshot() == ["base"]          # state untouched
    # the round persisted only StateRec + MIndex — no node lines
    assert rt.nvm.counters["pwb"] - pwb_before <= 3
    # the push is detectable: recovery returns its recorded response
    # without re-applying it
    replies = rt.recover()
    assert replies[(s.name, 1)] == "ACK"
    assert s.snapshot() == ["base"]


def test_queue_oldtail_guard():
    """A dequeuer never observes a value whose enqueue round has not yet
    published oldTail (single-threaded: oldTail always caught up, so
    values flow; the guard logic is exercised under threads in
    test_queue_no_loss_no_dup)."""
    rt, q = _make("queue", "pbcomb", n_threads=2)
    b = rt.attach(0).bind(q)
    b.enqueue("a")
    assert q.core.old_tail != q.core.dummy
    assert b.dequeue() == "a"
