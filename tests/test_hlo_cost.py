"""HLO cost parser: loop folding must recover analytic flop counts
(the raw cost_analysis counts while bodies once — the bug this module
exists to fix)."""

import subprocess
import sys
import textwrap


def _run_sub(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.launch.hlo_cost import HloCost, xla_cost_analysis
    """) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_scan_flops_folded_exactly():
    out = _run_sub("""
        def body(c, w):
            return jnp.tanh(c @ w), None
        W = jnp.zeros((10, 64, 64)); x = jnp.zeros((4, 64))
        def scanned(x, W):
            return jax.lax.scan(body, x, W)[0]
        comp = jax.jit(scanned).lower(x, W).compile()
        t = HloCost(comp.as_text()).totals()
        expected = 10 * 2 * 4 * 64 * 64
        assert t["flops"] == expected, (t["flops"], expected)
        # raw cost_analysis undercounts by the trip count
        raw = xla_cost_analysis(comp)["flops"]
        assert raw < expected / 5, raw
        print("OK folded", t["flops"], "raw", raw)
    """)
    assert "OK folded" in out


def test_grad_of_scan_is_three_matmuls_per_layer():
    out = _run_sub("""
        def body(c, w):
            return jnp.tanh(c @ w), None
        W = jnp.zeros((10, 64, 64)); x = jnp.zeros((4, 64))
        def loss(x, W):
            return jnp.sum(jax.lax.scan(body, x, W)[0] ** 2)
        comp = jax.jit(jax.grad(loss, argnums=1)).lower(x, W).compile()
        t = HloCost(comp.as_text()).totals()
        expected = 3 * 10 * 2 * 4 * 64 * 64   # fwd + 2 bwd matmuls/layer
        assert t["flops"] == expected, (t["flops"], expected)
        print("OK grad", t["flops"])
    """)
    assert "OK grad" in out


def test_collectives_folded_in_loops():
    out = _run_sub("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((8,), ("data",))
        def body(c, w):
            h = jnp.tanh(c @ w)
            return jax.lax.with_sharding_constraint(
                h, NamedSharding(mesh, P())), None
        W = jnp.zeros((10, 64, 64)); x = jnp.zeros((8, 64))
        def loss(x, W):
            return jnp.sum(jax.lax.scan(body, x, W)[0] ** 2)
        xs = NamedSharding(mesh, P("data", None))
        with mesh:
            comp = jax.jit(jax.grad(loss, argnums=1),
                           in_shardings=(xs, None)).lower(x, W).compile()
        t = HloCost(comp.as_text()).totals()
        counts = t["collective_counts"]
        total = sum(counts.values())
        assert total >= 10, counts     # loop-folded, not counted once
        assert t["collective_bytes"] > 0
        print("OK collectives", counts)
    """)
    assert "OK collectives" in out
