"""Combining serving engine: batching, oldTail commit rule, detectable
request recovery, elimination, KV slot recycling, priority admission."""

import threading
import time

import pytest

from repro.serving.engine import CombiningEngine
from repro.serving.kv_cache import SlotAllocator
from repro.serving.scheduler import RequestHeap


def _toy_engine(n=8, slots=8, max_batch=4, slow=0.0, runtime=None):
    def prefill_batch(prompts):
        if slow:
            time.sleep(slow)
        return [max(1, sum(p) % 97) for p in prompts], \
            [list(p) for p in prompts]

    def decode_batch(kvs, last):
        return [(t + 1) % 97 or 1 for t in last]

    return CombiningEngine(n, prefill_batch_fn=prefill_batch,
                           decode_batch_fn=decode_batch, n_kv_slots=slots,
                           max_batch=max_batch, eos_token=-1,
                           runtime=runtime)


def test_generate_and_batching():
    eng = _toy_engine()
    eng.start()
    results = {}

    def client(c):
        results[c] = eng.submit(c, [c, c + 1], max_tokens=6, seq=1)

    ts = [threading.Thread(target=client, args=(c,)) for c in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    eng.stop()
    assert all(len(r["tokens"]) == 6 for r in results.values())
    # combining actually batched: decode served > 1 sequence per round
    assert eng.stats["decode_batched"] > eng.stats["decode_rounds"]
    # one persist round can cover several completions (P1)
    assert eng.stats["persists"] <= 8


def test_engine_over_shm_runtime_nvm_response_log():
    """The engine wired through CombiningRuntime(backend="shm"): its
    durable response log is a registry ``log/pbcomb`` structure whose
    rich token payloads live in the shm blob heap (DESIGN.md §8) —
    completion batching, crash recovery and detectability all work
    unchanged over the shared segment."""
    import random

    from repro.api import CombiningRuntime

    rt = CombiningRuntime(n_threads=4, backend="shm", segments=2)
    try:
        eng = _toy_engine(n=4, slots=4, runtime=rt)
        assert eng.ckpt is None and eng.store is None
        assert eng.log.protocol == "pbcomb" and eng.log.kind == "log"
        eng.start()
        results = {}

        def client(c):
            for seq in (1, 2):
                results[(c, seq)] = eng.submit(c, [c, seq], max_tokens=5,
                                               seq=seq, timeout=60)

        ts = [threading.Thread(target=client, args=(c,))
              for c in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        eng.stop()
        psyncs = rt.nvm.counters["psync"]
        assert psyncs <= 8           # combining amortized completions
        # full machine crash: the shm NVM log survives, detectability
        # answers re-announced requests from it
        rt.crash(random.Random(11))
        eng.restart_after_crash()
        for c in range(4):
            assert eng.recover_request(c, [c, 2], 5, seq=2) \
                == results[(c, 2)]
        applied, resp = eng.cached_response(0, 1)
        assert not applied or resp == results[(0, 1)]
    finally:
        rt.close()


def test_detectable_request_recovery():
    eng = _toy_engine()
    eng.start()
    r1 = eng.submit(3, [1, 2, 3], max_tokens=4, seq=1)
    eng.restart_after_crash()               # volatile state gone
    r2 = eng.recover_request(3, [1, 2, 3], 4, seq=1)
    assert r2 == r1                          # cached response, not re-run
    # an UNSEEN request after recovery re-executes normally
    r3 = eng.recover_request(4, [9], 3, seq=1)
    assert len(r3["tokens"]) == 3
    eng.stop()


def test_elimination_cancel_pairs_with_generate():
    eng = _toy_engine(slots=1, max_batch=1, slow=0.05)
    eng.start()
    got = {}

    def blocker():
        eng.submit(0, [5], max_tokens=20, seq=1)

    def gen():
        got["gen"] = eng.submit(1, [7], max_tokens=10 ** 6, seq=1,
                                timeout=30)

    def canc():
        time.sleep(0.01)
        got["cancel"] = eng.cancel(2, target=(1, 1), seq=1, timeout=30)

    tb = threading.Thread(target=blocker)
    tg = threading.Thread(target=gen)
    tc = threading.Thread(target=canc)
    tb.start()
    time.sleep(0.005)
    tg.start()
    tc.start()
    for t in (tb, tg, tc):
        t.join(30)
    eng.stop()
    assert got["gen"]["cancelled"] is True
    assert got["cancel"]["cancelled_ok"] is True
    assert eng.stats["eliminated"] == 1


def test_slot_allocator_recycles_lifo():
    a = SlotAllocator(4)
    s = [a.alloc() for _ in range(4)]
    assert a.alloc() is None                 # exhausted
    a.free(s[1])
    a.free(s[2])
    assert a.alloc() == s[2]                 # LIFO (recycling stack)
    assert a.alloc() == s[1]
    assert a.stats["recycled_hits"] == 2


def test_request_heap_priority():
    h = RequestHeap()
    h.insert(5.0, "low")
    h.insert(1.0, "urgent")
    h.insert(3.0, "mid")
    assert h.delete_min() == "urgent"
    assert h.delete_min() == "mid"
    assert h.delete_min() == "low"
    assert h.delete_min() is None


def test_property_random_workload_exactly_once():
    """Randomized submit workloads across restarts: every request either
    returns its full generation or its cached response after recovery —
    never a duplicate or a loss."""
    import random as _random
    rng = _random.Random(42)
    eng = _toy_engine(n=6, slots=4, max_batch=3)
    eng.start()
    results = {}

    def client(c, n_reqs):
        for seq in range(1, n_reqs + 1):
            r = eng.submit(c, [c, seq], max_tokens=rng.randint(1, 4),
                           seq=seq, timeout=60)
            results[(c, seq)] = r

    ts = [threading.Thread(target=client, args=(c, 3)) for c in range(6)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    eng.stop()
    assert len(results) == 18
    # crash: every client's LAST completed request must be recoverable
    eng.restart_after_crash()
    for c in range(6):
        cached = eng.ckpt.response(c)
        assert cached is not None
        assert cached == results[(c, cached["seq"])]


def test_priority_admission_under_slot_pressure():
    eng = _toy_engine(slots=1, max_batch=1, slow=0.02)
    eng.start()
    order = []
    lock = threading.Lock()

    def client(c, prio):
        r = eng.submit(c, [c], max_tokens=2, seq=1, priority=prio)
        with lock:
            order.append(c)

    # client 0 occupies the only slot; 1 (low prio) and 2 (high prio)
    # queue; 2 must be admitted first.
    t0 = threading.Thread(target=client, args=(0, 0.0))
    t0.start()
    time.sleep(0.005)
    t1 = threading.Thread(target=client, args=(1, 9.0))
    t2 = threading.Thread(target=client, args=(2, 1.0))
    t1.start()
    t2.start()
    for t in (t0, t1, t2):
        t.join(30)
    eng.stop()
    assert order.index(2) < order.index(1)
