"""Scan-replay engine (kernels/scan_replay.py): the periodic modeled
pass fast-forwarded through a taped ``lax.scan`` is EXACT, not
approximate.

The exactness contract: replay performs the identical IEEE-754 double
operations in the identical order the eager simulator would have, so
every modeled column — us/op, pwbs/op, psyncs/op — is byte-identical
between ``engine="scan"`` and ``engine="eager"``.  Anything the tape
cannot verify as periodic falls back to the eager loop for every round
(aperiodic geometry, audit NVMs, clockless NVMs, runs too short to
amortize the taped window).  ``modeled_matrix`` rides this engine to
gate the full registry at depths the eager simulator could not afford
in CI.
"""

import pytest

from benchmarks import modeled
from repro.api import registry
from repro.core import NVM
from repro.kernels import scan_replay, vector_rounds
from repro.kernels.scan_replay import (ClockTape, _next_pow2,
                                       _replay_python, periodic_run)

#: Every registry cell of a scan-safe (allocation-free) kind.
SCAN_CELLS = [(k, p) for k in sorted(modeled._SCAN_SAFE_KINDS)
              for p in registry.protocols_for(k)]

_MODELED_KEYS = ("modeled_us_per_op", "modeled_pwb_per_op",
                 "modeled_pfence_per_op", "modeled_psync_per_op")


@pytest.mark.parametrize("kind,protocol", SCAN_CELLS)
def test_scan_replay_byte_identical_to_eager(kind, protocol):
    scan = modeled.modeled_cell(kind, protocol, rounds=512, engine="scan")
    eager = modeled.modeled_cell(kind, protocol, rounds=512,
                                 engine="eager")
    for key in _MODELED_KEYS:
        assert scan[key] == eager[key], (key, scan[key], eager[key])
    # the steady state of an allocation-free cell verifies: periods
    # were actually replayed, not eagerly simulated under a new name
    assert scan["replay_engine"] in ("scan", "python")
    if vector_rounds.available():
        assert scan["replay_engine"] == "scan"


def test_engine_auto_split():
    """``auto`` replays allocation-free kinds and leaves node-pool
    kinds (whose chunk-refill periods defeat bounded verification) on
    the eager simulator."""
    safe = modeled.modeled_cell("counter", "pbcomb", rounds=512,
                                engine="auto")
    assert safe["replay_engine"] in ("scan", "python")
    pool = modeled.modeled_cell("queue", "pbcomb", rounds=64,
                                engine="auto")
    assert "replay_engine" not in pool


def test_short_run_falls_back_exactly():
    scan = modeled.modeled_cell("counter", "pbcomb", rounds=10,
                                engine="scan")
    eager = modeled.modeled_cell("counter", "pbcomb", rounds=10,
                                 engine="eager")
    assert scan["replay_engine"] == "eager"
    for key in _MODELED_KEYS:
        assert scan[key] == eager[key]


def test_periodic_run_declines_unsupported_nvms():
    ran = []
    info = periodic_run(NVM(1 << 12), ran.append, 5)   # no virtual clock
    assert info == {"engine": "eager", "reason": "short-or-unsupported"}
    assert ran == list(range(5))

    nvm = NVM(1 << 12, profile="optane", audit=True)   # audit attached
    ran.clear()
    info = periodic_run(nvm, ran.append, 1000)
    assert info == {"engine": "eager", "reason": "short-or-unsupported"}
    assert ran == list(range(1000))


def _persist_round(nvm, r, burst_every):
    """One synthetic modeled round; a psync burst every
    ``burst_every`` rounds sets the geometry's period."""
    with nvm.clock.bind(0):
        nvm.write(0, r)
        nvm.pwb(0)
        nvm.pfence()
        if r % burst_every == 0:
            nvm.psync()


def test_aperiodic_tape_falls_back_exactly():
    """Period-3 geometry matches no candidate period (L..8L powers of
    two): the engine must refuse and run every round eagerly."""
    rounds = 200
    nvm = NVM(1 << 12, profile="optane")
    info = periodic_run(nvm, lambda r: _persist_round(nvm, r, 3), rounds)
    assert info == {"engine": "eager", "reason": "aperiodic"}

    ref = NVM(1 << 12, profile="optane")
    for r in range(rounds):
        _persist_round(ref, r, 3)
    assert dict(nvm.counters) == dict(ref.counters)
    assert nvm.clock.max_time_ns() == ref.clock.max_time_ns()


@pytest.mark.parametrize("rounds", [100, 1000, 4096 + 7])
def test_synthetic_periodic_replay_exact(rounds):
    """Power-of-two geometry verifies; replayed clocks and counters are
    byte-identical to the all-eager run, tail rounds included."""
    nvm = NVM(1 << 12, profile="optane")
    info = periodic_run(nvm, lambda r: _persist_round(nvm, r, 4), rounds)
    assert info["engine"] in ("scan", "python")
    assert info["replayed_periods"] > 0

    ref = NVM(1 << 12, profile="optane")
    for r in range(rounds):
        _persist_round(ref, r, 4)
    assert dict(nvm.counters) == dict(ref.counters)
    assert nvm.clock.max_time_ns() == ref.clock.max_time_ns()
    assert nvm.clock._device_free == ref.clock._device_free


@pytest.mark.skipif(not vector_rounds.available(), reason="no jax")
def test_replay_jax_matches_python_reference():
    """The jitted fori/scan replay computes exactly what the pure-python
    arithmetic reference does on a synthetic event tape."""
    A, M, D, N_, NOOP = (scan_replay._ADV, scan_replay._MRG,
                         scan_replay._DEV, scan_replay._NOW,
                         scan_replay._MRGC_NOOP)
    events = [(N_, 0, 0.0, 0), (A, 0, 3.5, 0), (N_, 1, 0.0, 0),
              (M, 1, 0.0, 2), (D, 1, 7.25, 0), (A, 1, 1.5, 0),
              (M, 0, 0.0, 3), (NOOP, 0, 123.0, 0)]
    times0, device0 = [10.0, 4.0], 6.0
    ring0, nc0 = [9.0, 2.0, 5.5, 1.0], 11
    k = 57
    py_t, py_d = _replay_python(list(times0), device0, list(ring0), nc0,
                                events, k)
    jx = scan_replay._jx()
    jx_t, jx_d = scan_replay._replay_jax(jx, list(times0), device0,
                                         list(ring0), nc0, events, k)
    assert jx_t == py_t
    assert jx_d == py_d


def test_tape_provenance_and_helpers():
    tape = ClockTape()
    t = tape.record_now("a", 5.0)
    assert isinstance(t, scan_replay.TapedTime) and t == 5.0 and t.idx == 0
    tape.record_mrg("b", t, 3.0)                 # taped operand -> _MRG
    tape.record_mrg("b", 2.0, 3.0)               # stale no-op constant
    tape.record_mrg("b", 9.0, 3.0)               # live constant: poison
    tape.mark_round()
    kinds = [e[0] for e in tape.rounds[0]]
    assert kinds == [scan_replay._NOW, scan_replay._MRG,
                     scan_replay._MRGC_NOOP, scan_replay._MRGC_LIVE]
    assert tape.rounds[0][1][3] == 1             # src_rel provenance
    assert [_next_pow2(n) for n in (1, 2, 3, 9)] == [1, 2, 4, 16]


def test_modeled_matrix_rows():
    """The CI-gated full-registry matrix: one deterministic row per
    (kind, protocol) cell, wall columns null, replay engine recorded."""
    rows = modeled.modeled_matrix()
    names = [r["name"] for r in rows]
    assert len(names) == len(set(names))
    expected = {f"modeled_matrix/{k}/{p}" for k in registry.kinds()
                for p in registry.protocols_for(k)}
    assert set(names) == expected
    for r in rows:
        kind = r["name"].split("/")[1]
        assert r["us_per_op"] is None and r["pwbs_per_op"] is None
        assert r["psyncs_per_op"] is None
        assert r["modeled_us_per_op"] > 0
        assert r["modeled_pwbs_per_op"] >= 0
        assert r["profile"] == modeled.DEFAULT_PROFILE
        if kind in modeled._SCAN_SAFE_KINDS:
            assert r["rounds"] == modeled.MATRIX_ROUNDS
            assert r["replay_engine"] in ("scan", "python")
        else:
            assert r["rounds"] == modeled.MATRIX_ROUNDS_EAGER
            assert r["replay_engine"] == "eager"
