"""Randomized crash-injection campaigns on the recoverable structures:
durable linearizability + detectability (paper Section 5 claims).

Method: announce a set of requests, run a combining round with a crash
armed at the k-th persistence instruction and adversarial write-back
drain, then recover every thread and check exactly-once semantics
against the set of values that are *conserved* (no value lost whose op
got a response; no value duplicated)."""

import random

import pytest

try:                                   # optional dep: `pip install .[test]`
    from hypothesis import given, settings, strategies as st
except ImportError:                    # property tests skip below
    given = settings = st = None

from repro.core import NVM, SimulatedCrash
from repro.core.pbcomb import RequestRec
from repro.structures import PBQueue, PBStack


@pytest.mark.parametrize("crash_at", range(10))
@pytest.mark.parametrize("seed", [None, 11, 22])
def test_pbstack_crash_mid_combine(crash_at, seed):
    nvm = NVM(1 << 20)
    s = PBStack(nvm, 3)
    # committed prefix
    s.op(0, "PUSH", "base", 1)
    # three announced pushes, combiner crashes mid-round
    for p in range(3):
        s.request[p] = RequestRec("PUSH", f"v{p}", 1 - s.request[p].activate, 1)
    nvm.arm_crash(crash_at, random.Random(seed) if seed else None)
    try:
        s._perform_request(0)
    except SimulatedCrash:
        pass
    nvm.disarm_crash()
    s.reset_volatile()
    seqs = {0: 2, 1: 1, 2: 1}
    rets = {p: s.recover(p, "PUSH", f"v{p}", seqs[p]) for p in range(3)}
    assert all(r == "ACK" for r in rets.values())
    content = s.drain()
    # exactly-once: all three values present once, base at the bottom
    assert sorted(content[:-1]) == ["v0", "v1", "v2"]
    assert content[-1] == "base"


@pytest.mark.parametrize("crash_at", range(12))
@pytest.mark.parametrize("seed", [None, 5])
def test_pbqueue_crash_mid_enqueue_round(crash_at, seed):
    nvm = NVM(1 << 20)
    q = PBQueue(nvm, 3)
    q.enq.op(0, "ENQ", "base", 1)
    for p in range(3):
        q.enq.request[p] = RequestRec(
            "ENQ", f"v{p}", 1 - q.enq.request[p].activate, 1)
    nvm.arm_crash(crash_at, random.Random(seed) if seed else None)
    try:
        q.enq._perform_request(1)
    except SimulatedCrash:
        pass
    nvm.disarm_crash()
    q.reset_volatile()
    seqs = {0: 2, 1: 1, 2: 1}
    for p in range(3):
        assert q.recover(p, "ENQ", f"v{p}", seqs[p]) == "ACK"
    content = q.drain()
    assert content[0] == "base"
    assert sorted(content[1:]) == ["v0", "v1", "v2"]


@pytest.mark.parametrize("crash_at", range(8))
def test_pbqueue_crash_mid_dequeue_round(crash_at):
    nvm = NVM(1 << 20)
    q = PBQueue(nvm, 2)
    seq = 0
    for i in range(4):
        seq += 1
        q.enq.op(0, "ENQ", i, seq)
    # two announced dequeues; crash mid-round
    for p in range(2):
        q.deq.request[p] = RequestRec(
            "DEQ", None, 1 - q.deq.request[p].activate, 1)
    nvm.arm_crash(crash_at, random.Random(3))
    try:
        q.deq._perform_request(0)
    except SimulatedCrash:
        pass
    nvm.disarm_crash()
    q.reset_volatile()
    rets = {p: q.recover(p, "DEQ", None, 1 if p else seq + 1)
            for p in range(2)}
    remaining = q.drain()
    # each dequeued value removed exactly once; FIFO preserved
    got = sorted(v for v in rets.values() if v is not None)
    assert sorted(got + remaining) == [0, 1, 2, 3]
    assert remaining == sorted(remaining)


@pytest.mark.parametrize("crash_at", range(10))
@pytest.mark.parametrize("seed", [None, 17])
def test_pwfstack_crash_mid_publish(crash_at, seed):
    """Wait-free stack: crash at every persistence instruction inside a
    pretend-combiner's publish; recovery applies every announced push
    exactly once."""
    from repro.structures import PWFStack
    nvm = NVM(1 << 20)
    s = PWFStack(nvm, 3, backoff=False)
    s.op(0, "PUSH", "base", 1)
    for p in range(3):
        s.request[p] = RequestRec("PUSH", f"v{p}",
                                  1 - s.request[p].activate, 1)
    nvm.arm_crash(crash_at, random.Random(seed) if seed else None)
    try:
        s._perform_request(1)
    except SimulatedCrash:
        pass
    nvm.disarm_crash()
    s.reset_volatile()
    seqs = {0: 2, 1: 1, 2: 1}
    for p in range(3):
        assert s.recover(p, "PUSH", f"v{p}", seqs[p]) == "ACK"
    content = s.drain()
    assert sorted(content[:-1]) == ["v0", "v1", "v2"]
    assert content[-1] == "base"


@pytest.mark.parametrize("crash_at", range(9))
@pytest.mark.parametrize("seed", [0, 3, 7])
def test_lock_undo_log_never_rolls_back_acked_ops(crash_at, seed):
    """The undo log's valid flag must be fenced AFTER the log entries:
    a crash that drains the valid-flag line but not the entry lines
    would otherwise roll back a STALE log image over acknowledged
    (psync'd) state.  Sweep crash points through a third op and check
    the two acknowledged items survive recovery exactly once."""
    from repro.api import CombiningRuntime
    rt = CombiningRuntime(n_threads=2)
    q = rt.make("queue", "lock-undo")
    b = rt.attach(0).bind(q)
    b.enqueue("a")
    b.enqueue("b")                       # acknowledged + psync'd
    rt.arm_crash(crash_at, random.Random(seed))
    try:
        b.enqueue("c")
    except SimulatedCrash:
        pass
    rt.crash(random.Random(seed + 1))
    rt.recover()                         # at-least-once replay of 'c'
    content = q.snapshot()
    assert content[:2] == ["a", "b"]     # acked prefix intact, in order
    assert all(v == "c" for v in content[2:]) and len(content) <= 4


if st is not None:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 14), st.integers(0, 2 ** 31 - 1),
           st.lists(st.sampled_from(["PUSH", "POP"]),
                    min_size=2, max_size=4))
    def test_property_pbstack_mixed_ops_crash(crash_at, seed, funcs):
        """Mixed push/pop rounds with crashes: conservation — every
        pushed value is either still in the stack or was returned by
        exactly one pop."""
        nvm = NVM(1 << 20)
        s = PBStack(nvm, len(funcs), elimination=False)
        committed = []
        for i in range(3):
            s.op(0, "PUSH", f"pre{i}", i + 1)
            committed.append(f"pre{i}")
        for p, f in enumerate(funcs):
            args = f"x{p}" if f == "PUSH" else None
            s.request[p] = RequestRec(f, args, 1 - s.request[p].activate, 1)
        nvm.arm_crash(crash_at, random.Random(seed))
        try:
            s._perform_request(0)
        except SimulatedCrash:
            pass
        nvm.disarm_crash()
        s.reset_volatile()
        seqs = [4 if p == 0 else 1 for p in range(len(funcs))]
        rets = {}
        for p, f in enumerate(funcs):
            args = f"x{p}" if f == "PUSH" else None
            rets[p] = s.recover(p, f, args, seqs[p])
        pushed = set(committed) | {f"x{p}" for p, f in enumerate(funcs)
                                   if f == "PUSH"}
        popped = [r for p, r in rets.items() if funcs[p] == "POP"
                  and r is not None]
        content = s.drain()
        # no duplicates anywhere
        assert len(popped) == len(set(popped))
        assert len(content) == len(set(content))
        # conservation
        assert set(content) | set(popped) == pushed
        assert not (set(content) & set(popped))
else:
    def test_property_pbstack_mixed_ops_crash():
        pytest.importorskip("hypothesis")
