"""Static protocol-invariant lint (repro.analysis.lint, DESIGN.md §10).

Synthetic single-rule fixtures (tmp_path modules that each violate
exactly one invariant), the allowlist parser/matcher, the CLI exit
codes, and the repo-level gate: linting the real protocol scope yields
zero non-allowlisted findings against the checked-in allowlist.
"""

import pytest

from repro.analysis.lint import (Allowlist, default_scope, lint_paths,
                                 load_allowlist, main, render_summary)


def _lint(tmp_path, source, name="mod.py"):
    p = tmp_path / name
    p.write_text(source)
    return p, lint_paths([str(p)])


def _rules(findings):
    return [f.rule for f in findings]


# --------------------------------------------------------------------- #
# one fixture per rule                                                  #
# --------------------------------------------------------------------- #
def test_raw_lock_via_module_attribute(tmp_path):
    _, fs = _lint(tmp_path, """
import threading

class Q:
    def __init__(self):
        self.lock = threading.Lock()
""")
    f, = fs
    assert f.rule == "raw-lock"
    assert f.site_key == "mod.py::Q.__init__"
    assert f.lineno == 6


def test_raw_lock_via_from_import(tmp_path):
    _, fs = _lint(tmp_path, """
from threading import RLock as L

def make():
    return L()
""")
    assert _rules(fs) == ["raw-lock"]
    assert fs[0].site_key == "mod.py::make"


def test_module_global_mutable(tmp_path):
    _, fs = _lint(tmp_path, """
REGISTRY = {}
CACHE: dict = dict()
__all__ = ["REGISTRY"]
FROZEN = ("a", "b")
LIMIT = 8

def f():
    local = {}          # locals are fine
    return local
""")
    assert sorted(_rules(fs)) == ["module-global", "module-global"]
    assert {f.site_key for f in fs} == {"mod.py::REGISTRY", "mod.py::CACHE"}


def test_wall_clock(tmp_path):
    _, fs = _lint(tmp_path, """
import time
import datetime

def stamp():
    return time.perf_counter()

def day():
    return datetime.datetime.now()

def backoff():
    time.sleep(0.001)   # scheduling, not modeled time: allowed
""")
    assert _rules(fs) == ["wall-clock", "wall-clock"]
    assert {f.qual for f in fs} == {"stamp", "day"}


def test_unseeded_random(tmp_path):
    _, fs = _lint(tmp_path, """
import random
from random import Random

def flaky():
    return random.random()

def also_flaky():
    return Random()

def fine(seed):
    return random.Random(seed).randint(0, 3)
""")
    assert _rules(fs) == ["unseeded-random", "unseeded-random"]
    assert {f.qual for f in fs} == {"flaky", "also_flaky"}


def test_unflushed_store(tmp_path):
    _, fs = _lint(tmp_path, """
class S:
    def bad(self, nvm, a):
        nvm.write(a, 1)

    def bad_alias(self, nvm, a):
        w = nvm.write_range
        w(a, [1, 2])

    def good(self, nvm, a):
        nvm.write(a, 1)
        nvm.pwb(a)

    def good_alias(self, nvm, a):
        flush = nvm.pwb_range
        nvm.copy_range(a, a + 8, 4)
        flush(a, 4)

    def apply(self, nvm, base, func, args):
        nvm.write(base, 1)          # exempt: round commit persists it

    def init_state(self, nvm, base):
        nvm.write_range(base, [0])  # exempt likewise
""")
    assert _rules(fs) == ["unflushed-store", "unflushed-store"]
    assert {f.site_key for f in fs} == {"mod.py::S.bad", "mod.py::S.bad_alias"}


def test_clean_module_has_no_findings(tmp_path):
    _, fs = _lint(tmp_path, """
from repro.core.nvm import NVM

class Obj:
    def op(self, nvm, a):
        nvm.write(a, 1)
        nvm.pwb(a)
        nvm.psync()
""")
    assert fs == []


# --------------------------------------------------------------------- #
# allowlist                                                             #
# --------------------------------------------------------------------- #
def test_allowlist_parse_and_match(tmp_path):
    p = tmp_path / "allow.txt"
    p.write_text("""\
# comment line

raw-lock mod.py::Q.*        # constructor seam, justified
module-global other.py::REG # frozen at import
""")
    allow = load_allowlist(str(p))
    assert len(allow.entries) == 2
    assert allow.allowed("raw-lock", "mod.py::Q.__init__")
    assert not allow.allowed("raw-lock", "mod.py::R.__init__")
    assert not allow.allowed("wall-clock", "mod.py::Q.__init__")  # per-rule
    assert allow.allowed("module-global", "other.py::REG")


def test_allowlist_rejects_malformed(tmp_path):
    p = tmp_path / "allow.txt"
    p.write_text("raw-lock\n")
    with pytest.raises(ValueError, match="malformed"):
        load_allowlist(str(p))


def test_allowlist_missing_file_is_empty(tmp_path):
    allow = load_allowlist(str(tmp_path / "nope.txt"))
    assert allow.entries == []
    assert not allow.allowed("raw-lock", "x.py::y")


# --------------------------------------------------------------------- #
# repo gate: the real scope is clean against the real allowlist         #
# --------------------------------------------------------------------- #
def test_repo_scope_zero_non_allowlisted():
    allow = load_allowlist()
    scope = default_scope()
    assert len(scope) >= 4          # pbcomb, pwfcomb, structures, api
    bad = [f for f in lint_paths(scope) if not allow.allowed(f.rule,
                                                            f.site_key)]
    assert bad == [], bad


def test_every_allowlist_entry_is_justified():
    for rule, pat, why in load_allowlist().entries:
        assert why, f"allowlist entry '{rule} {pat}' has no justification"


# --------------------------------------------------------------------- #
# CLI                                                                   #
# --------------------------------------------------------------------- #
def test_cli_fails_on_violation_and_passes_with_allowlist(tmp_path,
                                                          capsys):
    bad = tmp_path / "proto.py"
    bad.write_text("import threading\n"
                   "def f():\n"
                   "    return threading.Lock()\n")
    assert main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "[FAIL" in out and "raw-lock" in out

    allow = tmp_path / "allow.txt"
    allow.write_text("raw-lock proto.py::f  # fixture\n")
    summary = tmp_path / "summary.md"
    assert main([str(bad), "--allowlist", str(allow),
                 "--summary", str(summary)]) == 0
    assert "allowlisted" in summary.read_text()


def test_cli_clean_default_scope(capsys):
    assert main([]) == 0
    assert "non-allowlisted" in capsys.readouterr().out


def test_render_summary_flags_violations(tmp_path):
    p = tmp_path / "m.py"
    p.write_text("import threading\nL = threading.Lock()\n")
    found = lint_paths([str(p)])
    lines = render_summary(found, Allowlist([]))
    assert any("VIOLATION" in ln for ln in lines)
    lines = render_summary([], Allowlist([]))
    assert any("clean" in ln for ln in lines)
