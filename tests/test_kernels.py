"""Pallas kernels vs pure-jnp oracles (interpret mode, CPU).

Shape/dtype sweeps per the assignment: every kernel is checked against
its ref.py oracle with assert_allclose; gradients flow through the ops
wrappers (custom_vjp recompute-backward)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import attention_op, ssd_op
from repro.kernels.ssd_scan import ssd_scan

KEY = jax.random.PRNGKey(0)


def _qkv(B, H, Hkv, S, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, d), dtype)
    return q, k, v


@pytest.mark.parametrize("B,H,Hkv,S,d", [
    (1, 1, 1, 128, 64),
    (2, 4, 2, 256, 64),     # GQA
    (1, 8, 1, 256, 128),    # MQA
    (2, 2, 2, 512, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal_sweep(B, H, Hkv, S, d, dtype):
    q, k, v = _qkv(B, H, Hkv, S, d, dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [64, 128])
@pytest.mark.parametrize("softcap", [None, 50.0])
def test_flash_attention_window_softcap(window, softcap):
    q, k, v = _qkv(2, 4, 2, 256, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          softcap=softcap, interpret=True)
    exp = ref.attention_ref(q, k, v, causal=True, window=window,
                            softcap=softcap)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal():
    q, k, v = _qkv(1, 2, 2, 128, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    exp = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


def test_flash_attention_block_shape_independence():
    q, k, v = _qkv(1, 2, 2, 512, 64, jnp.float32)
    a = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    b = flash_attention(q, k, v, block_q=256, block_k=64, interpret=True)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_attention_op_grads():
    q, k, v = _qkv(1, 2, 1, 128, 32, jnp.float32)

    def loss_kernel(q, k, v):
        return jnp.sum(attention_op(q, k, v, True, None, None, True) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=True) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def _ssd_inputs(B, L, H, P, N, dtype=jnp.float32):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, L, H, P), dtype) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N), dtype) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, N), dtype) * 0.5
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("B,L,H,P,N,chunk", [
    (1, 128, 2, 64, 32, 64),
    (2, 256, 4, 64, 64, 128),
    (1, 256, 1, 32, 128, 64),
    (2, 64, 2, 16, 16, 32),
])
def test_ssd_scan_sweep(B, L, H, P, N, chunk):
    x, dt, A, Bm, Cm = _ssd_inputs(B, L, H, P, N)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, _ = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, y_ref, atol=2e-3, rtol=2e-3)


def test_ssd_scan_bf16():
    x, dt, A, Bm, Cm = _ssd_inputs(1, 128, 2, 32, 32, jnp.bfloat16)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=64, interpret=True)
    y_ref, _ = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=1e-1, rtol=1e-1)


def test_ssd_chunk_independence():
    x, dt, A, Bm, Cm = _ssd_inputs(1, 256, 2, 32, 32)
    a = ssd_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    b = ssd_scan(x, dt, A, Bm, Cm, chunk=256, interpret=True)
    np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3)


def test_ssd_op_grads():
    x, dt, A, Bm, Cm = _ssd_inputs(1, 64, 1, 16, 16)

    def loss_kernel(x, Bm, Cm):
        return jnp.sum(ssd_op(x, dt, A, Bm, Cm, True) ** 2)

    def loss_ref(x, Bm, Cm):
        return jnp.sum(ref.ssd_ref(x, dt, A, Bm, Cm)[0] ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, Bm, Cm)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, Bm, Cm)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("B,H,Hkv,S,hd,block", [
    (2, 8, 2, 1024, 64, 256),
    (1, 4, 4, 512, 128, 128),     # MHA
    (2, 8, 1, 256, 64, 64),      # MQA
])
@pytest.mark.parametrize("kv_len_frac", [1.0, 0.6])
def test_decode_attention_kernel(B, H, Hkv, S, hd, block, kv_len_frac):
    """Flash-decode kernel vs oracle across GQA configs and padded
    cache lengths."""
    from repro.kernels.decode_attention import decode_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), jnp.float32)
    kv_len = int(S * kv_len_frac)
    out = decode_attention(q, k, v, kv_len, block_s=block, interpret=True)
    exp = ref.attention_ref(q[:, :, None, :], k[:, :, :kv_len],
                            v[:, :, :kv_len], causal=False)
    np.testing.assert_allclose(out, exp[:, :, 0], atol=3e-5, rtol=3e-5)


def test_models_chunked_ssd_matches_sequential_ref():
    """The jnp chunked SSD used by the model matches the sequential
    oracle too (three-way agreement with the Pallas kernel)."""
    from repro.models.ssm import ssd_chunked_ref
    x, dt, A, Bm, Cm = _ssd_inputs(2, 256, 2, 32, 32)
    y, s = ssd_chunked_ref(x, dt, A, Bm, Cm, 64)
    y_ref, s_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, y_ref, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(s, s_ref, atol=2e-3, rtol=2e-3)
