"""End-to-end system behaviour: train -> crash -> detectable restore ->
continue, with the PBComb checkpointer and the deterministic data
pipeline; elastic rescale mid-run; serving against a real (smoke) model."""

import random
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.data.pipeline import make_batch
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import make_optimizer
from repro.persist.checkpoint import PBCombCheckpointer
from repro.persist.store import MemStore
from repro.runtime.elastic import ElasticCoordinator

def _max_diff(a, b):
    return max((float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                      - y.astype(jnp.float32))))
                for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))),
               default=0.0)


CFG = ARCHS["qwen3-1.7b"].smoke()
SHAPE = ShapeConfig("sys", 32, 4, "train")


def _fresh_state(dtype=jnp.float32):
    params = init_params(CFG, jax.random.PRNGKey(0), dtype=dtype)
    init_fn, _ = make_optimizer(CFG)
    return params, init_fn(params)


def test_train_crash_restore_continue():
    """The canonical recoverable-training loop:

    1. train 6 steps, checkpointing (announce + combine) every 2;
    2. crash (store adversarially drops unsynced data; process dies);
    3. recover: detectability tells the trainer exactly which step the
       durable state captured; the data pipeline resumes from it;
    4. continue to step 10 and verify the final state EXACTLY matches an
       uninterrupted run (bit-identical replay in f32).
    """
    train_step = jax.jit(make_train_step(CFG, None))
    store = MemStore()

    def pack_state(params, opt, step):
        return {"params": params, "opt": opt,
                "step": np.asarray(step, np.int32)}

    params, opt = _fresh_state()
    template = jax.tree.map(np.asarray, pack_state(params, opt, 0))
    ck = PBCombCheckpointer(store, 1, template)
    ck.initialize(jax.tree.map(np.asarray, pack_state(params, opt, 0)))

    step = jnp.zeros((), jnp.int32)
    ann = 0                                  # consecutive announce seq
    for i in range(6):
        batch = make_batch(CFG, SHAPE, seed=1, step=i)
        params, opt, step, loss = train_step(params, opt, step, batch)
        if (i + 1) % 2 == 0:
            ann += 1
            ck.announce(0, jax.tree.map(
                np.asarray, pack_state(params, opt, i + 1)), seq=ann,
                response=i + 1)
            ck.combine_once()

    store.crash(random.Random(0))           # kill the job

    # ---- recovery ----
    ck2 = PBCombCheckpointer(store, 1, template)
    payload = ck2.recover()
    restore_step = int(payload["step"])
    assert restore_step in (0, 2, 4, 6)     # a committed round, never torn
    if restore_step:
        # detectability: the announce with seq=restore_step/2 took effect
        # and its logged response is the captured training step
        assert ck2.was_applied(0, restore_step // 2)
        assert ck2.response(0) == restore_step
    params2 = jax.tree.map(jnp.asarray, payload["params"])
    opt2 = jax.tree.map(jnp.asarray, payload["opt"])
    step2 = jnp.asarray(restore_step, jnp.int32)
    for i in range(restore_step, 10):
        batch = make_batch(CFG, SHAPE, seed=1, step=i)
        params2, opt2, step2, _ = train_step(params2, opt2, step2, batch)

    # ---- uninterrupted reference ----
    params_ref, opt_ref = _fresh_state()
    step_ref = jnp.zeros((), jnp.int32)
    for i in range(10):
        batch = make_batch(CFG, SHAPE, seed=1, step=i)
        params_ref, opt_ref, step_ref, _ = train_step(
            params_ref, opt_ref, step_ref, batch)

    diff = _max_diff(params2, params_ref)
    assert diff < 1e-5, diff


def test_training_reduces_loss():
    train_step = jax.jit(make_train_step(CFG, None, lr=1e-3))
    params, opt = _fresh_state()
    step = jnp.zeros((), jnp.int32)
    first = last = None
    batch = make_batch(CFG, SHAPE, seed=2, step=0)   # fixed batch
    for _ in range(8):
        params, opt, step, loss = train_step(params, opt, step, batch)
        first = first if first is not None else float(loss)
        last = float(loss)
    assert last < first - 0.05, (first, last)


def test_elastic_rescale_replays_from_committed_step():
    co = ElasticCoordinator(4, heartbeat_timeout=0.01)
    for h in range(4):
        co.heartbeat(h, step=7)
    time.sleep(0.02)
    for h in (0, 1, 2):
        co.heartbeat(h, step=8)
    plan = co.rescale(committed_step=6, failed=co.detect_failures())
    assert plan.dp_size == 3 and plan.restore_step == 6
    batches = [make_batch(CFG, SHAPE, seed=9, step=plan.restore_step)
               for _ in plan.hosts]
    for b in batches[1:]:
        np.testing.assert_array_equal(batches[0]["tokens"], b["tokens"])


def test_serving_with_real_model():
    """The combining engine drives an actual (smoke) JAX model: the
    decode combiner's batch IS one decode_step over the shared batched
    state."""
    from repro.models import decode_step, prefill
    from repro.serving.engine import CombiningEngine

    params = init_params(CFG, jax.random.PRNGKey(3))
    jit_prefill = jax.jit(lambda p, t: prefill(p, CFG, t, {}, max_len=24))
    jit_decode = jax.jit(lambda p, s, t: decode_step(p, CFG, s, t))
    shared = {}

    FIXED_B = 4   # jit'd shapes are fixed; combiner batches are padded

    def prefill_batch(prompts):
        L = max(len(p) for p in prompts)
        rows = [list(p) + [0] * (L - len(p)) for p in prompts]
        rows += [[0] * L] * (FIXED_B - len(rows))
        logits, state = jit_prefill(params, jnp.asarray(rows, jnp.int32))
        shared["state"] = state
        first = np.asarray(jnp.argmax(logits, -1))
        return [int(t) for t in first[:len(prompts)]], \
            list(range(len(prompts)))

    def decode_batch(kvs, last):
        toks = list(last) + [0] * (FIXED_B - len(last))
        logits, new_state = jit_decode(params, shared["state"],
                                       jnp.asarray(toks, jnp.int32))
        shared["state"] = new_state
        nxt = np.asarray(jnp.argmax(logits, -1))
        return [int(t) for t in nxt[:len(last)]]

    eng = CombiningEngine(4, prefill_batch_fn=prefill_batch,
                          decode_batch_fn=decode_batch, n_kv_slots=4,
                          max_batch=4, eos_token=-1)
    eng.start()
    results = {}
    barrier = threading.Barrier(4)

    def client(c):
        barrier.wait()                 # announce together -> one round
        results[c] = eng.submit(c, [c + 1, c + 2, c + 3], max_tokens=4,
                                seq=1, timeout=180)

    ts = [threading.Thread(target=client, args=(c,)) for c in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    eng.stop()
    assert len(results) == 4
    for r in results.values():
        assert len(r["tokens"]) == 4
        assert all(0 <= t < CFG.padded_vocab for t in r["tokens"])
