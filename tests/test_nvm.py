"""Simulated NVMM semantics: epoch persistency + adversarial crashes."""

import random

import pytest

try:                                   # optional dep: `pip install .[test]`
    from hypothesis import given, settings, strategies as st
except ImportError:                    # property tests skip below
    given = settings = st = None

from repro.core import LINE, NVM


def test_write_read_volatile():
    nvm = NVM()
    a = nvm.alloc(4)
    nvm.write(a, 42)
    assert nvm.read(a) == 42
    assert nvm.durable_read(a) == 0           # not persisted yet


def test_psync_makes_durable():
    nvm = NVM()
    a = nvm.alloc(1)
    nvm.write(a, 7)
    nvm.pwb(a)
    nvm.psync()
    assert nvm.durable_read(a) == 7


def test_unsynced_pwb_may_be_lost():
    nvm = NVM()
    a = nvm.alloc(1)
    nvm.write(a, 7)
    nvm.pwb(a)
    nvm.crash(rng=None)                       # adversarial: nothing drains
    assert nvm.read(a) == 0


def test_pfence_orders_epochs():
    """A later epoch can never be durable while an earlier one is not."""
    for seed in range(50):
        nvm = NVM()
        a = nvm.alloc(LINE, align_line=True)
        b = nvm.alloc(LINE, align_line=True)
        nvm.write(a, 1)
        nvm.pwb(a)
        nvm.pfence()
        nvm.write(b, 2)
        nvm.pwb(b)
        nvm.crash(rng=random.Random(seed))
        if nvm.durable_read(b) == 2:          # later epoch drained =>
            assert nvm.durable_read(a) == 1   # earlier one drained too


def test_pwb_counts_lines():
    nvm = NVM()
    a = nvm.alloc(3 * LINE)
    nvm.pwb(a, 3 * LINE)                      # contiguous: 3 line flushes
    assert nvm.counters["pwb"] == 3


def test_crash_resets_volatile_to_durable():
    nvm = NVM()
    a = nvm.alloc(1)
    nvm.write(a, 5)
    nvm.pwb(a)
    nvm.psync()
    nvm.write(a, 9)                           # dirty, never pwb'd
    nvm.crash()
    assert nvm.read(a) == 5


def test_nop_flags():
    nvm = NVM(pwb_nop=True)
    a = nvm.alloc(1)
    nvm.write(a, 3)
    nvm.pwb(a)
    nvm.psync()
    assert nvm.durable_read(a) == 0           # pwbs were no-ops
    nvm2 = NVM(psync_nop=True)
    b = nvm2.alloc(1)
    nvm2.write(b, 3)
    nvm2.pwb(b)
    nvm2.psync()
    assert nvm2.durable_read(b) == 0


if st is not None:
    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.sampled_from(["w", "pwb", "fence", "sync"]),
                    min_size=1, max_size=40),
           st.integers(0, 2 ** 31 - 1))
    def test_property_durable_is_epoch_prefix(ops, seed):
        """After a crash, the durable value of a cell is some value it
        held at a pwb point, and psync'd values always survive."""
        nvm = NVM()
        a = nvm.alloc(1)
        val = 0
        pwbed_values = [0]
        synced_value = 0
        for op in ops:
            if op == "w":
                val += 1
                nvm.write(a, val)
            elif op == "pwb":
                nvm.pwb(a)
                pwbed_values.append(val)
            elif op == "fence":
                nvm.pfence()
            else:
                nvm.psync()
                synced_value = pwbed_values[-1]
        nvm.crash(rng=random.Random(seed))
        got = nvm.durable_read(a)
        assert got in pwbed_values
        assert got >= synced_value            # psync'd writes survive
else:
    def test_property_durable_is_epoch_prefix():
        pytest.importorskip("hypothesis")
