"""Multiprocess worker-pool backend: true-parallel linearizability,
measured combining degree past the GIL, machine-wide crash with live
worker processes, and the 4-process stress coverage for the baseline
race class (DurableMSQueue-style lost-link / mirror regression).

Each test forks real worker processes via
``CombiningRuntime(backend="shm").spawn_workers`` — sizes small enough
for 2-core CI runners.
"""

from collections import Counter

import pytest

from repro.api import CombiningRuntime

ADD_ACKED = {"enqueue", "push", "insert"}
REM = {"dequeue", "pop", "delete_min"}


def _tally(results_iter):
    """(acked adds, non-empty removals) multisets over op results."""
    added, removed = Counter(), Counter()
    for op, arg, ret in results_iter:
        if op in ADD_ACKED and (ret == "ACK" or ret is True):
            added[arg] += 1
        elif op in REM and ret is not None:
            removed[ret] += 1
    return added, removed


def _run_pairs_exact_once(kind, protocol, workers=4, pairs=60):
    rt = CombiningRuntime(n_threads=workers, backend="shm")
    try:
        obj = rt.make(kind, protocol)
        with rt.spawn_workers(workers) as pool:
            res = pool.run_pairs(obj, pairs, collect=True)
        added, removed = _tally(r for rep in res.reports
                                for r in rep.results)
        remaining = Counter(obj.snapshot())
        assert added == removed + remaining, (kind, protocol)
        assert res.ops_done == 2 * workers * pairs
        return obj.adapter.degree_stats(obj.core)
    finally:
        rt.close()


@pytest.mark.parametrize("kind,protocol", [
    ("queue", "pbcomb"), ("queue", "pwfcomb"), ("queue", "durable-ms"),
    ("queue", "lock-direct"), ("stack", "pbcomb"), ("stack", "pwfcomb"),
    ("stack", "dfc")])
def test_exact_once_under_true_parallelism(kind, protocol):
    """Every acked add appears exactly once in removals + final state,
    with 4 processes racing for real (no GIL serialization)."""
    _run_pairs_exact_once(kind, protocol)


def test_measured_degree_exceeds_one():
    """The point of the backend: combining rounds serve multiple
    announcements from OTHER processes.  degree_max is scheduler-robust
    (one >=2 round suffices); the >=2 degree_mean acceptance gate runs
    in mp_bench --check where sizes are bench-scale."""
    stats = _run_pairs_exact_once("queue", "pbcomb", workers=4, pairs=80)
    assert stats is not None and stats["rounds"] > 0
    assert stats["degree_max"] >= 2
    assert stats["ops_combined"] > stats["rounds"]   # mean > 1


def test_degree_stats_none_for_baselines():
    rt = CombiningRuntime(n_threads=2, backend="shm")
    try:
        obj = rt.make("queue", "lock-direct")
        assert obj.adapter.degree_stats(obj.core) is None
    finally:
        rt.close()


# --------------------------------------------------------------------- #
# machine-wide crash with live workers                                  #
# --------------------------------------------------------------------- #
def test_crash_mid_round_with_live_workers_recovers_exactly_once():
    """Arm the shared countdown so the machine halts while 4 worker
    processes are mid-workload; survivors stop on the halted flag,
    every worker reports its in-flight op (the paper's system-support
    contract), and recover(inflight=...) replays them exactly once."""
    rt = CombiningRuntime(n_threads=4, backend="shm")
    try:
        q = rt.make("queue", "pbcomb")
        pool = rt.spawn_workers(4)
        res0 = pool.run_pairs(q, 20, collect=True)
        assert not res0.crashed

        rt.nvm.arm_crash(150)
        res1 = pool.run_pairs(q, 80, collect=True)
        assert res1.crashed, "countdown should fire mid-workload"
        # crashed workers report (obj, tid, op, args, seq) records
        inflight = {(n, t): (op, args, seq)
                    for n, t, op, args, seq in res1.inflight}
        assert all(n == q.name for n, _t in inflight)

        replay = rt.recover(inflight=res1.inflight)
        added, removed = _tally(r for res in (res0, res1)
                                for rep in res.reports
                                for r in (rep.results or []))
        for key, ret in replay.items():
            op, args, _seq = inflight[key]
            if op == "enqueue" and ret == "ACK":
                added[args] += 1
            elif op == "dequeue" and ret is not None:
                removed[ret] += 1
        remaining = Counter(q.snapshot())
        assert added == removed + remaining

        # the same pool keeps working after recovery
        res2 = pool.run_pairs(q, 15)
        assert not res2.crashed and res2.ops_done == 4 * 2 * 15
    finally:
        rt.close()


def test_crash_halts_every_worker_not_just_the_tripper():
    """The halted flag reaches survivors: after one process trips the
    countdown, NO worker keeps completing operations against the dead
    machine (each either finished before the halt or reports crashed)."""
    rt = CombiningRuntime(n_threads=4, backend="shm")
    try:
        q = rt.make("queue", "pbcomb")
        pool = rt.spawn_workers(4)
        rt.nvm.arm_crash(40)
        res = pool.run_pairs(q, 200, collect=True)
        assert len(res.crashed) >= 2, \
            "halt must propagate beyond the tripping process"
        assert rt.nvm.halted
        rt.recover(inflight=res.inflight)
        assert not rt.nvm.halted
    finally:
        rt.close()


# --------------------------------------------------------------------- #
# 4-process stress: the ROADMAP-flagged baseline race class             #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("protocol", ["pbcomb", "pwfcomb"])
def test_heap_stress_four_processes(protocol):
    """4-process stress for the recoverable HEAP — until this PR the
    only structure with zero mp stress coverage.  Exact-once across
    insert/delete_min pairs, plus a post-run quiescent drain that must
    come out sorted (heap order survives true parallelism)."""
    rt = CombiningRuntime(n_threads=4, backend="shm")
    try:
        h = rt.make("heap", protocol)
        with rt.spawn_workers(4) as pool:
            res = pool.run_pairs(h, 100, collect=True)
        added, removed = _tally(r for rep in res.reports
                                for r in rep.results)
        drain = []
        fn = rt.attach(0).invoker(h, "delete_min", arity=0)
        while True:
            v = fn()
            if v is None:
                break
            drain.append(v)
        assert drain == sorted(drain)
        assert added == removed + Counter(drain)
        assert res.ops_done == 4 * 2 * 100
    finally:
        rt.close()


def test_heap_stress_rich_blob_values():
    """The same heap stress with blob-sized tuple values — heap order
    on tuples exercises blob decode on every sift comparison."""
    rt = CombiningRuntime(n_threads=4, backend="shm")
    try:
        h = rt.make("heap", "pbcomb")
        with rt.spawn_workers(4) as pool:
            res = pool.run_pairs(h, 40, collect=True, rich=True)
        added, removed = _tally(r for rep in res.reports
                                for r in rep.results)
        remaining = Counter(h.snapshot())
        assert added == removed + remaining
    finally:
        rt.close()


@pytest.mark.parametrize("protocol", ["durable-ms", "lock-undo"])
def test_baseline_stress_four_processes(protocol):
    """Heavier pairs stress on the per-op-persist baselines whose races
    the GIL used to mask: durable-ms (lost-link + head/tail-mirror
    regression class) and lock-undo (log/update mutual exclusion)."""
    for _round in range(3):
        _run_pairs_exact_once("queue", protocol, workers=4, pairs=120)


def test_durable_ms_head_mirror_never_regresses_under_crash():
    """The PR's audit fix: head/tail NVM mirrors are written inside the
    SC, so a crash can never expose a REGRESSED durable head (which
    would recover into re-serving arbitrarily many already-dequeued
    nodes).  Crash mid-stress, recover, and bound each value's servings
    by the at-least-once contract: at most one duplicate per replayed
    in-flight record (durable-ms is NOT detectable — a crashed op whose
    effect survived is legitimately re-executed; that documented
    duplication is the allowance below, head regression is not).  The
    recovered list must also be acyclic (drain terminates)."""
    rt = CombiningRuntime(n_threads=4, backend="shm")
    try:
        q = rt.make("queue", "durable-ms")
        pool = rt.spawn_workers(4)
        res0 = pool.run_pairs(q, 30, collect=True)
        rt.nvm.arm_crash(120)
        res1 = pool.run_pairs(q, 100, collect=True)
        assert res1.crashed
        replay = rt.recover(inflight=res1.inflight)

        added, removed = _tally(r for res in (res0, res1)
                                for rep in res.reports
                                for r in (rep.results or []))
        inflight = {(n, t): (op, args, seq)
                    for n, t, op, args, seq in res1.inflight}
        for key, ret in replay.items():
            op, args, _seq = inflight[key]
            if op == "enqueue" and ret == "ACK":
                added[args] += 1
            elif op == "dequeue" and ret is not None:
                removed[ret] += 1
        remaining = Counter(q.snapshot())      # terminates: list acyclic
        seen = removed + remaining
        # allowance: one extra serving per replayed in-flight ENQUEUE of
        # that value (its pre-crash effect may have survived durably)
        inflight_enq = Counter(args for (op, args, _s) in inflight.values()
                               if op == "enqueue")
        for v, n in seen.items():
            assert added[v] >= 1, f"value {v} never enqueued"
            assert n <= added[v] + inflight_enq[v], \
                f"value {v} served {n}x for {added[v]} enqueue(s) + " \
                f"{inflight_enq[v]} replay(s) — regressed durable head " \
                "(mirror race)"
    finally:
        rt.close()


# --------------------------------------------------------------------- #
# pool plumbing                                                         #
# --------------------------------------------------------------------- #
def test_spawn_workers_requires_shm_backend():
    rt = CombiningRuntime(n_threads=2)
    with pytest.raises(RuntimeError):
        rt.spawn_workers(2)


def test_spawn_workers_checks_real_substrate_not_kwarg():
    """A pre-built ShmNVM passed via nvm= works even with the default
    backend kwarg (the check looks at the actual NVM, where fork
    sharing is decided), and a thread NVM smuggled past backend="shm"
    cannot happen (the kwarg only governs lazy creation)."""
    from repro.core.shm import ShmNVM
    nvm = ShmNVM(1 << 14)
    try:
        rt = CombiningRuntime(nvm=nvm, n_threads=2)
        q = rt.make("queue", "pbcomb")
        with rt.spawn_workers(2) as pool:
            res = pool.run_pairs(q, 10)
        assert res.ops_done == 40
        rt.close()
        # the injected NVM belongs to the caller: close() left it open
        assert nvm.counters["psync"] > 0
        with pytest.raises(RuntimeError, match="closed"):
            rt.make("queue", "pwfcomb")
    finally:
        nvm.close()


def test_run_ops_explicit_programs():
    rt = CombiningRuntime(n_threads=2, backend="shm")
    try:
        h = rt.make("heap", "pbcomb")
        with rt.spawn_workers(2) as pool:
            res = pool.run_ops(h, {
                0: [("insert", 5), ("insert", 1), ("delete_min", None)],
                1: [("insert", 3), ("insert", 7)]})
        rets = {tid: [r[2] for r in rep]
                for tid, rep in res.results_by_tid().items()}
        assert rets[0][2] in (1, 3)        # min at that moment
        assert sorted(h.snapshot()) == h.snapshot()
        inserted = Counter([5, 1, 3, 7])
        popped = Counter([rets[0][2]])
        assert Counter(h.snapshot()) == inserted - popped
    finally:
        rt.close()


def test_worker_error_propagates():
    rt = CombiningRuntime(n_threads=2, backend="shm")
    try:
        q = rt.make("queue", "pbcomb")
        with rt.spawn_workers(2) as pool:
            with pytest.raises(RuntimeError, match="worker"):
                pool.run_ops(q, {0: [("frobnicate", 1)],
                                 1: [("enqueue", 1)]})
    finally:
        rt.close()
