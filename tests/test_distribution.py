"""Distribution correctness.  Multi-device tests run in subprocesses
with ``--xla_force_host_platform_device_count=8`` (the test process
itself keeps the real single CPU device)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.distributed.sharding import zero1_spec
from repro.launch.mesh import make_local_mesh
from jax.sharding import PartitionSpec as P


def _run_sub(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=None, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_zero1_spec_inserts_data_axis():
    assert zero1_spec(P(None, "model"), (64, 32), 4) == P("data", "model")
    assert zero1_spec(P("model", None), (32, 64), 4) == P("model", "data")
    # nothing divisible -> unchanged
    assert zero1_spec(P(), (3, 5), 4) == P(None, None)


def test_sharder_drops_nondivisible_axes():
    from repro.distributed.sharding import Sharder
    mesh = make_local_mesh(1, 1)
    s = Sharder(mesh)
    spec = s._filter(P(("pod", "data"), "model", None), (4, 4, 4))
    # only existing axes kept; all sizes 1 divide everything
    assert spec == P(("data",), "model", None)


def test_sharded_train_step_matches_single_device():
    """Loss + params after 2 steps agree between a (2,4) mesh and a
    single device (numerical tolerance: reductions reorder)."""
    out = _run_sub("""
        from repro.configs import ARCHS
        from repro.models import init_params, loss_fn
        from repro.launch.steps import make_train_step
        from repro.optim import make_optimizer
        from repro.launch.mesh import make_local_mesh

        cfg = ARCHS["qwen3-1.7b"].smoke()
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key, dtype=jnp.float32)
        tokens = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens, "extra": {}}
        init_fn, _ = make_optimizer(cfg)
        opt = init_fn(params)
        step = jnp.zeros((), jnp.int32)

        # single device
        ts1 = jax.jit(make_train_step(cfg, None))
        p1, o1, s1, l1 = ts1(params, opt, step, batch)
        p1, o1, s1, l1b = ts1(p1, o1, s1, batch)

        # sharded
        mesh = make_local_mesh(2, 4)
        with mesh:
            ts2 = jax.jit(make_train_step(cfg, mesh))
            p2, o2, s2, l2 = ts2(params, opt, step, batch)
            p2, o2, s2, l2b = ts2(p2, o2, s2, batch)
        np.testing.assert_allclose(float(l1), float(l2), rtol=2e-5)
        np.testing.assert_allclose(float(l1b), float(l2b), rtol=2e-4)
        d = max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                      - y.astype(jnp.float32))))
                for x, y in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 5e-4, d
        print("OK sharded==single", float(l1), float(l2))
    """)
    assert "OK sharded==single" in out


def test_sharded_serve_step_matches_single_device():
    out = _run_sub("""
        from repro.configs import ARCHS
        from repro.models import init_params, prefill, decode_step
        from repro.launch.steps import make_serve_step, make_prefill_step
        from repro.launch.mesh import make_local_mesh

        cfg = ARCHS["qwen3-1.7b"].smoke()
        key = jax.random.PRNGKey(1)
        params = init_params(cfg, key, dtype=jnp.float32)
        tokens = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens, "extra": {}}

        pf1 = jax.jit(make_prefill_step(cfg, None, max_len=24))
        sv1 = jax.jit(make_serve_step(cfg, None))
        t1, st1 = pf1(params, batch)
        t1b, _ = sv1(params, st1, t1)

        mesh = make_local_mesh(2, 4)
        with mesh:
            pf2 = jax.jit(make_prefill_step(cfg, mesh, max_len=24))
            sv2 = jax.jit(make_serve_step(cfg, mesh))
            t2, st2 = pf2(params, batch)
            t2b, _ = sv2(params, st2, t2)
        assert (np.asarray(t1) == np.asarray(t2)).mean() > 0.99, (t1, t2)
        assert (np.asarray(t1b) == np.asarray(t2b)).mean() > 0.99
        print("OK serve sharded==single")
    """)
    assert "OK serve sharded==single" in out


def test_vocab_parallel_loss_no_logit_allgather():
    """The CE loss must never all-gather [B,S,V] logits (DESIGN.md §5 /
    model.loss_fn docstring)."""
    out = _run_sub("""
        from repro.configs import ARCHS
        from repro.models import init_params
        from repro.launch.steps import make_train_step
        from repro.launch.mesh import make_local_mesh
        from repro.optim import make_optimizer
        from repro.data.pipeline import input_specs
        from repro.configs.base import ShapeConfig
        import re

        cfg = ARCHS["qwen3-1.7b"].smoke()
        mesh = make_local_mesh(2, 4)
        params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        init_fn, _ = make_optimizer(cfg)
        opt = jax.eval_shape(init_fn, params)
        shape = ShapeConfig("t", 64, 8, "train")
        batch = input_specs(cfg, shape)
        ts = make_train_step(cfg, mesh)
        with mesh:
            comp = jax.jit(ts).lower(
                params, opt, jax.ShapeDtypeStruct((), jnp.int32),
                batch).compile()
        txt = comp.as_text()
        V = cfg.padded_vocab
        bad = [l for l in txt.splitlines()
               if "all-gather" in l and str(V) in l]
        assert not bad, bad[:2]
        print("OK no logits all-gather")
    """)
    assert "OK no logits all-gather" in out


def test_moe_ep_shard_map_matches_baseline():
    """The §Perf expert-parallel MoE (shard_map local dispatch) computes
    the same function as the GSPMD baseline dispatch."""
    out = _run_sub("""
        from repro.configs import ARCHS
        from repro.models.moe import init_moe_params, moe_ffn, moe_ffn_ep
        from repro.launch.mesh import make_local_mesh
        import dataclasses

        cfg = dataclasses.replace(ARCHS["moonshot-v1-16b-a3b"].smoke(),
                                  capacity_factor=8.0)  # no drops -> exact
        key = jax.random.PRNGKey(0)
        params = init_moe_params(key, cfg, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                              jnp.float32) * 0.3
        ref = moe_ffn(params, x, cfg)
        mesh = make_local_mesh(2, 4)
        with mesh:
            got = jax.jit(lambda p, x: moe_ffn_ep(p, x, cfg, mesh))(params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-4, rtol=2e-4)
        print("OK moe ep==baseline")
    """)
    assert "OK moe ep==baseline" in out


def test_attn_explicit_shard_matches_baseline():
    out = _run_sub("""
        from repro.configs import ARCHS
        from repro.models import init_params, forward
        from repro.distributed.sharding import Sharder
        from repro.launch.mesh import make_local_mesh
        import dataclasses

        cfg = ARCHS["command-r-35b"].smoke()
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab_size)
        ref = forward(params, cfg, tokens)
        cfg2 = dataclasses.replace(cfg, attn_explicit_shard=True)
        mesh = make_local_mesh(2, 4)
        with mesh:
            got = jax.jit(lambda p, t: forward(
                p, cfg2, t, shard=Sharder(mesh)))(params, tokens)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=1e-3, rtol=1e-3)
        print("OK attnshard==baseline")
    """)
    assert "OK attnshard==baseline" in out


def test_pipeline_parallel_stage_equivalence():
    """Optional GPipe-style pipeline (shard_map + ppermute) computes the
    same function as the sequential composition."""
    out = _run_sub("""
        from repro.distributed.pipeline import pipeline_apply
        from jax.sharding import Mesh
        mesh = jax.make_mesh((4,), ("stage",))
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (4, 16, 16)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))

        def block(w, h):
            return jnp.tanh(h @ w)

        # sequential reference
        ref = x
        for i in range(4):
            ref = block(Ws[i], ref)

        got = pipeline_apply(block, Ws, x, mesh, n_microbatches=4)
        np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)
        print("OK pipeline==sequential")
    """)
    assert "OK pipeline==sequential" in out
