"""Data pipeline determinism/recoverability + optimizer behaviour +
gradient compression numerics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                   # optional dep: `pip install .[test]`
    from hypothesis import given, settings, strategies as st
except ImportError:                    # property tests skip below
    given = settings = st = None

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.data.pipeline import input_specs, make_batch
from repro.distributed.compression import dequantize, ef_quantize, quantize
from repro.optim import adafactor, adamw

SHAPE = ShapeConfig("t", 32, 4, "train")
CFG = ARCHS["qwen3-1.7b"].smoke()


def test_batches_deterministic_in_step():
    b1 = make_batch(CFG, SHAPE, seed=7, step=42)
    b2 = make_batch(CFG, SHAPE, seed=7, step=42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(CFG, SHAPE, seed=7, step=43)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_recovery_resumes_exact_stream():
    """Restoring the step counter reproduces the exact remaining stream
    — no duplicate or skipped batches (job-level detectability)."""
    stream_a = [make_batch(CFG, SHAPE, 3, s)["tokens"] for s in range(6)]
    committed_step = 3
    stream_b = [make_batch(CFG, SHAPE, 3, s)["tokens"]
                for s in range(committed_step, 6)]
    for i, t in enumerate(stream_b):
        np.testing.assert_array_equal(stream_a[committed_step + i], t)


def test_input_specs_match_real_batches():
    spec = input_specs(CFG, SHAPE)
    batch = make_batch(CFG, SHAPE, 0, 0)
    assert spec["tokens"].shape == batch["tokens"].shape
    assert spec["tokens"].dtype == batch["tokens"].dtype


def _quadratic_losses(opt_factory, steps=60):
    target = jnp.asarray([1.5, -2.0, 0.5, 3.0])
    params = {"w": jnp.zeros((4,), jnp.float32)}
    init_fn, update_fn = opt_factory
    opt = init_fn(params)
    losses = []
    for i in range(steps):
        loss, grads = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt = update_fn(grads, opt, params,
                                jnp.asarray(i, jnp.int32))
        losses.append(float(loss))
    return losses


def test_adamw_converges():
    losses = _quadratic_losses(adamw(lr=0.1, weight_decay=0.0))
    assert losses[-1] < losses[0] * 0.01


def test_adafactor_converges():
    losses = _quadratic_losses(adafactor(lr=0.05))
    assert losses[-1] < losses[0] * 0.2


def test_adafactor_state_is_factored():
    init_fn, _ = adafactor()
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    st = jax.eval_shape(init_fn, params)
    assert st["f"]["w"]["vr"].shape == (64,)
    assert st["f"]["w"]["vc"].shape == (32,)
    assert st["f"]["b"]["v"].shape == (64,)
    n_state = sum(np.prod(l.shape) for l in jax.tree.leaves(st))
    n_param = 64 * 32 + 64
    assert n_state < 0.1 * n_param


if st is not None:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(10, 3000))
    def test_quantize_roundtrip_error_bounded(seed, n):
        x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n,)))
        q, s = quantize(jnp.asarray(x))
        back = np.asarray(dequantize(q, s, n))
        # per-chunk max / 127 bounds the elementwise error
        chunks = np.pad(np.abs(x), (0, (-n) % 1024)).reshape(-1, 1024)
        bound = np.repeat(chunks.max(1) / 127.0 * 0.51, 1024)[:n] + 1e-9
        assert np.all(np.abs(back - x) <= bound + 1e-6)
else:
    def test_quantize_roundtrip_error_bounded():
        pytest.importorskip("hypothesis")


def test_error_feedback_accumulates_unbiased():
    """Sum of reconstructions + final error == sum of true inputs."""
    key = jax.random.PRNGKey(0)
    err = jnp.zeros((512,))
    total_true = jnp.zeros((512,))
    total_recon = jnp.zeros((512,))
    for i in range(20):
        x = jax.random.normal(jax.random.fold_in(key, i), (512,)) * 0.01
        q, s, err = ef_quantize(x, err)
        total_true += x
        total_recon += dequantize(q, s, 512)
    np.testing.assert_allclose(np.asarray(total_recon + err),
                               np.asarray(total_true), atol=1e-5)
