"""Blob-heap shm codec (core/shm.py, DESIGN.md §8): round trips for
rich payloads, allocator slab discipline, and crash-at-every-
publication-point old-or-new durability.

The deterministic tests below always run; the hypothesis properties
(arbitrary nested payloads, randomized alloc/free churn, randomized
crash cuts) ride the repo's optional-dependency convention.
"""

import random

import pytest

try:                                   # optional dep: `pip install .[test]`
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = settings = st = None

from repro.core.shm import (_BLOB_GRANULE, _BLOB_HDR, BlobHeap, ShmBackend,
                            ShmNVM, decode, encode)


def _mk_nvm():
    return ShmNVM(1 << 12)


RICH_SAMPLES = [
    (1, 2, "three"),
    {"tokens": [1, 2, 3], "seq": 9},
    b"\x00\xffbinary" * 7,
    "long string payload " * 9,
    2 ** 100, -(2 ** 77),
    ("nested", ({"a": (1.5, None)}, [b"x", True])),
    tuple(range(200)),
    "",                         # inline, but keep in the matrix
    None, True, 0, -1, 3.25, "ACK",
]


# --------------------------------------------------------------------- #
# deterministic coverage (always runs)                                  #
# --------------------------------------------------------------------- #
def test_inline_codec_unchanged():
    """The bare module-level codec still covers (only) the inline
    domain — backend words add the blob fallback on top."""
    for v in [0, 7, None, True, False, 1.5, "ACK"]:
        assert decode(*encode(v)) == v
    for v in [(1, 2), "x" * 17, 2 ** 64, b"bytes", [1]]:
        with pytest.raises(TypeError):
            encode(v)


def test_blob_round_trip_volatile_and_durable():
    nvm = _mk_nvm()
    try:
        a = nvm.alloc(len(RICH_SAMPLES))
        nvm.write_range(a, RICH_SAMPLES)
        got = nvm.read_range(a, len(RICH_SAMPLES))
        assert got == RICH_SAMPLES
        assert [type(g) for g in got] == [type(v) for v in RICH_SAMPLES]
        nvm.pwb(a, len(RICH_SAMPLES))
        nvm.psync()
        assert [nvm.durable_read(a + i)
                for i in range(len(RICH_SAMPLES))] == RICH_SAMPLES
    finally:
        nvm.close()


def test_blob_pwb_charges_payload_lines():
    """A pwb covering a blob-ref word charges the chunk's cache-line
    footprint — payload layout is visible in the counters (the per-op
    cost shape the serving/checkpoint benches measure)."""
    nvm = _mk_nvm()
    try:
        a = nvm.alloc(1)
        nvm.write(a, 7)
        nvm.pwb(a, 1)
        small = nvm.counters["pwb"]
        nvm.write(a, "x" * 1000)      # ~1KB payload: 16 blob lines
        nvm.pwb(a, 1)
        big = nvm.counters["pwb"] - small
        assert big >= 1 + (1000 + _BLOB_HDR) // 64
    finally:
        nvm.close()


def test_allocator_reuses_freed_chunks_without_overlap():
    nvm = _mk_nvm()
    try:
        heap = nvm.backend.heap
        a = nvm.alloc(1)
        for i in range(300):
            nvm.write(a, ("payload", i, "z" * (i % 120)))
        chunks = heap.chunks()
        # chunks tile the bump region: no gaps, no overlap
        off = 0
        for c_off, c_len, _rc, _gen in chunks:
            assert c_off == off
            assert c_len >= _BLOB_GRANULE and c_len % _BLOB_GRANULE == 0
            off += c_len
        # ping-ponging one word across size classes must not grow the
        # heap unboundedly: at most one live chunk per touched class
        live = [c for c in chunks if c[2] > 0]
        assert len(live) <= 4, live
    finally:
        nvm.close()


def test_crash_at_every_publication_point_old_or_new():
    """The satellite's torn-write sweep: arm the crash countdown at
    EVERY persistence instruction of an overwrite sequence and resolve
    the write-back ring adversarially; the durable value must decode as
    exactly the old or the new payload, never a mix."""
    old = ("old", "A" * 90, 1)
    new = ("new", {"B": [2] * 40}, 2)
    for countdown in range(1, 6):
        for seed in range(4):
            nvm = _mk_nvm()
            try:
                a = nvm.alloc(1)
                nvm.write(a, old)
                nvm.pwb(a, 1)
                nvm.psync()                      # old is durable
                nvm.arm_crash(countdown, random.Random(seed))
                try:
                    nvm.write(a, new)
                    nvm.pwb(a, 1)
                    nvm.pfence()
                    nvm.psync()
                except Exception:                # SimulatedCrash
                    pass
                nvm.disarm_crash()
                assert nvm.durable_read(a) in (old, new)
                # post-restore volatile view matches the durable one
                assert nvm.read(a) == nvm.durable_read(a)
            finally:
                nvm.close()


def test_stale_reader_retries_on_reuse():
    """A reader holding a pre-overwrite word re-reads when the chunk
    was reclaimed and re-handed out (generation mismatch)."""
    nvm = _mk_nvm()
    try:
        a = nvm.alloc(1)
        nvm.write(a, ("first", "x" * 40))
        heap = nvm.backend.heap
        (first_off, _l, _rc, first_gen), = \
            [c for c in heap.chunks() if c[2] > 0]
        # an overwrite allocates the new chunk BEFORE freeing the old
        # (publication order), so the old slab is re-handed out on the
        # write after next — with a bumped generation
        nvm.write(a, ("second", "y" * 40))
        nvm.write(a, ("third", "z" * 40))
        live = [c for c in heap.chunks() if c[2] > 0]
        assert [c[0] for c in live] == [first_off]
        assert live[0][3] > first_gen                 # generation bumped
        assert nvm.read(a) == ("third", "z" * 40)
    finally:
        nvm.close()


# --------------------------------------------------------------------- #
# hypothesis properties                                                 #
# --------------------------------------------------------------------- #
if st is not None:
    payloads = st.recursive(
        st.none() | st.booleans() | st.integers()
        | st.floats(allow_nan=False) | st.text(max_size=40)
        | st.binary(max_size=60),
        lambda inner: st.lists(inner, max_size=4).map(tuple)
        | st.lists(inner, max_size=4)
        | st.dictionaries(st.text(max_size=8), inner, max_size=4),
        max_leaves=12)

    @settings(max_examples=60, deadline=None)
    @given(values=st.lists(payloads, min_size=1, max_size=8))
    def test_property_round_trip_arbitrary_payloads(values):
        nvm = _mk_nvm()
        try:
            a = nvm.alloc(len(values))
            nvm.write_range(a, values)
            assert nvm.read_range(a, len(values)) == values
            nvm.pwb(a, len(values))
            nvm.psync()
            assert [nvm.durable_read(a + i)
                    for i in range(len(values))] == values
        finally:
            nvm.close()

    @settings(max_examples=40, deadline=None)
    @given(sizes=st.lists(st.integers(0, 800), min_size=1, max_size=40),
           frees=st.lists(st.integers(0, 10 ** 6), max_size=40))
    def test_property_allocator_never_overlaps(sizes, frees):
        """Random alloc/free churn directly against the heap: live
        chunks never overlap, freed chunks are re-handed out with a
        fresh generation, and the layout walk always tiles."""
        be = ShmBackend(data_words=1 << 10, aux_i64=1 << 10,
                        ring_i64=1 << 10)
        try:
            heap: BlobHeap = be.heap
            live = {}                     # off -> (len, gen)
            for i, size in enumerate(sizes):
                off, gen = heap.alloc(b"x" * size)
                assert off % _BLOB_GRANULE == 0
                assert off not in live, "re-handed a LIVE chunk"
                chunk_len = next(l for o, l, _rc, _g in heap.chunks()
                                 if o == off)
                assert chunk_len >= size + _BLOB_HDR
                for o2, (l2, _g2) in live.items():
                    assert off >= o2 + l2 or o2 >= off + chunk_len, \
                        "overlapping slabs handed out"
                live[off] = (chunk_len, gen)
                if frees and i < len(frees):
                    victims = sorted(live)
                    v = victims[frees[i] % len(victims)]
                    heap.dec(v)
                    del live[v]
            for off, (_len, gen) in live.items():
                chunk = next(c for c in heap.chunks() if c[0] == off)
                assert chunk[2] > 0 and chunk[3] == gen
        finally:
            be.close()

    @settings(max_examples=30, deadline=None)
    @given(old=payloads, new=payloads,
           countdown=st.integers(1, 5), seed=st.integers(0, 100))
    def test_property_crash_leaves_old_or_new(old, new, countdown, seed):
        nvm = _mk_nvm()
        try:
            a = nvm.alloc(1)
            nvm.write(a, old)
            nvm.pwb(a, 1)
            nvm.psync()
            nvm.arm_crash(countdown, random.Random(seed))
            try:
                nvm.write(a, new)
                nvm.pwb(a, 1)
                nvm.psync()
            except Exception:
                pass
            nvm.disarm_crash()
            got = nvm.durable_read(a)
            assert got == old or got == new
        finally:
            nvm.close()
else:
    def test_property_round_trip_arbitrary_payloads():
        pytest.importorskip("hypothesis")

    def test_property_allocator_never_overlaps():
        pytest.importorskip("hypothesis")

    def test_property_crash_leaves_old_or_new():
        pytest.importorskip("hypothesis")
