"""Sharded serving fleet: N shm runtimes × M workers under open-loop
traffic (DESIGN.md §9).

A ``Fleet`` owns ``n_shards`` independent ``CombiningRuntime``s on the
shared-memory backend — each with its own (multi-segment) ShmNVM, its
own fork()ed worker pool, and three recoverable structures:

  * ``ingress``  — per-shard request queue (``kind="queue"``; pbcomb by
    default — *Highly-Efficient Persistent FIFO Queues* is the backbone
    reference): the structure every request passes through, where
    combining amortizes enqueue/dequeue persistence under load;
  * ``log``      — durable response log (the KV-cache serving engine's
    completion path), one slot per client the router placed on this
    shard;
  * ``ckpt``     — the shard's checkpoint cell, target of the
    fleet-wide consistent-cut PERSIST.

Clients are placed onto shards once, by consistent hash of their
identity (``router.ConsistentHashRouter``), and keep their placement
for the fleet's lifetime; within a shard a client is pinned to one
ACTIVE worker per wave, which preserves per-client FIFO enqueue order
(what the durable-linearizability checker's per-producer checks key
on).

Traffic runs in WAVES: ``make_wave`` turns a seeded arrival process
into per-(shard, worker) schedules of ``(t_rel, client, seq,
deadline)`` requests; ``run_wave`` drives every shard's pool
concurrently through the ``openloop`` command.  Wave boundaries are the
fleet's quiescent points — where the consistent-cut checkpoint, elastic
rescales (``runtime/elastic.ElasticCoordinator``), and crash recovery
happen.

Consistent-cut checkpoint: between waves no operation is executing on
any shard, so persisting each shard's ``ckpt`` with the same fleet step
(plus that shard's durable per-client progress) is a consistent cut of
fleet state.  The step is COMMITTED only once every shard acked it;
``committed_step()`` reads the durable minimum back, so a crash of any
shard subset can only reveal a step every surviving and recovered
shard already persisted.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..api import CombiningRuntime, PoolResult
from ..runtime.elastic import ElasticCoordinator, RescalePlan
from .router import ConsistentHashRouter, shard_skew
from .traffic import (assign_clients, burst_schedule, poisson_schedule,
                      trace_schedule)

#: schedule entry: (t_rel seconds, shard-LOCAL client id, seq, deadline)
ScheduleEntry = Tuple[float, int, int, float]


@dataclass
class FleetConfig:
    n_shards: int = 2
    workers_per_shard: int = 4
    n_clients: int = 16
    protocol: str = "pbcomb"
    segments: int = 2               # per-shard NUMA-ish NVM striping
    gen_len: int = 8                # toy generation length (serving op)
    batch: int = 4                  # admission window per dequeue tick
    seed: int = 0
    nvm_words: Optional[int] = None
    heartbeat_timeout: float = 30.0  # parent-driven beats are per-wave;
                                     # membership changes are explicit
                                     # (leave/join), not timing races


class Shard:
    """One runtime shard: shm NVM + ingress/log/ckpt + worker pool."""

    def __init__(self, index: int, cfg: FleetConfig,
                 clients: Sequence[int]) -> None:
        self.index = index
        self.clients = list(clients)          # global ids; order = slot
        self.local = {c: i for i, c in enumerate(self.clients)}
        self.rt = CombiningRuntime(
            n_threads=cfg.workers_per_shard, backend="shm",
            segments=cfg.segments, nvm_words=cfg.nvm_words)
        self.ingress = self.rt.make("queue", cfg.protocol, name="ingress")
        self.log = self.rt.make("log", cfg.protocol, name="log",
                                n_clients=max(1, len(self.clients)))
        self.ckpt = self.rt.make("ckpt", cfg.protocol, name="ckpt")
        self.pool = None
        self.active_tids = list(range(cfg.workers_per_shard))

    def start(self, n_workers: int) -> None:
        self.pool = self.rt.spawn_workers(n_workers)

    # ------------- accounting ----------------------------------------- #
    def reset_stats(self) -> None:
        self.rt.nvm.reset_counters()
        for obj in (self.ingress, self.log, self.ckpt):
            obj.adapter.reset_degree_stats(obj.core)

    def degree(self) -> Dict[str, Any]:
        from ..core import merge_degree_stats
        return merge_degree_stats(
            [obj.adapter.degree_stats(obj.core)
             for obj in (self.ingress, self.log, self.ckpt)])

    def report(self, ops: int) -> Dict[str, Any]:
        """Per-shard bench columns over ``ops`` completed pool ops."""
        c = self.rt.nvm.counters
        segs = self.rt.nvm.segment_counters()
        d = self.degree() or {"rounds": 0, "ops_combined": 0,
                              "degree_max": 0}
        ops = max(1, ops)
        return {
            "shard": self.index,
            "clients": len(self.clients),
            "active_workers": len(self.active_tids),
            "ops": ops,
            "pwbs_per_op": c["pwb"] / ops,
            "psyncs_per_op": c["psync"] / ops,
            "seg_psyncs_per_op": [s["psync"] / ops for s in segs],
            "ring_spills": c["ring_spills"],
            "rounds": d["rounds"] or None,
            "degree_mean": (d["ops_combined"] / d["rounds"]
                            if d["rounds"] else None),
            "degree_max": d["degree_max"] if d["rounds"] else None,
        }


class Fleet:
    def __init__(self, config: Optional[FleetConfig] = None,
                 **kw) -> None:
        cfg = config or FleetConfig(**kw)
        if config is not None and kw:
            raise ValueError("pass FleetConfig or kwargs, not both")
        self.cfg = cfg
        self.router = ConsistentHashRouter(cfg.n_shards, seed=cfg.seed)
        placement = self.router.assign(
            f"client-{c}" for c in range(cfg.n_clients))
        # client key "client-<c>" -> shard; keep the global->local map
        by_shard = {s: [int(k.split("-")[1]) for k in keys]
                    for s, keys in placement.items()}
        self.shards = [Shard(i, cfg, by_shard[i])
                       for i in range(cfg.n_shards)]
        self._shard_of_client = {
            c: s for s in range(cfg.n_shards) for c in by_shard[s]}
        self.elastic = ElasticCoordinator(
            cfg.n_shards * cfg.workers_per_shard,
            heartbeat_timeout=cfg.heartbeat_timeout)
        self._seq = {c: 0 for c in range(cfg.n_clients)}
        self._wave = 0
        self._step = 0                 # last checkpoint step ATTEMPTED
        self._committed = 0            # last step acked by EVERY shard
        self._started = False

    # ------------------ lifecycle -------------------------------------- #
    def start(self) -> "Fleet":
        """Fork every shard's worker pool (structures are registered at
        construction, so the children inherit them)."""
        if not self._started:
            for s in self.shards:
                s.start(self.cfg.workers_per_shard)
            self._started = True
        return self

    def close(self) -> None:
        for s in self.shards:
            s.rt.close()               # closes the pool too

    def __enter__(self) -> "Fleet":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------ elastic membership ----------------------------- #
    def host_id(self, shard: int, tid: int) -> int:
        return shard * self.cfg.workers_per_shard + tid

    def leave(self, shard: int, tid: int) -> RescalePlan:
        """Worker ``tid`` of ``shard`` leaves the serving set; takes
        effect from the next wave (a rescale plan is combined from the
        coordinator's announcements, fleet-wide, like every other
        decision in this repo)."""
        self.elastic.leave(self.host_id(shard, tid))
        return self.rescale()

    def join(self, shard: int, tid: int) -> RescalePlan:
        """Worker rejoins (elastic scale-up) from the next wave."""
        self.elastic.join(self.host_id(shard, tid))
        return self.rescale()

    def rescale(self) -> RescalePlan:
        plan = self.elastic.rescale(self._committed)
        self._apply_plan(plan)
        return plan

    def _apply_plan(self, plan: RescalePlan) -> None:
        w = self.cfg.workers_per_shard
        for s in self.shards:
            tids = [h - s.index * w for h in plan.hosts
                    if s.index * w <= h < (s.index + 1) * w]
            if not tids:
                raise RuntimeError(
                    f"rescale plan leaves shard {s.index} with no "
                    "workers; keep at least one per shard")
            s.active_tids = tids

    # ------------------ traffic ---------------------------------------- #
    def make_wave(self, n_requests: int, *,
                  rate_rps: Optional[float] = None,
                  trace: Optional[Sequence[float]] = None,
                  burst: bool = False,
                  seed: Optional[int] = None
                  ) -> Dict[int, Dict[int, List[ScheduleEntry]]]:
        """Seeded open-loop schedules for the next wave:
        ``{shard: {tid: [(t_rel, local_client, seq, deadline), ...]}}``.

        Exactly one of ``rate_rps`` (Poisson), ``trace`` (explicit
        offsets) or ``burst`` selects the arrival process.  Per-client
        seq numbering continues across waves (the durable log's
        sequence contract), and each client is pinned to one ACTIVE
        worker of its shard for the wave."""
        if sum((rate_rps is not None, trace is not None, burst)) != 1:
            raise ValueError("pick exactly one of rate_rps, trace, burst")
        seed = (self.cfg.seed * 1000 + self._wave if seed is None
                else seed)
        if burst:
            arrivals = burst_schedule(n_requests)
        elif trace is not None:
            arrivals = trace_schedule(trace)
        else:
            arrivals = poisson_schedule(rate_rps, n_requests, seed)
        sched: Dict[int, Dict[int, List[ScheduleEntry]]] = {
            s.index: {tid: [] for tid in s.active_tids}
            for s in self.shards}
        for t, client, deadline in assign_clients(
                arrivals, self.cfg.n_clients, seed):
            s = self.shards[self._shard_of_client[client]]
            self._seq[client] += 1
            local = s.local[client]
            tid = s.active_tids[local % len(s.active_tids)]
            sched[s.index][tid].append(
                (t, local, self._seq[client], deadline))
        return sched

    def run_wave(self, schedules: Dict[int, Dict[int,
                                                 List[ScheduleEntry]]],
                 *, collect: bool = False) -> Dict[int, PoolResult]:
        """Drive every shard's pool through one open-loop window
        CONCURRENTLY (one dispatcher thread per shard); returns the
        per-shard ``PoolResult``.  Crashed shards are reported, not
        raised — recover them with ``recover_shards`` before the next
        wave.  Worker heartbeats land on the elastic coordinator as
        each report comes back."""
        if not self._started:
            raise RuntimeError("fleet not started")
        results: Dict[int, PoolResult] = {}
        errors: Dict[int, BaseException] = {}

        def drive(s: Shard) -> None:
            try:
                results[s.index] = s.pool.run_open_loop(
                    s.ingress, s.log, schedules.get(s.index, {}),
                    gen_len=self.cfg.gen_len, batch=self.cfg.batch,
                    collect=collect)
            except BaseException as e:          # pool-level failure
                errors[s.index] = e

        threads = [threading.Thread(target=drive, args=(s,))
                   for s in self.shards]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise RuntimeError(
                f"shard dispatch failed: { {i: str(e) for i, e in errors.items()} }")
        self._wave += 1
        for i, res in results.items():
            for rep in res.reports:
                self.elastic.heartbeat(self.host_id(i, rep.tid),
                                       self._wave)
        return results

    def recover_shards(self, results: Dict[int, PoolResult]
                       ) -> Dict[int, Dict[Tuple[str, int], Any]]:
        """Recover every shard that reported a crash in ``results``:
        replay its workers' in-flight records and power the shard back
        on.  Returns the replayed responses per shard (feed to the
        checker's ``apply_replay``)."""
        replies: Dict[int, Dict[Tuple[str, int], Any]] = {}
        for i, res in results.items():
            if res.crashed:
                replies[i] = self.shards[i].rt.recover(
                    inflight=res.inflight)
        return replies

    def arm_crash(self, shard: int, after_persist_ops: int,
                  rng=None, *, lose_segment=None) -> None:
        """Arm a crash countdown on ONE shard's NVM — the next wave
        halts that shard mid-traffic while the rest keep serving.
        ``lose_segment`` selects the shm partial-failure policy: that
        segment of the shard's NVM loses its pending write-backs at
        the crash (a failed DIMM) while the others drain fully."""
        if lose_segment is not None:
            self.shards[shard].rt.nvm.arm_crash(
                after_persist_ops, rng, lose_segment=lose_segment)
        else:
            self.shards[shard].rt.nvm.arm_crash(after_persist_ops, rng)

    def crash_shard(self, shard: int, rng=None) -> None:
        """Full power-off of one shard (adversarial write-back drain)."""
        self.shards[shard].rt.crash(rng)

    def recover_shard(self, shard: int, inflight=None
                      ) -> Dict[Tuple[str, int], Any]:
        return self.shards[shard].rt.recover(inflight=inflight)

    # ------------------ reclamation ------------------------------------ #
    def quiesce(self) -> Dict[int, Dict[str, Any]]:
        """Advance every shard's durable reclamation boundaries.  Wave
        boundaries are quiescent by construction (``run_wave`` joins all
        drivers), so this is safe between waves.  Returns the per-shard
        reclaim/blob-GC summaries."""
        return {s.index: s.rt.quiesce() for s in self.shards}

    def occupancy(self) -> Dict[int, Dict[str, Any]]:
        """Per-shard backend memory accounting (``NVM.occupancy``)."""
        return {s.index: s.rt.occupancy() for s in self.shards}

    # ------------------ consistent-cut checkpoint ---------------------- #
    def checkpoint(self) -> int:
        """Fleet-wide consistent cut: one PERSIST per shard ``ckpt`` at
        the next step, between waves (quiescent, so the cut is
        consistent by construction).  The step is committed — and
        returned — only once EVERY shard acked it; a crash racing the
        persist is recovered (the in-flight PERSIST replays) before the
        commit decision."""
        step = self._step + 1
        for s in self.shards:
            h = s.rt.attach(0)        # workers are idle between waves
            payload = {
                "step": step,
                "shard": s.index,
                "wave": self._wave,
                # durable per-client progress: the consistent cut's
                # content (recomputable from the shard's own log)
                "served": [seq for seq, _resp in s.log.snapshot()],
            }
            try:
                h.invoke(s.ckpt, "persist", (step, payload))
            except Exception as e:
                from ..core import SimulatedCrash
                if not isinstance(e, SimulatedCrash):
                    raise
                # crash landed inside the persist: recovery replays it
                # (idempotent — newest step wins), then verify
                s.rt.recover()
                snap = s.ckpt.snapshot()
                if snap["step"] < step:
                    h.invoke(s.ckpt, "persist", (step, payload))
        self._step = step
        self._committed = step
        return step

    def committed_step(self) -> int:
        """The durable fleet checkpoint step: the MINIMUM over shards of
        each ckpt cell's durable step — the newest cut every shard is
        guaranteed to hold, whatever subset just crashed."""
        return min(s.ckpt.snapshot()["step"] for s in self.shards)

    # ------------------ accounting ------------------------------------- #
    def reset_stats(self) -> None:
        for s in self.shards:
            s.reset_stats()

    def wave_report(self, results: Dict[int, PoolResult]
                    ) -> Dict[str, Any]:
        """Fleet-level bench columns for one wave: per-shard reports,
        request skew, aggregate psync/op."""
        per_shard = [self.shards[i].report(res.ops_done)
                     for i, res in sorted(results.items())]
        reqs = [sum(len(r.latencies or ()) for r in res.reports)
                for _i, res in sorted(results.items())]
        ops = sum(res.ops_done for res in results.values())
        psyncs = sum(self.shards[i].rt.nvm.counters["psync"]
                     for i in results)
        pwbs = sum(self.shards[i].rt.nvm.counters["pwb"]
                   for i in results)
        return {
            "per_shard": per_shard,
            "requests_per_shard": reqs,
            "shard_skew": shard_skew(reqs),
            "ops": ops,
            "psyncs_per_op": psyncs / max(1, ops),
            "pwbs_per_op": pwbs / max(1, ops),
            "degree_mean": (
                sum(r["degree_mean"] * r["rounds"] for r in per_shard
                    if r["rounds"])
                / max(1, sum(r["rounds"] for r in per_shard
                             if r["rounds"]))),
        }
