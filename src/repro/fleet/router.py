"""Consistent-hash request router: keys onto runtime shards.

The fleet routes every request key (a client identity) onto one of N
shards through a classic consistent-hash ring: each shard owns
``replicas`` pseudo-random points on a 160-bit circle (SHA-1 of
``seed/shard/replica``), and a key lands on the first shard point at or
after its own hash.  Properties the fleet leans on:

  * deterministic — the mapping is a pure function of (seed, shards),
    so a seeded bench run routes identically on every host;
  * stable under membership change — removing one shard only moves the
    keys that shard owned (its arc is absorbed by the clockwise
    neighbours); everything else keeps its placement, which is what
    makes shard-local ingress queues and response logs survivable
    across fleet reconfiguration;
  * balanced in expectation — ``replicas`` points per shard smooth the
    arcs; ``shard_skew`` quantifies the residual imbalance and is a
    reported bench column (a hot shard saturates before the fleet
    knee, so skew is a first-class observable).
"""

from __future__ import annotations

import bisect
import hashlib
from typing import Any, Dict, Hashable, Iterable, List, Sequence, Tuple


def _h(s: str) -> int:
    return int.from_bytes(hashlib.sha1(s.encode()).digest(), "big")


class ConsistentHashRouter:
    """Hash ring over ``n_shards`` shard ids (0..n-1)."""

    def __init__(self, n_shards: int, *, replicas: int = 64,
                 seed: int = 0) -> None:
        if n_shards < 1:
            raise ValueError("need at least one shard")
        self.n_shards = n_shards
        self.replicas = replicas
        self.seed = seed
        points: List[Tuple[int, int]] = []
        for shard in range(n_shards):
            for r in range(replicas):
                points.append((_h(f"{seed}/{shard}/{r}"), shard))
        points.sort()
        self._hashes = [h for h, _ in points]
        self._owners = [s for _, s in points]

    def shard_for(self, key: Hashable) -> int:
        """The shard owning ``key`` (first ring point clockwise)."""
        h = _h(str(key))
        i = bisect.bisect_right(self._hashes, h)
        if i == len(self._hashes):
            i = 0                          # wrap around the circle
        return self._owners[i]

    def assign(self, keys: Iterable[Hashable]) -> Dict[int, List[Any]]:
        """Group ``keys`` by owning shard (every shard present, possibly
        empty — the fleet sizes per-shard logs from these lists)."""
        out: Dict[int, List[Any]] = {s: [] for s in range(self.n_shards)}
        for k in keys:
            out[self.shard_for(k)].append(k)
        return out


def shard_skew(counts: Sequence[int]) -> float:
    """Load-imbalance measure: ``max/mean - 1`` over per-shard request
    counts (0.0 = perfectly balanced; 1.0 = the hottest shard carries
    twice the mean)."""
    counts = list(counts)
    if not counts:
        return 0.0
    mean = sum(counts) / len(counts)
    if mean == 0:
        return 0.0
    return max(counts) / mean - 1.0
