"""Open-loop arrival processes: Poisson, trace-driven, burst.

Open-loop means the generator's intent does not depend on the system's
completions: every request has an INTENDED arrival time fixed up front
by the arrival process, and latency is always measured from that
intended time (wrk2-style).  When a worker falls behind, the backlog
shows up as measured latency instead of silently stretching the
arrival gaps — the coordinated-omission failure mode of closed-loop
``us/op`` benches, and the reason the fleet harness exists.

All processes are seeded and deterministic: a bench run's schedule is
a pure function of (seed, rate, n), so ``bench.fleet.v1`` tables are
reproducible modulo wall-clock measurement noise.
"""

from __future__ import annotations

import random
from typing import Iterable, List, Sequence, Tuple

#: latency budgets (seconds) mixed into request priorities: a request's
#: priority is its absolute DEADLINE (intended arrival + budget), so
#: the admission heap serves interactive-class requests before
#: batch-class ones dequeued in the same window
PRIORITY_BUDGETS = (0.002, 0.010, 0.050)


def poisson_schedule(rate_rps: float, n_requests: int, seed: int,
                     start: float = 0.0) -> List[float]:
    """``n_requests`` arrival offsets (seconds) of a Poisson process at
    ``rate_rps``: i.i.d. exponential gaps, seeded."""
    if rate_rps <= 0:
        raise ValueError("rate_rps must be positive")
    rng = random.Random(seed)
    t, out = start, []
    for _ in range(n_requests):
        t += rng.expovariate(rate_rps)
        out.append(t)
    return out


def burst_schedule(n_requests: int, start: float = 0.0) -> List[float]:
    """All arrivals intended at the same instant — the saturation
    (infinite-rate) window used for the degree/psync-floor gate rows."""
    return [start] * n_requests


def trace_schedule(arrivals: Iterable[float]) -> List[float]:
    """Trace-driven arrivals: validate and normalize an explicit offset
    list (sorted, non-negative) — replayed production traces plug in
    here."""
    out = sorted(float(t) for t in arrivals)
    if out and out[0] < 0:
        raise ValueError("trace arrival offsets must be non-negative")
    return out


def assign_clients(arrivals: Sequence[float], n_clients: int,
                   seed: int) -> List[Tuple[float, int, float]]:
    """Attach a (seeded) client identity and deadline priority to each
    arrival: returns ``[(t_rel, client, priority), ...]`` in arrival
    order.  Clients are drawn uniformly — millions-of-users traffic is
    many independent streams multiplexed onto one arrival process."""
    if n_clients < 1:
        raise ValueError("need at least one client")
    rng = random.Random(seed ^ 0x9E3779B9)
    out = []
    for t in arrivals:
        client = rng.randrange(n_clients)
        deadline = t + rng.choice(PRIORITY_BUDGETS)
        out.append((t, client, deadline))
    return out
