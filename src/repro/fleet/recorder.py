"""Latency/throughput recorder + saturation-knee discovery.

Latencies arriving here were measured from INTENDED arrival times
(repro.api.mp ``openloop`` command), so the percentiles are
coordinated-omission-free by construction: the recorder never has to
correct for deferred sends because nothing was deferred — lateness is
already inside every sample.

Knee discovery ramps the offered arrival rate geometrically and stops
at the first window whose p99 blows through the latency budget: below
capacity, open-loop p99 tracks service time; past capacity the backlog
grows for the whole window and p99 diverges with it.  The knee estimate
is the geometric mean of the last compliant and first saturated rates
(the true capacity lies between them).
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence


def percentile(sorted_values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 1]) over an ASCENDING list."""
    if not sorted_values:
        return 0.0
    if not 0.0 <= q <= 1.0:
        raise ValueError("q must be in [0, 1]")
    rank = max(1, math.ceil(q * len(sorted_values)))
    return sorted_values[min(rank, len(sorted_values)) - 1]


class LatencyRecorder:
    """Accumulates per-request latencies (seconds) across workers and
    shards; summarizes in microseconds."""

    def __init__(self) -> None:
        self._lat: List[float] = []

    def add(self, latencies: Iterable[float]) -> None:
        self._lat.extend(latencies)

    def __len__(self) -> int:
        return len(self._lat)

    def summary(self) -> Dict[str, Any]:
        lat = sorted(self._lat)
        if not lat:
            return {"n": 0, "p50_us": None, "p99_us": None,
                    "p999_us": None, "max_us": None, "mean_us": None}
        return {
            "n": len(lat),
            "p50_us": percentile(lat, 0.50) * 1e6,
            "p99_us": percentile(lat, 0.99) * 1e6,
            "p999_us": percentile(lat, 0.999) * 1e6,
            "max_us": lat[-1] * 1e6,
            "mean_us": sum(lat) / len(lat) * 1e6,
        }


def find_knee(run_at: Callable[[float], Dict[str, Any]],
              rates: Sequence[float],
              p99_budget_us: float) -> Dict[str, Any]:
    """Ramp ``rates`` (ascending, requests/s) through ``run_at`` until
    p99 exceeds ``p99_budget_us``; returns the ramp steps plus the knee
    estimate.

    ``run_at(rate)`` runs one open-loop window and must return a dict
    containing ``p99_us``.  The ramp stops at the first saturated
    window (no point measuring deeper into collapse).  If even the
    first rate saturates, the knee is reported AT that rate with
    ``saturated_at_floor`` set — still a non-empty estimate, just an
    upper bound."""
    steps: List[Dict[str, Any]] = []
    last_ok: Optional[float] = None
    first_sat: Optional[float] = None
    for rate in rates:
        s = dict(run_at(rate))
        s["rate_rps"] = rate
        s["saturated"] = s["p99_us"] is None or s["p99_us"] > p99_budget_us
        steps.append(s)
        if s["saturated"]:
            first_sat = rate
            break
        last_ok = rate
    if first_sat is None:
        knee = None                     # ramp never saturated
    elif last_ok is None:
        knee = first_sat                # saturated at the floor rate
    else:
        knee = math.sqrt(last_ok * first_sat)
    return {"p99_budget_us": p99_budget_us,
            "last_ok_rate_rps": last_ok,
            "first_saturated_rate_rps": first_sat,
            "saturated_at_floor": first_sat is not None and last_ok is None,
            "knee_rate_rps": knee,
            "steps": steps}
