"""repro.fleet — sharded serving fleet + open-loop traffic harness.

N ``CombiningRuntime(backend="shm")`` shards behind a consistent-hash
router, driven by seeded open-loop arrival processes; latency measured
from intended arrival times (coordinated-omission-free), saturation
knee discovered by rate ramp, fleet state checkpointed as a consistent
cut across shards.  DESIGN.md §9.
"""

from .fleet import Fleet, FleetConfig, Shard
from .recorder import LatencyRecorder, find_knee, percentile
from .router import ConsistentHashRouter, shard_skew
from .traffic import (PRIORITY_BUDGETS, assign_clients, burst_schedule,
                      poisson_schedule, trace_schedule)

__all__ = [
    "ConsistentHashRouter", "Fleet", "FleetConfig", "LatencyRecorder",
    "PRIORITY_BUDGETS", "Shard", "assign_clients", "burst_schedule",
    "find_knee", "percentile", "poisson_schedule", "shard_skew",
    "trace_schedule",
]
