"""Execution-backend seam: where the protocols get their *volatile
shared* primitives from.

The combining protocols need a handful of shared-between-participants
volatile objects: the combiner-election lock, the announcement board
(Request[0..n-1]), PWFComb's Flush/CombRound arrays and its LL/SC S
reference, a few single-word cells (PBComb's LockVal, PBQueue's
oldTail), plain mutexes, and the measured-degree counters.  Under the
seed's thread model these were ordinary Python objects sharing the
interpreter heap; a multiprocess run needs every one of them backed by
``multiprocessing.shared_memory`` instead (core/shm.py).

``Backend`` is that seam.  Every ``NVM`` owns one (``nvm.backend``) and
the protocols build their volatile state exclusively through it, so the
SAME protocol code runs under both executions:

  * ``ThreadBackend`` (default) — plain ``threading`` primitives and
    interpreter-heap lists, byte-for-byte the seed's behavior (the
    deterministic modeled pass and the gated perf trajectory ride on
    this, so the thread implementations change no instruction
    sequence).
  * ``ShmBackend`` (core/shm.py) — the same interfaces over a shared
    memory segment + lock-striped CAS emulation, fork-inherited by
    worker processes (api/mp.py).

Reset semantics: a crash wipes volatile state.  The thread backend
recreates objects (exactly what the seed did); the shm backend must
instead reset *in place* — worker processes hold fork-inherited
references to the same views, so rebinding to fresh objects in the
recovering process would silently diverge the two sides.  Hence the
``reset_*`` methods: thread backends return fresh objects, shm backends
return the same object with its shared state re-initialized.
"""

from __future__ import annotations

import threading
from typing import Any, List, Optional, Tuple

from .atomics import AtomicInt, AtomicRef, Counters


class Cell:
    """One shared volatile word with a plain ``value`` attribute
    (PBComb's LockVal, PBQueue's oldTail)."""

    __slots__ = ("value",)

    def __init__(self, value: Any = None) -> None:
        self.value = value


class IntList(list):
    """A shared volatile int array (PWFComb's Flush, CombRound rows).
    ``list`` plus in-place ``fill`` so post-crash resets work on both
    backends through one call."""

    def fill(self, value: int) -> None:
        self[:] = [value] * len(self)


class RequestBoard(list):
    """The announcement board: ``board[p]`` is thread p's RequestRec.

    A plain list of RequestRec objects under threads (``board[p] = rec``
    and in-place field mutation both work, exactly as the seed did); the
    shm variant returns per-slot views into shared memory and copies
    assigned records field-by-field (valid last)."""

    def __init__(self, n_threads: int) -> None:
        from .pbcomb import RequestRec
        super().__init__(RequestRec() for _ in range(n_threads))

    def reset(self) -> None:
        from .pbcomb import RequestRec
        self[:] = [RequestRec() for _ in range(len(self))]


class DegreeStats:
    """Measured combining-degree counters (ROADMAP: the *measured* side
    of the paper's d-requests-per-psync claim).

    One record per combining round: ``rounds`` rounds served
    ``ops_combined`` requests in total; ``degree_max`` is the largest
    single round.  Updated once per round (PBComb: by the elected
    combiner; PWFComb: by the successful publisher), so the mutex is
    off every per-request hot path."""

    __slots__ = ("rounds", "ops_combined", "degree_max", "_mutex")

    def __init__(self) -> None:
        self.rounds = 0
        self.ops_combined = 0
        self.degree_max = 0
        self._mutex = threading.Lock()

    def record(self, served: int) -> None:
        with self._mutex:
            self.rounds += 1
            self.ops_combined += served
            if served > self.degree_max:
                self.degree_max = served

    def snapshot(self) -> dict:
        with self._mutex:
            return {"rounds": self.rounds,
                    "ops_combined": self.ops_combined,
                    "degree_max": self.degree_max}

    def reset(self) -> None:
        with self._mutex:
            self.rounds = 0
            self.ops_combined = 0
            self.degree_max = 0


def merge_degree_stats(snaps) -> Optional[dict]:
    """Aggregate several ``DegreeStats.snapshot()`` dicts (split-queue
    enq+deq instances) into one; None if there are none."""
    snaps = [s for s in snaps if s is not None]
    if not snaps:
        return None
    out = {"rounds": sum(s["rounds"] for s in snaps),
           "ops_combined": sum(s["ops_combined"] for s in snaps),
           "degree_max": max(s["degree_max"] for s in snaps)}
    out["degree_mean"] = (out["ops_combined"] / out["rounds"]
                          if out["rounds"] else 0.0)
    return out


class ThreadBackend:
    """Interpreter-heap primitives: the seed's thread execution model.

    Stateless — every NVM may own its own instance, and the factories
    below are exactly what the protocols constructed inline before the
    seam existed (fresh ``threading`` objects, plain lists)."""

    kind = "threads"

    # ------------- factories ------------------------------------------ #
    def mutex(self):
        return threading.Lock()

    def cell(self, value: Any = None) -> Cell:
        return Cell(value)

    def atomic_int(self, value: int = 0, *, shared: bool = False,
                   counters: Optional[Counters] = None,
                   clock: Optional[Any] = None) -> AtomicInt:
        return AtomicInt(value, shared=shared, counters=counters,
                         clock=clock)

    def atomic_ref(self, value: Any, *, shared: bool = False,
                   counters: Optional[Counters] = None,
                   clock: Optional[Any] = None,
                   mirror: Optional[Tuple[Any, int]] = None) -> AtomicRef:
        return AtomicRef(value, shared=shared, counters=counters,
                         clock=clock, mirror=mirror)

    def sref(self, nvm: Any, addr: int, value: int,
             counters: Optional[Counters] = None):
        from .pwfcomb import _SRef
        return _SRef(nvm, addr, value, counters)

    def int_array(self, n: int, init: int = 0) -> IntList:
        return IntList([init] * n)

    def int_matrix(self, rows: int, cols: int) -> List[IntList]:
        return [IntList([0] * cols) for _ in range(rows)]

    def request_board(self, n_threads: int) -> RequestBoard:
        return RequestBoard(n_threads)

    def degree_stats(self) -> DegreeStats:
        return DegreeStats()

    # ------------- tuning ---------------------------------------------- #
    def announce_park(self, prob: float, seconds: float
                      ) -> Tuple[float, float]:
        """(probability, duration) of the post-announce park — the
        paper's entry backoff.  The thread backend keeps the protocol's
        own constants (under the GIL a long park buys little: the
        parked thread's timeslice mostly goes to ONE other thread); the
        shm backend widens it, because with true parallelism a running
        combiner adopts every request parked during its round — that is
        what turns announcement overlap into measured degree."""
        return prob, seconds

    # ------------- post-crash resets ----------------------------------- #
    # Thread semantics: volatile state is *recreated* (what the seed's
    # reset_volatile code did); shm backends override these to reset the
    # same shared object in place and return it.
    def reset_mutex(self, m):
        return threading.Lock()

    def reset_atomic_int(self, a: AtomicInt, value: int = 0, *,
                         shared: bool = False,
                         counters: Optional[Counters] = None,
                         clock: Optional[Any] = None) -> AtomicInt:
        return AtomicInt(value, shared=shared, counters=counters,
                         clock=clock)

    def reset_atomic_ref(self, a, value: Any, *, shared: bool = False,
                         counters: Optional[Counters] = None,
                         clock: Optional[Any] = None,
                         mirror: Optional[Tuple[Any, int]] = None):
        return AtomicRef(value, shared=shared, counters=counters,
                         clock=clock, mirror=mirror)

    def reset_sref(self, s, nvm: Any, addr: int, value: int,
                   counters: Optional[Counters] = None):
        from .pwfcomb import _SRef
        return _SRef(nvm, addr, value, counters)
