"""Atomic single-word primitives (volatile) used by the combining protocols.

The paper assumes atomic read/write/CAS and LL/VL/SC on single words
(Section 2).  CPython's GIL makes individual loads/stores atomic; CAS and
SC are implemented under a per-object mutex.  LL/SC is simulated exactly
the way the paper's own evaluation does (Section 6): "we simulate an LL on
an object O with a read, and an SC with a CAS on a timestamped version of
O to avoid the ABA problem".

Instrumentation: every object can be tagged ``shared=True`` so reads and
writes on cache-shared locations are counted — this reproduces the
Table 1 counters (stores/reads on cache lines in shared state).

Backends: the classes here are the thread-execution implementations;
the multiprocess backend provides the same interfaces over
``multiprocessing.shared_memory`` words with lock-striped CAS emulation
(``core/shm.py``: ShmAtomicInt / ShmAtomicRef / ShmSRef).  Protocol
code obtains whichever variant fits the run through the ``nvm.backend``
seam (``core/backend.py``) rather than constructing these directly.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Tuple


class Counters:
    """Process-wide counters for shared-location traffic (paper Table 1)."""

    def __init__(self) -> None:
        self.shared_reads = 0
        self.shared_writes = 0
        self.cas_calls = 0
        self._lock = threading.Lock()

    def snapshot(self) -> Dict[str, int]:
        return {"shared_reads": self.shared_reads,
                "shared_writes": self.shared_writes,
                "cas_calls": self.cas_calls}

    def reset(self) -> None:
        self.shared_reads = 0
        self.shared_writes = 0
        self.cas_calls = 0


class AtomicInt:
    """Instrumentation is opt-in: traffic is counted only when a
    ``Counters`` object is supplied for a ``shared`` word (the Table 1
    harness does) — the un-instrumented hot path pays no bookkeeping.
    When a virtual clock (``NVM.clock``) is supplied, every CAS-class
    instruction additionally advances the calling thread's logical
    clock by the profile's ``cas_ns``."""

    __slots__ = ("_value", "_mutex", "_count", "_clock")

    def __init__(self, value: int = 0, *, shared: bool = False,
                 counters: Optional[Counters] = None,
                 clock: Optional[Any] = None) -> None:
        self._value = value
        self._mutex = threading.Lock()
        self._count = counters if (shared and counters is not None) else None
        self._clock = clock

    def load(self) -> int:
        if self._count is not None:
            self._count.shared_reads += 1
        return self._value

    def store(self, value: int) -> None:
        if self._count is not None:
            self._count.shared_writes += 1
        self._value = value

    def cas(self, old: int, new: int) -> bool:
        with self._mutex:
            if self._count is not None:
                self._count.cas_calls += 1
            if self._clock is not None:
                self._clock.advance(self._clock.profile.cas_ns)
            if self._value == old:
                self._value = new
                if self._count is not None:
                    self._count.shared_writes += 1
                return True
            return False

    def fetch_add(self, delta: int) -> int:
        with self._mutex:
            old = self._value
            self._value = old + delta
            if self._count is not None:
                self._count.shared_writes += 1
            if self._clock is not None:
                self._clock.advance(self._clock.profile.cas_ns)
            return old


class AtomicRef:
    """Versioned reference supporting LL/VL/SC (ABA-safe, as in paper §6).
    Instrumentation (counters, virtual clock) opt-in as for
    ``AtomicInt``.

    ``mirror=(nvm, addr)`` keeps an NVM word in sync with the reference
    *inside* the SC's critical section.  The durable-MS baseline needs
    this: mirroring head/tail with a plain store after the SC returns
    lets a slower loser overwrite a newer winner's mirror (the
    lost-link race class — harmless under the GIL's coarse
    interleavings in practice, routinely hit under true parallelism),
    and a later pwb then snapshots the regressed pointer into NVMM.
    """

    __slots__ = ("_value", "_mutex", "_count", "_clock", "_mnvm", "_maddr")

    def __init__(self, value: Any, *, shared: bool = False,
                 counters: Optional[Counters] = None,
                 clock: Optional[Any] = None,
                 mirror: Optional[Tuple[Any, int]] = None) -> None:
        self._value: Tuple[Any, int] = (value, 0)
        self._mutex = threading.Lock()
        self._count = counters if (shared and counters is not None) else None
        self._clock = clock
        self._mnvm, self._maddr = mirror if mirror is not None else (None, 0)
        if self._mnvm is not None:
            self._mnvm.write(self._maddr, value)

    def ll(self) -> Tuple[Any, int]:
        """Load-linked: returns (value, version); version feeds VL/SC."""
        if self._count is not None:
            self._count.shared_reads += 1
        return self._value

    def vl(self, version: int) -> bool:
        """Validate: has the reference changed since the LL?"""
        if self._count is not None:
            self._count.shared_reads += 1
        return self._value[1] == version

    def sc(self, version: int, new_value: Any) -> bool:
        """Store-conditional: succeeds iff no SC since the matching LL.
        A configured NVM mirror is updated inside the critical section,
        so mirror order always matches SC success order."""
        with self._mutex:
            if self._count is not None:
                self._count.cas_calls += 1
            if self._clock is not None:
                self._clock.advance(self._clock.profile.cas_ns)
            if self._value[1] == version:
                self._value = (new_value, version + 1)
                if self._mnvm is not None:
                    self._mnvm.write(self._maddr, new_value)
                if self._count is not None:
                    self._count.shared_writes += 1
                return True
            return False

    def load(self) -> Any:
        if self._count is not None:
            self._count.shared_reads += 1
        return self._value[0]
