"""Sequential object interface used by the combining protocols.

A combiner applies announced requests to the ``st`` field of a StateRec
living inside simulated NVMM.  Objects define how many NVM words their
state occupies and how to apply a request to it.  This is the paper's
"derive a recoverable implementation of any data structure from its
sequential implementation" interface (Section 8).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from .nvm import NVM

# Lazily-probed vectorized round bodies (repro.kernels.vector_rounds).
# The probe is deferred so environments without jax (the numpy-only CI
# legs) never pay — or fail — the kernels import; every ``vector_apply``
# then simply reports "no vector path" and combiners run the per-op
# loop.
_VR: Any = None


def _vector():
    global _VR
    if _VR is None:
        try:
            from ..kernels import vector_rounds
            _VR = vector_rounds if vector_rounds.available() else False
        except Exception:
            _VR = False
    return _VR or None


class SeqObject:
    """A sequential object whose state lives in ``state_words`` NVM words."""

    state_words: int = 1
    #: ops guaranteed never to write state.  The lock baselines skip
    #: the whole persistence sentence for these (nothing to flush, and
    #: the response only depends on state already psync'd under the
    #: same lock).  Ops that merely MAY be no-ops (stale CKPT, DEQ on
    #: empty) are not listed: the per-op-persist baselines pay their
    #: unconditional fence+psync there — the wasted work the audit's
    #: redundancy metric exists to expose.
    READ_ONLY: frozenset = frozenset()

    def init_state(self, nvm: NVM, st_base: int) -> None:
        raise NotImplementedError

    def apply(self, nvm: NVM, st_base: int, func: str, args: Any,
              ctx: Optional[Any] = None) -> Any:
        """Apply request ``(func, args)`` to state at ``st_base``; return the
        response.  ``ctx`` is the running combiner instance — structure
        implementations use it to record extra NVM ranges to persist
        (PBQueue's ``toPersist``)."""
        raise NotImplementedError

    def vector_apply(self, nvm: NVM, st_base: int, func: str,
                     args_list: List[Any],
                     ctx: Optional[Any] = None) -> Optional[List[Any]]:
        """VectorApply seam: apply a HOMOGENEOUS batch of ``func``
        announcements (one per combined request, in announcement order)
        as a single jitted kernel over the packed argument array, and
        return the per-request responses — or None to make the combiner
        fall back to d per-op ``apply`` calls.

        The contract is exactness-or-decline: an implementation may only
        return a response list if the resulting state words and
        responses are identical to what the per-op loop would produce
        (repro.kernels.vector_rounds documents the packing guards that
        enforce this).  State is read and written through the volatile
        ``read_range``/``write_range`` accessors, which cost zero NVM
        persistence instructions — the enclosing round's commit sentence
        persists the StateRec exactly as before, so modeled counters are
        untouched by the vector path.  The base object declines always:
        vectorization is opt-in per structure."""
        return None


class AtomicFloatObject(SeqObject):
    """The paper's synthetic benchmark object (Section 6, Figures 1-3):
    ``AtomicFloat(O, k)`` reads v, stores v*k, returns v."""

    state_words = 1

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, 1.0)

    def apply(self, nvm, st_base, func, args, ctx=None):
        v = nvm.read(st_base)
        nvm.write(st_base, v * args)
        return v

    def vector_apply(self, nvm, st_base, func, args_list, ctx=None):
        vr = _vector()
        if vr is None or func != "MUL":
            return None
        out = vr.mul_round(nvm.read(st_base), args_list)
        if out is None:
            return None
        v, resps = out
        nvm.write(st_base, v)
        return resps


class FetchAddObject(SeqObject):
    """Fetch&Add counter — handy for linearizability checking (the multiset
    of responses of k FAA(1) ops must be exactly {0..k-1})."""

    state_words = 1

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, 0)

    def apply(self, nvm, st_base, func, args, ctx=None):
        v = nvm.read(st_base)
        nvm.write(st_base, v + args)
        return v

    def vector_apply(self, nvm, st_base, func, args_list, ctx=None):
        vr = _vector()
        if vr is None or func != "FAA":
            return None
        out = vr.faa_round(nvm.read(st_base), args_list)
        if out is None:
            return None
        v, resps = out
        nvm.write(st_base, v)
        return resps


class SeqQueueObject(SeqObject):
    """Bounded sequential FIFO entirely inside the StateRec.

    State layout: word 0 = head index, word 1 = tail index, words
    2..capacity+1 = ring buffer (indices grow monotonically; the slot is
    ``index % capacity``).  Used by the lock/undo-log baselines so the
    protocol matrix covers ``queue`` for every protocol — the linked
    PBQueue/PWFQueue keep their node-based representation.
    """

    def __init__(self, capacity: int = 1024) -> None:
        self.capacity = capacity
        self.state_words = capacity + 2

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write_range(st_base, [0] * (self.capacity + 2))

    def apply(self, nvm, st_base, func, args, ctx=None):
        head, tail = nvm.read(st_base), nvm.read(st_base + 1)
        if func == "ENQ":
            if tail - head >= self.capacity:
                return False                      # full
            nvm.write(st_base + 2 + tail % self.capacity, args)
            nvm.write(st_base + 1, tail + 1)
            return "ACK"
        if func == "DEQ":
            if head == tail:
                return None                       # empty
            v = nvm.read(st_base + 2 + head % self.capacity)
            nvm.write(st_base, head + 1)
            return v
        raise ValueError(f"unknown queue op {func}")

    def vector_apply(self, nvm, st_base, func, args_list, ctx=None):
        vr = _vector()
        if vr is None or func not in ("ENQ", "DEQ"):
            return None
        head, tail = nvm.read(st_base), nvm.read(st_base + 1)
        if type(head) is not int or type(tail) is not int:
            return None
        ring = nvm.read_range(st_base + 2, self.capacity)
        out = vr.queue_round(ring, head, tail, func, args_list)
        if out is None:
            return None
        ring2, h2, t2, resps = out
        nvm.write(st_base, h2)
        nvm.write(st_base + 1, t2)
        nvm.write_range(st_base + 2, ring2)
        return resps

    def touch_plan(self, nvm: NVM, st_base: int, func: str,
                   args: Any) -> List[Tuple[int, int]]:
        """(offset, n_words) ranges the next ``apply`` will modify —
        lets the lock baselines persist/log only the touched lines
        (their documented scattered-per-op cost shape) instead of the
        whole bounded buffer."""
        head, tail = nvm.read(st_base), nvm.read(st_base + 1)
        if func == "ENQ":
            if tail - head >= self.capacity:
                return []
            return [(1, 1), (2 + tail % self.capacity, 1)]
        return [] if head == tail else [(0, 1)]

    def snapshot(self, nvm: NVM, st_base: int) -> List[Any]:
        head, tail = nvm.read(st_base), nvm.read(st_base + 1)
        return [nvm.read(st_base + 2 + i % self.capacity)
                for i in range(head, tail)]


class SeqStackObject(SeqObject):
    """Bounded sequential LIFO entirely inside the StateRec.

    State layout: word 0 = size, words 1..capacity = the array.  Used by
    the lock/undo-log baselines so the protocol matrix covers ``stack``
    for every protocol.
    """

    def __init__(self, capacity: int = 1024) -> None:
        self.capacity = capacity
        self.state_words = capacity + 1

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write_range(st_base, [0] * (self.capacity + 1))

    def apply(self, nvm, st_base, func, args, ctx=None):
        size = nvm.read(st_base)
        if func == "PUSH":
            if size >= self.capacity:
                return False                      # full
            nvm.write(st_base + 1 + size, args)
            nvm.write(st_base, size + 1)
            return "ACK"
        if func == "POP":
            if size == 0:
                return None                       # empty
            v = nvm.read(st_base + size)
            nvm.write(st_base, size - 1)
            return v
        raise ValueError(f"unknown stack op {func}")

    def vector_apply(self, nvm, st_base, func, args_list, ctx=None):
        vr = _vector()
        if vr is None or func not in ("PUSH", "POP"):
            return None
        size = nvm.read(st_base)
        if type(size) is not int:
            return None
        arr = nvm.read_range(st_base + 1, self.capacity)
        out = vr.stack_round(arr, size, func, args_list)
        if out is None:
            return None
        arr2, s2, resps = out
        nvm.write(st_base, s2)
        nvm.write_range(st_base + 1, arr2)
        return resps

    def touch_plan(self, nvm: NVM, st_base: int, func: str,
                   args: Any) -> List[Tuple[int, int]]:
        """See ``SeqQueueObject.touch_plan``."""
        size = nvm.read(st_base)
        if func == "PUSH":
            if size >= self.capacity:
                return []
            return [(0, 1), (1 + size, 1)]
        return [] if size == 0 else [(0, 1)]

    def snapshot(self, nvm: NVM, st_base: int) -> List[Any]:
        size = nvm.read(st_base)
        return [nvm.read(st_base + 1 + i)
                for i in range(size - 1, -1, -1)]   # top first


class ResponseLogObject(SeqObject):
    """Durable response log — the serving engine's completion path as a
    sequential object (DESIGN.md §8).

    State layout: client c owns words ``2c`` (last seq) and ``2c + 1``
    (last response).  Responses are rich payloads (token lists, dicts):
    on the shm backend they ride the blob heap; the thread backend's
    Python-object words hold them natively.

    Ops:
      * ``RECORD (client, seq, response)`` — overwrite c's pair; returns
        the response.  Idempotent: replaying a RECORD with the same
        arguments is a no-op in effect, which is what makes the
        adapter's crash replay exactly-once *in effect* without leaning
        on the protocol's per-thread announce parity (a batched
        RECORD_MANY advances the handle seq by more than one, so parity
        detectability does not apply here).
      * ``RECORD_MANY ((client, seq, response), ...)`` — one combining
        round persists every completion of a serving round together
        (one contiguous StateRec write, one psync).
      * ``LOOKUP client`` — (seq, response) pair; the paper's Recover
        reads this to answer re-announced requests from the log.
    """

    READ_ONLY = frozenset({"LOOKUP"})

    def __init__(self, n_clients: int = 8) -> None:
        self.n_clients = n_clients
        self.state_words = 2 * n_clients

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write_range(st_base, [0, None] * self.n_clients)

    def _record(self, nvm, st_base, client, seq, response) -> None:
        if not 0 <= client < self.n_clients:
            raise ValueError(f"client {client} out of range "
                             f"(log has {self.n_clients} slots)")
        # response before seq: a torn StateRec can never pair a new seq
        # with an old response (same publication discipline as the words)
        nvm.write(st_base + 2 * client + 1, response)
        nvm.write(st_base + 2 * client, seq)

    def apply(self, nvm, st_base, func, args, ctx=None):
        if func == "RECORD":
            client, seq, response = args
            self._record(nvm, st_base, client, seq, response)
            return response
        if func == "RECORD_MANY":
            for client, seq, response in args:
                self._record(nvm, st_base, client, seq, response)
            return tuple(r for _c, _s, r in args)
        if func == "LOOKUP":
            c = args
            return (nvm.read(st_base + 2 * c),
                    nvm.read(st_base + 2 * c + 1))
        raise ValueError(f"unknown log op {func}")

    def vector_apply(self, nvm, st_base, func, args_list, ctx=None):
        # KV/log record batches: d RECORDs scatter-scanned in one kernel
        # (RECORD_MANY batches are tuples-of-tuples — eager path).
        vr = _vector()
        if vr is None or func != "RECORD":
            return None
        if not all(isinstance(t, (tuple, list)) and len(t) == 3
                   for t in args_list):
            return None
        out = vr.log_round(self.n_clients, args_list)
        if out is None:
            return None
        writes, resps = out
        for client, seq, resp in writes:
            # response before seq — same torn-StateRec discipline as
            # the eager ``_record``
            nvm.write(st_base + 2 * client + 1, resp)
            nvm.write(st_base + 2 * client, seq)
        return resps

    def touch_plan(self, nvm: NVM, st_base: int, func: str,
                   args: Any) -> List[Tuple[int, int]]:
        if func == "RECORD":
            return [(2 * args[0], 2)]
        if func == "RECORD_MANY":
            return [(2 * c, 2) for c, _s, _r in args]
        return []

    def snapshot(self, nvm: NVM, st_base: int) -> List[Tuple[int, Any]]:
        return [(nvm.read(st_base + 2 * c), nvm.read(st_base + 2 * c + 1))
                for c in range(self.n_clients)]


class CheckpointObject(SeqObject):
    """Checkpoint cell — the sharded-checkpoint commit as a sequential
    object: one (step, payload) pair, newest step wins (exactly the
    ``PBCombCheckpointer``'s object semantics, but living in NVM words
    so the shm backend can combine checkpoint announcements from real
    worker processes).

    Ops:
      * ``CKPT (step, payload)`` — install iff ``step`` advances the
        durable step; response is the step now current (monotone, so
        crash replay is idempotent: a replayed CKPT that already took
        effect — or was superseded — changes nothing).
      * ``CKPTGET`` — the (step, payload) pair.
    """

    state_words = 2
    READ_ONLY = frozenset({"CKPTGET"})

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write_range(st_base, [0, None])

    def apply(self, nvm, st_base, func, args, ctx=None):
        if func == "CKPT":
            step, payload = args
            cur = nvm.read(st_base)
            if step > cur:
                # payload before step: a torn StateRec never pairs a
                # new step with an old payload
                nvm.write(st_base + 1, payload)
                nvm.write(st_base, step)
                return step
            return cur
        if func == "CKPTGET":
            return (nvm.read(st_base), nvm.read(st_base + 1))
        raise ValueError(f"unknown checkpoint op {func}")

    def vector_apply(self, nvm, st_base, func, args_list, ctx=None):
        vr = _vector()
        if vr is None or func != "CKPT":
            return None
        if not all(isinstance(t, (tuple, list)) and len(t) == 2
                   for t in args_list):
            return None
        out = vr.ckpt_round(nvm.read(st_base), args_list)
        if out is None:
            return None
        st, pl, resps = out
        if pl is not None:       # some element advanced the step
            # payload before step — same torn-StateRec discipline
            nvm.write(st_base + 1, pl)
            nvm.write(st_base, st)
        return resps

    def touch_plan(self, nvm: NVM, st_base: int, func: str,
                   args: Any) -> List[Tuple[int, int]]:
        if func == "CKPT" and args[0] > nvm.read(st_base):
            return [(0, 2)]
        return []

    def snapshot(self, nvm: NVM, st_base: int) -> Dict[str, Any]:
        return {"step": nvm.read(st_base),
                "payload": nvm.read(st_base + 1)}


class HeapObject(SeqObject):
    """Bounded sequential min-heap (paper Section 5, PBHEAP).

    State layout: word 0 = current size, words 1..capacity = the array.
    Supports HINSERT / HDELETEMIN / HGETMIN.
    """

    READ_ONLY = frozenset({"HGETMIN"})

    def __init__(self, capacity: int) -> None:
        self.capacity = capacity
        self.state_words = capacity + 1

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write_range(st_base, [0] * (self.capacity + 1))

    # -- sequential helpers on NVM words ------------------------------- #
    def _get(self, nvm, b, i):
        return nvm.read(b + 1 + i)

    def _set(self, nvm, b, i, v):
        nvm.write(b + 1 + i, v)

    def apply(self, nvm, st_base, func, args, ctx=None):
        size = nvm.read(st_base)
        if func == "HGETMIN":
            return self._get(nvm, st_base, 0) if size > 0 else None
        if func == "HINSERT":
            if size >= self.capacity:
                return False
            i = size
            self._set(nvm, st_base, i, args)
            while i > 0:
                parent = (i - 1) // 2
                if self._get(nvm, st_base, parent) <= self._get(nvm, st_base, i):
                    break
                a = self._get(nvm, st_base, parent)
                b_ = self._get(nvm, st_base, i)
                self._set(nvm, st_base, parent, b_)
                self._set(nvm, st_base, i, a)
                i = parent
            nvm.write(st_base, size + 1)
            return True
        if func == "HDELETEMIN":
            if size == 0:
                return None
            top = self._get(nvm, st_base, 0)
            last = self._get(nvm, st_base, size - 1)
            size -= 1
            nvm.write(st_base, size)
            if size > 0:
                self._set(nvm, st_base, 0, last)
                i = 0
                while True:
                    l, r = 2 * i + 1, 2 * i + 2
                    smallest = i
                    if l < size and self._get(nvm, st_base, l) < self._get(nvm, st_base, smallest):
                        smallest = l
                    if r < size and self._get(nvm, st_base, r) < self._get(nvm, st_base, smallest):
                        smallest = r
                    if smallest == i:
                        break
                    a = self._get(nvm, st_base, i)
                    b_ = self._get(nvm, st_base, smallest)
                    self._set(nvm, st_base, i, b_)
                    self._set(nvm, st_base, smallest, a)
                    i = smallest
            return top
        raise ValueError(f"unknown heap op {func}")

    def vector_apply(self, nvm, st_base, func, args_list, ctx=None):
        # heap key-array ops: a homogeneous HINSERT/HDELETEMIN round is
        # one lax.scan over the announcements, each step sifting via a
        # lax.while_loop on the packed key array
        vr = _vector()
        if vr is None or func not in ("HINSERT", "HDELETEMIN"):
            return None
        size = nvm.read(st_base)
        if type(size) is not int:
            return None
        arr = nvm.read_range(st_base + 1, self.capacity)
        out = vr.heap_round(arr, size, func, args_list)
        if out is None:
            return None
        arr2, size2, resps = out
        nvm.write(st_base, size2)
        nvm.write_range(st_base + 1, arr2)
        return resps
