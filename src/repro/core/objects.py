"""Sequential object interface used by the combining protocols.

A combiner applies announced requests to the ``st`` field of a StateRec
living inside simulated NVMM.  Objects define how many NVM words their
state occupies and how to apply a request to it.  This is the paper's
"derive a recoverable implementation of any data structure from its
sequential implementation" interface (Section 8).
"""

from __future__ import annotations

from typing import Any, List, Optional, Tuple

from .nvm import NVM


class SeqObject:
    """A sequential object whose state lives in ``state_words`` NVM words."""

    state_words: int = 1

    def init_state(self, nvm: NVM, st_base: int) -> None:
        raise NotImplementedError

    def apply(self, nvm: NVM, st_base: int, func: str, args: Any,
              ctx: Optional[Any] = None) -> Any:
        """Apply request ``(func, args)`` to state at ``st_base``; return the
        response.  ``ctx`` is the running combiner instance — structure
        implementations use it to record extra NVM ranges to persist
        (PBQueue's ``toPersist``)."""
        raise NotImplementedError


class AtomicFloatObject(SeqObject):
    """The paper's synthetic benchmark object (Section 6, Figures 1-3):
    ``AtomicFloat(O, k)`` reads v, stores v*k, returns v."""

    state_words = 1

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, 1.0)

    def apply(self, nvm, st_base, func, args, ctx=None):
        v = nvm.read(st_base)
        nvm.write(st_base, v * args)
        return v


class FetchAddObject(SeqObject):
    """Fetch&Add counter — handy for linearizability checking (the multiset
    of responses of k FAA(1) ops must be exactly {0..k-1})."""

    state_words = 1

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, 0)

    def apply(self, nvm, st_base, func, args, ctx=None):
        v = nvm.read(st_base)
        nvm.write(st_base, v + args)
        return v


class HeapObject(SeqObject):
    """Bounded sequential min-heap (paper Section 5, PBHEAP).

    State layout: word 0 = current size, words 1..capacity = the array.
    Supports HINSERT / HDELETEMIN / HGETMIN.
    """

    def __init__(self, capacity: int) -> None:
        self.capacity = capacity
        self.state_words = capacity + 1

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, 0)
        for i in range(1, self.capacity + 1):
            nvm.write(st_base + i, 0)

    # -- sequential helpers on NVM words ------------------------------- #
    def _get(self, nvm, b, i):
        return nvm.read(b + 1 + i)

    def _set(self, nvm, b, i, v):
        nvm.write(b + 1 + i, v)

    def apply(self, nvm, st_base, func, args, ctx=None):
        size = nvm.read(st_base)
        if func == "HGETMIN":
            return self._get(nvm, st_base, 0) if size > 0 else None
        if func == "HINSERT":
            if size >= self.capacity:
                return False
            i = size
            self._set(nvm, st_base, i, args)
            while i > 0:
                parent = (i - 1) // 2
                if self._get(nvm, st_base, parent) <= self._get(nvm, st_base, i):
                    break
                a = self._get(nvm, st_base, parent)
                b_ = self._get(nvm, st_base, i)
                self._set(nvm, st_base, parent, b_)
                self._set(nvm, st_base, i, a)
                i = parent
            nvm.write(st_base, size + 1)
            return True
        if func == "HDELETEMIN":
            if size == 0:
                return None
            top = self._get(nvm, st_base, 0)
            last = self._get(nvm, st_base, size - 1)
            size -= 1
            nvm.write(st_base, size)
            if size > 0:
                self._set(nvm, st_base, 0, last)
                i = 0
                while True:
                    l, r = 2 * i + 1, 2 * i + 2
                    smallest = i
                    if l < size and self._get(nvm, st_base, l) < self._get(nvm, st_base, smallest):
                        smallest = l
                    if r < size and self._get(nvm, st_base, r) < self._get(nvm, st_base, smallest):
                        smallest = r
                    if smallest == i:
                        break
                    a = self._get(nvm, st_base, i)
                    b_ = self._get(nvm, st_base, smallest)
                    self._set(nvm, st_base, i, b_)
                    self._set(nvm, st_base, smallest, a)
                    i = smallest
            return top
        raise ValueError(f"unknown heap op {func}")
