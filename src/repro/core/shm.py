"""Shared-memory multiprocess execution backend (DESIGN.md §7).

Under CPython's GIL the *measured* combining degree is pinned near 1 —
only the modeled pass could stage paper-scale rounds (ROADMAP).  This
module moves every word the protocols share into one
``multiprocessing.shared_memory`` segment so fork()ed worker processes
announce/combine against the same board with true parallelism:

  * ``ShmNVM`` — the simulated NVMM (volatile + durable images, the
    epoch write-back ring, pwb/pfence/psync counters, crash countdown
    and the machine-off ``halted`` flag) entirely in shared memory,
    guarded by one fork-inherited lock.  Same public interface and
    crash semantics as ``NVM``; the fused persistence sentences fall
    back to their discrete forms (``_fast_ok`` is False), which keeps
    pwb/pfence/psync counter arithmetic identical to the in-thread
    backend — that is what the replay-equivalence tests pin.
  * ``ShmBackend`` — the ``core.backend`` seam over the same segment:
    lock-striped CAS emulation for AtomicInt/AtomicRef/SRef, shared
    request boards, cells, int arrays, degree counters.

Word encoding: each simulated NVM word (and each board/cell slot) is
``WORD_I64`` int64s — a tag plus 16 payload bytes — covering the value
domain the recoverable structures actually store: ints, None, bools,
floats, and short strings (op tags like "ENQ", responses like "ACK").
Anything else raises ``TypeError`` with the offending value; rich
payloads belong to the thread backend.

Atomicity notes.  Aligned 8-byte loads/stores through a ``cast('q')``
memoryview are single C-level stores; mutating operations (cas,
fetch_add, SC) additionally serialize through a striped lock, and
multi-i64 slots order payload-before-tag on write (tag-before-payload
on read) with the protocols' own ``valid`` flags providing the
publication barrier — the same discipline the GIL gave the thread
backend for free.

Fork discipline: create the runtime, its structures, and the worker
pool IN THAT ORDER — mp primitives and shared views are inherited by
fork, so everything shared must exist before ``spawn_workers``.
"""

from __future__ import annotations

import multiprocessing
import struct
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

from .atomics import Counters
from .backend import ThreadBackend
from .nvm import LINE, NVM, SimulatedCrash

WORD_I64 = 3          # int64s per codec word: tag + 2 payload words

# value tags
_T_INT = 0
_T_NONE = 1
_T_FALSE = 2
_T_TRUE = 3
_T_FLOAT = 4
_T_STR = 16           # tag = _T_STR + utf-8 byte length (0..16)
_STR_MAX = 16

_I64_MIN, _I64_MAX = -(1 << 63), (1 << 63) - 1


def encode(value: Any) -> Tuple[int, int, int]:
    """Python value -> (tag, payload_a, payload_b).  The supported
    domain is exactly what the recoverable structures store in NVM
    words; see module docstring."""
    if value is None:
        return _T_NONE, 0, 0
    if value is True:
        return _T_TRUE, 0, 0
    if value is False:
        return _T_FALSE, 0, 0
    if type(value) is int:
        if not _I64_MIN <= value <= _I64_MAX:
            raise TypeError(f"int {value!r} exceeds the shm backend's "
                            "64-bit word")
        return _T_INT, value, 0
    if type(value) is float:
        return _T_FLOAT, struct.unpack("<q", struct.pack("<d", value))[0], 0
    if type(value) is str:
        raw = value.encode("utf-8")
        if len(raw) > _STR_MAX:
            raise TypeError(f"str {value!r} exceeds {_STR_MAX} utf-8 "
                            "bytes (shm backend word)")
        raw = raw.ljust(_STR_MAX, b"\0")
        return (_T_STR + len(value.encode('utf-8')),
                int.from_bytes(raw[:8], "little", signed=True),
                int.from_bytes(raw[8:], "little", signed=True))
    raise TypeError(
        f"the shm backend stores ints, floats, bools, None and short "
        f"strings in NVM words; got {type(value).__name__}: {value!r}")


def decode(tag: int, a: int, b: int) -> Any:
    if tag == _T_INT:
        return a
    if tag == _T_NONE:
        return None
    if tag == _T_TRUE:
        return True
    if tag == _T_FALSE:
        return False
    if tag == _T_FLOAT:
        return struct.unpack("<d", struct.pack("<q", a))[0]
    if _T_STR <= tag <= _T_STR + _STR_MAX:
        raw = (a.to_bytes(8, "little", signed=True)
               + b.to_bytes(8, "little", signed=True))
        return raw[:tag - _T_STR].decode("utf-8")
    raise ValueError(f"corrupt shm word tag {tag}")


class _Words:
    """Codec-word array view: word i lives at i64 offset
    ``base + WORD_I64 * i`` of the backing memoryview."""

    __slots__ = ("mv", "base")

    def __init__(self, mv, base_i64: int) -> None:
        self.mv = mv
        self.base = base_i64

    def get(self, i: int) -> Any:
        o = self.base + WORD_I64 * i
        mv = self.mv
        return decode(mv[o], mv[o + 1], mv[o + 2])

    def set(self, i: int, value: Any) -> None:
        t, a, b = encode(value)
        o = self.base + WORD_I64 * i
        mv = self.mv
        # payload before tag: a reader that sees the new tag sees the
        # new payload (TSO); single-word int updates hinge on mv[o+1]
        mv[o + 1] = a
        mv[o + 2] = b
        mv[o] = t

    def get_range(self, i: int, n: int) -> List[Any]:
        return [self.get(i + j) for j in range(n)]

    def set_range(self, i: int, values) -> None:
        for j, v in enumerate(values):
            self.set(i + j, v)


# --------------------------------------------------------------------- #
# Backend primitives                                                    #
# --------------------------------------------------------------------- #
class ShmMutex:
    """Mutex over a fork-inherited semaphore.  ``reset`` drains it back
    to exactly one permit — a crashed holder can never be unwound from
    another process, so post-crash recovery forces the released state."""

    __slots__ = ("_sem",)

    def __init__(self, ctx) -> None:
        self._sem = ctx.Semaphore(1)

    def acquire(self, blocking: bool = True) -> bool:
        return self._sem.acquire(blocking)

    def release(self) -> None:
        self._sem.release()

    def __enter__(self):
        self._sem.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self._sem.release()

    def reset(self) -> None:
        while self._sem.acquire(False):
            pass
        self._sem.release()


class ShmAtomicInt:
    """AtomicInt over one shared int64: plain aligned load/store, CAS
    and fetch&add emulated under a striped fork-inherited lock."""

    __slots__ = ("_mv", "_off", "_lock", "_count", "_clock")

    def __init__(self, backend: "ShmBackend", value: int = 0, *,
                 shared: bool = False,
                 counters: Optional[Counters] = None,
                 clock: Optional[Any] = None) -> None:
        self._mv = backend.mv
        self._off = backend.aux_alloc(1)
        self._lock = backend.stripe(self._off)
        self._count = counters if (shared and counters is not None) else None
        self._clock = clock          # always None in shm mode (no profile)
        self._mv[self._off] = value

    def load(self) -> int:
        if self._count is not None:
            self._count.shared_reads += 1
        return self._mv[self._off]

    def store(self, value: int) -> None:
        if self._count is not None:
            self._count.shared_writes += 1
        self._mv[self._off] = value

    def cas(self, old: int, new: int) -> bool:
        with self._lock:
            if self._count is not None:
                self._count.cas_calls += 1
            if self._mv[self._off] == old:
                self._mv[self._off] = new
                if self._count is not None:
                    self._count.shared_writes += 1
                return True
            return False

    def fetch_add(self, delta: int) -> int:
        with self._lock:
            old = self._mv[self._off]
            self._mv[self._off] = old + delta
            if self._count is not None:
                self._count.shared_writes += 1
            return old

    def reset(self, value: int = 0) -> None:
        self._mv[self._off] = value


class ShmAtomicRef:
    """Versioned LL/VL/SC reference over shared memory (codec value +
    raw version word).  Supports the same ``mirror=(nvm, addr)`` as the
    thread AtomicRef: the mirror write lands inside the SC's critical
    section."""

    __slots__ = ("_words", "_idx", "_mv", "_voff", "_lock", "_count",
                 "_mnvm", "_maddr")

    def __init__(self, backend: "ShmBackend", value: Any, *,
                 shared: bool = False,
                 counters: Optional[Counters] = None,
                 clock: Optional[Any] = None,
                 mirror: Optional[Tuple[Any, int]] = None) -> None:
        off = backend.aux_alloc(WORD_I64 + 1)
        self._words = _Words(backend.mv, off)
        self._idx = 0
        self._mv = backend.mv
        self._voff = off + WORD_I64
        self._lock = backend.stripe(off)
        self._count = counters if (shared and counters is not None) else None
        self._mnvm, self._maddr = mirror if mirror is not None else (None, 0)
        self.reset(value)

    def ll(self) -> Tuple[Any, int]:
        if self._count is not None:
            self._count.shared_reads += 1
        # version first: if it is unchanged after the value read, the
        # value belongs to that version (SC bumps version last)
        ver = self._mv[self._voff]
        return self._words.get(self._idx), ver

    def vl(self, version: int) -> bool:
        if self._count is not None:
            self._count.shared_reads += 1
        return self._mv[self._voff] == version

    def sc(self, version: int, new_value: Any) -> bool:
        with self._lock:
            if self._count is not None:
                self._count.cas_calls += 1
            if self._mv[self._voff] == version:
                self._words.set(self._idx, new_value)
                if self._mnvm is not None:
                    self._mnvm.write(self._maddr, new_value)
                self._mv[self._voff] = version + 1
                if self._count is not None:
                    self._count.shared_writes += 1
                return True
            return False

    def load(self) -> Any:
        if self._count is not None:
            self._count.shared_reads += 1
        return self._words.get(self._idx)

    def reset(self, value: Any) -> None:
        with self._lock:
            self._words.set(self._idx, value)
            if self._mnvm is not None:
                self._mnvm.write(self._maddr, value)
            self._mv[self._voff] = 0


class ShmSRef:
    """PWFComb's S: versioned LL/VL/SC whose value is mirrored into an
    NVM word inside the SC mutex (the shm variant of ``_SRef``)."""

    __slots__ = ("nvm", "addr", "_mv", "_voff", "_soff", "_mutex",
                 "_counters")

    def __init__(self, backend: "ShmBackend", nvm: "ShmNVM", addr: int,
                 value: int, counters: Optional[Counters] = None) -> None:
        off = backend.aux_alloc(2)
        self._mv = backend.mv
        self._soff = off          # slot id (int, raw)
        self._voff = off + 1      # version
        self._mutex = backend.stripe(off)
        self.nvm = nvm
        self.addr = addr
        self._counters = counters
        self.reset(nvm, addr, value)

    def ll(self):
        if self._counters:
            self._counters.shared_reads += 1
        ver = self._mv[self._voff]
        return self._mv[self._soff], ver

    def vl(self, version: int) -> bool:
        return self._mv[self._voff] == version

    def sc(self, version: int, new_value: int) -> bool:
        with self._mutex:
            if self._counters:
                self._counters.cas_calls += 1
            if self._mv[self._voff] == version:
                self._mv[self._soff] = new_value
                self.nvm.write(self.addr, new_value)
                self._mv[self._voff] = version + 1
                return True
            return False

    def load(self) -> int:
        return self._mv[self._soff]

    def reset(self, nvm: "ShmNVM", addr: int, value: int) -> None:
        with self._mutex:
            self._mv[self._soff] = value
            nvm.write(addr, value)
            self._mv[self._voff] = 0


class ShmCell:
    """One shared codec word with a ``value`` attribute (LockVal,
    oldTail).  Single-word plain loads/stores, like the thread Cell."""

    __slots__ = ("_words",)

    def __init__(self, backend: "ShmBackend", value: Any = None) -> None:
        self._words = _Words(backend.mv, backend.aux_alloc(WORD_I64))
        self._words.set(0, value)

    @property
    def value(self) -> Any:
        return self._words.get(0)

    @value.setter
    def value(self, v: Any) -> None:
        self._words.set(0, v)


class ShmIntArray:
    """Raw shared int64 array (PWFComb's Flush / CombRound rows)."""

    __slots__ = ("_mv", "_off", "_n")

    def __init__(self, mv, off: int, n: int, init: int = 0) -> None:
        self._mv = mv
        self._off = off
        self._n = n
        self.fill(init)

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, i: int) -> int:
        return self._mv[self._off + i]

    def __setitem__(self, i: int, v: int) -> None:
        self._mv[self._off + i] = v

    def fill(self, value: int) -> None:
        mv, off = self._mv, self._off
        for i in range(self._n):
            mv[off + i] = value


# Request-board field offsets (codec words per RequestRec slot).
_RB_FUNC, _RB_ARGS, _RB_ACT, _RB_VALID, _RB_VTIME, _RB_WORDS = 0, 1, 2, 3, 4, 5


class ShmRequestRec:
    """View of one announcement slot; property-per-field so the
    protocols' in-place announce sequence (valid=0 ... valid=1) hits
    shared memory in program order."""

    __slots__ = ("_w", "_b")

    def __init__(self, words: _Words, base_word: int) -> None:
        self._w = words
        self._b = base_word

    @property
    def func(self):
        return self._w.get(self._b + _RB_FUNC)

    @func.setter
    def func(self, v):
        self._w.set(self._b + _RB_FUNC, v)

    @property
    def args(self):
        return self._w.get(self._b + _RB_ARGS)

    @args.setter
    def args(self, v):
        self._w.set(self._b + _RB_ARGS, v)

    @property
    def activate(self):
        return self._w.get(self._b + _RB_ACT)

    @activate.setter
    def activate(self, v):
        self._w.set(self._b + _RB_ACT, v)

    @property
    def valid(self):
        return self._w.get(self._b + _RB_VALID)

    @valid.setter
    def valid(self, v):
        self._w.set(self._b + _RB_VALID, v)

    @property
    def vtime(self):
        return self._w.get(self._b + _RB_VTIME)

    @vtime.setter
    def vtime(self, v):
        self._w.set(self._b + _RB_VTIME, v)


class ShmRequestBoard(list):
    """Announcement board in shared memory: ``board[p]`` is a live view;
    assigning a RequestRec copies its fields (valid published last)."""

    def __init__(self, backend: "ShmBackend", n_threads: int) -> None:
        words = _Words(backend.mv,
                       backend.aux_alloc(WORD_I64 * _RB_WORDS * n_threads))
        super().__init__(ShmRequestRec(words, _RB_WORDS * p)
                         for p in range(n_threads))
        self.reset()

    def __setitem__(self, p: int, rec: Any) -> None:
        view = list.__getitem__(self, p)
        view.valid = 0
        view.func = rec.func
        view.args = rec.args
        view.activate = rec.activate
        view.vtime = rec.vtime
        view.valid = rec.valid

    def reset(self) -> None:
        for view in self:
            view.valid = 0
            view.func = None
            view.args = None
            view.activate = 0
            view.vtime = 0.0


class ShmDegreeStats:
    """Measured-degree counters in shared memory — combiners in any
    process accumulate into the same three words."""

    __slots__ = ("_mv", "_off", "_lock")

    def __init__(self, backend: "ShmBackend") -> None:
        self._off = backend.aux_alloc(3)
        self._mv = backend.mv
        self._lock = backend.stripe(self._off)
        self.reset()

    def record(self, served: int) -> None:
        mv, off = self._mv, self._off
        with self._lock:
            mv[off] += 1
            mv[off + 1] += served
            if served > mv[off + 2]:
                mv[off + 2] = served

    def snapshot(self) -> dict:
        mv, off = self._mv, self._off
        with self._lock:
            return {"rounds": mv[off], "ops_combined": mv[off + 1],
                    "degree_max": mv[off + 2]}

    def reset(self) -> None:
        mv, off = self._mv, self._off
        with self._lock:
            mv[off] = mv[off + 1] = mv[off + 2] = 0


# --------------------------------------------------------------------- #
# The backend                                                           #
# --------------------------------------------------------------------- #
# meta slot indexes (int64)
_M_ALLOC = 0        # NVM word bump pointer
_M_AUX = 1          # aux-area bump pointer (i64 units, relative)
_M_COUNT = 2        # crash countdown (-1 = disarmed)
_M_SEED = 3         # adversarial-drain seed (-1 = drain nothing)
_M_HALT = 4         # machine-off flag
_M_EPOCH = 5        # current epoch id
_M_EFLAG = 6        # 1 iff the current epoch has queued entries
_M_RING = 7         # ring used (i64 units, relative to ring base)
_M_PWB, _M_PFENCE, _M_PSYNC, _M_CRASHES = 8, 9, 10, 11
_M_SPILLS = 12      # ring-overflow early drains (visibility)
_META_I64 = 16

_CTR_SLOT = {"pwb": _M_PWB, "pfence": _M_PFENCE, "psync": _M_PSYNC,
             "crashes": _M_CRASHES, "ring_spills": _M_SPILLS}


class _ShmCounters:
    """Dict-like view of the shared pwb/pfence/psync/crashes slots, so
    ``nvm.counters["pwb"]`` reads the machine-wide count from any
    process."""

    __slots__ = ("_mv",)

    def __init__(self, mv) -> None:
        self._mv = mv

    def __getitem__(self, key: str) -> int:
        return self._mv[_CTR_SLOT[key]]

    def __setitem__(self, key: str, value: int) -> None:
        self._mv[_CTR_SLOT[key]] = value

    def __iter__(self) -> Iterator[str]:
        return iter(_CTR_SLOT)

    def keys(self):
        return _CTR_SLOT.keys()

    def snapshot(self) -> Dict[str, int]:
        return {k: self._mv[v] for k, v in _CTR_SLOT.items()}

    def __repr__(self) -> str:
        return f"_ShmCounters({self.snapshot()})"


class ShmBackend(ThreadBackend):
    """``core.backend`` seam over one shared-memory segment.

    Inherits the thread backend and overrides every factory whose
    object must be visible across processes; the ``reset_*`` overrides
    reset IN PLACE (fork-inherited views in workers must stay
    attached).  All factories are create-before-fork: call them (i.e.
    build runtimes/structures) before ``spawn_workers``.
    """

    kind = "shm"

    #: striped-lock pool size: enough to make false sharing of stripes
    #: unlikely at 8 workers, few enough to keep fd/semaphore count low.
    N_STRIPES = 16

    #: Entry backoff under true parallelism (see
    #: ``ThreadBackend.announce_park``): park every announcement for
    #: ~one round so a concurrent combiner adopts it — the measured
    #: degree >= 2 the reproduction targets comes from this window.
    #: Tunable per backend instance (mp_bench exposes --park).
    PARK_PROB = 1.0
    PARK_SECONDS = 1e-4

    def __init__(self, data_words: int = 1 << 18, *,
                 aux_i64: int = 1 << 16, ring_i64: int = 1 << 18) -> None:
        from multiprocessing import shared_memory
        self._ctx = multiprocessing.get_context("fork")
        self.data_words = data_words
        total = (_META_I64 + 2 * data_words * WORD_I64 + ring_i64
                 + aux_i64)
        self._shm = shared_memory.SharedMemory(create=True, size=total * 8)
        self.mv = self._shm.buf.cast("q")
        # fresh /dev/shm pages are zero-filled; meta needs two non-zeros
        self.mv[_M_COUNT] = -1
        self.mv[_M_SEED] = -1
        self.vol_base = _META_I64
        self.dur_base = self.vol_base + data_words * WORD_I64
        self.ring_base = self.dur_base + data_words * WORD_I64
        self.ring_cap = ring_i64
        self.aux_base = self.ring_base + ring_i64
        self.aux_cap = aux_i64
        self._stripes = [self._ctx.Lock() for _ in range(self.N_STRIPES)]
        self._alloc_lock = self._ctx.Lock()
        self.nvm_lock = self._ctx.Lock()     # guards images/ring/counters
        self.device_lock = self._ctx.Lock()  # wall persist_latency drains
        self._closed = False

    # ---------------- segment plumbing --------------------------------- #
    def aux_alloc(self, n_i64: int) -> int:
        """Bump-allocate ``n_i64`` aux slots; absolute i64 offset."""
        with self._alloc_lock:
            used = self.mv[_M_AUX]
            if used + n_i64 > self.aux_cap:
                raise MemoryError("shm backend aux area exhausted "
                                  f"({self.aux_cap} i64)")
            self.mv[_M_AUX] = used + n_i64
            return self.aux_base + used

    def stripe(self, off: int):
        return self._stripes[off % self.N_STRIPES]

    def close(self) -> None:
        """Release the segment (call from the creating process, after
        worker pools are joined).  Safe to call twice."""
        if self._closed:
            return
        self._closed = True
        mv, self.mv = self.mv, None
        mv.release()
        self._shm.close()
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass

    # ---------------- factories ---------------------------------------- #
    def mutex(self) -> ShmMutex:
        return ShmMutex(self._ctx)

    def cell(self, value: Any = None) -> ShmCell:
        return ShmCell(self, value)

    def atomic_int(self, value: int = 0, *, shared: bool = False,
                   counters: Optional[Counters] = None,
                   clock: Optional[Any] = None) -> ShmAtomicInt:
        return ShmAtomicInt(self, value, shared=shared, counters=counters,
                            clock=clock)

    def atomic_ref(self, value: Any, *, shared: bool = False,
                   counters: Optional[Counters] = None,
                   clock: Optional[Any] = None,
                   mirror: Optional[Tuple[Any, int]] = None) -> ShmAtomicRef:
        return ShmAtomicRef(self, value, shared=shared, counters=counters,
                            clock=clock, mirror=mirror)

    def sref(self, nvm: Any, addr: int, value: int,
             counters: Optional[Counters] = None) -> ShmSRef:
        return ShmSRef(self, nvm, addr, value, counters)

    def int_array(self, n: int, init: int = 0) -> ShmIntArray:
        return ShmIntArray(self.mv, self.aux_alloc(n), n, init)

    def int_matrix(self, rows: int, cols: int) -> List[ShmIntArray]:
        return [self.int_array(cols) for _ in range(rows)]

    def request_board(self, n_threads: int) -> ShmRequestBoard:
        return ShmRequestBoard(self, n_threads)

    def degree_stats(self) -> ShmDegreeStats:
        return ShmDegreeStats(self)

    def announce_park(self, prob: float, seconds: float
                      ) -> Tuple[float, float]:
        return self.PARK_PROB, self.PARK_SECONDS

    # ---------------- in-place resets ----------------------------------- #
    def reset_mutex(self, m: ShmMutex) -> ShmMutex:
        m.reset()
        return m

    def reset_atomic_int(self, a: ShmAtomicInt, value: int = 0,
                         **_kw) -> ShmAtomicInt:
        a.reset(value)
        return a

    def reset_atomic_ref(self, a: ShmAtomicRef, value: Any, *,
                         mirror: Optional[Tuple[Any, int]] = None,
                         **_kw) -> ShmAtomicRef:
        a.reset(value)
        return a

    def reset_sref(self, s: ShmSRef, nvm: Any, addr: int, value: int,
                   counters: Optional[Counters] = None) -> ShmSRef:
        s.reset(nvm, addr, value)
        return s


# --------------------------------------------------------------------- #
# The NVM                                                               #
# --------------------------------------------------------------------- #
class ShmNVM(NVM):
    """Simulated NVMM whose images, write-back ring, counters and crash
    machinery live in the backend's shared segment.

    Same interface and crash semantics as ``NVM`` with three
    multiprocess-specific differences, all visible only to shm runs:

      * fused persistence sentences always take the discrete path
        (identical counters/durability — the fused forms are a
        same-process lock elision that a cross-process lock cannot
        reproduce), so the virtual clock/profile is unsupported here;
      * ``crash()`` additionally raises the shared ``halted`` flag —
        a SimulatedCrash only unwinds the process that hit it, so
        survivors poll the flag from persistence instructions and wait
        loops and stop as if their power was cut.  ``disarm_crash``
        (called by ``CombiningRuntime.recover``) clears it;
      * if the write-back ring fills, the oldest pending write-backs
        are drained to the durable image early (counted in
        ``ring_spills``).  Legal under explicit epoch persistency: the
        lines were pwb'd, the hardware may complete them any time
        before the psync.
    """

    def __init__(self, n_words: int = 1 << 18, *,
                 backend: Optional[ShmBackend] = None,
                 pwb_nop: bool = False, psync_nop: bool = False,
                 persist_latency: float = 0.0) -> None:
        if backend is None:
            backend = ShmBackend(data_words=n_words)
        if n_words > backend.data_words:
            raise ValueError(f"n_words={n_words} exceeds backend segment "
                             f"({backend.data_words} words)")
        # deliberately NOT calling NVM.__init__: the images live in the
        # segment, and every inherited method that touches them is
        # overridden (the fused sentences dispatch through _fast_ok).
        self.backend = backend
        self.n_words = n_words
        self._vol = _Words(backend.mv, backend.vol_base)
        self._dur = _Words(backend.mv, backend.dur_base)
        self._mv = backend.mv
        self._lock = backend.nvm_lock
        self.pwb_nop = pwb_nop
        self.psync_nop = psync_nop
        self.persist_latency = persist_latency
        self.clock = None
        self.force_discrete = False
        self.counters = _ShmCounters(backend.mv)
        self._crash_rng = None
        mv = self._mv
        with self._lock:
            if mv[_M_ALLOC] == 0:
                mv[_M_ALLOC] = LINE      # line 0 reserved (NULL)

    # ------------------------------------------------------------------ #
    @property
    def halted(self) -> bool:
        return self._mv[_M_HALT] != 0

    def _fast_ok(self) -> bool:
        return False        # fused sentences always take the discrete path

    # ---------------- allocation --------------------------------------- #
    def alloc(self, n_words: int, align_line: bool = True) -> int:
        mv = self._mv
        with self._lock:
            ptr = mv[_M_ALLOC]
            if align_line and ptr % LINE:
                ptr += LINE - ptr % LINE
            base = ptr
            ptr += n_words
            if ptr > self.n_words:
                raise MemoryError("simulated (shm) NVMM exhausted")
            mv[_M_ALLOC] = ptr
            return base

    # ---------------- volatile image ------------------------------------ #
    def read(self, addr: int) -> Any:
        return self._vol.get(addr)

    def write(self, addr: int, value: Any) -> None:
        self._vol.set(addr, value)

    def read_range(self, addr: int, n: int) -> List[Any]:
        return self._vol.get_range(addr, n)

    def write_range(self, addr: int, values) -> None:
        self._vol.set_range(addr, values)

    def copy_range(self, dst: int, src: int, n: int) -> None:
        mv = self._mv
        a = self.backend.vol_base + WORD_I64 * src
        d = self.backend.vol_base + WORD_I64 * dst
        n3 = WORD_I64 * n
        mv[d:d + n3] = mv[a:a + n3]

    def durable_read(self, addr: int) -> Any:
        return self._dur.get(addr)

    # ---------------- write-back ring ------------------------------------ #
    # Entry layout (i64): [epoch_id, first_line, n_lines,
    #                      payload: n_lines * LINE * WORD_I64]
    def _ring_append_locked(self, first: int, n_lines: int) -> None:
        mv = self._mv
        size = 3 + n_lines * LINE * WORD_I64
        used = mv[_M_RING]
        if used + size > self.backend.ring_cap:
            # early completion of pending write-backs (see class doc)
            self._drain_ring_locked()
            mv[_M_SPILLS] += 1
            used = 0
            if size > self.backend.ring_cap:
                raise MemoryError("shm write-back ring smaller than one "
                                  f"pwb of {n_lines} lines")
        o = self.backend.ring_base + used
        mv[o] = mv[_M_EPOCH]
        mv[o + 1] = first
        mv[o + 2] = n_lines
        src = self.backend.vol_base + WORD_I64 * first * LINE
        n3 = n_lines * LINE * WORD_I64
        mv[o + 3:o + 3 + n3] = mv[src:src + n3]
        mv[_M_RING] = used + size
        mv[_M_EFLAG] = 1

    def _ring_entries_locked(self) -> List[Tuple[int, int, int, int]]:
        """[(epoch, first_line, n_lines, payload_i64_offset)] in order."""
        mv = self._mv
        out = []
        o = self.backend.ring_base
        end = o + mv[_M_RING]
        while o < end:
            n_lines = mv[o + 2]
            out.append((mv[o], mv[o + 1], n_lines, o + 3))
            o += 3 + n_lines * LINE * WORD_I64
        return out

    def _drain_entry_locked(self, first: int, n_lines: int,
                            payload: int) -> None:
        mv = self._mv
        dst = self.backend.dur_base + WORD_I64 * first * LINE
        n3 = n_lines * LINE * WORD_I64
        mv[dst:dst + n3] = mv[payload:payload + n3]

    def _drain_ring_locked(self) -> List[Tuple[int, int]]:
        drained = []
        for _e, first, n_lines, payload in self._ring_entries_locked():
            self._drain_entry_locked(first, n_lines, payload)
            drained.append((first, n_lines))
        self._mv[_M_RING] = 0
        self._mv[_M_EFLAG] = 0
        return drained

    # ---------------- persistence instructions --------------------------- #
    def _tick_crash_point(self) -> None:
        mv = self._mv
        if mv[_M_HALT]:
            raise SimulatedCrash()
        if mv[_M_COUNT] >= 0:
            with self._lock:
                cd = mv[_M_COUNT]
                if cd < 0:           # another process just fired it
                    fire = False
                else:
                    mv[_M_COUNT] = cd - 1
                    fire = cd - 1 < 0
                if fire:
                    mv[_M_COUNT] = -1
            if fire:
                rng = self._crash_rng
                if rng is None and mv[_M_SEED] >= 0:
                    import random
                    rng = random.Random(mv[_M_SEED])
                self.crash(rng)
                raise SimulatedCrash()

    def _halt_check_locked(self) -> None:
        """Raise before an instruction takes ANY shared effect on a
        powered-off machine.  Must run under ``self._lock``: ``crash``
        raises the flag under the same lock, so a surviving process can
        never slip a ring append or counter bump past the cut."""
        if self._mv[_M_HALT]:
            raise SimulatedCrash()

    def pwb(self, addr: int, n_words: int = 1) -> None:
        first = addr // LINE
        n_lines = (addr + n_words - 1) // LINE - first + 1
        with self._lock:
            self._halt_check_locked()
            if not self.pwb_nop:
                self._ring_append_locked(first, n_lines)
            self._mv[_M_PWB] += n_lines
        self._tick_crash_point()

    pwb_range = pwb

    def persist_lines(self, ranges) -> None:
        if isinstance(ranges, list) and len(ranges) == 1:
            addr, n_words = ranges[0]
            self.pwb(addr, n_words)
            return
        runs = self._pending_lines(ranges)
        if not runs:
            return
        n_total = sum(n for _first, n in runs)
        with self._lock:
            self._halt_check_locked()
            if not self.pwb_nop:
                for first, n_lines in runs:
                    self._ring_append_locked(first, n_lines)
            self._mv[_M_PWB] += n_total
        self._tick_crash_point()

    def pfence(self) -> None:
        mv = self._mv
        with self._lock:
            self._halt_check_locked()
            mv[_M_PFENCE] += 1
            if mv[_M_EFLAG]:
                mv[_M_EPOCH] += 1
                mv[_M_EFLAG] = 0
        self._tick_crash_point()

    def psync(self) -> None:
        drained: List[Tuple[int, int]] = []
        with self._lock:
            self._halt_check_locked()
            self._mv[_M_PSYNC] += 1
            if not self.psync_nop:
                drained = self._drain_ring_locked()
        if drained and self.persist_latency:
            runs, total_lines = self._run_stats(drained)
            cost = (self.persist_latency + runs * self.SEEK_COST
                    + total_lines * self.STREAM_COST)
            with self.backend.device_lock:
                time.sleep(cost)
        self._tick_crash_point()

    # ---------------- crash / recovery ----------------------------------- #
    def arm_crash(self, after_persist_ops: int, rng=None) -> None:
        """Shared countdown: WHICHEVER process issues the
        ``after_persist_ops``-th next persistence instruction crashes
        the machine.  ``rng`` governs the adversarial drain when the
        arming process itself trips the countdown; a different process
        falls back to a seed captured here (same distribution, not the
        same draw) — pass ``rng=None`` for the deterministic
        drain-nothing cut either way."""
        mv = self._mv
        self._crash_rng = rng
        mv[_M_SEED] = (-1 if rng is None
                       else hash(rng.getstate()) & 0x7FFFFFFF)
        mv[_M_COUNT] = after_persist_ops

    def disarm_crash(self) -> None:
        """Disarm any countdown AND clear the machine-off flag — the
        runtime's ``recover`` calls this first, which is exactly when
        the machine powers back on."""
        mv = self._mv
        mv[_M_COUNT] = -1
        mv[_M_HALT] = 0
        self._crash_rng = None

    def crash(self, rng=None) -> None:
        mv = self._mv
        with self._lock:
            mv[_M_CRASHES] += 1
            entries = self._ring_entries_locked()
            if rng is not None:
                # mirror NVM.crash: epochs = distinct ids in order plus
                # a trailing empty epoch when the current one is empty
                distinct: List[int] = []
                for e, _f, _n, _p in entries:
                    if not distinct or distinct[-1] != e:
                        distinct.append(e)
                n_epochs = len(distinct) + (0 if mv[_M_EFLAG] else 1)
                cut = rng.randint(0, n_epochs - 1)
                for e, first, n_lines, payload in entries:
                    if e in distinct[:cut]:
                        self._drain_entry_locked(first, n_lines, payload)
                if cut < len(distinct):
                    cut_id = distinct[cut]
                    cut_epoch: List[Tuple[int, int]] = []
                    for e, first, n_lines, payload in entries:
                        if e == cut_id:
                            for j in range(n_lines):
                                cut_epoch.append(
                                    (first + j,
                                     payload + j * LINE * WORD_I64))
                    taken_upto: Dict[int, int] = {}
                    for i, (line, _snap) in enumerate(cut_epoch):
                        if rng.random() < 0.5:
                            taken_upto[line] = i
                    for i, (line, snap) in enumerate(cut_epoch):
                        if i <= taken_upto.get(line, -1):
                            self._drain_entry_locked(line, 1, snap)
            mv[_M_RING] = 0
            mv[_M_EFLAG] = 0
            mv[_M_EPOCH] = 0
            # volatile image lost: reset to the durable one (raw copy)
            n3 = self.n_words * WORD_I64
            mv[self.backend.vol_base:self.backend.vol_base + n3] = \
                mv[self.backend.dur_base:self.backend.dur_base + n3]
            mv[_M_COUNT] = -1
            mv[_M_HALT] = 1          # machine off until disarm_crash

    # ---------------- introspection -------------------------------------- #
    def pending_lines(self) -> int:
        with self._lock:
            return sum(n for _e, _f, n, _p in self._ring_entries_locked())

    def reset_counters(self) -> None:
        mv = self._mv
        for slot in _CTR_SLOT.values():
            mv[slot] = 0

    def close(self) -> None:
        self._vol = self._dur = self._mv = None
        self.counters = None
        self.backend.close()
