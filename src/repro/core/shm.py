"""Shared-memory multiprocess execution backend (DESIGN.md §7, §8).

Under CPython's GIL the *measured* combining degree is pinned near 1 —
only the modeled pass could stage paper-scale rounds (ROADMAP).  This
module moves every word the protocols share into one
``multiprocessing.shared_memory`` segment so fork()ed worker processes
announce/combine against the same board with true parallelism:

  * ``ShmNVM`` — the simulated NVMM (volatile + durable images, the
    epoch write-back rings, pwb/pfence/psync counters, crash countdown
    and the machine-off ``halted`` flag) entirely in shared memory,
    guarded by one fork-inherited lock.  Same public interface and
    crash semantics as ``NVM``; the fused persistence sentences fall
    back to their discrete forms (``_fast_ok`` is False), which keeps
    pwb/pfence/psync counter arithmetic identical to the in-thread
    backend — that is what the replay-equivalence tests pin.
  * ``ShmBackend`` — the ``core.backend`` seam over the same segment:
    lock-striped CAS emulation for AtomicInt/AtomicRef/SRef, shared
    request boards, cells, int arrays, degree counters, and the blob
    heap below.
  * ``BlobHeap`` — a slab/free-list allocator inside the segment for
    variable-length pickled payloads (DESIGN.md §8).  Values that do
    not fit the 16-byte inline word codec (tuples, dicts, long
    strings, big ints, byte strings...) are stored as immutable,
    generation-tagged, refcounted chunks; the word stores a blob REF.
    Payload-before-tag publication order means a torn blob value is
    never observable: readers validate the generation before and after
    copying the bytes and retry the word read on a mismatch.
  * multi-segment NVM (NUMA-ish, ROADMAP follow-up): the word space is
    striped into ``segments`` equal spans, each with its own write-back
    ring, modeled sync device, allocation pointer, and pwb/psync/spill
    accounting.  Structures are placed on segments by the runtime's
    affinity policy (``CombiningRuntime(backend="shm", segments=N)``).

Word encoding: each simulated NVM word (and each board/cell slot) is
``WORD_I64`` int64s — a tag plus 16 payload bytes — covering ints,
None, bools, floats and short strings inline; anything richer goes to
the blob heap when the word belongs to a backend (``_Words`` carries
the heap), or raises ``TypeError`` through the bare module-level
``encode`` (which has no heap to allocate from).

Atomicity notes.  Aligned 8-byte loads/stores through a ``cast('q')``
memoryview are single C-level stores; mutating operations (cas,
fetch_add, SC) additionally serialize through a striped lock, and
multi-i64 slots order payload-before-tag on write (tag-before-payload
on read) with the protocols' own ``valid`` flags providing the
publication barrier — the same discipline the GIL gave the thread
backend for free.

Blob durability model (DESIGN.md §8).  Chunks are immutable for the
lifetime of one allocation (generation): the bytes a pwb would
snapshot are by construction the bytes a later psync drains, so the
epoch ring records blob REFS (pinned via the refcount) rather than
byte copies, and charges the pwb counter with the chunk's cache-line
footprint — payload layout is visible in the numbers, which is the
point (MOD / Fatourou-et-al. FIFO-queue line of work).  A chunk is
reclaimed onto its size-class free list only when no volatile word, no
durable word and no pending ring entry references it, so a post-crash
durable image can always decode every blob it names.

Fork discipline: create the runtime, its structures, and the worker
pool IN THAT ORDER — mp primitives and shared views are inherited by
fork, so everything shared must exist before ``spawn_workers``.
"""

from __future__ import annotations

import atexit
import itertools
import multiprocessing
import os
import pickle
import struct
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

from .atomics import Counters
from .backend import ThreadBackend
from .nvm import LINE, NVM, SimulatedCrash

WORD_I64 = 3          # int64s per codec word: tag + 2 payload words

# value tags
_T_INT = 0
_T_NONE = 1
_T_FALSE = 2
_T_TRUE = 3
_T_FLOAT = 4
_T_BLOB = 5           # payload a = blob byte offset, b = generation
_T_STR = 16           # tag = _T_STR + utf-8 byte length (0..16)
_STR_MAX = 16

_I64_MIN, _I64_MAX = -(1 << 63), (1 << 63) - 1

#: retries before a blob read declares the word permanently unstable
#: (a torn word would mean a writer died mid-publication, which the
#: payload-before-tag order makes impossible; this bounds the loop)
_STALE_RETRIES = 10_000


def encode(value: Any) -> Tuple[int, int, int]:
    """Python value -> (tag, payload_a, payload_b) for the INLINE word
    domain: ints, None, bools, floats, short strings.  Backend words go
    through ``_Words.set``, which falls back to the blob heap for
    anything this function rejects."""
    if value is None:
        return _T_NONE, 0, 0
    if value is True:
        return _T_TRUE, 0, 0
    if value is False:
        return _T_FALSE, 0, 0
    if type(value) is int:
        if not _I64_MIN <= value <= _I64_MAX:
            raise TypeError(f"int {value!r} exceeds the shm backend's "
                            "64-bit inline word")
        return _T_INT, value, 0
    if type(value) is float:
        return _T_FLOAT, struct.unpack("<q", struct.pack("<d", value))[0], 0
    if type(value) is str:
        raw = value.encode("utf-8")
        if len(raw) > _STR_MAX:
            raise TypeError(f"str {value!r} exceeds {_STR_MAX} utf-8 "
                            "bytes (inline shm word)")
        raw = raw.ljust(_STR_MAX, b"\0")
        return (_T_STR + len(value.encode('utf-8')),
                int.from_bytes(raw[:8], "little", signed=True),
                int.from_bytes(raw[8:], "little", signed=True))
    raise TypeError(
        f"inline shm words store ints, floats, bools, None and short "
        f"strings; got {type(value).__name__}: {value!r} (rich payloads "
        "go through a backend word, which blob-encodes them)")


def decode(tag: int, a: int, b: int) -> Any:
    if tag == _T_INT:
        return a
    if tag == _T_NONE:
        return None
    if tag == _T_TRUE:
        return True
    if tag == _T_FALSE:
        return False
    if tag == _T_FLOAT:
        return struct.unpack("<d", struct.pack("<q", a))[0]
    if _T_STR <= tag <= _T_STR + _STR_MAX:
        raw = (a.to_bytes(8, "little", signed=True)
               + b.to_bytes(8, "little", signed=True))
        return raw[:tag - _T_STR].decode("utf-8")
    if tag == _T_BLOB:
        raise ValueError("blob word needs its backend heap to decode "
                         "(use _Words.get, not the bare decode)")
    raise ValueError(f"corrupt shm word tag {tag}")


# --------------------------------------------------------------------- #
# Blob heap                                                             #
# --------------------------------------------------------------------- #
_BLOB_GRANULE = 64        # bytes: smallest chunk class AND line size for
_BLOB_LINE = 64           # the blob write-back accounting
_BLOB_HDR = 16            # per-chunk in-image header: gen, nbytes
_BLOB_CLASSES = 16        # 64B << 15 = 2MB largest chunk


class BlobHeap:
    """Slab/free-list allocator for variable-length payloads inside the
    backend segment (DESIGN.md §8).

    Chunks are power-of-two size classes (64B..2MB), carved from one
    bump region; a freed chunk goes on its class free list and is only
    re-handed-out there, so chunks never overlap and never change
    class.  Each chunk carries an in-image header ``[gen, nbytes]``
    and side metadata (refcount, authoritative generation, class, free
    link) OUTSIDE the imaged areas, so crash restores never clobber
    allocator state.

    Invariants:
      * a chunk's payload is immutable for the lifetime of one
        generation — publication is alloc+write THEN word publish;
      * ``rc`` counts every volatile word, durable word and pending
        ring-entry reference; reclamation only at rc == 0;
      * ``gen`` is bumped (under the alloc lock) BEFORE a reused
        chunk's payload is rewritten, so a reader holding a stale ref
        observes the mismatch no later than its post-copy check.
    """

    __slots__ = ("mv", "raw", "base_b", "cap_b", "_rc", "_gen", "_cls",
                 "_nxt", "lock", "_meta_heads")

    def __init__(self, backend: "ShmBackend") -> None:
        self.mv = backend.mv
        self.raw = backend.raw
        self.base_b = backend.blob_base * 8       # absolute byte offset
        self.cap_b = backend.blob_bytes
        n_gran = backend.blob_bytes // _BLOB_GRANULE
        side = backend.blob_side_base
        self._rc = side
        self._gen = side + n_gran
        self._cls = side + 2 * n_gran
        self._nxt = side + 3 * n_gran
        self.lock = backend._alloc_lock
        self._meta_heads = _M_CLASS0

    # ------------- allocation ------------------------------------------ #
    def alloc(self, data: bytes) -> Tuple[int, int]:
        """Allocate a chunk, write header+payload, rc=1.  Returns
        (byte offset, generation) — the word's (a, b) payload."""
        mv = self.mv
        need = _BLOB_HDR + len(data)
        cls_b = max(_BLOB_GRANULE, 1 << (need - 1).bit_length())
        ci = (cls_b // _BLOB_GRANULE).bit_length() - 1
        if ci >= _BLOB_CLASSES or cls_b > self.cap_b:
            raise TypeError(f"payload of {len(data)} bytes exceeds the "
                            "blob heap's largest chunk class")
        with self.lock:
            head = mv[self._meta_heads + ci]
            if head:
                off = head - 1
                g = off // _BLOB_GRANULE
                mv[self._meta_heads + ci] = mv[self._nxt + g]
            else:
                off = mv[_M_BLOB_BUMP]
                if off + cls_b > self.cap_b:
                    raise MemoryError(
                        f"shm blob heap exhausted ({self.cap_b} bytes)")
                mv[_M_BLOB_BUMP] = off + cls_b
                g = off // _BLOB_GRANULE
                mv[self._cls + g] = cls_b
            gen = mv[self._gen + g] + 1
            mv[self._gen + g] = gen
            mv[self._rc + g] = 1
            mv[_M_BLOBBED] = 1
            # gen first (stale readers of a reused chunk bail before the
            # payload is overwritten), then length, then the bytes
            qb = (self.base_b + off) // 8
            mv[qb] = gen
            mv[qb + 1] = len(data)
            b0 = self.base_b + off + _BLOB_HDR
            self.raw[b0:b0 + len(data)] = data
            return off, gen

    # ------------- read ------------------------------------------------ #
    def read(self, off: int, gen: int) -> Optional[bytes]:
        """Chunk payload for generation ``gen``, or None when the chunk
        was reallocated since (the caller re-reads the word)."""
        mv = self.mv
        qb = (self.base_b + off) // 8
        if mv[qb] != gen:
            return None
        n = mv[qb + 1]
        b0 = self.base_b + off + _BLOB_HDR
        data = bytes(self.raw[b0:b0 + n])
        if mv[qb] != gen:          # reallocated mid-copy: bytes are torn
            return None
        return data

    # ------------- refcounting ----------------------------------------- #
    def inc(self, off: int) -> None:
        with self.lock:
            self.mv[self._rc + off // _BLOB_GRANULE] += 1

    def try_pin(self, off: int, gen: int) -> bool:
        """Validated pin: take a reference iff the chunk still carries
        ``gen`` and is live.  Raw-copy paths (ring snapshots, StateRec
        copies) use this instead of a blind ``inc`` — between their
        word read and the pin, the word's writer may have released the
        chunk and the allocator re-handed it out; (off, gen) pairs
        never recur, so a stale pair is detected here and the caller
        re-reads the word."""
        with self.lock:
            g = off // _BLOB_GRANULE
            if self.mv[self._gen + g] == gen and self.mv[self._rc + g] > 0:
                self.mv[self._rc + g] += 1
                return True
            return False

    def dec(self, off: int) -> None:
        mv = self.mv
        with self.lock:
            g = off // _BLOB_GRANULE
            rc = mv[self._rc + g] - 1
            mv[self._rc + g] = rc
            if rc == 0:
                cls_b = mv[self._cls + g]
                ci = (cls_b // _BLOB_GRANULE).bit_length() - 1
                mv[self._nxt + g] = mv[self._meta_heads + ci]
                mv[self._meta_heads + ci] = off + 1

    # ------------- accounting / introspection -------------------------- #
    def lines(self, off: int) -> int:
        """Cache-line footprint of the chunk's USED bytes (header +
        payload) — what a pwb of a referencing word writes back."""
        qb = (self.base_b + off) // 8
        return (_BLOB_HDR + self.mv[qb + 1] + _BLOB_LINE - 1) // _BLOB_LINE

    def chunks(self) -> List[Tuple[int, int, int, int]]:
        """[(off, class_bytes, rc, gen)] for every chunk ever carved,
        in address order (allocator-audit introspection for tests)."""
        mv = self.mv
        out = []
        off = 0
        while off < mv[_M_BLOB_BUMP]:
            g = off // _BLOB_GRANULE
            cls_b = mv[self._cls + g]
            out.append((off, cls_b, mv[self._rc + g], mv[self._gen + g]))
            off += cls_b
        return out

    def occupancy(self) -> Dict[str, int]:
        """Live/free chunk accounting (the soak harness's leak gauge)."""
        with self.lock:
            out = {"live_chunks": 0, "live_bytes": 0,
                   "free_chunks": 0, "free_bytes": 0,
                   "bump_bytes": self.mv[_M_BLOB_BUMP],
                   "cap_bytes": self.cap_b}
            for _off, cls_b, rc, _gen in self.chunks():
                if rc > 0:
                    out["live_chunks"] += 1
                    out["live_bytes"] += cls_b
                else:
                    out["free_chunks"] += 1
                    out["free_bytes"] += cls_b
            return out

    # ------------- GC / compaction ------------------------------------- #
    def gc(self) -> Dict[str, int]:
        """Free-space maintenance at a quiescent point: coalesce runs
        of adjacent free chunks into the largest classes that fit,
        retreat the bump pointer over a trailing free run, and rebuild
        the class free lists.  Chunk identity safety: a coalesced-away
        chunk keeps rc == 0 at its old granule, so any stale
        ``try_pin(off, gen)`` fails; (off, gen) pairs still never
        recur because ``alloc`` bumps the generation on every reuse."""
        mv = self.mv
        with self.lock:
            coalesced = retreated = 0
            runs: List[Tuple[int, int, int]] = []   # (start, span, n_chunks)
            start = span = count = 0
            for off, cls_b, rc, _gen in self.chunks():
                if rc == 0:
                    if count == 0:
                        start = off
                    span += cls_b
                    count += 1
                else:
                    if count:
                        runs.append((start, span, count))
                    span = count = 0
            if count:
                # trailing free run: give it back to the bump region
                retreated = span
                for j in range(span // _BLOB_GRANULE):
                    mv[self._cls + start // _BLOB_GRANULE + j] = 0
                mv[_M_BLOB_BUMP] = start
            for rstart, rspan, rcount in runs:
                if rcount < 2:
                    continue
                coalesced += rcount
                for j in range(rspan // _BLOB_GRANULE):
                    mv[self._cls + rstart // _BLOB_GRANULE + j] = 0
                off = rstart
                left = rspan
                max_cls = _BLOB_GRANULE << (_BLOB_CLASSES - 1)
                while left:
                    cls_b = min(1 << left.bit_length() - 1, max_cls)
                    g = off // _BLOB_GRANULE
                    mv[self._cls + g] = cls_b
                    mv[self._rc + g] = 0
                    off += cls_b
                    left -= cls_b
            # rebuild every class free list from the surviving layout
            for ci in range(_BLOB_CLASSES):
                mv[self._meta_heads + ci] = 0
            for off, cls_b, rc, _gen in self.chunks():
                if rc == 0:
                    g = off // _BLOB_GRANULE
                    ci = (cls_b // _BLOB_GRANULE).bit_length() - 1
                    mv[self._nxt + g] = mv[self._meta_heads + ci]
                    mv[self._meta_heads + ci] = off + 1
            return {"coalesced_chunks": coalesced,
                    "bump_retreat_bytes": retreated}

    def _lowest_free_below(self, cls_b: int, below: int) -> Optional[int]:
        """Pop the lowest-offset free chunk of class ``cls_b`` strictly
        below byte offset ``below`` from its free list (caller holds
        the lock)."""
        mv = self.mv
        ci = (cls_b // _BLOB_GRANULE).bit_length() - 1
        best = best_prev = None
        prev = None
        head = mv[self._meta_heads + ci]
        while head:
            off = head - 1
            if off < below and (best is None or off < best):
                best, best_prev = off, prev
            prev = off
            head = mv[self._nxt + off // _BLOB_GRANULE]
        if best is None:
            return None
        nxt = mv[self._nxt + best // _BLOB_GRANULE]
        if best_prev is None:
            mv[self._meta_heads + ci] = nxt
        else:
            mv[self._nxt + best_prev // _BLOB_GRANULE] = nxt
        return best

    def compact(self, word_spans) -> Dict[str, int]:
        """Generation-safe chunk movement: slide live chunks into lower
        free slots of the same class so ``gc()`` can retreat the bump
        pointer.  ``word_spans`` is the [(base_i64, n_words)] list of
        every TAGGED-WORD region that may hold blob refs (the NVM's
        allocated vol+dur spans); a chunk moves only when the refs
        found there account for its ENTIRE refcount — anything also
        referenced from a board slot, a ring snapshot, or a Python-side
        pin stays put.  Movement follows the existing publication
        discipline: fresh generation, header+payload written at the
        destination BEFORE any referring word is switched (gen word
        first, then offset), and the source bytes are left intact, so
        a concurrent reader sees old-or-new, never torn."""
        mv = self.mv
        moved = 0
        with self.lock:
            ref_map: Dict[int, List[int]] = {}
            for base, n in word_spans:
                end = base + WORD_I64 * n
                for o in range(base, end, WORD_I64):
                    if mv[o] == _T_BLOB:
                        ref_map.setdefault(mv[o + 1], []).append(o)
            for off, cls_b, rc, gen in reversed(self.chunks()):
                if rc <= 0:
                    continue
                refs = [o for o in ref_map.get(off, ())
                        if mv[o + 1] == off and mv[o + 2] == gen]
                if len(refs) != rc:
                    continue
                dest = self._lowest_free_below(cls_b, off)
                if dest is None:
                    continue
                gsrc = off // _BLOB_GRANULE
                gd = dest // _BLOB_GRANULE
                gen_d = mv[self._gen + gd] + 1
                mv[self._gen + gd] = gen_d
                nbytes = mv[(self.base_b + off) // 8 + 1]
                qd = (self.base_b + dest) // 8
                mv[qd] = gen_d
                mv[qd + 1] = nbytes
                b_src = self.base_b + off + _BLOB_HDR
                b_dst = self.base_b + dest + _BLOB_HDR
                self.raw[b_dst:b_dst + nbytes] = \
                    self.raw[b_src:b_src + nbytes]
                for o in refs:
                    mv[o + 2] = gen_d
                    mv[o + 1] = dest
                mv[self._rc + gd] = rc
                mv[self._rc + gsrc] = 0
                ci = (cls_b // _BLOB_GRANULE).bit_length() - 1
                mv[self._nxt + gsrc] = mv[self._meta_heads + ci]
                mv[self._meta_heads + ci] = off + 1
                ref_map[dest] = refs
                moved += 1
        return {"moved_chunks": moved}

    def leak_check(self, word_spans) -> Dict[str, int]:
        """Refcount audit: compare each live chunk's rc against the
        refs found in ``word_spans``.  ``excess_rc`` > 0 over EMPTY
        rings and quiesced boards indicates a pin without a matching
        unpin (the class of bug the ring-snapshot re-copy path had)."""
        mv = self.mv
        with self.lock:
            found: Dict[int, int] = {}
            for base, n in word_spans:
                end = base + WORD_I64 * n
                for o in range(base, end, WORD_I64):
                    if mv[o] == _T_BLOB:
                        found[mv[o + 1]] = found.get(mv[o + 1], 0) + 1
            excess = live = 0
            for off, _cls_b, rc, _gen in self.chunks():
                if rc > 0:
                    live += 1
                    excess += max(0, rc - found.get(off, 0))
            return {"live_chunks": live, "excess_rc": excess}


class _Words:
    """Codec-word array view: word i lives at i64 offset
    ``base + WORD_I64 * i`` of the backing memoryview.  ``heap`` (when
    attached to a backend) serves the rich-value fallback."""

    __slots__ = ("mv", "base", "heap")

    def __init__(self, mv, base_i64: int,
                 heap: Optional[BlobHeap] = None) -> None:
        self.mv = mv
        self.base = base_i64
        self.heap = heap

    def get(self, i: int) -> Any:
        o = self.base + WORD_I64 * i
        mv = self.mv
        for _ in range(_STALE_RETRIES):
            t = mv[o]
            if t != _T_BLOB:
                return decode(t, mv[o + 1], mv[o + 2])
            data = self.heap.read(mv[o + 1], mv[o + 2])
            if data is not None:
                return pickle.loads(data)
            # chunk reallocated between the word read and the byte copy:
            # the word necessarily changed too — re-read it
        raise RuntimeError("shm blob word kept changing under the "
                           "reader (writer died mid-publication?)")

    def set(self, i: int, value: Any) -> None:
        o = self.base + WORD_I64 * i
        mv = self.mv
        heap = self.heap
        old_off = mv[o + 1] if (heap is not None and mv[o] == _T_BLOB) \
            else -1
        try:
            t, a, b = encode(value)
        except TypeError:
            if heap is None:
                raise
            a, b = heap.alloc(pickle.dumps(value, protocol=4))
            t = _T_BLOB
        # payload before tag: a reader that sees the new tag sees the
        # new payload (TSO); single-word int updates hinge on mv[o+1].
        # For blobs the chunk bytes were fully written by alloc() above,
        # BEFORE this publication — old-or-new, never torn.
        mv[o + 1] = a
        mv[o + 2] = b
        mv[o] = t
        if old_off >= 0:
            heap.dec(old_off)

    def get_range(self, i: int, n: int) -> List[Any]:
        return [self.get(i + j) for j in range(n)]

    def set_range(self, i: int, values) -> None:
        for j, v in enumerate(values):
            self.set(i + j, v)


# --------------------------------------------------------------------- #
# Backend primitives                                                    #
# --------------------------------------------------------------------- #
class ShmMutex:
    """Mutex over a fork-inherited semaphore.  ``reset`` drains it back
    to exactly one permit — a crashed holder can never be unwound from
    another process, so post-crash recovery forces the released state."""

    __slots__ = ("_sem",)

    def __init__(self, ctx) -> None:
        self._sem = ctx.Semaphore(1)

    def acquire(self, blocking: bool = True) -> bool:
        return self._sem.acquire(blocking)

    def release(self) -> None:
        self._sem.release()

    def __enter__(self):
        self._sem.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self._sem.release()

    def reset(self) -> None:
        while self._sem.acquire(False):
            pass
        self._sem.release()


class ShmAtomicInt:
    """AtomicInt over one shared int64: plain aligned load/store, CAS
    and fetch&add emulated under a striped fork-inherited lock."""

    __slots__ = ("_mv", "_off", "_lock", "_count", "_clock")

    def __init__(self, backend: "ShmBackend", value: int = 0, *,
                 shared: bool = False,
                 counters: Optional[Counters] = None,
                 clock: Optional[Any] = None) -> None:
        self._mv = backend.mv
        self._off = backend.aux_alloc(1)
        self._lock = backend.stripe(self._off)
        self._count = counters if (shared and counters is not None) else None
        self._clock = clock          # always None in shm mode (no profile)
        self._mv[self._off] = value

    def load(self) -> int:
        if self._count is not None:
            self._count.shared_reads += 1
        return self._mv[self._off]

    def store(self, value: int) -> None:
        if self._count is not None:
            self._count.shared_writes += 1
        self._mv[self._off] = value

    def cas(self, old: int, new: int) -> bool:
        with self._lock:
            if self._count is not None:
                self._count.cas_calls += 1
            if self._mv[self._off] == old:
                self._mv[self._off] = new
                if self._count is not None:
                    self._count.shared_writes += 1
                return True
            return False

    def fetch_add(self, delta: int) -> int:
        with self._lock:
            old = self._mv[self._off]
            self._mv[self._off] = old + delta
            if self._count is not None:
                self._count.shared_writes += 1
            return old

    def reset(self, value: int = 0) -> None:
        self._mv[self._off] = value


class ShmAtomicRef:
    """Versioned LL/VL/SC reference over shared memory (codec value +
    raw version word).  Supports the same ``mirror=(nvm, addr)`` as the
    thread AtomicRef: the mirror write lands inside the SC's critical
    section."""

    __slots__ = ("_words", "_idx", "_mv", "_voff", "_lock", "_count",
                 "_mnvm", "_maddr")

    def __init__(self, backend: "ShmBackend", value: Any, *,
                 shared: bool = False,
                 counters: Optional[Counters] = None,
                 clock: Optional[Any] = None,
                 mirror: Optional[Tuple[Any, int]] = None) -> None:
        off = backend.aux_alloc(WORD_I64 + 1)
        self._words = _Words(backend.mv, off, backend.heap)
        self._idx = 0
        self._mv = backend.mv
        self._voff = off + WORD_I64
        self._lock = backend.stripe(off)
        self._count = counters if (shared and counters is not None) else None
        self._mnvm, self._maddr = mirror if mirror is not None else (None, 0)
        self.reset(value)

    def ll(self) -> Tuple[Any, int]:
        if self._count is not None:
            self._count.shared_reads += 1
        # version first: if it is unchanged after the value read, the
        # value belongs to that version (SC bumps version last)
        ver = self._mv[self._voff]
        return self._words.get(self._idx), ver

    def vl(self, version: int) -> bool:
        if self._count is not None:
            self._count.shared_reads += 1
        return self._mv[self._voff] == version

    def sc(self, version: int, new_value: Any) -> bool:
        with self._lock:
            if self._count is not None:
                self._count.cas_calls += 1
            if self._mv[self._voff] == version:
                self._words.set(self._idx, new_value)
                if self._mnvm is not None:
                    self._mnvm.write(self._maddr, new_value)
                self._mv[self._voff] = version + 1
                if self._count is not None:
                    self._count.shared_writes += 1
                return True
            return False

    def load(self) -> Any:
        if self._count is not None:
            self._count.shared_reads += 1
        return self._words.get(self._idx)

    def reset(self, value: Any) -> None:
        with self._lock:
            self._words.set(self._idx, value)
            # construction / post-crash reset seeds the ref with the
            # mirror word's own durable value — rewriting it would dirty
            # the line with nothing new to persist (see _SRef.__init__)
            if self._mnvm is not None and self._mnvm.read(self._maddr) != value:
                self._mnvm.write(self._maddr, value)
            self._mv[self._voff] = 0


class ShmSRef:
    """PWFComb's S: versioned LL/VL/SC whose value is mirrored into an
    NVM word inside the SC mutex (the shm variant of ``_SRef``)."""

    __slots__ = ("nvm", "addr", "_mv", "_voff", "_soff", "_mutex",
                 "_counters")

    def __init__(self, backend: "ShmBackend", nvm: "ShmNVM", addr: int,
                 value: int, counters: Optional[Counters] = None) -> None:
        off = backend.aux_alloc(2)
        self._mv = backend.mv
        self._soff = off          # slot id (int, raw)
        self._voff = off + 1      # version
        self._mutex = backend.stripe(off)
        self.nvm = nvm
        self.addr = addr
        self._counters = counters
        self.reset(nvm, addr, value)

    def ll(self):
        if self._counters:
            self._counters.shared_reads += 1
        ver = self._mv[self._voff]
        return self._mv[self._soff], ver

    def vl(self, version: int) -> bool:
        return self._mv[self._voff] == version

    def sc(self, version: int, new_value: int) -> bool:
        with self._mutex:
            if self._counters:
                self._counters.cas_calls += 1
            if self._mv[self._voff] == version:
                self._mv[self._soff] = new_value
                self.nvm.write(self.addr, new_value)
                self._mv[self._voff] = version + 1
                return True
            return False

    def load(self) -> int:
        return self._mv[self._soff]

    def reset(self, nvm: "ShmNVM", addr: int, value: int) -> None:
        with self._mutex:
            self._mv[self._soff] = value
            # Post-crash reset passes the durable word's own value back
            # in — rewriting it would dirty the line with nothing new
            # to persist (see _SRef.__init__).
            if nvm.read(addr) != value:
                nvm.write(addr, value)
            self._mv[self._voff] = 0


class ShmCell:
    """One shared codec word with a ``value`` attribute (LockVal,
    oldTail).  Single-word plain loads/stores, like the thread Cell."""

    __slots__ = ("_words",)

    def __init__(self, backend: "ShmBackend", value: Any = None) -> None:
        self._words = _Words(backend.mv, backend.aux_alloc(WORD_I64),
                             backend.heap)
        self._words.set(0, value)

    @property
    def value(self) -> Any:
        return self._words.get(0)

    @value.setter
    def value(self, v: Any) -> None:
        self._words.set(0, v)


class ShmIntArray:
    """Raw shared int64 array (PWFComb's Flush / CombRound rows)."""

    __slots__ = ("_mv", "_off", "_n")

    def __init__(self, mv, off: int, n: int, init: int = 0) -> None:
        self._mv = mv
        self._off = off
        self._n = n
        self.fill(init)

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, i: int) -> int:
        return self._mv[self._off + i]

    def __setitem__(self, i: int, v: int) -> None:
        self._mv[self._off + i] = v

    def fill(self, value: int) -> None:
        mv, off = self._mv, self._off
        for i in range(self._n):
            mv[off + i] = value


# Request-board field offsets (codec words per RequestRec slot).
_RB_FUNC, _RB_ARGS, _RB_ACT, _RB_VALID, _RB_VTIME, _RB_STAMP, _RB_WORDS = \
    0, 1, 2, 3, 4, 5, 6


class ShmRequestRec:
    """View of one announcement slot; property-per-field so the
    protocols' in-place announce sequence (valid=0 ... valid=1) hits
    shared memory in program order."""

    __slots__ = ("_w", "_b")

    def __init__(self, words: _Words, base_word: int) -> None:
        self._w = words
        self._b = base_word

    @property
    def func(self):
        return self._w.get(self._b + _RB_FUNC)

    @func.setter
    def func(self, v):
        self._w.set(self._b + _RB_FUNC, v)

    @property
    def args(self):
        return self._w.get(self._b + _RB_ARGS)

    @args.setter
    def args(self, v):
        self._w.set(self._b + _RB_ARGS, v)

    @property
    def activate(self):
        return self._w.get(self._b + _RB_ACT)

    @activate.setter
    def activate(self, v):
        self._w.set(self._b + _RB_ACT, v)

    @property
    def valid(self):
        return self._w.get(self._b + _RB_VALID)

    @valid.setter
    def valid(self, v):
        self._w.set(self._b + _RB_VALID, v)

    @property
    def vtime(self):
        return self._w.get(self._b + _RB_VTIME)

    @vtime.setter
    def vtime(self, v):
        self._w.set(self._b + _RB_VTIME, v)

    @property
    def stamp(self):
        return self._w.get(self._b + _RB_STAMP)

    @stamp.setter
    def stamp(self, v):
        self._w.set(self._b + _RB_STAMP, v)


class ShmRequestBoard(list):
    """Announcement board in shared memory: ``board[p]`` is a live view;
    assigning a RequestRec copies its fields under the announce seqlock
    (stamp odd while rewriting, valid published before the even
    stamp — see ``RequestRec.stamp``)."""

    def __init__(self, backend: "ShmBackend", n_threads: int) -> None:
        words = _Words(backend.mv,
                       backend.aux_alloc(WORD_I64 * _RB_WORDS * n_threads),
                       backend.heap)
        super().__init__(ShmRequestRec(words, _RB_WORDS * p)
                         for p in range(n_threads))
        self.reset()

    def __setitem__(self, p: int, rec: Any) -> None:
        view = list.__getitem__(self, p)
        st = view.stamp + 1
        view.stamp = st                 # odd: rewrite in progress
        view.valid = 0
        view.func = rec.func
        view.args = rec.args
        view.activate = rec.activate
        view.vtime = rec.vtime
        view.valid = rec.valid
        view.stamp = st + 1             # even: published

    def reset(self) -> None:
        for view in self:
            st = view.stamp + 1
            view.stamp = st
            view.valid = 0
            view.func = None
            view.args = None
            view.activate = 0
            view.vtime = 0.0
            view.stamp = st + 1


class ShmDegreeStats:
    """Measured-degree counters in shared memory — combiners in any
    process accumulate into the same three words."""

    __slots__ = ("_mv", "_off", "_lock")

    def __init__(self, backend: "ShmBackend") -> None:
        self._off = backend.aux_alloc(3)
        self._mv = backend.mv
        self._lock = backend.stripe(self._off)
        self.reset()

    def record(self, served: int) -> None:
        mv, off = self._mv, self._off
        with self._lock:
            mv[off] += 1
            mv[off + 1] += served
            if served > mv[off + 2]:
                mv[off + 2] = served

    def snapshot(self) -> dict:
        mv, off = self._mv, self._off
        with self._lock:
            return {"rounds": mv[off], "ops_combined": mv[off + 1],
                    "degree_max": mv[off + 2]}

    def reset(self) -> None:
        mv, off = self._mv, self._off
        with self._lock:
            mv[off] = mv[off + 1] = mv[off + 2] = 0


# --------------------------------------------------------------------- #
# The backend                                                           #
# --------------------------------------------------------------------- #
# machine meta slot indexes (int64)
_M_AUX = 0          # aux-area bump pointer (i64 units, relative)
_M_COUNT = 1        # crash countdown (-1 = disarmed)
_M_SEED = 2         # adversarial-drain seed (-1 = drain nothing)
_M_HALT = 3         # machine-off flag
_M_PWB, _M_PFENCE, _M_PSYNC, _M_CRASHES = 4, 5, 6, 7
_M_SPILLS = 8       # ring-overflow early drains (machine-wide)
_M_BLOBBED = 9      # 1 iff the blob heap ever allocated (fast-path skip)
_M_BLOB_BUMP = 10   # blob-area bump pointer (bytes, relative)
_M_LOSESEG = 11     # segment to LOSE at the next crash (-1 = none):
                    # that DIMM drops every pending write-back while the
                    # surviving segments drain fully (repro.fuzz's
                    # partial-failure class)
_M_CLASS0 = 16      # blob class free-list heads (byte offset + 1; 0=nil)
_META_I64 = _M_CLASS0 + _BLOB_CLASSES

# per-segment meta slots (int64), at seg_meta + s * _SEG_I64
_S_ALLOC = 0        # word bump pointer (absolute word index)
_S_EPOCH = 1        # current epoch id
_S_EFLAG = 2        # 1 iff the current epoch has queued entries
_S_RING = 3         # ring used (i64, relative to this segment's ring)
_S_PWB = 4          # lines written back through this segment's device
_S_PSYNC = 5        # psyncs that ENGAGED this segment's device
_S_SPILLS = 6       # ring-overflow early drains on this segment
_SEG_I64 = 8

_CTR_SLOT = {"pwb": _M_PWB, "pfence": _M_PFENCE, "psync": _M_PSYNC,
             "crashes": _M_CRASHES, "ring_spills": _M_SPILLS}

# ring entry header: [epoch, first_line, n_lines, blob_lines]
_ENT_HDR = 4


class _ShmCounters:
    """Dict-like view of the shared pwb/pfence/psync/crashes slots, so
    ``nvm.counters["pwb"]`` reads the machine-wide count from any
    process."""

    __slots__ = ("_mv",)

    def __init__(self, mv) -> None:
        self._mv = mv

    def __getitem__(self, key: str) -> int:
        return self._mv[_CTR_SLOT[key]]

    def __setitem__(self, key: str, value: int) -> None:
        self._mv[_CTR_SLOT[key]] = value

    def __iter__(self) -> Iterator[str]:
        return iter(_CTR_SLOT)

    def __contains__(self, key: str) -> bool:
        return key in _CTR_SLOT

    def get(self, key: str, default=None):
        return self._mv[_CTR_SLOT[key]] if key in _CTR_SLOT else default

    def keys(self):
        return _CTR_SLOT.keys()

    def snapshot(self) -> Dict[str, int]:
        return {k: self._mv[v] for k, v in _CTR_SLOT.items()}

    def __repr__(self) -> str:
        return f"_ShmCounters({self.snapshot()})"


# ------------------------------------------------------------------ #
# Segment lifecycle (leak-robust unlink)                             #
# ------------------------------------------------------------------ #
# Segments get recognizable names ("psc-<owner pid>-<seq>") so a
# crashed run's leftovers in /dev/shm are attributable and reapable.
# Three layers of cleanup:
#   * ``close()`` unlinks, but only in the owning process — a forked
#     worker (or its atexit) must never unlink a segment the parent is
#     still using;
#   * an atexit hook in the owner unlinks anything close() never
#     reached (exceptions, SIGTERM-with-handlers);
#   * ``reap_orphan_segments()`` removes segments whose owner pid is
#     dead — the kill -9 case nothing in-process can cover.  The
#     runtime calls it on ``recover()``.
_SEG_PREFIX = "psc-"
_SEG_SEQ = itertools.count()
#: name -> (owner pid, SharedMemory): segments created by this process
#: and not yet unlinked
_LIVE_SEGMENTS: Dict[str, Tuple[int, Any]] = {}


def _register_segment(name: str, shm) -> None:
    if not _LIVE_SEGMENTS:
        atexit.register(_reap_at_exit)
    _LIVE_SEGMENTS[name] = (os.getpid(), shm)


def _reap_at_exit() -> None:
    for name in list(_LIVE_SEGMENTS):
        pid, shm = _LIVE_SEGMENTS[name]
        if pid != os.getpid():      # inherited entry in a forked child
            continue
        del _LIVE_SEGMENTS[name]
        try:
            shm.close()
        except (OSError, BufferError):
            pass
        try:
            shm.unlink()
        except (FileNotFoundError, OSError):
            pass


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


def reap_orphan_segments(shm_dir: str = "/dev/shm") -> List[str]:
    """Unlink ``psc-<pid>-*`` segments whose owner process is dead
    (killed before teardown).  Never touches live owners' segments or
    this process's own.  Returns the reaped names."""
    reaped: List[str] = []
    try:
        names = os.listdir(shm_dir)
    except OSError:
        return reaped
    for name in names:
        if not name.startswith(_SEG_PREFIX):
            continue
        try:
            pid = int(name.split("-")[1])
        except (IndexError, ValueError):
            continue
        if pid == os.getpid() or _pid_alive(pid):
            continue
        try:
            os.unlink(os.path.join(shm_dir, name))
            reaped.append(name)
        except OSError:
            pass
    return reaped


class ShmBackend(ThreadBackend):
    """``core.backend`` seam over one shared-memory segment.

    Inherits the thread backend and overrides every factory whose
    object must be visible across processes; the ``reset_*`` overrides
    reset IN PLACE (fork-inherited views in workers must stay
    attached).  All factories are create-before-fork: call them (i.e.
    build runtimes/structures) before ``spawn_workers``.
    """

    kind = "shm"

    #: striped-lock pool size: enough to make false sharing of stripes
    #: unlikely at 8 workers, few enough to keep fd/semaphore count low.
    N_STRIPES = 16

    #: Entry backoff under true parallelism (see
    #: ``ThreadBackend.announce_park``): park every announcement for
    #: ~one round so a concurrent combiner adopts it — the measured
    #: degree >= 2 the reproduction targets comes from this window.
    #: Tunable per backend instance (mp_bench exposes --park).
    PARK_PROB = 1.0
    PARK_SECONDS = 1e-4

    def __init__(self, data_words: int = 1 << 18, *,
                 aux_i64: int = 1 << 16, ring_i64: int = 1 << 18,
                 segments: int = 1, blob_bytes: int = 1 << 20) -> None:
        from multiprocessing import shared_memory
        if segments < 1:
            raise ValueError(f"segments must be >= 1, got {segments}")
        if blob_bytes % _BLOB_GRANULE:
            raise ValueError("blob_bytes must be a multiple of "
                             f"{_BLOB_GRANULE}")
        self._ctx = multiprocessing.get_context("fork")
        # equal line-aligned word spans per segment
        per = -(-data_words // segments)
        per += (-per) % LINE
        self.data_words = data_words = per * segments
        self.words_per_seg = per
        self.segments = segments
        self.ring_seg = max(_ENT_HDR + LINE * WORD_I64,
                            ring_i64 // segments)
        n_gran = blob_bytes // _BLOB_GRANULE
        total = (_META_I64 + segments * _SEG_I64
                 + 2 * data_words * WORD_I64
                 + segments * self.ring_seg + aux_i64
                 + 4 * n_gran + blob_bytes // 8)
        # recognizable, owner-stamped segment name (see the lifecycle
        # note above ``reap_orphan_segments``); collisions with a stale
        # same-pid leftover are resolved by advancing the sequence
        self._owner_pid = os.getpid()
        while True:
            name = f"{_SEG_PREFIX}{self._owner_pid}-{next(_SEG_SEQ)}"
            try:
                self._shm = shared_memory.SharedMemory(
                    create=True, name=name, size=total * 8)
                break
            except FileExistsError:
                continue
        self.name = name
        _register_segment(name, self._shm)
        self.mv = self._shm.buf.cast("q")
        self.raw = self._shm.buf
        # fresh /dev/shm pages are zero-filled; meta needs non-zeros
        self.mv[_M_COUNT] = -1
        self.mv[_M_SEED] = -1
        self.mv[_M_LOSESEG] = -1
        self.seg_meta = _META_I64
        self.vol_base = self.seg_meta + segments * _SEG_I64
        self.dur_base = self.vol_base + data_words * WORD_I64
        self.ring_base = self.dur_base + data_words * WORD_I64
        self.aux_base = self.ring_base + segments * self.ring_seg
        self.aux_cap = aux_i64
        self.blob_side_base = self.aux_base + aux_i64
        self.blob_bytes = blob_bytes
        self.blob_base = self.blob_side_base + 4 * n_gran
        # per-segment word allocation pointers (segment 0 reserves line
        # 0: address 0 doubles as NULL for the linked structures)
        for s in range(segments):
            self.mv[self.seg_meta + s * _SEG_I64 + _S_ALLOC] = \
                s * per if s else LINE
        self._stripes = [self._ctx.Lock() for _ in range(self.N_STRIPES)]
        self._alloc_lock = self._ctx.Lock()
        self.nvm_lock = self._ctx.Lock()     # guards images/rings/counters
        # one modeled write-back device per segment (wall persist_latency
        # drains serialize per device, not machine-wide)
        self.device_locks = [self._ctx.Lock() for _ in range(segments)]
        self.heap = BlobHeap(self)
        self._closed = False

    # ---------------- segment plumbing --------------------------------- #
    def aux_alloc(self, n_i64: int) -> int:
        """Bump-allocate ``n_i64`` aux slots; absolute i64 offset."""
        with self._alloc_lock:
            used = self.mv[_M_AUX]
            if used + n_i64 > self.aux_cap:
                raise MemoryError("shm backend aux area exhausted "
                                  f"({self.aux_cap} i64)")
            self.mv[_M_AUX] = used + n_i64
            return self.aux_base + used

    def stripe(self, off: int):
        return self._stripes[off % self.N_STRIPES]

    def close(self) -> None:
        """Release the segment.  Safe to call twice, and safe to call
        from a forked worker: only the creating process unlinks (a
        non-owner close releases its own mapping and nothing else)."""
        if self._closed:
            return
        self._closed = True
        self.raw = None
        self.heap = None
        mv, self.mv = self.mv, None
        mv.release()
        self._shm.close()
        if os.getpid() != self._owner_pid:
            return
        _LIVE_SEGMENTS.pop(self.name, None)
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass

    # ---------------- factories ---------------------------------------- #
    def mutex(self) -> ShmMutex:
        return ShmMutex(self._ctx)

    def cell(self, value: Any = None) -> ShmCell:
        return ShmCell(self, value)

    def atomic_int(self, value: int = 0, *, shared: bool = False,
                   counters: Optional[Counters] = None,
                   clock: Optional[Any] = None) -> ShmAtomicInt:
        return ShmAtomicInt(self, value, shared=shared, counters=counters,
                            clock=clock)

    def atomic_ref(self, value: Any, *, shared: bool = False,
                   counters: Optional[Counters] = None,
                   clock: Optional[Any] = None,
                   mirror: Optional[Tuple[Any, int]] = None) -> ShmAtomicRef:
        return ShmAtomicRef(self, value, shared=shared, counters=counters,
                            clock=clock, mirror=mirror)

    def sref(self, nvm: Any, addr: int, value: int,
             counters: Optional[Counters] = None) -> ShmSRef:
        return ShmSRef(self, nvm, addr, value, counters)

    def int_array(self, n: int, init: int = 0) -> ShmIntArray:
        return ShmIntArray(self.mv, self.aux_alloc(n), n, init)

    def int_matrix(self, rows: int, cols: int) -> List[ShmIntArray]:
        return [self.int_array(cols) for _ in range(rows)]

    def request_board(self, n_threads: int) -> ShmRequestBoard:
        return ShmRequestBoard(self, n_threads)

    def degree_stats(self) -> ShmDegreeStats:
        return ShmDegreeStats(self)

    def announce_park(self, prob: float, seconds: float
                      ) -> Tuple[float, float]:
        return self.PARK_PROB, self.PARK_SECONDS

    # ---------------- in-place resets ----------------------------------- #
    def reset_mutex(self, m: ShmMutex) -> ShmMutex:
        m.reset()
        return m

    def reset_atomic_int(self, a: ShmAtomicInt, value: int = 0,
                         **_kw) -> ShmAtomicInt:
        a.reset(value)
        return a

    def reset_atomic_ref(self, a: ShmAtomicRef, value: Any, *,
                         mirror: Optional[Tuple[Any, int]] = None,
                         **_kw) -> ShmAtomicRef:
        a.reset(value)
        return a

    def reset_sref(self, s: ShmSRef, nvm: Any, addr: int, value: int,
                   counters: Optional[Counters] = None) -> ShmSRef:
        s.reset(nvm, addr, value)
        return s


# --------------------------------------------------------------------- #
# The NVM                                                               #
# --------------------------------------------------------------------- #
class ShmNVM(NVM):
    """Simulated NVMM whose images, write-back rings, counters and crash
    machinery live in the backend's shared segment.

    Same interface and crash semantics as ``NVM`` with these
    multiprocess-specific differences, all visible only to shm runs:

      * fused persistence sentences always take the discrete path
        (identical counters/durability — the fused forms are a
        same-process lock elision that a cross-process lock cannot
        reproduce), so the virtual clock/profile is unsupported here;
      * ``crash()`` additionally raises the shared ``halted`` flag —
        a SimulatedCrash only unwinds the process that hit it, so
        survivors poll the flag from persistence instructions and wait
        loops and stop as if their power was cut.  ``disarm_crash``
        (called by ``CombiningRuntime.recover``) clears it;
      * if a write-back ring fills, the oldest pending write-backs
        are drained to the durable image early (counted in
        ``ring_spills``).  Legal under explicit epoch persistency: the
        lines were pwb'd, the hardware may complete them any time
        before the psync;
      * NUMA-ish segmentation (DESIGN.md §8): the word space is striped
        into ``segments`` spans, each with its own epoch ring, modeled
        sync device, allocation pointer and per-segment accounting
        (``segment_counters()``); ``alloc(..., segment=s)`` or the
        ``placement(s)`` context manager pin a structure to a span;
      * rich word values ride the backend's ``BlobHeap`` — blob-ref
        words charge the referenced chunk's cache-line footprint to
        every pwb that covers them, and the ring pins chunks (by
        refcount) instead of copying their immutable bytes.
    """

    def __init__(self, n_words: int = 1 << 18, *,
                 backend: Optional[ShmBackend] = None,
                 segments: int = 1,
                 pwb_nop: bool = False, psync_nop: bool = False,
                 persist_latency: float = 0.0,
                 audit: bool = False) -> None:
        if backend is None:
            backend = ShmBackend(data_words=n_words, segments=segments)
            n_words = backend.data_words
        elif segments not in (1, backend.segments):
            raise ValueError(
                f"segments={segments} contradicts the supplied backend "
                f"(built with segments={backend.segments}); segmentation "
                "is a property of the segment layout, so pass it where "
                "the backend is constructed")
        if n_words > backend.data_words:
            raise ValueError(f"n_words={n_words} exceeds backend segment "
                             f"({backend.data_words} words)")
        # deliberately NOT calling NVM.__init__: the images live in the
        # segment, and every inherited method that touches them is
        # overridden (the fused sentences dispatch through _fast_ok).
        self.backend = backend
        self.segments = backend.segments
        self.words_per_seg = backend.words_per_seg
        self.n_words = n_words
        self._vol = _Words(backend.mv, backend.vol_base, backend.heap)
        self._dur = _Words(backend.mv, backend.dur_base, backend.heap)
        self._mv = backend.mv
        self._lock = backend.nvm_lock
        self.pwb_nop = pwb_nop
        self.psync_nop = psync_nop
        self.persist_latency = persist_latency
        self.clock = None
        self.force_discrete = False
        self.counters = _ShmCounters(backend.mv)
        self._crash_rng = None
        self._injector = None       # process-local, see _tick_crash_point
        self._default_seg = 0
        # Persist-ordering audit (DESIGN.md §10): per-PROCESS state —
        # sound and complete for in-process drivers (the deterministic
        # analysis sweep); worker processes each see only their own
        # instructions.  The shm NVM has no VClock, so the audit covers
        # the flush-state classes (unflushed/redundant), not order
        # races.  Disabled under the NOP ablations, like the thread NVM.
        self._audit = None
        if audit and not (pwb_nop or psync_nop):
            from ..analysis.audit import PersistAudit   # lazy: no cycle
            self._audit = PersistAudit(self)
            self._install_audit_hooks()

    # ------------------------------------------------------------------ #
    @property
    def halted(self) -> bool:
        return self._mv[_M_HALT] != 0

    def _fast_ok(self) -> bool:
        return False        # fused sentences always take the discrete path

    def _seg_slot(self, s: int, field: int) -> int:
        return self.backend.seg_meta + s * _SEG_I64 + field

    def segment_of(self, addr: int) -> int:
        return min(addr // self.words_per_seg, self.segments - 1)

    # ---------------- allocation --------------------------------------- #
    def current_segment(self) -> int:
        return self._default_seg

    def set_default_segment(self, segment: int) -> None:
        if not 0 <= segment < self.segments:
            raise ValueError(f"segment {segment} out of range "
                             f"(0..{self.segments - 1})")
        self._default_seg = segment

    def placement(self, segment: int):
        """Context manager: allocations inside run on ``segment`` (the
        runtime's structure-affinity policy uses this)."""
        from contextlib import contextmanager

        @contextmanager
        def _cm():
            prev = self._default_seg
            self.set_default_segment(segment)
            try:
                yield self
            finally:
                self._default_seg = prev
        return _cm()

    def alloc(self, n_words: int, align_line: bool = True,
              segment: Optional[int] = None) -> int:
        s = self._default_seg if segment is None else segment
        if not 0 <= s < self.segments:
            raise ValueError(f"segment {s} out of range")
        mv = self._mv
        slot = self._seg_slot(s, _S_ALLOC)
        limit = min(self.n_words, (s + 1) * self.words_per_seg)
        with self._lock:
            ptr = mv[slot]
            if align_line and ptr % LINE:
                ptr += LINE - ptr % LINE
            base = ptr
            ptr += n_words
            if ptr > limit:
                raise MemoryError(
                    f"simulated (shm) NVMM segment {s} exhausted")
            mv[slot] = ptr
            return base

    # ---------------- volatile image ------------------------------------ #
    def read(self, addr: int) -> Any:
        return self._vol.get(addr)

    def write(self, addr: int, value: Any) -> None:
        self._vol.set(addr, value)

    def read_range(self, addr: int, n: int) -> List[Any]:
        return self._vol.get_range(addr, n)

    def write_range(self, addr: int, values) -> None:
        self._vol.set_range(addr, values)

    def copy_range(self, dst: int, src: int, n: int) -> None:
        mv = self._mv
        vb = self.backend.vol_base
        if mv[_M_BLOBBED]:
            # NOTE: _M_BLOBBED is machine-wide and sticky by design —
            # a per-segment flag would be unsound here because a racy
            # source (a PWFComb slot being rewritten mid-copy) can gain
            # its first blob ref AFTER any pre-scan, and aux words have
            # no segment to key a flag on.  The per-word cost is
            # confined to runtimes that actually store rich values.
            # a raw copy duplicates blob refs, so it goes word by word:
            # each source blob ref is VALIDATED-pinned (try_pin) before
            # the dst word is published over the old one — a concurrent
            # writer releasing the source chunk mid-copy is caught by
            # the generation check and that word re-read.  (Non-blob
            # words keep the raw-copy tearing exposure the protocols
            # already discard via their own validation.)
            heap = self.backend.heap
            for j in range(n):
                so = vb + WORD_I64 * (src + j)
                do = vb + WORD_I64 * (dst + j)
                for _ in range(_STALE_RETRIES):
                    t, a, b = mv[so], mv[so + 1], mv[so + 2]
                    if t != _T_BLOB or heap.try_pin(a, b):
                        break
                else:
                    raise RuntimeError("shm blob word kept changing "
                                       "under copy_range")
                old_off = mv[do + 1] if mv[do] == _T_BLOB else -1
                mv[do + 1] = a
                mv[do + 2] = b
                mv[do] = t
                if old_off >= 0:
                    heap.dec(old_off)
            return
        a = vb + WORD_I64 * src
        d = vb + WORD_I64 * dst
        n3 = WORD_I64 * n
        mv[d:d + n3] = mv[a:a + n3]

    def durable_read(self, addr: int) -> Any:
        return self._dur.get(addr)

    # ---------------- write-back rings ----------------------------------- #
    # Per-segment entry layout (i64): [epoch_id, first_line, n_lines,
    #   blob_lines, payload: n_lines * LINE * WORD_I64]
    def _blob_refs_in(self, base_i64: int, n_words: int) -> List[int]:
        """Blob offsets referenced by words at [base_i64, +n_words) of
        the backing view, one per OCCURRENCE (callers dedupe for line
        accounting, keep occurrences for refcounts)."""
        mv = self._mv
        return [mv[o + 1]
                for o in range(base_i64, base_i64 + WORD_I64 * n_words,
                               WORD_I64)
                if mv[o] == _T_BLOB]

    def _blob_lines(self, refs: List[int]) -> int:
        heap = self.backend.heap
        return sum(heap.lines(off) for off in set(refs))

    def _ring_append_locked(self, s: int, first: int,
                            n_lines: int, spill_out=None) -> int:
        """Append one entry to segment ``s``'s ring; returns the blob
        line count charged on top of the word lines.  ``spill_out``
        collects the line runs of any overflow early-drain so the audit
        can retire them without an ordering judgment."""
        mv = self._mv
        size = _ENT_HDR + n_lines * LINE * WORD_I64
        rslot = self._seg_slot(s, _S_RING)
        used = mv[rslot]
        if used + size > self.backend.ring_seg:
            # early completion of pending write-backs (see class doc)
            drained = self._drain_ring_locked(s)
            if spill_out is not None:
                spill_out.extend(drained)
            mv[_M_SPILLS] += 1
            mv[self._seg_slot(s, _S_SPILLS)] += 1
            used = 0
            if size > self.backend.ring_seg:
                raise MemoryError("shm write-back ring smaller than one "
                                  f"pwb of {n_lines} lines")
        o = self.backend.ring_base + s * self.backend.ring_seg + used
        mv[o] = mv[self._seg_slot(s, _S_EPOCH)]
        mv[o + 1] = first
        mv[o + 2] = n_lines
        src = self.backend.vol_base + WORD_I64 * first * LINE
        n3 = n_lines * LINE * WORD_I64
        mv[o + _ENT_HDR:o + _ENT_HDR + n3] = mv[src:src + n3]
        blob_lines = 0
        if mv[_M_BLOBBED]:
            # pin every referenced chunk per occurrence: the ring's
            # snapshot words hold refs, not byte copies — the pin is
            # what keeps the (immutable) bytes around until drain.
            # Pins are VALIDATED (try_pin): a writer racing this pwb
            # may have released the chunk between the slice copy above
            # and here, in which case the fresh word is re-snapshotted
            # (either value is a legal pwb-time capture).
            heap = self.backend.heap
            pinned = []
            for w in range(n_lines * LINE):
                so = o + _ENT_HDR + WORD_I64 * w
                for _ in range(_STALE_RETRIES):
                    if mv[so] != _T_BLOB:
                        break
                    if heap.try_pin(mv[so + 1], mv[so + 2]):
                        pinned.append(mv[so + 1])
                        break
                    vo = src + WORD_I64 * w
                    mv[so:so + WORD_I64] = mv[vo:vo + WORD_I64]
                else:
                    # the entry is abandoned (ring cursor never
                    # advances past it) — release the pins this loop
                    # already took or their chunks leak forever
                    for poff in pinned:
                        heap.dec(poff)
                    raise RuntimeError("shm blob word kept changing "
                                       "under pwb snapshot")
            if pinned:
                blob_lines = self._blob_lines(pinned)
        mv[o + 3] = blob_lines
        mv[rslot] = used + size
        mv[self._seg_slot(s, _S_EFLAG)] = 1
        return blob_lines

    def _ring_entries_locked(self, s: int
                             ) -> List[Tuple[int, int, int, int, int]]:
        """[(epoch, first_line, n_lines, blob_lines, payload_off)]."""
        mv = self._mv
        out = []
        o = self.backend.ring_base + s * self.backend.ring_seg
        end = o + mv[self._seg_slot(s, _S_RING)]
        while o < end:
            n_lines = mv[o + 2]
            out.append((mv[o], mv[o + 1], n_lines, mv[o + 3],
                        o + _ENT_HDR))
            o += _ENT_HDR + n_lines * LINE * WORD_I64
        return out

    def _drain_entry_locked(self, first: int, n_lines: int,
                            payload: int) -> None:
        """Install a snapshot span over the durable image.  The
        snapshot's blob refs were pinned at append time; they become
        the durable words' refs here, so only the refs of the durable
        words being BURIED are released."""
        mv = self._mv
        dst = self.backend.dur_base + WORD_I64 * first * LINE
        n3 = n_lines * LINE * WORD_I64
        if mv[_M_BLOBBED]:
            heap = self.backend.heap
            for off in self._blob_refs_in(dst, n_lines * LINE):
                heap.dec(off)
        mv[dst:dst + n3] = mv[payload:payload + n3]

    def _discard_span_locked(self, payload: int, n_words: int) -> None:
        """Release the pins of a snapshot span that will never drain
        (crash dropped it)."""
        if self._mv[_M_BLOBBED]:
            heap = self.backend.heap
            for off in self._blob_refs_in(payload, n_words):
                heap.dec(off)

    def _drain_ring_locked(self, s: int) -> List[Tuple[int, int]]:
        drained = []
        for _e, first, n_lines, _bl, payload in \
                self._ring_entries_locked(s):
            self._drain_entry_locked(first, n_lines, payload)
            drained.append((first, n_lines))
        self._mv[self._seg_slot(s, _S_RING)] = 0
        self._mv[self._seg_slot(s, _S_EFLAG)] = 0
        return drained

    # ---------------- persistence instructions --------------------------- #
    def _tick_crash_point(self, kind: str = "") -> None:
        mv = self._mv
        if mv[_M_HALT]:
            raise SimulatedCrash()
        inj = self._injector
        if inj is not None and inj.tick(kind):
            # process-LOCAL injector (same seam as the thread NVM): the
            # arming process's own instruction stream trips it — the
            # deterministic in-parent fuzz drivers; the shared countdown
            # below stays the cross-process crash mechanism
            self._injector = None
            self.crash(inj.rng)
            raise SimulatedCrash()
        if mv[_M_COUNT] >= 0:
            with self._lock:
                cd = mv[_M_COUNT]
                if cd < 0:           # another process just fired it
                    fire = False
                else:
                    mv[_M_COUNT] = cd - 1
                    fire = cd - 1 < 0
                if fire:
                    mv[_M_COUNT] = -1
            if fire:
                rng = self._crash_rng
                if rng is None and mv[_M_SEED] >= 0:
                    import random
                    rng = random.Random(mv[_M_SEED])
                self.crash(rng)
                raise SimulatedCrash()

    def _halt_check_locked(self) -> None:
        """Raise before an instruction takes ANY shared effect on a
        powered-off machine.  Must run under ``self._lock``: ``crash``
        raises the flag under the same lock, so a surviving process can
        never slip a ring append or counter bump past the cut."""
        if self._mv[_M_HALT]:
            raise SimulatedCrash()

    def _split_runs(self, runs) -> List[Tuple[int, int, int]]:
        """Split (first_line, n_lines) runs at segment boundaries:
        [(segment, first_line, n_lines)] — each write-back entry lives
        on exactly one device."""
        if self.segments == 1:
            return [(0, first, n) for first, n in runs]
        lps = self.words_per_seg // LINE
        out = []
        for first, n in runs:
            while n:
                s = min(first // lps, self.segments - 1)
                take = n if s == self.segments - 1 \
                    else min(n, (s + 1) * lps - first)
                out.append((s, first, take))
                first += take
                n -= take
        return out

    def _persist_runs(self, runs) -> None:
        """Shared body of pwb/persist_lines: queue every (line) run on
        its segment's ring, count word + blob lines."""
        split = self._split_runs(runs)
        aud = self._audit
        spilled: Optional[list] = [] if aud is not None else None
        mv = self._mv
        with self._lock:
            self._halt_check_locked()
            total = 0
            for s, first, n_lines in split:
                if not self.pwb_nop:
                    blob_lines = self._ring_append_locked(s, first,
                                                          n_lines,
                                                          spilled)
                elif mv[_M_BLOBBED]:
                    refs = self._blob_refs_in(
                        self.backend.vol_base + WORD_I64 * first * LINE,
                        n_lines * LINE)
                    blob_lines = self._blob_lines(refs)
                else:
                    blob_lines = 0
                mv[self._seg_slot(s, _S_PWB)] += n_lines + blob_lines
                total += n_lines + blob_lines
            mv[_M_PWB] += total
        if aud is not None:
            if spilled:
                aud.on_spill(spilled)
            aud.on_pwb([(first, n) for _s, first, n in split])
        self._tick_crash_point("pwb")

    def pwb(self, addr: int, n_words: int = 1) -> None:
        first = addr // LINE
        n_lines = (addr + n_words - 1) // LINE - first + 1
        self._persist_runs([(first, n_lines)])

    pwb_range = pwb

    def persist_lines(self, ranges) -> None:
        if isinstance(ranges, list) and len(ranges) == 1:
            addr, n_words = ranges[0]
            self.pwb(addr, n_words)
            return
        runs = self._pending_lines(ranges)
        if not runs:
            return
        self._persist_runs(runs)

    def pfence(self) -> None:
        mv = self._mv
        had_pending = False
        with self._lock:
            self._halt_check_locked()
            mv[_M_PFENCE] += 1
            for s in range(self.segments):
                if mv[self._seg_slot(s, _S_EFLAG)]:
                    had_pending = True
                    mv[self._seg_slot(s, _S_EPOCH)] += 1
                    mv[self._seg_slot(s, _S_EFLAG)] = 0
        if self._audit is not None:
            self._audit.on_pfence(had_pending)
        self._tick_crash_point("pfence")

    def psync(self) -> None:
        drained_by_seg: Dict[int, List[Tuple[int, int]]] = {}
        mv = self._mv
        with self._lock:
            self._halt_check_locked()
            mv[_M_PSYNC] += 1
            if not self.psync_nop:
                for s in range(self.segments):
                    if mv[self._seg_slot(s, _S_RING)]:
                        drained_by_seg[s] = self._drain_ring_locked(s)
                        # one device round trip per ENGAGED segment —
                        # this is the per-segment psync accounting the
                        # NUMA-ish model exists to expose
                        mv[self._seg_slot(s, _S_PSYNC)] += 1
        if self._audit is not None:
            # no VClock on the shm NVM: sync_now=0 disables the order
            # check, leaving the flush-state classes active
            self._audit.on_psync(
                [r for d in drained_by_seg.values() for r in d], 0.0)
        if drained_by_seg and self.persist_latency:
            for s, drained in drained_by_seg.items():
                runs, total_lines = self._run_stats(drained)
                cost = (self.persist_latency + runs * self.SEEK_COST
                        + total_lines * self.STREAM_COST)
                with self.backend.device_locks[s]:
                    time.sleep(cost)
        self._tick_crash_point("psync")

    # ---------------- crash / recovery ----------------------------------- #
    def arm_crash(self, after_persist_ops: int, rng=None, *,
                  lose_segment: Optional[int] = None) -> None:
        """Shared countdown: WHICHEVER process issues the
        ``after_persist_ops``-th next persistence instruction crashes
        the machine.  ``rng`` governs the adversarial drain when the
        arming process itself trips the countdown; a different process
        falls back to a seed captured here (same distribution, not the
        same draw) — pass ``rng=None`` for the deterministic
        drain-nothing cut either way.

        ``lose_segment``: partial-failure policy for the crash this arms
        — that segment's DIMM loses every pending write-back while all
        other segments drain fully (the maximally skewed per-device
        power-loss cut, repro.fuzz's segment-loss class).  Overrides the
        rng drain policy; shared, so whichever process trips the
        countdown applies it."""
        mv = self._mv
        if lose_segment is not None and \
                not 0 <= lose_segment < self.segments:
            raise ValueError(f"lose_segment {lose_segment} out of range "
                             f"(0..{self.segments - 1})")
        self._crash_rng = rng
        mv[_M_SEED] = (-1 if rng is None
                       else hash(rng.getstate()) & 0x7FFFFFFF)
        mv[_M_LOSESEG] = -1 if lose_segment is None else lose_segment
        mv[_M_COUNT] = after_persist_ops

    def disarm_crash(self) -> None:
        """Disarm any countdown AND clear the machine-off flag — the
        runtime's ``recover`` calls this first, which is exactly when
        the machine powers back on.

        Powering on is also when the volatile word image is restored
        from the durable one (with the blob refcount fix-up).  Doing it
        here rather than in ``crash()`` is deliberate: at crash time
        surviving worker processes may still be unwinding (plain stores
        between two persistence instructions), so a restore racing them
        could corrupt the blob refcounts; by the time the parent calls
        ``recover`` every worker has reported and parked — the restore
        scans run quiesced.  Until power-on, reads of the volatile
        image are reads of a dead machine's RAM (nothing meaningful);
        the durable image is fully resolved at crash time."""
        mv = self._mv
        with self._lock:
            mv[_M_COUNT] = -1
            mv[_M_LOSESEG] = -1
            if mv[_M_HALT]:
                self._restore_volatile_locked()
                mv[_M_HALT] = 0
        self._crash_rng = None

    def _restore_volatile_locked(self) -> None:
        """vol := dur, with the blob refs of the buried volatile words
        released and the restored (durable) refs duplicated.  Chunks
        are immutable while referenced, so the restored refs decode
        against the very bytes the durable words were drained with —
        no blob image copy exists or is needed."""
        mv = self._mv
        heap = self.backend.heap
        blobbed = bool(mv[_M_BLOBBED])
        if blobbed:
            for s in range(self.segments):
                start, end = self._seg_word_span(s)
                for off in self._blob_refs_in(
                        self.backend.vol_base + WORD_I64 * start,
                        end - start):
                    heap.dec(off)
        n3 = self.backend.data_words * WORD_I64
        mv[self.backend.vol_base:self.backend.vol_base + n3] = \
            mv[self.backend.dur_base:self.backend.dur_base + n3]
        if blobbed:
            for s in range(self.segments):
                start, end = self._seg_word_span(s)
                for off in self._blob_refs_in(
                        self.backend.vol_base + WORD_I64 * start,
                        end - start):
                    heap.inc(off)

    def _seg_word_span(self, s: int) -> Tuple[int, int]:
        """Allocated [start, end) word range of segment ``s`` (the only
        words a blob-ref rescan needs to walk)."""
        start = s * self.words_per_seg + (LINE if s == 0 else 0)
        return start, self._mv[self._seg_slot(s, _S_ALLOC)]

    def crash(self, rng=None) -> None:
        mv = self._mv
        with self._lock:
            mv[_M_CRASHES] += 1
            blobbed = bool(mv[_M_BLOBBED])
            lose_seg = mv[_M_LOSESEG]
            mv[_M_LOSESEG] = -1
            for s in range(self.segments):
                entries = self._ring_entries_locked(s)
                drained_snaps: set = set()      # payload line offsets
                if lose_seg >= 0:
                    # segment-loss cut: the lost DIMM drains NOTHING;
                    # every surviving segment drains its whole ring —
                    # the most skewed per-device power-loss outcome
                    if s != lose_seg:
                        for _e, first, n_lines, _bl, payload in entries:
                            self._drain_entry_locked(first, n_lines,
                                                     payload)
                            for j in range(n_lines):
                                drained_snaps.add(
                                    payload + j * LINE * WORD_I64)
                elif rng is not None and entries:
                    # mirror NVM.crash per segment: epochs = distinct
                    # ids in order plus a trailing empty epoch when the
                    # current one is empty
                    distinct: List[int] = []
                    for e, _f, _n, _bl, _p in entries:
                        if not distinct or distinct[-1] != e:
                            distinct.append(e)
                    n_epochs = len(distinct) + \
                        (0 if mv[self._seg_slot(s, _S_EFLAG)] else 1)
                    cut = rng.randint(0, n_epochs - 1)
                    for e, first, n_lines, _bl, payload in entries:
                        if e in distinct[:cut]:
                            self._drain_entry_locked(first, n_lines,
                                                     payload)
                            for j in range(n_lines):
                                drained_snaps.add(
                                    payload + j * LINE * WORD_I64)
                    if cut < len(distinct):
                        cut_id = distinct[cut]
                        cut_epoch: List[Tuple[int, int]] = []
                        for e, first, n_lines, _bl, payload in entries:
                            if e == cut_id:
                                for j in range(n_lines):
                                    cut_epoch.append(
                                        (first + j,
                                         payload + j * LINE * WORD_I64))
                        taken_upto: Dict[int, int] = {}
                        for i, (line, _snap) in enumerate(cut_epoch):
                            if rng.random() < 0.5:
                                taken_upto[line] = i
                        for i, (line, snap) in enumerate(cut_epoch):
                            if i <= taken_upto.get(line, -1):
                                self._drain_entry_locked(line, 1, snap)
                                drained_snaps.add(snap)
                if blobbed:
                    # release the pins of every snapshot line the
                    # adversary dropped (drained lines transferred
                    # their pins to the durable words)
                    for _e, _first, n_lines, _bl, payload in entries:
                        for j in range(n_lines):
                            snap = payload + j * LINE * WORD_I64
                            if snap not in drained_snaps:
                                self._discard_span_locked(snap, LINE)
                mv[self._seg_slot(s, _S_RING)] = 0
                mv[self._seg_slot(s, _S_EFLAG)] = 0
                mv[self._seg_slot(s, _S_EPOCH)] = 0
            mv[_M_COUNT] = -1
            # machine off until disarm_crash — which is also where the
            # volatile image restore (and its blob-ref fix-up) happens:
            # surviving processes may still be mid-store right now, and
            # power-on is the first quiesced point (see disarm_crash)
            mv[_M_HALT] = 1
        if self._audit is not None:
            self._audit.on_crash()

    # ---------------- introspection -------------------------------------- #
    def pending_lines(self) -> int:
        with self._lock:
            return sum(n + bl
                       for s in range(self.segments)
                       for _e, _f, n, bl, _p in
                       self._ring_entries_locked(s))

    def segment_counters(self) -> List[Dict[str, int]]:
        """Per-segment device accounting: write-back lines, engaged
        psyncs, ring spills, allocated words."""
        mv = self._mv
        out = []
        for s in range(self.segments):
            start, end = self._seg_word_span(s)
            out.append({"segment": s,
                        "pwb": mv[self._seg_slot(s, _S_PWB)],
                        "psync": mv[self._seg_slot(s, _S_PSYNC)],
                        "ring_spills": mv[self._seg_slot(s, _S_SPILLS)],
                        "words_used": max(0, end - start)})
        return out

    def reset_counters(self) -> None:
        mv = self._mv
        for slot in _CTR_SLOT.values():
            mv[slot] = 0
        for s in range(self.segments):
            for f in (_S_PWB, _S_PSYNC, _S_SPILLS):
                mv[self._seg_slot(s, f)] = 0
        if self._audit is not None:
            self._audit.reset_metrics()

    def occupancy(self) -> Dict[str, int]:
        """Machine-wide memory gauge for the soak harness: allocated
        word footprint plus live blob bytes."""
        words = sum(sc["words_used"] for sc in self.segment_counters())
        heap = self.backend.heap.occupancy()
        word_bytes = words * WORD_I64 * 8
        return {"backend": "shm", "words_used": words,
                "word_bytes": word_bytes,
                "live_chunks": heap["live_chunks"],
                "blob_live_bytes": heap["live_bytes"],
                "blob_bump_bytes": heap["bump_bytes"],
                "occupancy_bytes": word_bytes + heap["live_bytes"]}

    def _blob_word_spans(self) -> List[Tuple[int, int]]:
        """Tagged-word (base_i64, n_words) regions that may hold blob
        refs: the allocated vol+dur span of every segment."""
        spans = []
        for s in range(self.segments):
            start, end = self._seg_word_span(s)
            if end > start:
                spans.append((self.backend.vol_base + WORD_I64 * start,
                              end - start))
                spans.append((self.backend.dur_base + WORD_I64 * start,
                              end - start))
        return spans

    def gc_blobs(self, compact: bool = True) -> Dict[str, int]:
        """Blob-heap GC pass (quiescent-point maintenance, e.g. from
        ``CombiningRuntime.quiesce``): optionally compact live chunks
        downward, then coalesce free space and retreat the bump
        pointer.  Requires empty write-back rings — ring snapshots pin
        chunks by ref, and a moved chunk must not leave a stale ref in
        an entry that drains later; callers psync first."""
        mv = self._mv
        with self._lock:
            for s in range(self.segments):
                if mv[self._seg_slot(s, _S_RING)]:
                    raise RuntimeError("gc_blobs needs empty write-back "
                                       "rings; psync before collecting")
            heap = self.backend.heap
            out = {"moved_chunks": 0}
            if compact and mv[_M_BLOBBED]:
                out = heap.compact(self._blob_word_spans())
            out.update(heap.gc())
            return out

    def blob_leak_check(self) -> Dict[str, int]:
        """Refcount audit over the word images (see
        ``BlobHeap.leak_check``); call with empty rings and quiesced
        boards for an exact answer."""
        return self.backend.heap.leak_check(self._blob_word_spans())

    def close(self) -> None:
        self._vol = self._dur = self._mv = None
        self.counters = None
        self.backend.close()
