"""PBComb — the paper's blocking recoverable combining protocol.

Faithful implementation of Algorithms 1 and 2.  Design decisions
(paper Definition 1) and how each respects the persistence principles
(Definition 2):

  1. combiner election: CAS on a *volatile* integer ``Lock`` whose parity
     encodes taken/free; a thread may leave the entry section without ever
     CAS-ing if its request was served (P1: the lock is never persisted).
  2. requests: flat volatile ``Request[0..n-1]`` array (P1 — never
     persisted; ``valid`` bits are reset by a crash, which is exactly what
     recovery needs).
  3. updates applied to a *copy* of the state: 2-slot non-volatile
     ``MemState[0..1]``; the combiner works on slot ``1 - MIndex`` (P3 —
     one contiguous pwb covers state + responses + deactivate bits).
  4. responses: ``ReturnVal[0..n-1]`` inside the StateRec (P3).
  5. served-detection: per-thread ``activate`` (volatile, in Request) vs
     ``Deactivate`` (inside the persisted StateRec).  Only deactivate is
     persisted; the system-provided ``seq`` parity replaces activate at
     recovery (P1).

Per combining round of degree d: pwb(StateRec) + pfence + pwb(MIndex) +
psync — i.e. O(1) persistence instructions for d requests.

StateRec NVM layout (contiguous, line-aligned):
    [ st : state_words | ReturnVal[0..n-1] | Deactivate[0..n-1] ]
"""

from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Any, Optional

from .atomics import Counters
from .nvm import NVM, SimulatedCrash
from .objects import SeqObject


@dataclass(slots=True)
class RequestRec:
    func: Optional[str] = None
    args: Any = None
    activate: int = 0
    valid: int = 0
    # Virtual-clock announce timestamp (ns): a combiner adopting this
    # request merges it Lamport-style, so a round's modeled latency is
    # the max over its participants (unused when no profile is engaged).
    vtime: float = 0.0
    # Announce seqlock (volatile, costs no NVM instruction): odd while
    # an in-place announce is rewriting the fields, bumped even when it
    # publishes.  The paper's Request[p] is a single pointer store (one
    # atomic publication); our field-per-field record needs this so a
    # combiner scanning under TRUE parallelism can never adopt a MIXED
    # record — func from one announcement, args from the next (caught
    # by the mp heap stress: a torn HINSERT/None pair).  Scanners
    # re-check the stamp after reading the fields and skip the record
    # on a mismatch; the writer's announce is then simply "not yet
    # published" for that pass.
    stamp: int = 0


class PBComb:
    # Announce-backoff: a small random fraction of operations parks
    # briefly right after announcing, widening the window in which a
    # concurrent combiner adopts the request into ITS round.  Served
    # ops skip their own round entirely — fewer pwbs/psyncs per op, the
    # very effect combining exists to create (and what the paper's
    # backoff at the protocol entry is for).  Disable with park=False
    # for deterministic single-threaded tests.
    ANNOUNCE_PARK_PROB = 0.03
    ANNOUNCE_PARK_SECONDS = 1e-6   # OS floor applies; "as short as possible"

    # Test-only seeded-bug fixture (repro.fuzz.bugs): when True, the
    # combiner's scan emulates the PR 5 torn-announcement read — args
    # adopted from a STALE generation of the request record, the very
    # mix the seqlock stamp re-check exists to prevent.  Never set
    # directly; tests toggle it via ``seeded_bug("torn-announce")``.
    torn_announce_bug = False

    def __init__(self, nvm: NVM, n_threads: int, obj: SeqObject,
                 counters: Optional[Counters] = None,
                 park: bool = True, vector_apply: bool = False) -> None:
        self.nvm = nvm
        self.n = n_threads
        self.obj = obj
        self._counters = counters
        # VectorApply (DESIGN.md §11): when enabled, a combining pass
        # collects its adoptable announcements first and a homogeneous
        # batch executes as ONE jitted kernel (obj.vector_apply); any
        # decline — mixed funcs, rich payloads, no jax — falls back to
        # the identical per-op loop.  Off by default: the gated modeled
        # trajectory is produced with the eager path, and the
        # equivalence property tests are what license turning this on.
        self._vector_enabled = bool(vector_apply)
        sw = obj.state_words
        self.state_words = sw
        self.rec_words = sw + 2 * n_threads
        # --- shared non-volatile variables --------------------------- #
        self.mem_base = [nvm.alloc(self.rec_words) for _ in range(2)]
        self.mindex_addr = nvm.alloc(1)
        nvm.write(self.mindex_addr, 0)
        for ind in range(2):
            obj.init_state(nvm, self.mem_base[ind])
            for q in range(n_threads):
                nvm.write(self._retval_addr(ind, q), None)
                nvm.write(self._deact_addr(ind, q), 0)
        # Initial image must be durable (the paper assumes initialized NVMM).
        nvm.pwb(self.mem_base[0], self.rec_words)
        nvm.pwb(self.mem_base[1], self.rec_words)
        nvm.pwb(self.mindex_addr, 1)
        nvm.psync()
        nvm.reset_counters()
        # --- shared volatile variables -------------------------------- #
        # Everything shared between participants comes from the NVM's
        # execution backend (DESIGN.md §7): interpreter-heap objects on
        # the thread backend, shared-memory views on the multiprocess
        # one.  Combiner-local scratch stays a plain attribute.
        be = nvm.backend
        self.request = be.request_board(n_threads)
        self._clock = nvm.clock
        # Virtual time at which the last committed round's psync landed;
        # waiters picking up a response merge it (Lamport hand-off).  A
        # later round may overwrite it before a slow waiter reads it —
        # merge is a max, so that only ever charges the waiter MORE.
        self._round_end_vt = 0.0
        self.lock = be.atomic_int(0, shared=True, counters=counters,
                                  clock=nvm.clock)
        self._lockval = be.cell(0)  # written by the combiner, read by waiters
        # Combiner election (the line 8 CAS) as a non-blocking mutex
        # try-acquire: same atomicity, one C call instead of a guarded
        # compare under a Python-level mutex.  ``lock`` itself is then
        # written only by the elected combiner (plain GIL-atomic store).
        self._elect = be.mutex()
        self.park_enabled = park
        # entry backoff, backend-tuned (wide under true parallelism)
        self._park_prob, self._park_secs = be.announce_park(
            self.ANNOUNCE_PARK_PROB, self.ANNOUNCE_PARK_SECONDS)
        self._rng = random.Random(0x9B5EED)   # seeded: runs reproducible
        # Measured combining degree (requests served per committed
        # round) — the wall-clock counterpart of the modeled degree-4
        # staging; mp_bench and the matrix bench report it.
        self.stats = be.degree_stats()
        self._round_served = 0

    # LockVal lives in a backend cell so a combiner process's write is
    # visible to waiter processes; property keeps the paper's name.
    @property
    def lockval(self) -> int:
        return self._lockval.value

    @lockval.setter
    def lockval(self, v: int) -> None:
        self._lockval.value = v

    # ---------------- field address helpers --------------------------- #
    def _st_base(self, ind: int) -> int:
        return self.mem_base[ind]

    def _retval_addr(self, ind: int, q: int) -> int:
        return self.mem_base[ind] + self.state_words + q

    def _deact_addr(self, ind: int, q: int) -> int:
        return self.mem_base[ind] + self.state_words + self.n + q

    def _mindex(self) -> int:
        return self.nvm.read(self.mindex_addr)

    # ---------------- public API (Algorithm 1) ------------------------ #
    def op(self, p: int, func: str, args: Any, seq: int) -> Any:
        """PBCOMB(func, args, seq) executed by thread p.

        The announcement mutates p's RequestRec in place instead of
        allocating a fresh record per op.  This is race-safe: p's
        previous request is necessarily served already (p stays inside
        ``_perform_request`` until it is), so a concurrent combiner
        skips the record while ``valid`` is 0 and observes the new
        (func, args, activate) only after ``valid`` flips back to 1.
        """
        req = self.request[p]
        st = req.stamp + 1
        req.stamp = st          # odd: announce in progress (seqlock)
        req.valid = 0
        req.func = func
        req.args = args
        req.activate = 1 - req.activate
        clk = self._clock
        if clk is not None:
            req.vtime = clk.now()
        req.valid = 1
        req.stamp = st + 1      # even: published
        if self.park_enabled and self._rng.random() < self._park_prob:
            time.sleep(self._park_secs)
            # a combiner may have served the parked request: if its
            # round already psync'd (lock even), return the recorded
            # response without a round of our own (cf. Recover's path)
            nvm = self.nvm
            if self.lock.load() % 2 == 0:
                mindex = nvm.read(self.mindex_addr)
                if req.activate == nvm.read(self._deact_addr(mindex, p)):
                    if clk is not None:
                        clk.merge(self._round_end_vt)
                    return nvm.read(self._retval_addr(mindex, p))
        return self._perform_request(p)

    def recover(self, p: int, func: str, args: Any, seq: int) -> Any:
        """Recovery function (Algorithm 1, lines 3-6).  Called by the
        "system" for every thread that had an operation in flight at crash
        time, with the same arguments (Section 2's system-support
        assumption)."""
        self.request[p] = RequestRec(func, args, seq % 2, 1)
        if self.nvm.read(self._deact_addr(self._mindex(), p)) != seq % 2:
            return self._perform_request(p)
        return self.nvm.read(self._retval_addr(self._mindex(), p))

    def reset_volatile(self) -> None:
        """Re-initialize volatile protocol state after a crash (the crash
        wiped registers/caches/DRAM — Request, Lock, LockVal are volatile).

        The recreated lock keeps the original ``Counters`` reference so
        synchronization-cost measurements keep accumulating in post-crash
        benchmark phases.  Request activate bits are re-seeded from the
        durable deactivate bits (``resync_request``) so a thread whose
        next operation arrives through the normal ``op`` path — not
        ``recover`` — still flips to a fresh parity.

        All through the backend's reset methods: the thread backend
        recreates the objects (the seed's behavior), the shm backend
        resets the shared state in place so fork-inherited views in
        worker processes stay attached."""
        be = self.nvm.backend
        self.request.reset()
        self.lock = be.reset_atomic_int(self.lock, 0,
                                        shared=True,
                                        counters=self._counters,
                                        clock=self.nvm.clock)
        self.lockval = 0
        self._elect = be.reset_mutex(self._elect)  # may be held at crash
        for p in range(self.n):
            self.resync_request(p)

    def resync_request(self, p: int) -> None:
        """Re-seed thread p's volatile activate parity from the durable
        deactivate bit (the paper's system hands recovery the in-flight
        seq; for threads with no in-flight op the persisted parity is the
        only survivor of the crash)."""
        deact = self.nvm.read(self._deact_addr(self._mindex(), p))
        self.request[p] = RequestRec(None, None, deact, 0)

    # A waiter spins a few GIL-yields, then parks on a real (tiny) sleep.
    # On hardware the paper's waiters spin on a cache line; under CPython
    # a pure ``sleep(0)`` spinner can convoy the GIL against the combiner
    # (it re-wins the handoff), starving the very round that would serve
    # it.  Parking lets the combiner run — and widens the announcement
    # window, so rounds combine MORE requests per psync, which is the
    # effect the protocol exists to create.
    SPIN_FAST = 3
    PARK_SECONDS = 2e-5

    def _wait_while(self, expected: int) -> None:
        lock = self.lock
        nvm = self.nvm
        spins = 0
        while lock.load() == expected:
            # Machine-off check: a crash in ANOTHER process cannot unwind
            # this one, so waiters poll the shared halted flag instead of
            # spinning on a lock word the dead combiner never releases.
            if nvm.halted:
                raise SimulatedCrash()
            spins += 1
            time.sleep(0 if spins <= self.SPIN_FAST else self.PARK_SECONDS)

    # ---------------- Algorithm 2 ------------------------------------- #
    def _perform_request(self, p: int) -> Any:
        nvm = self.nvm
        clk = self._clock
        while True:
            lval = self.lock.load()                          # line 6
            if lval % 2 == 0:                                # line 7
                if self._elect.acquire(False):               # line 8 (CAS)
                    if self._counters is not None:
                        self._counters.cas_calls += 1
                    if clk is not None:
                        clk.advance(clk.profile.cas_ns)
                    # while _elect is held nobody else stores the lock,
                    # and its last writer left it even — re-read in case
                    # a whole round completed since the line 6 load
                    lval = self.lock.load()
                    self.lock.store(lval + 1)
                    break                                    # p is combiner
                if self._counters is not None:
                    self._counters.cas_calls += 1
                if clk is not None:
                    clk.advance(clk.profile.cas_ns)
                lval += 1                                    # line 9
            self._wait_while(lval)                           # line 10
            mindex = self._mindex()
            if self.request[p].activate == nvm.read(self._deact_addr(mindex, p)):  # line 11
                if self.lockval != lval:                     # line 12
                    # Served by an in-flight round: wait for its psync.
                    self._wait_while(lval + 2)
                if clk is not None:
                    # Lamport hand-off: the waiter's clock jumps to the
                    # serving round's commit time (max, not sum).
                    clk.merge(self._round_end_vt)
                return nvm.read(self._retval_addr(self._mindex(), p))  # line 13
        return self._combine(p, lval + 1)

    def _combine(self, p: int, lock_val: int) -> Any:
        """Combiner code, Algorithm 2 lines 14-29.  Hot path: addresses
        are derived once per round and NVM accessors bound to locals —
        the loop body is the per-request cost the paper amortizes.
        ``lock_val`` is the (odd) lock value this combiner installed at
        line 8: while the lock is held nobody else writes it, so the
        line 24 read and line 28 increment are plain arithmetic."""
        nvm = self.nvm
        wr = nvm.write
        clk = self._clock
        if clk is not None:
            clk.advance(clk.profile.round_ns)   # round fusion bookkeeping
        mindex = nvm.read(self.mindex_addr)
        ind = 1 - mindex                                     # line 14
        base = self.mem_base[ind]
        nvm.copy_range(base, self.mem_base[mindex], self.rec_words)  # line 15
        self._round_served = 0
        self._begin_round(ind, p)
        retval_base = base + self.state_words
        deact_base = retval_base + self.n
        request = self.request
        served = 0
        # Simulation loop (line 16), iterated to a fixpoint: one pass
        # serves everything announced before it, and a further pass
        # adopts announcements that landed WHILE it ran.  Under the GIL
        # the second pass finds nothing (the scan isn't preempted) and
        # this is the paper's single scan; under true parallelism it is
        # where measured degree comes from — announcers overlap the
        # combiner's applies and still ride this round's single psync.
        # Bounded: a served thread blocks until the round commits, so
        # each thread contributes at most one request per round (at
        # most n passes, typically 2).
        vector = self._vector_enabled
        while True:
            pass_served = 0
            batch = [] if vector else None
            deacts = nvm.read_range(deact_base, self.n)  # one slice, n reads
            for q in range(self.n):                          # line 16
                req = request[q]
                # seqlock snapshot: skip records mid-announce, and
                # re-check the stamp after the field reads so a mixed
                # (func from one announce, args from the next) record
                # is never applied — a skipped record is adopted by a
                # later fixpoint pass or the announcer's own round
                s1 = req.stamp
                act = req.activate
                if s1 & 1 or req.valid != 1 or act == deacts[q]:  # line 17
                    continue
                func, args, vt = req.func, req.args, req.vtime
                if req.stamp != s1:
                    continue
                if PBComb.torn_announce_bug:
                    args = self._bug_torn_args(q, args)
                if clk is not None:
                    clk.merge(vt)         # Lamport receive of announce
                if batch is not None:
                    # VectorApply: adopt now, apply the whole pass below
                    # (merging first is clock-identical — merge is a max)
                    batch.append((q, func, args, act))
                    continue
                ret = self._apply(q, func, args, ind, p)       # lines 18-19
                wr(retval_base + q, ret)                           # line 20
                wr(deact_base + q, act)                            # line 21
                pass_served += 1
            if batch:
                rets = self._apply_batch(batch, ind, p)
                for (q, _f, _a, act), ret in zip(batch, rets):
                    wr(retval_base + q, ret)                       # line 20
                    wr(deact_base + q, act)                        # line 21
                pass_served = len(batch)
            served += pass_served
            if pass_served == 0:
                break
        pending = self._post_simulation(ind, p)
        self.lockval = lock_val                              # line 24
        # lines 22-23 + 25-27 as one fused commit (identical counters,
        # durable effect, and crash-tick behavior — see NVM.commit_round)
        nvm.commit_round(base, self.rec_words, self.mindex_addr, ind,
                         pending=pending)
        # Measured degree: requests this committed round served (the
        # loop above plus any eliminated pairs _begin_round recorded).
        self.stats.record(served + self._round_served)
        if clk is not None:
            self._round_end_vt = clk.now()   # published before the unlock
        self._pre_unlock(ind, p)
        self.lock.store(lock_val + 1)                        # line 28
        self._elect.release()
        # line 29 reads ReturnVal[MIndex][p]; MIndex == ind until the
        # next combiner (which needs the lock we just released) flips it
        return nvm.read(retval_base + p)

    def _bug_torn_args(self, q: int, args: Any) -> Any:
        """Seeded-bug fixture body (``torn_announce_bug``): every third
        adoption of a thread whose PREVIOUS announce carried different
        args gets the stale args — the mixed-generation record a torn
        seqlock read would produce.  The combiner then applies (and
        acks) an op the announcer never asked for, which the history
        checker reports as a conjured/lost value pair."""
        prev = getattr(self, "_bug_prev", None)
        if prev is None:
            prev = self._bug_prev = {}
            self._bug_ctr = 0
        stale = prev.get(q)
        prev[q] = args
        if stale is not None and stale != args and args is not None:
            self._bug_ctr += 1
            if self._bug_ctr % 3 == 0:
                return stale
        return args

    # ---------------- structure hooks --------------------------------- #
    def _apply(self, q: int, func: str, args: Any, ind: int,
               combiner: int) -> Any:
        return self.obj.apply(self.nvm, self.mem_base[ind], func, args,
                              ctx=self)

    def _apply_batch(self, batch, ind: int, combiner: int) -> list:
        """One collected combining pass: ``batch`` is the adoptable
        announcements ``[(q, func, args, act), ...]`` in scan order.  A
        homogeneous batch goes through the object's VectorApply seam
        (one jitted kernel — DESIGN.md §11); a heterogeneous batch or a
        seam decline runs the identical per-op loop."""
        func = batch[0][1]
        if all(b[1] == func for b in batch):
            rets = self.obj.vector_apply(
                self.nvm, self.mem_base[ind], func,
                [b[2] for b in batch], ctx=self)
            if rets is not None:
                return rets
        return [self._apply(q, f, a, ind, combiner)
                for q, f, a, _act in batch]

    def _begin_round(self, ind: int, combiner: int) -> None:
        """Called after the state copy, before the simulation loop.
        PBStack's elimination pass lives here."""

    def _post_simulation(self, ind: int, combiner: int):
        """Called after the simulation loop, before pwb(StateRec).
        Returns the round's extra NVM ranges to persist ahead of the
        StateRec — PBQueue's enqueue instance reports its ``toPersist``
        node set here (Algorithm 5 line 24) — or None."""
        return None

    def _pre_unlock(self, ind: int, combiner: int) -> None:
        """Called after psync, before the lock release.  PBQueue's enqueue
        instance publishes ``oldTail`` here (Algorithm 5 line 31)."""
