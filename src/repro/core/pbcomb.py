"""PBComb — the paper's blocking recoverable combining protocol.

Faithful implementation of Algorithms 1 and 2.  Design decisions
(paper Definition 1) and how each respects the persistence principles
(Definition 2):

  1. combiner election: CAS on a *volatile* integer ``Lock`` whose parity
     encodes taken/free; a thread may leave the entry section without ever
     CAS-ing if its request was served (P1: the lock is never persisted).
  2. requests: flat volatile ``Request[0..n-1]`` array (P1 — never
     persisted; ``valid`` bits are reset by a crash, which is exactly what
     recovery needs).
  3. updates applied to a *copy* of the state: 2-slot non-volatile
     ``MemState[0..1]``; the combiner works on slot ``1 - MIndex`` (P3 —
     one contiguous pwb covers state + responses + deactivate bits).
  4. responses: ``ReturnVal[0..n-1]`` inside the StateRec (P3).
  5. served-detection: per-thread ``activate`` (volatile, in Request) vs
     ``Deactivate`` (inside the persisted StateRec).  Only deactivate is
     persisted; the system-provided ``seq`` parity replaces activate at
     recovery (P1).

Per combining round of degree d: pwb(StateRec) + pfence + pwb(MIndex) +
psync — i.e. O(1) persistence instructions for d requests.

StateRec NVM layout (contiguous, line-aligned):
    [ st : state_words | ReturnVal[0..n-1] | Deactivate[0..n-1] ]
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, List, Optional

from .atomics import AtomicInt, Counters
from .nvm import NVM
from .objects import SeqObject


@dataclass
class RequestRec:
    func: Optional[str] = None
    args: Any = None
    activate: int = 0
    valid: int = 0


class PBComb:
    def __init__(self, nvm: NVM, n_threads: int, obj: SeqObject,
                 counters: Optional[Counters] = None) -> None:
        self.nvm = nvm
        self.n = n_threads
        self.obj = obj
        self._counters = counters
        sw = obj.state_words
        self.state_words = sw
        self.rec_words = sw + 2 * n_threads
        # --- shared non-volatile variables --------------------------- #
        self.mem_base = [nvm.alloc(self.rec_words) for _ in range(2)]
        self.mindex_addr = nvm.alloc(1)
        nvm.write(self.mindex_addr, 0)
        for ind in range(2):
            obj.init_state(nvm, self.mem_base[ind])
            for q in range(n_threads):
                nvm.write(self._retval_addr(ind, q), None)
                nvm.write(self._deact_addr(ind, q), 0)
        # Initial image must be durable (the paper assumes initialized NVMM).
        nvm.pwb(self.mem_base[0], self.rec_words)
        nvm.pwb(self.mem_base[1], self.rec_words)
        nvm.pwb(self.mindex_addr, 1)
        nvm.psync()
        nvm.reset_counters()
        # --- shared volatile variables -------------------------------- #
        self.request: List[RequestRec] = [RequestRec() for _ in range(n_threads)]
        self.lock = AtomicInt(0, shared=True, counters=counters)
        self.lockval = 0  # written only by the combiner, read by waiters

    # ---------------- field address helpers --------------------------- #
    def _st_base(self, ind: int) -> int:
        return self.mem_base[ind]

    def _retval_addr(self, ind: int, q: int) -> int:
        return self.mem_base[ind] + self.state_words + q

    def _deact_addr(self, ind: int, q: int) -> int:
        return self.mem_base[ind] + self.state_words + self.n + q

    def _mindex(self) -> int:
        return self.nvm.read(self.mindex_addr)

    # ---------------- public API (Algorithm 1) ------------------------ #
    def op(self, p: int, func: str, args: Any, seq: int) -> Any:
        """PBCOMB(func, args, seq) executed by thread p."""
        req = self.request[p]
        self.request[p] = RequestRec(func, args, 1 - req.activate, 1)
        return self._perform_request(p)

    def recover(self, p: int, func: str, args: Any, seq: int) -> Any:
        """Recovery function (Algorithm 1, lines 3-6).  Called by the
        "system" for every thread that had an operation in flight at crash
        time, with the same arguments (Section 2's system-support
        assumption)."""
        self.request[p] = RequestRec(func, args, seq % 2, 1)
        if self.nvm.read(self._deact_addr(self._mindex(), p)) != seq % 2:
            return self._perform_request(p)
        return self.nvm.read(self._retval_addr(self._mindex(), p))

    def reset_volatile(self) -> None:
        """Re-initialize volatile protocol state after a crash (the crash
        wiped registers/caches/DRAM — Request, Lock, LockVal are volatile).

        The recreated lock keeps the original ``Counters`` reference so
        synchronization-cost measurements keep accumulating in post-crash
        benchmark phases.  Request activate bits are re-seeded from the
        durable deactivate bits (``resync_request``) so a thread whose
        next operation arrives through the normal ``op`` path — not
        ``recover`` — still flips to a fresh parity."""
        self.request = [RequestRec() for _ in range(self.n)]
        self.lock = AtomicInt(0, shared=True, counters=self._counters)
        self.lockval = 0
        for p in range(self.n):
            self.resync_request(p)

    def resync_request(self, p: int) -> None:
        """Re-seed thread p's volatile activate parity from the durable
        deactivate bit (the paper's system hands recovery the in-flight
        seq; for threads with no in-flight op the persisted parity is the
        only survivor of the crash)."""
        deact = self.nvm.read(self._deact_addr(self._mindex(), p))
        self.request[p] = RequestRec(None, None, deact, 0)

    # ---------------- Algorithm 2 ------------------------------------- #
    def _perform_request(self, p: int) -> Any:
        nvm = self.nvm
        while True:
            lval = self.lock.load()                          # line 6
            if lval % 2 == 0:                                # line 7
                if self.lock.cas(lval, lval + 1):            # line 8
                    break                                    # p is combiner
                lval += 1                                    # line 9
            while self.lock.load() == lval:                  # line 10
                time.sleep(0)
            mindex = self._mindex()
            if self.request[p].activate == nvm.read(self._deact_addr(mindex, p)):  # line 11
                if self.lockval != lval:                     # line 12
                    # Served by an in-flight round: wait for its psync.
                    while self.lock.load() == lval + 2:
                        time.sleep(0)
                return nvm.read(self._retval_addr(self._mindex(), p))  # line 13
        return self._combine(p)

    def _combine(self, p: int) -> Any:
        """Combiner code, Algorithm 2 lines 14-29."""
        nvm = self.nvm
        mindex = self._mindex()
        ind = 1 - mindex                                     # line 14
        nvm.write_range(self.mem_base[ind],
                        nvm.read_range(self.mem_base[mindex], self.rec_words))  # line 15
        self._begin_round(ind, p)
        for q in range(self.n):                              # line 16
            req = self.request[q]
            if req.valid == 1 and req.activate != nvm.read(self._deact_addr(ind, q)):  # line 17
                ret = self._apply(q, req.func, req.args, ind, p)       # lines 18-19
                nvm.write(self._retval_addr(ind, q), ret)              # line 20
                nvm.write(self._deact_addr(ind, q), req.activate)      # line 21
        self._post_simulation(ind, p)
        nvm.pwb(self.mem_base[ind], self.rec_words)          # line 22
        nvm.pfence()                                         # line 23
        self.lockval = self.lock.load()                      # line 24
        nvm.write(self.mindex_addr, ind)                     # line 25
        nvm.pwb(self.mindex_addr, 1)                         # line 26
        nvm.psync()                                          # line 27
        self._pre_unlock(ind, p)
        self.lock.store(self.lock.load() + 1)               # line 28
        return nvm.read(self._retval_addr(self._mindex(), p))  # line 29

    # ---------------- structure hooks --------------------------------- #
    def _apply(self, q: int, func: str, args: Any, ind: int,
               combiner: int) -> Any:
        return self.obj.apply(self.nvm, self._st_base(ind), func, args, ctx=self)

    def _begin_round(self, ind: int, combiner: int) -> None:
        """Called after the state copy, before the simulation loop.
        PBStack's elimination pass lives here."""

    def _post_simulation(self, ind: int, combiner: int) -> None:
        """Called after the simulation loop, before pwb(StateRec).
        PBQueue's enqueue instance persists its ``toPersist`` node set here
        (Algorithm 5 line 24)."""

    def _pre_unlock(self, ind: int, combiner: int) -> None:
        """Called after psync, before the lock release.  PBQueue's enqueue
        instance publishes ``oldTail`` here (Algorithm 5 line 31)."""
