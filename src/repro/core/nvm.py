"""Simulated Non-Volatile Main Memory with explicit epoch persistency.

Faithful model of the paper's memory assumptions (Section 2):

  * Memory is word-addressable; words are grouped into cache lines of
    ``LINE`` words.  Writes go to the *volatile* image (cache).
  * ``pwb(addr)`` queues a write-back of the cache line(s) covering
    ``addr`` — it does NOT wait.  The written-back value is the line's
    content at pwb-issue time (TSO: per-line program order preserved).
  * ``pfence()`` orders: every pwb issued before the fence completes
    before any pwb issued after it ("epochs").
  * ``psync()`` blocks until all previously issued pwbs are durable.
  * *Explicit* epoch persistency (Izraelevitz et al. [35], adopted by the
    paper): a line reaches NVMM **only** via pwb — no spontaneous
    evictions.

Crash semantics (``crash()``): the adversary picks how far the write-back
queue drained — all epochs before some cut are durable, plus an arbitrary
per-line-prefix-respecting subset of the cut epoch.  Everything volatile
is lost (reset to the persisted image).  Tests sweep/randomize the cut to
exercise every reachable post-crash state.

``crash_after_persist_ops`` arms a countdown so a ``SimulatedCrash`` is
raised in the middle of protocol code — this is how the crash-recovery
tests enumerate crash points *inside* the combiner.

Counters expose the paper's performance metrics: pwbs (counted per cache
line, so persistence principle P3 — contiguity — is visible in the
numbers), pfences, psyncs.  ``pwb_nop``/``psync_nop`` reproduce the
ablations of paper Figures 3 and 6.

Batching (DESIGN.md §5): the write-back queue stores line *runs* —
``(first_line, n_lines, snapshot)`` — not individual lines, so a
combining round's one contiguous StateRec pwb is one queue entry, one
slice copy, and one slice drain at psync, however many lines it covers.
``persist_lines`` coalesces several (addr, n_words) ranges into the
union of their cache lines in a single lock acquisition (duplicate lines
within one call count once — the coalescing the flat-combining and MOD
lines of work show is where persistence wins live), and ``copy_range``
gives the combiner's state copy a single slice-assign path.  Snapshots
are Python list slices (C-level pointer memcpy), not numpy arrays: NVM
words hold arbitrary Python payloads (tuples, strings), which object
ndarrays reject in range stores.
"""

from __future__ import annotations

import random
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple, Union

LINE = 8  # words per simulated cache line


class SimulatedCrash(Exception):
    """Raised when an armed crash countdown fires inside protocol code."""


# --------------------------------------------------------------------- #
# Virtual-clock timing engine (DESIGN.md §6)                            #
# --------------------------------------------------------------------- #
# Host sleep granularity (~250us here) cannot express Optane-scale
# (1-3us) psync latencies, so the wall-clock ``persist_latency`` knob
# distorts rather than models.  The virtual clock instead *counts* time:
# every persistence instruction advances the calling thread's logical
# clock by a profile-defined cost, combining hand-offs merge clocks
# Lamport-style (a round's latency is the max over its participants, not
# the sum), and the makespan max(thread clocks) / ops is a deterministic
# ``modeled_us_per_op`` that survives host drift — the MOD / DFC
# evaluation methodology, machine-checkable in CI.

@dataclass(frozen=True)
class CostProfile:
    """Per-instruction modeled costs, all in nanoseconds."""

    name: str
    pwb_ns: float     # per cache line queued by a pwb (CLWB issue)
    pfence_ns: float  # per pfence (store-fence retire)
    psync_ns: float   # fixed device round trip per psync drain
    seek_ns: float    # per discontiguous run of lines drained (P3 visible)
    line_ns: float    # per line streamed within a contiguous run
    cas_ns: float     # per CAS / LL-SC on a shared word
    round_ns: float   # combiner round fusion/hand-off bookkeeping


#: Built-in profiles.  "wall-clock mode" is not a profile: it is
#: ``profile=None`` plus the pre-existing ``persist_latency`` sleep knob.
PROFILES: Dict[str, CostProfile] = {
    # Optane DCPMM shape: psync in the 1-3us band the ROADMAP names,
    # expensive seeks for scattered lines (XPLine write amplification).
    "optane": CostProfile("optane", pwb_ns=30.0, pfence_ns=30.0,
                          psync_ns=1500.0, seek_ns=300.0, line_ns=60.0,
                          cas_ns=25.0, round_ns=50.0),
    # NVDIMM-N / emulated-DRAM shape: flushes cheap, drains fast.
    "dram": CostProfile("dram", pwb_ns=15.0, pfence_ns=20.0,
                        psync_ns=120.0, seek_ns=30.0, line_ns=8.0,
                        cas_ns=25.0, round_ns=50.0),
    # Battery-backed / eADR shape: the persistence domain covers the
    # caches, so write-backs are ordering tokens, draining is ~free.
    "battery-backed": CostProfile("battery-backed", pwb_ns=5.0,
                                  pfence_ns=10.0, psync_ns=30.0,
                                  seek_ns=0.0, line_ns=0.0,
                                  cas_ns=25.0, round_ns=50.0),
}


def resolve_profile(profile: Union[str, CostProfile, None]
                    ) -> Optional[CostProfile]:
    if profile is None:
        return None
    if isinstance(profile, CostProfile):
        return profile
    try:
        return PROFILES[profile]
    except KeyError:
        raise ValueError(f"unknown cost profile {profile!r}; "
                         f"available: {sorted(PROFILES)}") from None


class VClock:
    """Per-thread logical clocks (ns) advanced by a ``CostProfile``.

    A "thread" is normally the OS thread, but a driver multiplexing many
    logical threads onto one OS thread (the deterministic modeled bench
    pass) rebinds the key with ``bind(logical_id)`` — this is how the
    handle layer charges costs to the handle's tid regardless of which
    OS thread runs the call.

    Merge rule (Lamport): an event that *receives* from another thread
    (a combiner adopting an announced request, a waiter picking up its
    response) merges the sender's timestamp with ``merge`` — so a
    combining round's modeled latency is the max over its participants,
    never the sum.  ``sync_device`` models the single per-DIMM
    write-back engine: concurrent psyncs serialize on device time.
    """

    __slots__ = ("profile", "_times", "_tls", "_device_free",
                 "_device_lock", "_tape")

    def __init__(self, profile: CostProfile) -> None:
        self.profile = profile
        self._times: Dict[Any, float] = {}
        self._tls = threading.local()
        self._device_free = 0.0
        self._device_lock = threading.Lock()
        # Optional event recorder (kernels.scan_replay.ClockTape) for the
        # periodic modeled-replay engine.  Single-threaded drivers only —
        # attach/detach via that module, never while workers run.
        self._tape = None

    def _key(self) -> Any:
        lid = getattr(self._tls, "lid", None)
        return threading.get_ident() if lid is None else lid

    @contextmanager
    def bind(self, logical_id: Any):
        """Charge this OS thread's costs to ``logical_id`` while bound."""
        tls = self._tls
        prev = getattr(tls, "lid", None)
        tls.lid = logical_id
        try:
            yield self
        finally:
            tls.lid = prev

    def now(self) -> float:
        t = self._times.get(self._key(), 0.0)
        if self._tape is not None:
            return self._tape.record_now(self._key(), t)
        return t

    def advance(self, ns: float) -> None:
        key = self._key()
        self._times[key] = self._times.get(key, 0.0) + ns
        if self._tape is not None:
            self._tape.record_adv(key, ns)

    def merge(self, t_ns: float) -> None:
        key = self._key()
        cur = self._times.get(key, 0.0)
        if t_ns > cur:
            self._times[key] = t_ns
        if self._tape is not None:
            self._tape.record_mrg(key, t_ns, cur)

    def sync_device(self, cost_ns: float) -> float:
        """Advance through the (serialized) write-back device: the drain
        starts when both this thread and the device are free."""
        key = self._key()
        with self._device_lock:
            t = self._times.get(key, 0.0)
            if self._device_free > t:
                t = self._device_free
            t += cost_ns
            self._device_free = t
        self._times[key] = t
        if self._tape is not None:
            self._tape.record_dev(key, cost_ns)
        return t

    def max_time_ns(self) -> float:
        """Makespan: the latest clock (modeled elapsed time so far).
        tuple() snapshots the dict atomically under the GIL — concurrent
        threads insert their key on their first clocked instruction."""
        return max(tuple(self._times.values()), default=0.0)

    def reset(self) -> None:
        self._times.clear()
        self._device_free = 0.0


class NVM:
    #: Machine-off flag: False for the in-process NVM (a SimulatedCrash
    #: unwinds every thread synchronously, so no one keeps running).
    #: The multiprocess ShmNVM overrides this with a shared-memory flag
    #: that surviving worker processes poll in their wait loops.
    halted = False

    #: Device topology: the in-thread NVM models ONE DIMM (a single
    #: write-back device).  The multi-segment ShmNVM overrides this and
    #: the segment accessors below (DESIGN.md §8); they exist here so
    #: benches and the runtime's placement policy run backend-agnostic.
    segments = 1

    def __init__(self, n_words: int = 1 << 20, *, pwb_nop: bool = False,
                 psync_nop: bool = False,
                 persist_latency: float = 0.0,
                 profile: Union[str, CostProfile, None] = None,
                 backend: Optional[Any] = None,
                 audit: bool = False) -> None:
        """``persist_latency``: seconds a psync blocks the calling thread
        (models NVMM write-back latency, ~1-3us on Optane DCPMM; the
        benchmark harness sets it so the paper's cost trends — one psync
        per combining ROUND vs one per op — are visible on a host where
        memory writes are otherwise free).  The sleep happens OUTSIDE the
        queue lock: other threads keep announcing while the combiner
        waits, which is exactly the contention window combining exploits.

        ``profile``: a ``CostProfile`` (or its name) engaging the virtual
        clock — every persistence instruction then advances the calling
        thread's logical clock by the modeled cost instead of sleeping
        (``self.clock``; see module docs / DESIGN.md §6).  The NOP
        ablations compose: a nop'd instruction charges nothing.

        ``backend``: the execution backend the protocols draw their
        volatile shared primitives from (DESIGN.md §7); defaults to the
        thread backend.  The multiprocess path constructs ``ShmNVM``
        with a ``ShmBackend`` instead.

        ``audit``: opt-in persist-ordering detector (DESIGN.md §10) —
        attaches a ``repro.analysis.audit.PersistAudit`` tracking
        per-line flush state and happens-before, exposed as
        ``self.audit``.  Pins ``force_discrete`` so the fused
        persistence sentences take their counter-identical discrete
        fallbacks: counters and modeled costs stay byte-identical to a
        non-audited run.  Silently disabled under the pwb/psync NOP
        ablations (there is no real persistence to audit there).
        """
        if backend is None:
            from .backend import ThreadBackend
            backend = ThreadBackend()
        self.backend = backend
        self.n_words = n_words
        self._vol: List[Any] = [0] * n_words        # volatile (cache) image
        self._dur: List[Any] = [0] * n_words        # durable (NVMM) image
        # Write-back queue: list of epochs; each epoch is an ordered list
        # of line runs (first_line, n_lines, snapshot_of_run_words) taken
        # at pwb-issue time.
        self._epochs: List[List[Tuple[int, int, List[Any]]]] = [[]]
        # Line 0 is reserved: address 0 doubles as the NULL pointer for the
        # linked structures, so no allocation may ever receive it.
        self._alloc_ptr = LINE
        self._lock = threading.Lock()
        self.pwb_nop = pwb_nop
        self.psync_nop = psync_nop
        self.persist_latency = persist_latency
        prof = resolve_profile(profile)
        self.clock: Optional[VClock] = VClock(prof) if prof else None
        # Test knob: force every fused persistence sentence onto its
        # discrete-instruction fallback (the fused-vs-discrete
        # equivalence property tests pin cost and counter equality).
        self.force_discrete = False
        self.counters: Dict[str, int] = {
            "pwb": 0, "pfence": 0, "psync": 0, "crashes": 0}
        # Crash-point injection: countdown on persistence "events".
        self._crash_countdown: Optional[int] = None
        self._crash_rng: Optional[random.Random] = None
        # Instruction-kind crash-point injector (repro.fuzz): consulted
        # at every pwb/pfence/psync tick with the instruction kind, so a
        # fuzzer can land a crash at "the 3rd psync" rather than the
        # aggregate countdown's "the Nth persistence event".  None when
        # disarmed — zero cost on the default path, same contract as the
        # audit seam.
        self._injector: Optional[Any] = None
        self._audit = None
        if audit and not (pwb_nop or psync_nop):
            from ..analysis.audit import PersistAudit   # lazy: no cycle
            self._audit = PersistAudit(self)
            self.force_discrete = True
            self._install_audit_hooks()

    @property
    def audit(self):
        """The attached ``PersistAudit`` (None when auditing is off)."""
        return self._audit

    def _install_audit_hooks(self) -> None:
        """Shadow the hot volatile accessors with auditing wrappers as
        INSTANCE attributes: the default path (audit off) keeps the bare
        class methods, so auditing costs nothing when not engaged.
        Wrapping the *bound* methods resolves subclass overrides
        (ShmNVM) for free."""
        aud = self._audit
        read, write = self.read, self.write
        read_range, write_range = self.read_range, self.write_range
        copy_range = self.copy_range

        def read_a(addr):
            aud.on_read(addr)
            return read(addr)

        def read_range_a(addr, n):
            aud.on_read(addr, n)
            return read_range(addr, n)

        def write_a(addr, value):
            write(addr, value)
            aud.on_write(addr, 1)

        def write_range_a(addr, values):
            write_range(addr, values)
            aud.on_write(addr, len(values))

        def copy_range_a(dst, src, n):
            copy_range(dst, src, n)
            aud.on_write(dst, n)

        self.read = read_a
        self.read_range = read_range_a
        self.write = write_a
        self.write_range = write_range_a
        self.copy_range = copy_range_a

    # ------------------------------------------------------------------ #
    # Allocation                                                         #
    # ------------------------------------------------------------------ #
    def alloc(self, n_words: int, align_line: bool = True,
              segment: Optional[int] = None) -> int:
        """Bump-allocate ``n_words``; line-aligned so P3 layouts are real.
        ``segment`` is accepted for interface parity with the
        multi-segment ShmNVM (this NVM models one DIMM; only 0/None)."""
        if segment not in (None, 0):
            raise ValueError("the in-thread NVM models a single DIMM "
                             f"(segment {segment} does not exist)")
        with self._lock:
            if align_line and self._alloc_ptr % LINE:
                self._alloc_ptr += LINE - self._alloc_ptr % LINE
            base = self._alloc_ptr
            self._alloc_ptr += n_words
            if self._alloc_ptr > self.n_words:
                raise MemoryError("simulated NVMM exhausted")
            return base

    # ------------------------------------------------------------------ #
    # Volatile-image access (normal loads/stores)                        #
    # ------------------------------------------------------------------ #
    def read(self, addr: int) -> Any:
        return self._vol[addr]

    def write(self, addr: int, value: Any) -> None:
        self._vol[addr] = value

    def read_range(self, addr: int, n: int) -> List[Any]:
        return self._vol[addr:addr + n]

    def write_range(self, addr: int, values: List[Any]) -> None:
        self._vol[addr:addr + len(values)] = values

    def copy_range(self, dst: int, src: int, n: int) -> None:
        """Volatile memcpy — the combiner's state copy as one slice
        assignment instead of a read_range/write_range round trip."""
        vol = self._vol
        vol[dst:dst + n] = vol[src:src + n]

    # ------------------------------------------------------------------ #
    # Persistence instructions                                           #
    # ------------------------------------------------------------------ #
    def _tick_crash_point(self, kind: str = "") -> None:
        inj = self._injector
        if inj is not None and inj.tick(kind):
            self._injector = None     # one shot: fire, then disarm
            self.crash(inj.rng)
            raise SimulatedCrash()
        if self._crash_countdown is not None:
            self._crash_countdown -= 1
            if self._crash_countdown < 0:
                self._crash_countdown = None
                self.crash(self._crash_rng)
                raise SimulatedCrash()

    def arm_injector(self, injector: Any) -> None:
        """Attach an instruction-kind crash-point injector: an object
        whose ``tick(kind) -> bool`` is called at every pwb/pfence/psync
        (True = crash NOW, adversarial drain by ``injector.rng``).
        Unlike the ``arm_crash`` countdown, the injector survives
        ``disarm_crash`` — which is what lets a fuzzer crash INSIDE
        ``recover`` (recover's first act is ``disarm_crash``).  Arming
        pins the fused persistence sentences onto their discrete
        fallbacks so ticks land between individual instructions."""
        self._injector = injector

    def disarm_injector(self) -> None:
        self._injector = None

    def pwb(self, addr: int, n_words: int = 1) -> None:
        """Queue write-back of every line covering [addr, addr+n_words).

        One contiguous run is one queue entry and one slice snapshot,
        however many lines it covers; the counter still counts lines.
        """
        first = addr // LINE
        n_lines = (addr + n_words - 1) // LINE - first + 1
        with self._lock:
            if not self.pwb_nop:
                self._epochs[-1].append(
                    (first, n_lines,
                     self._vol[first * LINE:(first + n_lines) * LINE]))
            self.counters["pwb"] += n_lines
        if self.clock is not None and not self.pwb_nop:
            self.clock.advance(n_lines * self.clock.profile.pwb_ns)
        if self._audit is not None:
            self._audit.on_pwb(((first, n_lines),))
        self._tick_crash_point("pwb")

    # Explicit alias: round persistence paths call this so the intent —
    # one coalesced range, not a per-word loop — reads at the call site.
    pwb_range = pwb

    def persist_lines(self, ranges) -> None:
        """Queue write-back of the UNION of cache lines covering several
        ``(addr, n_words)`` ranges — one persistence event, one lock
        acquisition.  Lines named by more than one range are snapshotted
        (and counted) once: this is the cache-line coalescing a combining
        round gets for free by persisting all its node/state touches
        together (P3)."""
        if isinstance(ranges, list) and len(ranges) == 1:
            # single range: plain pwb (same event count, no set/merge)
            addr, n_words = ranges[0]
            self.pwb(addr, n_words)
            return
        runs = self._pending_lines(ranges)
        if not runs:
            return
        n_total = sum(n for _first, n in runs)
        vol = self._vol
        with self._lock:
            if not self.pwb_nop:
                epoch = self._epochs[-1]
                for first, n_lines in runs:
                    epoch.append(
                        (first, n_lines,
                         vol[first * LINE:(first + n_lines) * LINE]))
            self.counters["pwb"] += n_total
        if self.clock is not None and not self.pwb_nop:
            self.clock.advance(n_total * self.clock.profile.pwb_ns)
        if self._audit is not None:
            self._audit.on_pwb(runs)
        self._tick_crash_point("pwb")

    def pfence(self) -> None:
        had_pending = False
        with self._lock:
            self.counters["pfence"] += 1
            if self._epochs[-1]:
                had_pending = True
                self._epochs.append([])
        if self.clock is not None:
            self.clock.advance(self.clock.profile.pfence_ns)
        if self._audit is not None:
            self._audit.on_pfence(had_pending)
        self._tick_crash_point("pfence")

    # ---------------- fused round-commit paths ------------------------ #
    # A combining round ends with a fixed persistence sentence — e.g.
    # PBComb: pwb(StateRec); pfence; MIndex := ind; pwb(&MIndex); psync.
    # Issuing it as four locked calls costs more simulator overhead than
    # the protocol work it models.  The fused paths below execute the
    # SAME sentence under one lock acquisition with identical counter
    # arithmetic and durable effect; whenever an observer could tell the
    # difference — an armed crash countdown (ticks must land *between*
    # instructions), pwb/psync NOP ablations, or a psync cost model —
    # they fall back to the separate instructions.

    def _fast_ok(self) -> bool:
        return (self._crash_countdown is None and self._injector is None
                and not self.pwb_nop
                and not self.psync_nop and not self.persist_latency
                and not self.force_discrete and self._audit is None)

    def _pending_lines(self, pending) -> List[Tuple[int, int]]:
        """Dedupe/merge (addr, n_words) ranges to [first, n_lines] runs
        (same coalescing as persist_lines, for the fused paths)."""
        lines = set()
        add = lines.add
        for addr, n_words in pending:
            first = addr // LINE
            last = (addr + n_words - 1) // LINE
            add(first)
            while first < last:
                first += 1
                add(first)
        runs: List[List[int]] = []
        for line in sorted(lines):
            if runs and line == runs[-1][0] + runs[-1][1]:
                runs[-1][1] += 1
            else:
                runs.append([line, 1])
        return runs

    def pwb_fence(self, addr: int, n_words: int, pending=None) -> None:
        """``[persist_lines(pending);] pwb_range(addr, n_words); pfence()``
        fused.  ``pending`` carries a round's node touches so the whole
        pre-publish persistence sentence is one lock acquisition."""
        if not self._fast_ok():
            if pending:
                self.persist_lines(pending)
            self.pwb_range(addr, n_words)
            self.pfence()
            return
        runs = self._pending_lines(pending) if pending else ()
        first = addr // LINE
        n_lines = (addr + n_words - 1) // LINE - first + 1
        vol = self._vol
        with self._lock:
            epoch = self._epochs[-1]
            n_pending = 0
            for pfirst, pn in runs:
                epoch.append(
                    (pfirst, pn, vol[pfirst * LINE:(pfirst + pn) * LINE]))
                n_pending += pn
            epoch.append(
                (first, n_lines, vol[first * LINE:(first + n_lines) * LINE]))
            self._epochs.append([])
            c = self.counters
            c["pwb"] += n_lines + n_pending
            c["pfence"] += 1
        clock = self.clock
        if clock is not None:
            # Charge the exact advance sequence of the discrete fallback:
            # persist_lines(pending); pwb_range(addr); pfence.
            prof = clock.profile
            if n_pending:
                clock.advance(n_pending * prof.pwb_ns)
            clock.advance(n_lines * prof.pwb_ns)
            clock.advance(prof.pfence_ns)

    def pwb_sync(self, addr: int, n_words: int = 1) -> None:
        """``pwb(addr); psync()`` fused: queue the line(s), then drain
        the whole write-back queue straight to the durable image."""
        if not self._fast_ok():
            self.pwb(addr, n_words)
            self.psync()
            return
        first = addr // LINE
        n_lines = (addr + n_words - 1) // LINE - first + 1
        clock = self.clock
        drained: Optional[List[Tuple[int, int]]] = \
            [] if clock is not None else None
        with self._lock:
            dur, vol = self._dur, self._vol
            for epoch in self._epochs:
                for efirst, en, snap in epoch:
                    dur[efirst * LINE:efirst * LINE + len(snap)] = snap
                    if drained is not None:
                        drained.append((efirst, en))
            a, b = first * LINE, (first + n_lines) * LINE
            dur[a:b] = vol[a:b]
            if drained is not None:
                drained.append((first, n_lines))
            self._epochs = [[]]
            c = self.counters
            c["pwb"] += n_lines
            c["psync"] += 1
        if clock is not None:
            # Exact discrete sequence: pwb(addr); psync().
            clock.advance(n_lines * clock.profile.pwb_ns)
            clock.sync_device(self._drain_cost_ns(drained))

    def commit_round(self, state_addr: int, n_words: int,
                     index_addr: int, index_value: Any,
                     pending=None) -> None:
        """PBComb's full round commit (Algorithm 2 lines 22-27):
        ``[persist_lines(pending);] pwb(StateRec); pfence;
        MIndex := v; pwb(&MIndex); psync`` — ``pending`` carries the
        round's node touches (Algorithm 5 line 24)."""
        if not self._fast_ok():
            if pending:
                self.persist_lines(pending)
            self.pwb_range(state_addr, n_words)
            self.pfence()
            self.write(index_addr, index_value)
            self.pwb(index_addr, 1)
            self.psync()
            return
        runs = self._pending_lines(pending) if pending else ()
        first = state_addr // LINE
        n_lines = (state_addr + n_words - 1) // LINE - first + 1
        clock = self.clock
        drained: Optional[List[Tuple[int, int]]] = \
            [] if clock is not None else None
        with self._lock:
            dur, vol = self._dur, self._vol
            # drain epochs queued before this commit, the round's node
            # lines, the StateRec, then MIndex — everything the round's
            # psync would have drained
            for epoch in self._epochs:
                for efirst, en, snap in epoch:
                    dur[efirst * LINE:efirst * LINE + len(snap)] = snap
                    if drained is not None:
                        drained.append((efirst, en))
            n_pending = 0
            for pfirst, pn in runs:
                a = pfirst * LINE
                b = a + pn * LINE
                dur[a:b] = vol[a:b]
                n_pending += pn
                if drained is not None:
                    drained.append((pfirst, pn))
            a, b = first * LINE, (first + n_lines) * LINE
            dur[a:b] = vol[a:b]
            if drained is not None:
                drained.append((first, n_lines))
            vol[index_addr] = index_value
            iline = index_addr // LINE
            a = iline * LINE
            dur[a:a + LINE] = vol[a:a + LINE]
            if drained is not None:
                drained.append((iline, 1))
            self._epochs = [[]]
            c = self.counters
            c["pwb"] += n_lines + n_pending + 1
            c["pfence"] += 1
            c["psync"] += 1
        if clock is not None:
            # Exact discrete sequence: persist_lines(pending);
            # pwb(StateRec); pfence; pwb(&MIndex); psync — same advance
            # granularity, same drained multiset (duplicates included),
            # so the charged floats are bit-identical to the fallback's.
            prof = clock.profile
            if n_pending:
                clock.advance(n_pending * prof.pwb_ns)
            clock.advance(n_lines * prof.pwb_ns)
            clock.advance(prof.pfence_ns)
            clock.advance(1 * prof.pwb_ns)
            clock.sync_device(self._drain_cost_ns(drained))

    # One write-back engine per DIMM: concurrent psyncs serialize on the
    # device (an infinite-bandwidth model would let per-op-persist
    # baselines overlap all their syncs for free).
    _device_lock = threading.Lock()
    SEEK_COST = 4e-6     # per discontiguous run of lines (P3 visible!)
    STREAM_COST = 5e-7   # per line within a contiguous run

    @staticmethod
    def _run_stats(drained: List[Tuple[int, int]]) -> Tuple[int, int]:
        """(discontiguous runs, total lines) over drained (first, n)
        entries.  Lines drained more than once (queued in several
        epochs) count each time — they cost device writes each time.
        Contiguous layouts (persistence principle P3) drain in few runs,
        scattered ones pay a seek per run."""
        drained = sorted(drained)
        runs, prev_end, total_lines = 0, None, 0
        for first, n_lines in drained:
            if prev_end is None or first > prev_end + 1:
                runs += 1
            end = first + n_lines - 1
            prev_end = end if prev_end is None else max(prev_end, end)
            total_lines += n_lines
        return runs, total_lines

    def _drain_cost_ns(self, drained: List[Tuple[int, int]]) -> float:
        """Modeled cost of one psync draining ``drained``: fixed device
        round trip + seek per discontiguous run + stream per line."""
        prof = self.clock.profile
        if not drained:
            return prof.psync_ns
        runs, total_lines = self._run_stats(drained)
        return (prof.psync_ns + runs * prof.seek_ns
                + total_lines * prof.line_ns)

    def psync(self) -> None:
        aud = self._audit
        sync_now = (self.clock.now()
                    if aud is not None and self.clock is not None else 0.0)
        drained: List[Tuple[int, int]] = []
        with self._lock:
            self.counters["psync"] += 1
            if not self.psync_nop:
                dur = self._dur
                for epoch in self._epochs:
                    for first, n_lines, snap in epoch:
                        dur[first * LINE:first * LINE + len(snap)] = snap
                        drained.append((first, n_lines))
                self._epochs = [[]]
        if self.clock is not None and not self.psync_nop:
            self.clock.sync_device(self._drain_cost_ns(drained))
        if aud is not None:
            aud.on_psync(drained, sync_now)
        if drained and self.persist_latency:
            # wall-clock cost model (sleep): same shape as the virtual
            # one, bounded below by host sleep granularity (~250us here,
            # the distortion the virtual clock exists to remove).
            runs, total_lines = self._run_stats(drained)
            cost = (self.persist_latency + runs * self.SEEK_COST
                    + total_lines * self.STREAM_COST)
            with NVM._device_lock:
                time.sleep(cost)
        self._tick_crash_point("psync")

    # ------------------------------------------------------------------ #
    # Crash / recovery                                                   #
    # ------------------------------------------------------------------ #
    def arm_crash(self, after_persist_ops: int,
                  rng: Optional[random.Random] = None, *,
                  lose_segment: Optional[int] = None) -> None:
        """Raise SimulatedCrash after ``after_persist_ops`` more pwb/pfence/
        psync calls (the crash resolves the write-back queue adversarially
        with ``rng``, or deterministically drains nothing if rng is None).

        ``lose_segment`` is the multi-segment ShmNVM's partial-failure
        knob (one DIMM loses all pending write-backs while the others
        drain fully); the in-thread NVM models a single DIMM, so only
        None is accepted here."""
        if lose_segment is not None:
            raise ValueError("the in-thread NVM models a single DIMM "
                             "(lose_segment requires the multi-segment "
                             "ShmNVM)")
        self._crash_countdown = after_persist_ops
        self._crash_rng = rng

    def disarm_crash(self) -> None:
        self._crash_countdown = None

    def crash(self, rng: Optional[random.Random] = None) -> None:
        """System-wide crash.

        Resolves the write-back queue: with ``rng``, a random cut epoch is
        chosen; all earlier epochs drain fully, and a per-line prefix subset
        of the cut epoch drains.  Without ``rng`` nothing pending drains
        (the most adversarial *loss* outcome; note the dual adversarial
        outcome — everything drained — is exercised by rng sweeps).
        Afterwards the volatile image is reset to the durable image.
        """
        with self._lock:
            self.counters["crashes"] += 1
            epochs = self._epochs
            if rng is not None and epochs:
                cut = rng.randint(0, len(epochs) - 1)
                for epoch in epochs[:cut]:
                    for first, _n, snap in epoch:
                        self._dur[first * LINE:first * LINE + len(snap)] = snap
                # Partial drain of the cut epoch: expand its runs back to
                # per-line entries (cold path — only on crash) and keep a
                # prefix per line so same-line program order is respected.
                cut_epoch: List[Tuple[int, List[Any]]] = []
                for first, n_lines, snap in epochs[cut]:
                    for j in range(n_lines):
                        cut_epoch.append(
                            (first + j, snap[j * LINE:(j + 1) * LINE]))
                taken_upto: Dict[int, int] = {}
                for i, (line, _snap) in enumerate(cut_epoch):
                    if rng.random() < 0.5:
                        taken_upto[line] = i
                for i, (line, snap) in enumerate(cut_epoch):
                    if i <= taken_upto.get(line, -1):
                        self._dur[line * LINE:(line + 1) * LINE] = snap
            self._epochs = [[]]
            self._vol = list(self._dur)
            self._crash_countdown = None
        if self._audit is not None:
            self._audit.on_crash()

    # ------------------------------------------------------------------ #
    # Introspection                                                      #
    # ------------------------------------------------------------------ #
    def durable_read(self, addr: int) -> Any:
        return self._dur[addr]

    def pending_lines(self) -> int:
        with self._lock:
            return sum(n for e in self._epochs for _first, n, _snap in e)

    def current_segment(self) -> int:
        """The segment new allocations default to (always 0 here; the
        multi-segment ShmNVM returns its placement-context segment)."""
        return 0

    def placement(self, segment: int):
        """Segment-affinity context manager (interface parity with the
        multi-segment ShmNVM; the single-DIMM NVM only has segment 0)."""
        if segment != 0:
            raise ValueError("the in-thread NVM models a single DIMM "
                             f"(segment {segment} does not exist)")
        return contextmanager(lambda: iter([self]))()

    def segment_counters(self) -> List[Dict[str, int]]:
        """Per-segment device accounting; one entry for the single
        modeled DIMM (mirrors ``ShmNVM.segment_counters``)."""
        return [{"segment": 0, "pwb": self.counters["pwb"],
                 "psync": self.counters["psync"], "ring_spills": 0,
                 "words_used": self._alloc_ptr - LINE}]

    def occupancy(self) -> Dict[str, int]:
        """Memory gauge (mirrors ``ShmNVM.occupancy``): this backend
        has no blob heap, so the footprint is the allocated words at a
        nominal 8 bytes each."""
        words = self._alloc_ptr - LINE
        return {"backend": "threads", "words_used": words,
                "word_bytes": words * 8, "live_chunks": 0,
                "blob_live_bytes": 0, "blob_bump_bytes": 0,
                "occupancy_bytes": words * 8}

    def modeled_time_us(self) -> float:
        """Virtual-clock makespan in microseconds (0.0 when no profile
        is engaged): max over per-thread logical clocks."""
        return self.clock.max_time_ns() / 1e3 if self.clock else 0.0

    def reset_counters(self) -> None:
        for k in self.counters:
            self.counters[k] = 0
        if self._audit is not None:
            self._audit.reset_metrics()
