"""Simulated Non-Volatile Main Memory with explicit epoch persistency.

Faithful model of the paper's memory assumptions (Section 2):

  * Memory is word-addressable; words are grouped into cache lines of
    ``LINE`` words.  Writes go to the *volatile* image (cache).
  * ``pwb(addr)`` queues a write-back of the cache line(s) covering
    ``addr`` — it does NOT wait.  The written-back value is the line's
    content at pwb-issue time (TSO: per-line program order preserved).
  * ``pfence()`` orders: every pwb issued before the fence completes
    before any pwb issued after it ("epochs").
  * ``psync()`` blocks until all previously issued pwbs are durable.
  * *Explicit* epoch persistency (Izraelevitz et al. [35], adopted by the
    paper): a line reaches NVMM **only** via pwb — no spontaneous
    evictions.

Crash semantics (``crash()``): the adversary picks how far the write-back
queue drained — all epochs before some cut are durable, plus an arbitrary
per-line-prefix-respecting subset of the cut epoch.  Everything volatile
is lost (reset to the persisted image).  Tests sweep/randomize the cut to
exercise every reachable post-crash state.

``crash_after_persist_ops`` arms a countdown so a ``SimulatedCrash`` is
raised in the middle of protocol code — this is how the crash-recovery
tests enumerate crash points *inside* the combiner.

Counters expose the paper's performance metrics: pwbs (counted per cache
line, so persistence principle P3 — contiguity — is visible in the
numbers), pfences, psyncs.  ``pwb_nop``/``psync_nop`` reproduce the
ablations of paper Figures 3 and 6.
"""

from __future__ import annotations

import random
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

LINE = 8  # words per simulated cache line


class SimulatedCrash(Exception):
    """Raised when an armed crash countdown fires inside protocol code."""


class NVM:
    def __init__(self, n_words: int = 1 << 20, *, pwb_nop: bool = False,
                 psync_nop: bool = False,
                 persist_latency: float = 0.0) -> None:
        """``persist_latency``: seconds a psync blocks the calling thread
        (models NVMM write-back latency, ~1-3us on Optane DCPMM; the
        benchmark harness sets it so the paper's cost trends — one psync
        per combining ROUND vs one per op — are visible on a host where
        memory writes are otherwise free).  The sleep happens OUTSIDE the
        queue lock: other threads keep announcing while the combiner
        waits, which is exactly the contention window combining exploits.
        """
        self.n_words = n_words
        self._vol: List[Any] = [0] * n_words        # volatile (cache) image
        self._dur: List[Any] = [0] * n_words        # durable (NVMM) image
        # Write-back queue: list of epochs; each epoch is an ordered list of
        # (line_index, snapshot_of_line_words) taken at pwb-issue time.
        self._epochs: List[List[Tuple[int, List[Any]]]] = [[]]
        # Line 0 is reserved: address 0 doubles as the NULL pointer for the
        # linked structures, so no allocation may ever receive it.
        self._alloc_ptr = LINE
        self._lock = threading.Lock()
        self.pwb_nop = pwb_nop
        self.psync_nop = psync_nop
        self.persist_latency = persist_latency
        self.counters: Dict[str, int] = {
            "pwb": 0, "pfence": 0, "psync": 0, "crashes": 0}
        # Crash-point injection: countdown on persistence "events".
        self._crash_countdown: Optional[int] = None
        self._crash_rng: Optional[random.Random] = None

    # ------------------------------------------------------------------ #
    # Allocation                                                         #
    # ------------------------------------------------------------------ #
    def alloc(self, n_words: int, align_line: bool = True) -> int:
        """Bump-allocate ``n_words``; line-aligned so P3 layouts are real."""
        with self._lock:
            if align_line and self._alloc_ptr % LINE:
                self._alloc_ptr += LINE - self._alloc_ptr % LINE
            base = self._alloc_ptr
            self._alloc_ptr += n_words
            if self._alloc_ptr > self.n_words:
                raise MemoryError("simulated NVMM exhausted")
            return base

    # ------------------------------------------------------------------ #
    # Volatile-image access (normal loads/stores)                        #
    # ------------------------------------------------------------------ #
    def read(self, addr: int) -> Any:
        return self._vol[addr]

    def write(self, addr: int, value: Any) -> None:
        self._vol[addr] = value

    def read_range(self, addr: int, n: int) -> List[Any]:
        return self._vol[addr:addr + n]

    def write_range(self, addr: int, values: List[Any]) -> None:
        self._vol[addr:addr + len(values)] = values

    # ------------------------------------------------------------------ #
    # Persistence instructions                                           #
    # ------------------------------------------------------------------ #
    def _tick_crash_point(self) -> None:
        if self._crash_countdown is not None:
            self._crash_countdown -= 1
            if self._crash_countdown < 0:
                self._crash_countdown = None
                self.crash(self._crash_rng)
                raise SimulatedCrash()

    def pwb(self, addr: int, n_words: int = 1) -> None:
        """Queue write-back of every line covering [addr, addr+n_words)."""
        first = addr // LINE
        last = (addr + n_words - 1) // LINE
        with self._lock:
            for line in range(first, last + 1):
                if not self.pwb_nop:
                    snap = self._vol[line * LINE:(line + 1) * LINE]
                    self._epochs[-1].append((line, snap))
                self.counters["pwb"] += 1
        self._tick_crash_point()

    def pfence(self) -> None:
        with self._lock:
            self.counters["pfence"] += 1
            if self._epochs[-1]:
                self._epochs.append([])
        self._tick_crash_point()

    # One write-back engine per DIMM: concurrent psyncs serialize on the
    # device (an infinite-bandwidth model would let per-op-persist
    # baselines overlap all their syncs for free).
    _device_lock = threading.Lock()
    SEEK_COST = 4e-6     # per discontiguous run of lines (P3 visible!)
    STREAM_COST = 5e-7   # per line within a contiguous run

    def psync(self) -> None:
        lines: List[int] = []
        with self._lock:
            self.counters["psync"] += 1
            if not self.psync_nop:
                for epoch in self._epochs:
                    for line, snap in epoch:
                        self._dur[line * LINE:(line + 1) * LINE] = snap
                        lines.append(line)
                self._epochs = [[]]
        if lines and self.persist_latency:
            # cost model: fixed sync latency + seek per discontiguous run
            # + stream per line — contiguous layouts (persistence
            # principle P3) drain in few runs, scattered ones pay seeks.
            lines.sort()
            runs = 1 + sum(1 for a, b in zip(lines, lines[1:])
                           if b > a + 1)
            cost = (self.persist_latency + runs * self.SEEK_COST
                    + len(lines) * self.STREAM_COST)
            with NVM._device_lock:
                time.sleep(cost)
        self._tick_crash_point()

    # ------------------------------------------------------------------ #
    # Crash / recovery                                                   #
    # ------------------------------------------------------------------ #
    def arm_crash(self, after_persist_ops: int,
                  rng: Optional[random.Random] = None) -> None:
        """Raise SimulatedCrash after ``after_persist_ops`` more pwb/pfence/
        psync calls (the crash resolves the write-back queue adversarially
        with ``rng``, or deterministically drains nothing if rng is None)."""
        self._crash_countdown = after_persist_ops
        self._crash_rng = rng

    def disarm_crash(self) -> None:
        self._crash_countdown = None

    def crash(self, rng: Optional[random.Random] = None) -> None:
        """System-wide crash.

        Resolves the write-back queue: with ``rng``, a random cut epoch is
        chosen; all earlier epochs drain fully, and a per-line prefix subset
        of the cut epoch drains.  Without ``rng`` nothing pending drains
        (the most adversarial *loss* outcome; note the dual adversarial
        outcome — everything drained — is exercised by rng sweeps).
        Afterwards the volatile image is reset to the durable image.
        """
        with self._lock:
            self.counters["crashes"] += 1
            epochs = self._epochs
            if rng is not None and epochs:
                cut = rng.randint(0, len(epochs) - 1)
                for epoch in epochs[:cut]:
                    for line, snap in epoch:
                        self._dur[line * LINE:(line + 1) * LINE] = snap
                # Partial drain of the cut epoch: keep a prefix per line so
                # same-line program order is respected.
                cut_epoch = epochs[cut]
                taken_upto: Dict[int, int] = {}
                for i, (line, _snap) in enumerate(cut_epoch):
                    if rng.random() < 0.5:
                        taken_upto[line] = i
                for i, (line, snap) in enumerate(cut_epoch):
                    if i <= taken_upto.get(line, -1):
                        self._dur[line * LINE:(line + 1) * LINE] = snap
            self._epochs = [[]]
            self._vol = list(self._dur)
            self._crash_countdown = None

    # ------------------------------------------------------------------ #
    # Introspection                                                      #
    # ------------------------------------------------------------------ #
    def durable_read(self, addr: int) -> Any:
        return self._dur[addr]

    def pending_lines(self) -> int:
        with self._lock:
            return sum(len(e) for e in self._epochs)

    def reset_counters(self) -> None:
        for k in self.counters:
            self.counters[k] = 0
