"""Persistent software combining — the paper's core protocols.

Exports the simulated NVMM (epoch persistency + crash semantics), the two
recoverable combining protocols (PBComb: blocking, PWFComb: wait-free),
and the sequential-object interface they transform into recoverable
concurrent objects.
"""

from .atomics import AtomicInt, AtomicRef, Counters
from .backend import (Cell, DegreeStats, ThreadBackend, merge_degree_stats)
from .nvm import (LINE, NVM, PROFILES, CostProfile, SimulatedCrash, VClock,
                  resolve_profile)
from .objects import (AtomicFloatObject, CheckpointObject, FetchAddObject,
                      HeapObject, ResponseLogObject, SeqObject,
                      SeqQueueObject, SeqStackObject)
from .pbcomb import PBComb, RequestRec
from .pwfcomb import PWFComb

__all__ = [
    "AtomicInt", "AtomicRef", "Counters",
    "Cell", "DegreeStats", "ThreadBackend", "merge_degree_stats",
    "LINE", "NVM", "SimulatedCrash",
    "PROFILES", "CostProfile", "VClock", "resolve_profile",
    "AtomicFloatObject", "CheckpointObject", "FetchAddObject",
    "HeapObject", "ResponseLogObject", "SeqObject",
    "SeqQueueObject", "SeqStackObject",
    "PBComb", "PWFComb", "RequestRec",
]
