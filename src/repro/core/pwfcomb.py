"""PWFComb — the paper's wait-free recoverable combining protocol.

Faithful implementation of Algorithms 3 and 4.  Every thread *pretends*
to be the combiner: it copies the StateRec pointed to by ``S`` into one of
its two private NVM slots, applies all announced valid+active requests to
the copy, persists the copy (one contiguous pwb + pfence), and tries to
publish it with SC(S, ...).  After two failed attempts the thread's own
request is guaranteed served (Herlihy-style helping argument), so it
returns the response recorded in the current StateRec.

Persistence-principle machinery (paper Section 4):
  * ``Index[0..n-1]`` lives *inside* the StateRec so the slot-alternation
    bookkeeping persists together with the state (P3) — without it a
    recovered thread could reuse the slot currently published in S.
  * ``Flush[]`` (volatile) parity tells whether the publishing round's
    pwb(S)+psync already happened, so most threads skip persisting S (P1).
  * ``CombRound[][]`` (volatile) tells a thread which publishing round
    served it, so it only helps persist that round (P2).

Deviations from the paper's pseudocode, documented per the repo's
DESIGN.md:
  * Algorithm 4 line 15 reads ``Flush[lsPtr->pid]`` (the *previous*
    combiner's counter) to derive the round number.  We read the thread's
    own ``Flush[p]`` — the textual description ("p changes Flush[p] to an
    odd value") implies per-thread monotone round numbers, which the
    cross-thread read would break (stale ``CombRound`` entries could alias
    a later round).
  * In the fallback path (lines 38-50) the paper skips persisting S
    whenever ``CombRound`` does not match, even if ``Flush`` is odd.  We
    persist whenever ``Flush`` of the current publisher is odd: there is a
    narrow 3-round overlap window in which the skip could let a thread
    return before any psync of an S value covering its request.  The
    common-case saving (skip when even) is preserved.

LL/VL/SC on S is simulated exactly as in the paper's own evaluation:
a versioned CAS (Section 6).
"""

from __future__ import annotations

import random
import threading
import time
from typing import Any, Optional

from .atomics import Counters
from .nvm import NVM
from .objects import SeqObject
from .pbcomb import RequestRec


class _SRef:
    """Versioned LL/VL/SC reference whose value is mirrored into an NVM
    word under the SC mutex (so pwb(&S) snapshots are never stale)."""

    def __init__(self, nvm: NVM, addr: int, value: int,
                 counters: Optional[Counters] = None) -> None:
        self.nvm = nvm
        self.addr = addr
        self._value = (value, 0)
        self._mutex = threading.Lock()
        self._counters = counters
        self._clock = nvm.clock
        # Recovery rebuilds the mirror from the durable word itself
        # (reset_sref passes nvm.read(addr) back in); rewriting the
        # identical value would dirty the line with nothing new to
        # persist before the recovery psync.
        if nvm.read(addr) != value:
            nvm.write(addr, value)

    def ll(self):
        if self._counters:
            self._counters.shared_reads += 1
        return self._value

    def vl(self, version: int) -> bool:
        return self._value[1] == version

    def sc(self, version: int, new_value: int) -> bool:
        with self._mutex:
            if self._counters:
                self._counters.cas_calls += 1
            if self._clock is not None:
                self._clock.advance(self._clock.profile.cas_ns)
            if self._value[1] == version:
                self._value = (new_value, version + 1)
                self.nvm.write(self.addr, new_value)
                return True
            return False

    def load(self) -> int:
        return self._value[0]


class PWFComb:
    MAX_BACKOFF = 64  # spin iterations; adaptive, tiny on a 1-core host
    ANNOUNCE_PARK_PROB = 0.05
    ANNOUNCE_PARK_SECONDS = 1e-6   # OS floor applies

    def __init__(self, nvm: NVM, n_threads: int, obj: SeqObject,
                 counters: Optional[Counters] = None,
                 backoff: bool = True, vector_apply: bool = False) -> None:
        self.nvm = nvm
        self.n = n_threads
        self.obj = obj
        self._counters = counters
        self.backoff_enabled = backoff
        # VectorApply (DESIGN.md §11): homogeneous simulation passes run
        # as one jitted kernel over the announcement batch; declines
        # fall back to the identical per-op loop.  See PBComb.
        self._vector_enabled = bool(vector_apply)
        sw = obj.state_words
        self.state_words = sw
        # StateRec: st | ReturnVal[n] | Deactivate[n] | Index[n] | pid
        self.rec_words = sw + 3 * n_threads + 1
        # --- shared non-volatile: (n+1) owners x 2 slots + S ---------- #
        self.slot_base = [nvm.alloc(self.rec_words)
                          for _ in range((n_threads + 1) * 2)]
        self.s_addr = nvm.alloc(1)
        dummy = self._slot_id(n_threads, 0)
        for s in range(len(self.slot_base)):
            self._init_rec(s)
        self.S = nvm.backend.sref(nvm, self.s_addr, dummy, counters)
        for s in range(len(self.slot_base)):
            nvm.pwb(self.slot_base[s], self.rec_words)
        nvm.pwb(self.s_addr, 1)
        nvm.psync()
        nvm.reset_counters()
        # --- shared volatile ------------------------------------------ #
        # Shared-between-participants state comes from the execution
        # backend (DESIGN.md §7); per-thread scratch (rng, backoff
        # windows) stays process-local.
        be = nvm.backend
        self.request = be.request_board(n_threads)
        self._clock = nvm.clock
        # Virtual time of the last durable publication (pwb(S)+psync);
        # served threads merge it on pickup — see PBComb._round_end_vt.
        self._round_end_vt = 0.0
        self.flush = be.int_array(n_threads + 1)
        self.comb_round = be.int_matrix(n_threads + 1, n_threads)
        self._rng = random.Random(0xC0FFEE)
        self._backoff_window = [1] * n_threads
        self._flush_mutex = be.mutex()
        # entry backoff, backend-tuned (wide under true parallelism)
        self._park_prob, self._park_secs = be.announce_park(
            self.ANNOUNCE_PARK_PROB, self.ANNOUNCE_PARK_SECONDS)
        # Measured combining degree: requests served per successful
        # publication (the wait-free analogue of PBComb's round).
        self.stats = be.degree_stats()
        # per-thread count of requests a _begin_attempt hook served
        # outside the scan (PWFStack's elimination) — attempts by
        # different threads run concurrently, hence one slot per tid
        self._attempt_served = [0] * n_threads

    # ---------------- layout helpers ---------------------------------- #
    def _slot_id(self, owner: int, ind: int) -> int:
        return owner * 2 + ind

    def _base(self, slot: int) -> int:
        return self.slot_base[slot]

    def _retval_addr(self, slot: int, q: int) -> int:
        return self._base(slot) + self.state_words + q

    def _deact_addr(self, slot: int, q: int) -> int:
        return self._base(slot) + self.state_words + self.n + q

    def _index_addr(self, slot: int, q: int) -> int:
        return self._base(slot) + self.state_words + 2 * self.n + q

    def _pid_addr(self, slot: int) -> int:
        return self._base(slot) + self.state_words + 3 * self.n

    def _init_rec(self, slot: int) -> None:
        nvm = self.nvm
        self.obj.init_state(nvm, self._base(slot))
        for q in range(self.n):
            nvm.write(self._retval_addr(slot, q), None)
            nvm.write(self._deact_addr(slot, q), 0)
            nvm.write(self._index_addr(slot, q), 0)
        nvm.write(self._pid_addr(slot), self.n)

    # ---------------- public API (Algorithm 3) ------------------------ #
    def op(self, p: int, func: str, args: Any, seq: int) -> Any:
        # Announce in place (line 1).  Mutating the existing RequestRec
        # is race-safe: p's previous request is already served (p was
        # inside _perform_request until then), so scanners skip it while
        # ``valid`` is 0 — and the stamp seqlock (see RequestRec) keeps
        # a truly-parallel scanner from adopting a half-rewritten
        # record.
        req = self.request[p]
        st = req.stamp + 1
        req.stamp = st          # odd: announce in progress
        req.valid = 0
        req.func = func
        req.args = args
        req.activate = 1 - req.activate
        if self._clock is not None:
            req.vtime = self._clock.now()
        req.valid = 1
        req.stamp = st + 1      # even: published
        # line 2 (backoff): a small random fraction of ops parks after
        # announcing so a concurrent pretend-combiner adopts the request
        # into its round — _try_finish then returns the recorded
        # response without a publication of our own (cf. PBComb).
        if self.backoff_enabled:
            if self._rng.random() < self._park_prob:
                time.sleep(self._park_secs)
            else:
                self._backoff(p)
        return self._perform_request(p)

    def recover(self, p: int, func: str, args: Any, seq: int) -> Any:
        self.request[p] = RequestRec(func, args, seq % 2, 1)
        s = self.S.load()
        if self.nvm.read(self._deact_addr(s, p)) != seq % 2:
            return self._perform_request(p)
        return self.nvm.read(self._retval_addr(s, p))

    def reset_volatile(self) -> None:
        """Post-crash volatile re-initialization.  S (non-volatile) is
        rebuilt from its durable NVM word; Request/Flush/CombRound are
        volatile and start fresh.  The rebuilt S keeps the original
        ``Counters`` reference (synchronization-cost measurements must
        keep accumulating after a crash) and request activate bits are
        re-seeded from the published StateRec's deactivate bits."""
        be = self.nvm.backend
        self.S = be.reset_sref(self.S, self.nvm, self.s_addr,
                               self.nvm.read(self.s_addr), self._counters)
        self.request.reset()
        self.flush.fill(0)
        for row in self.comb_round:
            row.fill(0)
        self._flush_mutex = be.reset_mutex(self._flush_mutex)
        for p in range(self.n):
            self.resync_request(p)

    def resync_request(self, p: int) -> None:
        """Re-seed thread p's volatile activate parity from the durable
        deactivate bit of the currently published StateRec."""
        deact = self.nvm.read(self._deact_addr(self.S.load(), p))
        self.request[p] = RequestRec(None, None, deact, 0)

    # ---------------- Algorithm 4 -------------------------------------- #
    def _try_finish(self, p: int):
        """Helping fast path: if p's request was already served by the
        *published* StateRec, ensure that publication is durable (the
        fallback's lines 42-50) and return its recorded response — no
        copy, no simulation, no SC.  The paper reaches this state only
        through the fallback after two failed attempts; checking before
        each attempt removes the duplicated pretend-combiner work that
        dominates under contention (every applied request's response and
        deactivate bit are already in the StateRec S points to)."""
        nvm = self.nvm
        rd = nvm.read
        ls = self.S.load()
        if self.request[p].activate != rd(
                self._base(ls) + self.state_words + self.n + p):
            return False, None
        s_pid = rd(self._pid_addr(ls))
        lval = self.flush[s_pid]
        if lval % 2 == 1:                   # publication not yet flushed
            nvm.pwb_sync(self.s_addr, 1)
            if lval == self.comb_round[s_pid][p]:
                self._cas_flush(s_pid, lval, lval + 1)
        if self._clock is not None:
            self._clock.merge(self._round_end_vt)   # Lamport hand-off
        return True, rd(self._retval_addr(self.S.load(), p))

    def _perform_request(self, p: int) -> Any:
        nvm = self.nvm
        rd, wr = nvm.read, nvm.write
        clk = self._clock
        my_slots = (self._slot_id(p, 0), self._slot_id(p, 1))
        sw, n = self.state_words, self.n
        for _attempt in range(2):                                # line 5
            done, val = self._try_finish(p)
            if done:
                return val
            ls, ver = self.S.ll()                                # line 9
            ind = rd(self._base(ls) + sw + 2 * n + p)            # line 11
            dst = my_slots[ind]
            dst_base = self._base(dst)
            nvm.copy_range(dst_base, self._base(ls), self.rec_words)  # line 13
            wr(dst_base + sw + 3 * n, p)                         # line 14
            lval = self.flush[p]                                 # line 15 (own, see module doc)
            lval = lval + 1 if lval % 2 == 0 else lval + 2       # lines 16-17
            if not self.S.vl(ver):                               # line 18
                continue
            self._attempt_served[p] = 0
            self._begin_attempt(dst, p)
            retval_base = dst_base + sw
            deact_base = retval_base + n
            request = self.request
            comb_round = self.comb_round[p]
            served = 0
            batch = [] if self._vector_enabled else None
            deacts = nvm.read_range(deact_base, n)    # one slice, n reads
            for q in range(n):                                   # line 19
                req = request[q]
                # seqlock snapshot (see RequestRec.stamp): never apply
                # a mixed record; a skipped mid-announce request is
                # simply not-yet-announced for this attempt
                s1 = req.stamp
                act = req.activate
                if s1 & 1 or req.valid != 1 or act == deacts[q]:  # line 20
                    continue
                func, args, vt = req.func, req.args, req.vtime
                if req.stamp != s1:
                    continue
                if clk is not None:
                    clk.merge(vt)          # Lamport receive (announce)
                if batch is not None:
                    # VectorApply: adopt now, apply the pass as one
                    # batch below (merge-first is clock-identical)
                    batch.append((q, func, args, act))
                    continue
                ret = self._apply(q, func, args, dst, p)        # lines 21-22
                wr(retval_base + q, ret)                            # line 23
                wr(deact_base + q, act)                             # line 24
                comb_round[q] = lval                                # line 25
                served += 1
            if batch:
                rets = self._apply_batch(batch, dst, p)
                for (q, _f, _a, act), ret in zip(batch, rets):
                    wr(retval_base + q, ret)                        # line 23
                    wr(deact_base + q, act)                         # line 24
                    comb_round[q] = lval                            # line 25
                served = len(batch)
            if self.S.vl(ver):                                   # line 26
                index_addr = deact_base + n + p
                wr(index_addr, 1 - rd(index_addr))               # line 27
                pending = self._pre_publish(dst, p)
                nvm.pwb_fence(dst_base, self.rec_words,
                              pending=pending)                   # lines 28-29
                self.flush[p] = lval                             # line 30
                if self.S.sc(ver, dst):                          # line 31
                    nvm.pwb_sync(self.s_addr, 1)                 # lines 32-33
                    self._cas_flush(p, lval, lval + 1)           # line 34
                    # Measured degree: requests this publication served
                    # in one pwb(S)+psync (scan + eliminated pairs).
                    self.stats.record(served + self._attempt_served[p])
                    if clk is not None:
                        clk.advance(clk.profile.round_ns)
                        self._round_end_vt = clk.now()
                    # Hook runs after S is durable: safe point to recycle
                    # nodes the published round removed.
                    self._on_publish_success(dst, p)
                    return nvm.read(self._retval_addr(self.S.load(), p))  # line 35
            self._attempt_failed(dst, p)
            self._backoff(p, grow=True)                          # line 36
        # Fallback (lines 38-50): request guaranteed served by now.
        ls = self.S.load()                                       # line 38
        s_pid = nvm.read(self._pid_addr(ls))
        lval = self.flush[s_pid]                                 # line 40
        if lval % 2 == 1:                                        # line 42 (see module doc)
            nvm.pwb_sync(self.s_addr, 1)                         # lines 44-46
            if lval == self.comb_round[s_pid][p]:
                self._cas_flush(s_pid, lval, lval + 1)           # line 48
        if clk is not None:
            clk.merge(self._round_end_vt)                # Lamport hand-off
        return nvm.read(self._retval_addr(self.S.load(), p))     # line 50

    # ---------------- helpers ------------------------------------------ #
    def _cas_flush(self, i: int, old: int, new: int) -> None:
        # per-instance mutex (guards this instance's flush[] only — a
        # class-level lock would serialize unrelated instances, e.g. a
        # split queue's enqueue and dequeue sides)
        with self._flush_mutex:
            if self.flush[i] == old:
                self.flush[i] = new

    def _apply(self, q: int, func: str, args: Any, slot: int,
               combiner: int) -> Any:
        return self.obj.apply(self.nvm, self._base(slot), func, args, ctx=self)

    def _apply_batch(self, batch, slot: int, combiner: int) -> list:
        """One collected simulation pass: ``batch`` is the adoptable
        announcements ``[(q, func, args, act), ...]`` in scan order.  A
        homogeneous batch goes through the object's VectorApply seam
        (one jitted kernel — DESIGN.md §11); a heterogeneous batch or a
        seam decline runs the identical per-op loop."""
        func = batch[0][1]
        if all(b[1] == func for b in batch):
            rets = self.obj.vector_apply(
                self.nvm, self._base(slot), func,
                [b[2] for b in batch], ctx=self)
            if rets is not None:
                return rets
        return [self._apply(q, f, a, slot, combiner)
                for q, f, a, _act in batch]

    # ---------------- structure hooks ---------------------------------- #
    def _begin_attempt(self, slot: int, p: int) -> None:
        """Called after a consistent copy, before the simulation loop."""

    def _pre_publish(self, slot: int, p: int):
        """Called before pwb(StateRec).  Returns the attempt-local node
        allocations to persist ahead of the StateRec (they must be
        durable before S can move), or None."""
        return None

    def _on_publish_success(self, slot: int, p: int) -> None:
        """Called right after a successful SC."""

    def _attempt_failed(self, slot: int, p: int) -> None:
        """Called when an attempt is abandoned (failed VL or SC) — return
        attempt-local node allocations to the pool."""

    PARK_QUANTUM = 1e-5   # seconds per backoff unit (real GIL handoff)

    def _backoff(self, p: int, grow: bool = False) -> None:
        """Adaptive backoff (Algorithm 3 line 2 / Algorithm 4 line 36).
        The window only opens after a failed attempt and closes again on
        success, so the uncontended fast path skips the RNG and the park
        entirely — contention is what the backoff is for.  Parking is a
        real (tiny) sleep, not a bare GIL yield: under CPython a yield
        spinner can win the GIL straight back and starve the publisher
        that would have served this thread's announced request."""
        if not self.backoff_enabled:
            return
        window = self._backoff_window[p]
        if grow:
            window = min(window * 2, self.MAX_BACKOFF)
            self._backoff_window[p] = window
        elif window <= 1:
            return
        else:
            self._backoff_window[p] = max(1, window // 2)
        time.sleep(self._rng.randint(0, window) * self.PARK_QUANTUM)
