"""PWFComb — the paper's wait-free recoverable combining protocol.

Faithful implementation of Algorithms 3 and 4.  Every thread *pretends*
to be the combiner: it copies the StateRec pointed to by ``S`` into one of
its two private NVM slots, applies all announced valid+active requests to
the copy, persists the copy (one contiguous pwb + pfence), and tries to
publish it with SC(S, ...).  After two failed attempts the thread's own
request is guaranteed served (Herlihy-style helping argument), so it
returns the response recorded in the current StateRec.

Persistence-principle machinery (paper Section 4):
  * ``Index[0..n-1]`` lives *inside* the StateRec so the slot-alternation
    bookkeeping persists together with the state (P3) — without it a
    recovered thread could reuse the slot currently published in S.
  * ``Flush[]`` (volatile) parity tells whether the publishing round's
    pwb(S)+psync already happened, so most threads skip persisting S (P1).
  * ``CombRound[][]`` (volatile) tells a thread which publishing round
    served it, so it only helps persist that round (P2).

Deviations from the paper's pseudocode, documented per the repo's
DESIGN.md:
  * Algorithm 4 line 15 reads ``Flush[lsPtr->pid]`` (the *previous*
    combiner's counter) to derive the round number.  We read the thread's
    own ``Flush[p]`` — the textual description ("p changes Flush[p] to an
    odd value") implies per-thread monotone round numbers, which the
    cross-thread read would break (stale ``CombRound`` entries could alias
    a later round).
  * In the fallback path (lines 38-50) the paper skips persisting S
    whenever ``CombRound`` does not match, even if ``Flush`` is odd.  We
    persist whenever ``Flush`` of the current publisher is odd: there is a
    narrow 3-round overlap window in which the skip could let a thread
    return before any psync of an S value covering its request.  The
    common-case saving (skip when even) is preserved.

LL/VL/SC on S is simulated exactly as in the paper's own evaluation:
a versioned CAS (Section 6).
"""

from __future__ import annotations

import random
import threading
import time
from typing import Any, List, Optional

from .atomics import Counters
from .nvm import NVM
from .objects import SeqObject
from .pbcomb import RequestRec


class _SRef:
    """Versioned LL/VL/SC reference whose value is mirrored into an NVM
    word under the SC mutex (so pwb(&S) snapshots are never stale)."""

    def __init__(self, nvm: NVM, addr: int, value: int,
                 counters: Optional[Counters] = None) -> None:
        self.nvm = nvm
        self.addr = addr
        self._value = (value, 0)
        self._mutex = threading.Lock()
        self._counters = counters
        nvm.write(addr, value)

    def ll(self):
        if self._counters:
            self._counters.shared_reads += 1
        return self._value

    def vl(self, version: int) -> bool:
        return self._value[1] == version

    def sc(self, version: int, new_value: int) -> bool:
        with self._mutex:
            if self._counters:
                self._counters.cas_calls += 1
            if self._value[1] == version:
                self._value = (new_value, version + 1)
                self.nvm.write(self.addr, new_value)
                return True
            return False

    def load(self) -> int:
        return self._value[0]


class PWFComb:
    MAX_BACKOFF = 64  # spin iterations; adaptive, tiny on a 1-core host

    def __init__(self, nvm: NVM, n_threads: int, obj: SeqObject,
                 counters: Optional[Counters] = None,
                 backoff: bool = True) -> None:
        self.nvm = nvm
        self.n = n_threads
        self.obj = obj
        self._counters = counters
        self.backoff_enabled = backoff
        sw = obj.state_words
        self.state_words = sw
        # StateRec: st | ReturnVal[n] | Deactivate[n] | Index[n] | pid
        self.rec_words = sw + 3 * n_threads + 1
        # --- shared non-volatile: (n+1) owners x 2 slots + S ---------- #
        self.slot_base = [nvm.alloc(self.rec_words)
                          for _ in range((n_threads + 1) * 2)]
        self.s_addr = nvm.alloc(1)
        dummy = self._slot_id(n_threads, 0)
        for s in range(len(self.slot_base)):
            self._init_rec(s)
        self.S = _SRef(nvm, self.s_addr, dummy, counters)
        for s in range(len(self.slot_base)):
            nvm.pwb(self.slot_base[s], self.rec_words)
        nvm.pwb(self.s_addr, 1)
        nvm.psync()
        nvm.reset_counters()
        # --- shared volatile ------------------------------------------ #
        self.request: List[RequestRec] = [RequestRec() for _ in range(n_threads)]
        self.flush: List[int] = [0] * (n_threads + 1)
        self.comb_round = [[0] * n_threads for _ in range(n_threads + 1)]
        self._rng = random.Random(0xC0FFEE)
        self._backoff_window = [1] * n_threads

    # ---------------- layout helpers ---------------------------------- #
    def _slot_id(self, owner: int, ind: int) -> int:
        return owner * 2 + ind

    def _base(self, slot: int) -> int:
        return self.slot_base[slot]

    def _retval_addr(self, slot: int, q: int) -> int:
        return self._base(slot) + self.state_words + q

    def _deact_addr(self, slot: int, q: int) -> int:
        return self._base(slot) + self.state_words + self.n + q

    def _index_addr(self, slot: int, q: int) -> int:
        return self._base(slot) + self.state_words + 2 * self.n + q

    def _pid_addr(self, slot: int) -> int:
        return self._base(slot) + self.state_words + 3 * self.n

    def _init_rec(self, slot: int) -> None:
        nvm = self.nvm
        self.obj.init_state(nvm, self._base(slot))
        for q in range(self.n):
            nvm.write(self._retval_addr(slot, q), None)
            nvm.write(self._deact_addr(slot, q), 0)
            nvm.write(self._index_addr(slot, q), 0)
        nvm.write(self._pid_addr(slot), self.n)

    # ---------------- public API (Algorithm 3) ------------------------ #
    def op(self, p: int, func: str, args: Any, seq: int) -> Any:
        req = self.request[p]
        self.request[p] = RequestRec(func, args, 1 - req.activate, 1)  # line 1
        self._backoff(p)                                               # line 2
        return self._perform_request(p)

    def recover(self, p: int, func: str, args: Any, seq: int) -> Any:
        self.request[p] = RequestRec(func, args, seq % 2, 1)
        s = self.S.load()
        if self.nvm.read(self._deact_addr(s, p)) != seq % 2:
            return self._perform_request(p)
        return self.nvm.read(self._retval_addr(s, p))

    def reset_volatile(self) -> None:
        """Post-crash volatile re-initialization.  S (non-volatile) is
        rebuilt from its durable NVM word; Request/Flush/CombRound are
        volatile and start fresh.  The rebuilt S keeps the original
        ``Counters`` reference (synchronization-cost measurements must
        keep accumulating after a crash) and request activate bits are
        re-seeded from the published StateRec's deactivate bits."""
        self.S = _SRef(self.nvm, self.s_addr, self.nvm.read(self.s_addr),
                       self._counters)
        self.request = [RequestRec() for _ in range(self.n)]
        self.flush = [0] * (self.n + 1)
        self.comb_round = [[0] * self.n for _ in range(self.n + 1)]
        for p in range(self.n):
            self.resync_request(p)

    def resync_request(self, p: int) -> None:
        """Re-seed thread p's volatile activate parity from the durable
        deactivate bit of the currently published StateRec."""
        deact = self.nvm.read(self._deact_addr(self.S.load(), p))
        self.request[p] = RequestRec(None, None, deact, 0)

    # ---------------- Algorithm 4 -------------------------------------- #
    def _perform_request(self, p: int) -> Any:
        nvm = self.nvm
        my_slots = (self._slot_id(p, 0), self._slot_id(p, 1))
        for _attempt in range(2):                                # line 5
            ls, ver = self.S.ll()                                # line 9
            ind = nvm.read(self._index_addr(ls, p))              # line 11
            dst = my_slots[ind]
            nvm.write_range(self._base(dst),
                            nvm.read_range(self._base(ls), self.rec_words))  # line 13
            nvm.write(self._pid_addr(dst), p)                    # line 14
            lval = self.flush[p]                                 # line 15 (own, see module doc)
            lval = lval + 1 if lval % 2 == 0 else lval + 2       # lines 16-17
            if not self.S.vl(ver):                               # line 18
                continue
            self._begin_attempt(dst, p)
            for q in range(self.n):                              # line 19
                req = self.request[q]
                if req.valid == 1 and req.activate != nvm.read(self._deact_addr(dst, q)):  # line 20
                    ret = self._apply(q, req.func, req.args, dst, p)    # lines 21-22
                    nvm.write(self._retval_addr(dst, q), ret)           # line 23
                    nvm.write(self._deact_addr(dst, q), req.activate)   # line 24
                    self.comb_round[p][q] = lval                        # line 25
            if self.S.vl(ver):                                   # line 26
                nvm.write(self._index_addr(dst, p),
                          1 - nvm.read(self._index_addr(dst, p)))       # line 27
                self._pre_publish(dst, p)
                nvm.pwb(self._base(dst), self.rec_words)         # line 28
                nvm.pfence()                                     # line 29
                self.flush[p] = lval                             # line 30
                if self.S.sc(ver, dst):                          # line 31
                    nvm.pwb(self.s_addr, 1)                      # line 32
                    nvm.psync()                                  # line 33
                    self._cas_flush(p, lval, lval + 1)           # line 34
                    # Hook runs after S is durable: safe point to recycle
                    # nodes the published round removed.
                    self._on_publish_success(dst, p)
                    return nvm.read(self._retval_addr(self.S.load(), p))  # line 35
            self._attempt_failed(dst, p)
            self._backoff(p, grow=True)                          # line 36
        # Fallback (lines 38-50): request guaranteed served by now.
        ls = self.S.load()                                       # line 38
        s_pid = nvm.read(self._pid_addr(ls))
        lval = self.flush[s_pid]                                 # line 40
        if lval % 2 == 1:                                        # line 42 (see module doc)
            nvm.pwb(self.s_addr, 1)                              # line 44
            nvm.psync()                                          # line 46
            if lval == self.comb_round[s_pid][p]:
                self._cas_flush(s_pid, lval, lval + 1)           # line 48
        return nvm.read(self._retval_addr(self.S.load(), p))     # line 50

    # ---------------- helpers ------------------------------------------ #
    _flush_mutex = threading.Lock()

    def _cas_flush(self, i: int, old: int, new: int) -> None:
        with self._flush_mutex:
            if self.flush[i] == old:
                self.flush[i] = new

    def _apply(self, q: int, func: str, args: Any, slot: int,
               combiner: int) -> Any:
        return self.obj.apply(self.nvm, self._base(slot), func, args, ctx=self)

    # ---------------- structure hooks ---------------------------------- #
    def _begin_attempt(self, slot: int, p: int) -> None:
        """Called after a consistent copy, before the simulation loop."""

    def _pre_publish(self, slot: int, p: int) -> None:
        """Called before pwb(StateRec) — persist attempt-local node
        allocations here (they must be durable before S can move)."""

    def _on_publish_success(self, slot: int, p: int) -> None:
        """Called right after a successful SC."""

    def _attempt_failed(self, slot: int, p: int) -> None:
        """Called when an attempt is abandoned (failed VL or SC) — return
        attempt-local node allocations to the pool."""

    def _backoff(self, p: int, grow: bool = False) -> None:
        if not self.backoff_enabled:
            return
        if grow:
            self._backoff_window[p] = min(self._backoff_window[p] * 2,
                                          self.MAX_BACKOFF)
        for _ in range(self._rng.randint(0, self._backoff_window[p])):
            time.sleep(0)
