"""Mixture-of-Experts FFN with sort-based token dispatch.

Dispatch avoids the classic GShard one-hot (T,E,C) tensor — infeasible at
1M tokens — by sorting (token, slot) pairs by expert id and
gathering/scattering through a capacity-bounded expert buffer
[E, C, D].  All shapes are static (capacity-dropped tokens fall into an
overflow row), so the same code lowers for the dry-run at 778B scale and
runs the CPU smoke tests.

Sharding: the expert buffer and expert weights carry a
``with_sharding_constraint`` placing E on the 'model' axis (expert
parallelism); token arrays stay batch-sharded on 'data'.  The baseline
lets XLA pick the dispatch collectives (gather across data shards); the
§Perf hillclimb replaces this with an explicit shard_map all-to-all —
both paths are kept selectable (``ep_mode``).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import dense_init


def init_moe_params(key, cfg, dtype=jnp.bfloat16) -> Dict[str, Any]:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (D, E), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, D, F), in_axis=1, dtype=dtype),
        "w_up": dense_init(ks[2], (E, D, F), in_axis=1, dtype=dtype),
        "w_down": dense_init(ks[3], (E, F, D), in_axis=1, dtype=dtype),
    }


def capacity(T: int, cfg) -> int:
    c = int(math.ceil(T * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)   # round up to a multiple of 8, >= 8


def moe_ffn(params, x, cfg, constrain=None):
    """x: [B, S, D] -> [B, S, D].  ``constrain(tensor, spec)`` applies
    sharding constraints (no-op when None)."""
    if constrain is None:
        constrain = lambda t, spec: t
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(T, cfg)
    xt = x.reshape(T, D)

    # ---- router ----
    logits = (xt.astype(jnp.float32) @ params["router"])        # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)             # [T, K]
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # ---- sort-based dispatch ----
    flat_expert = expert_idx.reshape(-1)                        # [T*K]
    order = jnp.argsort(flat_expert)                            # stable
    sorted_expert = flat_expert[order]
    sorted_token = order // K
    counts = jnp.zeros((E,), jnp.int32).at[flat_expert].add(1)
    offsets = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * K, dtype=jnp.int32) - offsets[sorted_expert]
    keep = pos < C
    slot = jnp.where(keep, sorted_expert * C + pos, E * C)      # overflow row

    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(xt[sorted_token])
    ebuf = constrain(buf[:E * C].reshape(E, C, D), P("model", None, None))

    # ---- expert FFN (einsum over per-expert weights, E on 'model') ----
    g = jnp.einsum("ecd,edf->ecf", ebuf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", ebuf, params["w_up"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    y = constrain(y, P("model", None, None))

    # ---- combine ----
    ypad = jnp.concatenate([y.reshape(E * C, D),
                            jnp.zeros((1, D), y.dtype)], axis=0)
    contrib = ypad[slot]                                        # [T*K, D]
    gates_sorted = (gate_vals.reshape(-1)[order] *
                    keep.astype(jnp.float32))                   # [T*K]
    out = jnp.zeros((T, D), jnp.float32).at[sorted_token].add(
        contrib.astype(jnp.float32) * gates_sorted[:, None])
    return out.reshape(B, S, D).astype(x.dtype)


def moe_ffn_ep(params, x, cfg, mesh):
    """Expert-parallel MoE via shard_map (§Perf variant).

    The baseline ``moe_ffn`` traces global [T_global, ...] dispatch
    arrays and lets GSPMD shard them — at 1M tokens the partitioner
    falls back to replicated sort/scatter buffers (hundreds of GiB, the
    dominant collective term in the moonshot/llama4 baselines).  Here
    every device dispatches its LOCAL tokens to its LOCAL experts
    directly:

      * activations arrive batch-sharded over ('pod','data') and
        replicated over 'model' — each model shard sees every local
        token and simply filters for its own experts (no all-to-all
        needed at this replication layout);
      * the per-device expert buffer is [E/TP, C_local, D];
      * one psum over 'model' recombines expert outputs — the same
        collective shape as a Megatron MLP.
    """
    from jax.experimental.shard_map import shard_map

    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = mesh.shape.get("model", 1)
    assert E % tp == 0
    e_loc = E // tp
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    t_loc = (B // dp) * S
    C = capacity(t_loc, cfg)

    def f(xl, router, wg, wu, wd):
        b_loc = xl.shape[0]
        xt = xl.reshape(b_loc * S, D)
        T = xt.shape[0]
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

        e0 = jax.lax.axis_index("model") * e_loc
        fe = expert_idx.reshape(-1)                      # [T*K]
        mine = (fe >= e0) & (fe < e0 + e_loc)
        sort_key = jnp.where(mine, fe - e0, e_loc)       # strangers last
        order = jnp.argsort(sort_key)
        s_fe = sort_key[order]
        s_tok = order // K
        counts = jnp.zeros((e_loc + 1,), jnp.int32).at[sort_key].add(1)
        offsets = jnp.cumsum(counts) - counts
        pos = jnp.arange(T * K, dtype=jnp.int32) - offsets[s_fe]
        keep = (s_fe < e_loc) & (pos < C)
        slot = jnp.where(keep, s_fe * C + pos, e_loc * C)

        buf = jnp.zeros((e_loc * C + 1, D), xl.dtype).at[slot].set(
            xt[s_tok])
        ebuf = buf[:e_loc * C].reshape(e_loc, C, D)
        g = jnp.einsum("ecd,edf->ecf", ebuf, wg)
        u = jnp.einsum("ecd,edf->ecf", ebuf, wu)
        h = jax.nn.silu(g) * u
        y = jnp.einsum("ecf,efd->ecd", h, wd)

        ypad = jnp.concatenate([y.reshape(e_loc * C, D),
                                jnp.zeros((1, D), y.dtype)], axis=0)
        contrib = ypad[slot]
        gates_sorted = (gate_vals.reshape(-1)[order] *
                        keep.astype(jnp.float32))
        out = jnp.zeros((T, D), jnp.float32).at[s_tok].add(
            contrib.astype(jnp.float32) * gates_sorted[:, None])
        out = jax.lax.psum(out, "model")                 # combine experts
        return out.reshape(b_loc, S, D).astype(xl.dtype)

    fn = shard_map(
        f, mesh=mesh,
        in_specs=(P(axes, None, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=P(axes, None, None), check_rep=False)
    return fn(x, params["router"], params["w_gate"], params["w_up"],
              params["w_down"])


def aux_load_balance_loss(params, x, cfg):
    """Switch-style load-balancing auxiliary loss (fraction*prob form)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * mean_prob)
