"""Mamba2 mixer — SSD (state-space duality) chunked scan, arXiv:2405.21060.

Training/prefill use the chunked SSD algorithm: within a chunk the
quadratic "attention-like" term runs on the MXU; across chunks a
recurrence carries the (heads, head_dim, state) tensor via ``lax.scan``.
Decode performs the O(1) per-token recurrence.

This jnp implementation is the reference semantics; the Pallas TPU kernel
in ``repro.kernels.ssd_scan`` computes the same chunked scan with VMEM
tiling and is validated against it.

Projections are kept SEPARATE (z, x, B, C, dt) rather than fused as in
the reference CUDA implementation: under tensor parallelism the inner
dimension (d_inner, sharded over 'model') and the small B/C/dt heads
(replicated) live on different shardings, and a fused out-dim would put
segment boundaries mid-shard.  This is a deliberate TPU adaptation
(DESIGN.md §2).

Layout conventions (ngroups = 1):
  x_ssm: [B, L, H, P]   (H ssm heads, P = ssm_head_dim)
  B_ssm, C_ssm: [B, L, N]  (N = ssm_state)
  dt: [B, L, H]  (softplus-activated step size)
  A: [H]  (negative reals: A = -exp(A_log))
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm


class SSMState(NamedTuple):
    conv_x: jnp.ndarray  # [B, W-1, d_inner] rolling conv windows (raw)
    conv_b: jnp.ndarray  # [B, W-1, N]
    conv_c: jnp.ndarray  # [B, W-1, N]
    ssm: jnp.ndarray     # [B, H, P, N] recurrent state


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads


def init_ssm_params(key, cfg, dtype=jnp.bfloat16) -> Dict[str, Any]:
    D = cfg.d_model
    d_inner, H = _dims(cfg)
    N, W = cfg.ssm_state, cfg.ssm_conv_width
    ks = jax.random.split(key, 8)
    return {
        "wz": dense_init(ks[0], (D, d_inner), dtype=dtype),
        "wx": dense_init(ks[1], (D, d_inner), dtype=dtype),
        "wB": dense_init(ks[2], (D, N), dtype=dtype),
        "wC": dense_init(ks[3], (D, N), dtype=dtype),
        "wdt": dense_init(ks[4], (D, H), dtype=dtype),
        "conv_x": dense_init(ks[5], (W, d_inner), dtype=dtype),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_B": dense_init(ks[6], (W, N), dtype=dtype),
        "conv_B_b": jnp.zeros((N,), dtype),
        "conv_C": dense_init(ks[7], (W, N), dtype=dtype),
        "conv_C_b": jnp.zeros((N,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(ks[0], (d_inner, D), dtype=dtype),
    }


def _conv_train(xs, w, b):
    """Causal depthwise conv over time; xs: [B, L, C], w: [W, C]."""
    W = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xs.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _segsum(a):
    """log-space segment sums: out[i, j] = sum_{k=j+1..i} a[k] for i >= j,
    -inf above the diagonal.  a: [..., L] -> [..., L, L]."""
    L = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked_ref(x, dt, A, Bm, Cm, chunk: int,
                    init_state: Optional[jnp.ndarray] = None):
    """Chunked SSD scan.  Returns (y [B,L,H,P], final_state [B,H,P,N]).

    x: [B,L,H,P]; dt: [B,L,H]; A: [H]; Bm, Cm: [B,L,N].
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    if L % chunk:
        chunk = L          # degenerate single chunk (short smoke inputs)
    nc = L // chunk
    f32 = jnp.float32
    xc = (x * dt[..., None]).astype(f32).reshape(Bsz, nc, chunk, H, P)
    dA = (dt.astype(f32) * A.astype(f32)).reshape(Bsz, nc, chunk, H)
    Bc = Bm.astype(f32).reshape(Bsz, nc, chunk, N)
    Cc = Cm.astype(f32).reshape(Bsz, nc, chunk, N)
    dA_cs = jnp.cumsum(dA, axis=2)                       # [B,nc,cl,H]
    # --- intra-chunk (quadratic, MXU-friendly) ---
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, 3, 2)))      # [B,nc,H,cl,cl]
    att = jnp.einsum("bzin,bzjn->bzij", Cc, Bc)          # [B,nc,cl,cl]
    y_intra = jnp.einsum("bzij,bzhij,bzjhp->bzihp", att, Lmat, xc)
    # --- chunk summaries ---
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # [B,nc,cl,H]
    S_chunk = jnp.einsum("bzjn,bzjh,bzjhp->bzhpn",
                         Bc, decay_to_end, xc)           # [B,nc,H,P,N]
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])            # [B,nc,H]
    # --- inter-chunk recurrence ---
    s0 = jnp.zeros((Bsz, H, P, N), f32) if init_state is None \
        else init_state.astype(f32)

    def step(s, inp):
        s_c, decay_c = inp
        out_prev = s
        s = s * decay_c[..., None, None] + s_c
        return s, out_prev

    s_final, s_prevs = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(S_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                # [B,nc,H,P,N]
    in_decay = jnp.exp(dA_cs)
    y_inter = jnp.einsum("bzin,bzih,bzhpn->bzihp", Cc, in_decay, s_prevs)
    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y, s_final


def _project(params, x, cfg):
    """x: [B, L, D] -> z, xs_raw, B_raw, C_raw, dt_raw (pre-conv)."""
    z = x @ params["wz"]
    xs = x @ params["wx"]
    Bm = x @ params["wB"]
    Cm = x @ params["wC"]
    dt = x @ params["wdt"]
    return z, xs, Bm, Cm, dt


def _mix_out(params, y, xs, z, cfg, Bsz, L):
    d_inner, H = _dims(cfg)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, L, d_inner).astype(z.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ params["out_proj"]


def _ssd_inputs(params, xs_c, Bm_c, Cm_c, dt, cfg, Bsz, L):
    d_inner, H = _dims(cfg)
    xs = xs_c.reshape(Bsz, L, H, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    return xs, dt, A


def ssm_block_train(params, x, cfg, chunk: int = 128):
    """Full Mamba2 mixer over a sequence.  x: [B, L, D] -> [B, L, D]."""
    Bsz, L, _ = x.shape
    z, xs_raw, B_raw, C_raw, dt_raw = _project(params, x, cfg)
    xs_c = _conv_train(xs_raw, params["conv_x"], params["conv_x_b"])
    Bm = _conv_train(B_raw, params["conv_B"], params["conv_B_b"])
    Cm = _conv_train(C_raw, params["conv_C"], params["conv_C_b"])
    xs, dt, A = _ssd_inputs(params, xs_c, Bm, Cm, dt_raw, cfg, Bsz, L)
    y, _ = ssd_chunked_ref(xs, dt, A, Bm, Cm, chunk)
    return _mix_out(params, y, xs, z, cfg, Bsz, L)


def ssm_block_prefill(params, x, cfg, chunk: int = 128):
    """Like train but also returns the decode SSMState."""
    Bsz, L, _ = x.shape
    W = cfg.ssm_conv_width
    z, xs_raw, B_raw, C_raw, dt_raw = _project(params, x, cfg)
    xs_c = _conv_train(xs_raw, params["conv_x"], params["conv_x_b"])
    Bm = _conv_train(B_raw, params["conv_B"], params["conv_B_b"])
    Cm = _conv_train(C_raw, params["conv_C"], params["conv_C_b"])
    xs, dt, A = _ssd_inputs(params, xs_c, Bm, Cm, dt_raw, cfg, Bsz, L)
    y, s_final = ssd_chunked_ref(xs, dt, A, Bm, Cm, chunk)
    out = _mix_out(params, y, xs, z, cfg, Bsz, L)
    state = SSMState(conv_x=xs_raw[:, L - (W - 1):, :],
                     conv_b=B_raw[:, L - (W - 1):, :],
                     conv_c=C_raw[:, L - (W - 1):, :],
                     ssm=s_final)
    return out, state


def init_ssm_state(cfg, batch: int, dtype=jnp.bfloat16) -> SSMState:
    d_inner, H = _dims(cfg)
    W, N = cfg.ssm_conv_width, cfg.ssm_state
    return SSMState(
        conv_x=jnp.zeros((batch, W - 1, d_inner), dtype),
        conv_b=jnp.zeros((batch, W - 1, N), dtype),
        conv_c=jnp.zeros((batch, W - 1, N), dtype),
        ssm=jnp.zeros((batch, H, cfg.ssm_head_dim, cfg.ssm_state),
                      jnp.float32),
    )


def _conv_step(window, w, b):
    """window: [B, W, C] (raw inputs incl. current) -> [B, C]."""
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32))
    return jax.nn.silu(out + b.astype(jnp.float32))


def ssm_block_decode(params, x, cfg, state: SSMState):
    """One-token recurrence.  x: [B, 1, D] -> ([B, 1, D], new state)."""
    d_inner, H = _dims(cfg)
    Bsz = x.shape[0]
    z, xs_raw, B_raw, C_raw, dt_raw = _project(params, x, cfg)
    win_x = jnp.concatenate([state.conv_x, xs_raw], axis=1)
    win_b = jnp.concatenate([state.conv_b, B_raw], axis=1)
    win_c = jnp.concatenate([state.conv_c, C_raw], axis=1)
    xs = _conv_step(win_x, params["conv_x"], params["conv_x_b"])
    Bm = _conv_step(win_b, params["conv_B"], params["conv_B_b"])
    Cm = _conv_step(win_c, params["conv_C"], params["conv_C_b"])
    xs = xs.reshape(Bsz, H, cfg.ssm_head_dim)
    dt1 = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                          + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt1 * A)                                 # [B,H]
    dx = xs * dt1[..., None]
    s = state.ssm * a[..., None, None] + jnp.einsum("bn,bhp->bhpn", Bm, dx)
    y = jnp.einsum("bn,bhpn->bhp", Cm, s)
    y = y + params["D"][None, :, None] * xs
    y = y.reshape(Bsz, 1, d_inner).astype(x.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
    new_state = SSMState(conv_x=win_x[:, 1:], conv_b=win_b[:, 1:],
                         conv_c=win_c[:, 1:], ssm=s)
    return y @ params["out_proj"], new_state
