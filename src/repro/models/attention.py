"""GQA attention: training (full-sequence causal), prefill (returns KV),
decode (one token against a cache), cross-attention; sliding-window and
attn-logit softcap (gemma2), per-head qk-norm (qwen3).

The jnp path here is the reference/XLA implementation used by train and
dry-run lowering; ``repro.kernels.flash_attention`` is the TPU Pallas
drop-in for the same math (validated against this path in tests).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, rms_norm, softcap

NEG_INF = -2.0 ** 30  # large-negative instead of -inf (avoids NaN in padded rows)


class KVCache(NamedTuple):
    """Decode KV cache in [B, n_kv, S_max, hd] layout — heads-major so
    the decode attention dot reads the cache WITHOUT a transpose copy
    (§Perf: the [B, S, H, d] layout materialized two transposed copies
    of the per-layer cache every step — the dominant decode traffic)."""
    k: jnp.ndarray       # [B, n_kv, S_max, hd]
    v: jnp.ndarray       # [B, n_kv, S_max, hd]
    length: jnp.ndarray  # [] int32 — tokens currently valid


def _qkv(params: Dict[str, Any], x: jnp.ndarray, cfg, positions,
         rope: bool = True, shard=None):
    """Project x -> (q [B,S,H,hd], k,v [B,S,Hkv,hd]) with optional qk-norm.

    With ``cfg.attn_explicit_shard`` (§Perf variant): q is pinned to
    head-sharding over 'model' and k/v are replicated — with Hkv < TP the
    partitioner otherwise invents expensive reshards around the 4D
    reshapes (observed: GiB-scale all-gathers per layer on command-r).
    The out-projection contracts the sharded head axis, so the only
    collective left is its natural psum.
    """
    from jax.sharding import PartitionSpec as P
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    k = (x @ params["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (x @ params["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    if cfg.attn_explicit_shard and shard is not None:
        q = shard(q, P(("pod", "data"), None, "model", None))
        k = shard(k, P(("pod", "data"), None, None, None))
        v = shard(v, P(("pod", "data"), None, None, None))
    if cfg.use_bias:
        q = q + params["bq"].reshape(cfg.n_heads, cfg.hd)
        k = k + params["bk"].reshape(cfg.n_kv_heads, cfg.hd)
        v = v + params["bv"].reshape(cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


Q_CHUNK = 512  # q rows per attention chunk for long sequences


def _sdpa_block(qg, k, v, *, scale, q_start, causal, window,
                attn_softcap, kv_len, bf16_math=False,
                kv_heads_major=False):
    """One q-chunk of attention.  qg: [B, cq, Hkv, G, hd];
    k, v: [B, Sk, Hkv, hd]; q_start: absolute position of row 0.

    ``bf16_math`` (§Perf variant): bf16 matmul inputs with f32 MXU
    accumulation — never materializes an f32 copy of K/V (for decode
    that copy is the entire KV cache: 2x the cache read traffic,
    observed as the dominant memory term in the baseline)."""
    cq = qg.shape[1]
    Sk = k.shape[2] if kv_heads_major else k.shape[1]
    if not bf16_math:
        qg = qg.astype(jnp.float32)
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
    eq_k = "bqhgd,bhkd->bhgqk" if kv_heads_major else "bqhgd,bkhd->bhgqk"
    logits = jnp.einsum(eq_k, qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, attn_softcap)
    q_pos = jnp.arange(cq)[:, None] + q_start
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((cq, Sk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
        if window is not None:
            mask &= q_pos < (k_pos + window)
    if kv_len is not None:
        mask &= k_pos < kv_len
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    eq_v = "bhgqk,bhkd->bqhgd" if kv_heads_major else "bhgqk,bkhd->bqhgd"
    return jnp.einsum(eq_v, probs, v,
                      preferred_element_type=jnp.float32)


def _sdpa(q, k, v, *, scale, causal_offset=None, window=None,
          attn_softcap=None, kv_len=None, q_chunk: int = Q_CHUNK,
          bf16_math: bool = False, kv_heads_major: bool = False):
    """Scaled dot-product attention with GQA head-group broadcasting.

    q: [B, Sq, H, hd];  k, v: [B, Sk, Hkv, hd].
    causal_offset: absolute position of q row 0 (None = not causal).
    kv_len: number of valid kv entries (decode caches are padded).

    Long sequences are processed in q-chunks (lax.scan) so the logits
    transient is [B, Hkv, G, q_chunk, Sk] instead of the full quadratic
    [.., Sq, Sk] — the XLA-level counterpart of the Pallas flash kernel
    (repro.kernels.flash_attention), which replaces this on real TPU.
    """
    B, Sq, H, hd = q.shape
    if kv_heads_major:
        Hkv, Sk = k.shape[1], k.shape[2]
    else:
        Sk, Hkv = k.shape[1], k.shape[2]
    groups = H // Hkv
    qg = q.reshape(B, Sq, Hkv, groups, hd)
    causal = causal_offset is not None
    base = causal_offset if causal else 0

    if Sq > q_chunk and Sq % q_chunk == 0:
        nq = Sq // q_chunk
        qs = jnp.moveaxis(qg.reshape(B, nq, q_chunk, Hkv, groups, hd), 1, 0)

        def body(_, inp):
            q_c, i = inp
            out = _sdpa_block(q_c, k, v, scale=scale,
                              q_start=base + i * q_chunk, causal=causal,
                              window=window, attn_softcap=attn_softcap,
                              kv_len=kv_len, bf16_math=bf16_math,
                              kv_heads_major=kv_heads_major)
            return 0, out

        _, outs = jax.lax.scan(body, 0, (qs, jnp.arange(nq)))
        # outs: [nq, B, cq, Hkv, G, hd] -> [B, Sq, H, hd]
        out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hkv, groups, hd)
        return out.reshape(B, Sq, H, hd).astype(q.dtype)

    out = _sdpa_block(qg, k, v, scale=scale, q_start=base, causal=causal,
                      window=window, attn_softcap=attn_softcap,
                      kv_len=kv_len, bf16_math=bf16_math,
                      kv_heads_major=kv_heads_major)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def layer_window(cfg, layer_idx):
    """Effective sliding window for a layer.  ``layer_idx`` may be a traced
    scan index: local/global alternation is expressed with jnp.where so a
    single homogeneous layer scan lowers for gemma2."""
    if cfg.sliding_window is None:
        return None
    if not cfg.local_global_pattern:
        return cfg.sliding_window
    return jnp.where(layer_idx % 2 == 0, cfg.sliding_window, 1 << 30)


def self_attention(params, x, cfg, *, window=None,
                   positions: Optional[jnp.ndarray] = None,
                   cache: Optional[KVCache] = None,
                   return_cache: bool = False, shard=None):
    """Causal self-attention.

    * train / prefill: full sequence; if ``return_cache`` also returns a
      KVCache primed with the sequence (prefill path).
    * decode: ``cache`` given, x is [B, 1, D]; appends to the cache.
    """
    B, S, _ = x.shape
    scale = cfg.hd ** -0.5
    if cache is None:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q, k, v = _qkv(params, x, cfg, positions, shard=shard)
        out = _sdpa(q, k, v, scale=scale, causal_offset=0, window=window,
                    attn_softcap=cfg.attn_softcap,
                    bf16_math=cfg.attn_bf16_math)
        new_cache = KVCache(k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3),
                            jnp.asarray(S, jnp.int32)) \
            if return_cache else None
    else:
        pos = cache.length
        positions = pos[None, None] + jnp.zeros((B, S), jnp.int32)
        q, k, v = _qkv(params, x, cfg, positions)
        # cache layout [B, Hkv, S, hd]: the new token transposes (cheap,
        # S=1); the big cache is never transposed.
        k_all = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.transpose(0, 2, 1, 3), pos, axis=2)
        v_all = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.transpose(0, 2, 1, 3), pos, axis=2)
        out = _sdpa(q, k_all, v_all, scale=scale,
                    causal_offset=pos, window=window,
                    attn_softcap=cfg.attn_softcap, kv_len=pos + S,
                    bf16_math=cfg.attn_bf16_math, kv_heads_major=True)
        new_cache = KVCache(k_all, v_all, pos + S)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd) @ params["wo"]
    if cfg.use_bias:
        out = out + params["bo"]
    return (out, new_cache) if (return_cache or cache is not None) else out


def cross_attention(params, x, memory, cfg,
                    mem_cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None):
    """Cross-attention: q from x [B,S,D], kv from memory [B,M,Dm].

    ``mem_cache``: precomputed (k, v) of the memory (decode reuses it).
    Returns (out, (k, v)).
    """
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    if cfg.use_bias:
        q = q + params["bq"].reshape(cfg.n_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
    if mem_cache is None:
        M = memory.shape[1]
        k = (memory @ params["wk"]).reshape(B, M, cfg.n_kv_heads, cfg.hd)
        v = (memory @ params["wv"]).reshape(B, M, cfg.n_kv_heads, cfg.hd)
        if cfg.use_bias:
            k = k + params["bk"].reshape(cfg.n_kv_heads, cfg.hd)
            v = v + params["bv"].reshape(cfg.n_kv_heads, cfg.hd)
        if cfg.qk_norm:
            k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    else:
        k, v = mem_cache
    out = _sdpa(q, k, v, scale=cfg.hd ** -0.5,
                attn_softcap=cfg.attn_softcap,
                bf16_math=cfg.attn_bf16_math)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd) @ params["wo"]
    if cfg.use_bias:
        out = out + params["bo"]
    return out, (k, v)


# --------------------------------------------------------------------- #
# Parameter init
# --------------------------------------------------------------------- #
def init_attn_params(key, cfg, cross: bool = False,
                     dtype=jnp.bfloat16) -> Dict[str, Any]:
    from .layers import dense_init
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (D, Hkv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (D, Hkv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, D), dtype=dtype),
    }
    if cfg.use_bias:
        p.update(bq=jnp.zeros((H * hd,), dtype),
                 bk=jnp.zeros((Hkv * hd,), dtype),
                 bv=jnp.zeros((Hkv * hd,), dtype),
                 bo=jnp.zeros((D,), dtype))
    if cfg.qk_norm:
        p.update(q_norm=jnp.zeros((hd,), dtype),
                 k_norm=jnp.zeros((hd,), dtype))
    return p
