"""Model assembly for all assigned families.

Layers are *scanned* (weights stacked on a leading layer axis and run via
``jax.lax.scan``), which keeps HLO size and compile time independent of
depth — essential for 48-layer 778B dry-runs.  Heterogeneous depth
patterns become grouped scans:

  dense / moe / ssm : one homogeneous scan over all layers
  hybrid (zamba2)   : scan over groups of ``attn_every`` mamba layers,
                      one SHARED attn+MLP block applied per group
  vlm (llama3.2-v)  : scan over groups of ``cross_attn_every`` layers,
                      the last layer of each group cross-attends to the
                      stubbed image embeddings
  audio (whisper)   : encoder scan (non-causal) + decoder scan with
                      cross-attention to the encoder output

Entry points:
  init_params(cfg, key)                  (run under eval_shape for dry-run)
  forward(params, cfg, tokens, extra)    -> logits           (train/prefill)
  loss_fn(params, cfg, batch)            -> scalar
  init_decode_state(cfg, batch, max_len) -> state pytree
  prefill(params, cfg, tokens, extra)    -> (last_logits, state)
  decode_step(params, cfg, state, token) -> (logits, state)

``shard`` is an optional callable ``shard(x, PartitionSpec) -> x`` used to
pin activation/cache shardings (see repro.distributed.sharding).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from .attention import (KVCache, cross_attention, init_attn_params,
                        layer_window, self_attention)
from .layers import dense_init, embed, rms_norm, softcap, swiglu, unembed
from .moe import init_moe_params, moe_ffn, moe_ffn_ep
from .ssm import (SSMState, init_ssm_params, init_ssm_state,
                  ssm_block_decode, ssm_block_train)


def _noshard(x, spec):
    return x


# ===================================================================== #
# Parameter initialization                                              #
# ===================================================================== #
def _init_mlp(key, cfg, dtype=jnp.bfloat16):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_gate": dense_init(ks[0], (D, F), dtype=dtype),
         "w_up": dense_init(ks[1], (D, F), dtype=dtype),
         "w_down": dense_init(ks[2], (F, D), dtype=dtype)}
    if cfg.use_bias:
        p.update(b_gate=jnp.zeros((F,), dtype), b_up=jnp.zeros((F,), dtype),
                 b_down=jnp.zeros((cfg.d_model,), dtype))
    return p


def _init_attn_block(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 2)
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attn_params(ks[0], cfg, dtype=dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": _init_mlp(ks[1], cfg, dtype)}


def _init_moe_block(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 2)
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attn_params(ks[0], cfg, dtype=dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "moe": init_moe_params(ks[1], cfg, dtype)}


def _init_ssm_block(key, cfg, dtype=jnp.bfloat16):
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "ssm": init_ssm_params(key, cfg, dtype)}


def _init_cross_block(key, cfg, dtype=jnp.bfloat16):
    """VLM cross layer: self-attn + cross-attn + mlp."""
    ks = jax.random.split(key, 3)
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attn_params(ks[0], cfg, dtype=dtype),
            "lnx": jnp.zeros((cfg.d_model,), dtype),
            "xattn": init_attn_params(ks[1], cfg, dtype=dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": _init_mlp(ks[2], cfg, dtype)}


def _stack(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model),
                                    jnp.float32) * 0.01).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], (cfg.d_model, cfg.padded_vocab),
                                       dtype=dtype)
    fam = cfg.family
    if fam == "dense":
        params["blocks"] = _stack(lambda k: _init_attn_block(k, cfg, dtype),
                                  keys[2], cfg.n_layers)
    elif fam == "moe":
        params["blocks"] = _stack(lambda k: _init_moe_block(k, cfg, dtype),
                                  keys[2], cfg.n_layers)
    elif fam == "ssm":
        params["blocks"] = _stack(lambda k: _init_ssm_block(k, cfg, dtype),
                                  keys[2], cfg.n_layers)
    elif fam == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        params["blocks"] = _stack(lambda k: _init_ssm_block(k, cfg, dtype),
                                  keys[2], cfg.n_layers)
        params["shared"] = _init_attn_block(keys[3], cfg, dtype)  # ONE block
        del n_groups
    elif fam == "vlm":
        g = cfg.cross_attn_every
        n_groups = cfg.n_layers // g
        params["plain"] = _stack(lambda k: _init_attn_block(k, cfg, dtype),
                                 keys[2], n_groups * (g - 1))
        params["cross"] = _stack(lambda k: _init_cross_block(k, cfg, dtype),
                                 keys[3], n_groups)
        # reshape plain to [G, g-1, ...]
        params["plain"] = jax.tree.map(
            lambda a: a.reshape(n_groups, g - 1, *a.shape[1:]),
            params["plain"])
    elif fam == "audio":
        params["enc_blocks"] = _stack(
            lambda k: _init_attn_block(k, cfg, dtype), keys[2],
            cfg.n_enc_layers)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
        params["blocks"] = _stack(lambda k: _init_cross_block(k, cfg, dtype),
                                  keys[3], cfg.n_layers)
    else:
        raise ValueError(fam)
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def active_param_count(params, cfg: ArchConfig) -> int:
    """Active parameters per token (MoE counts top_k of n_experts)."""
    total = param_count(params)
    if not cfg.n_experts:
        return total
    expert = sum(int(x.size) for name in ("w_gate", "w_up", "w_down")
                 for x in jax.tree.leaves(
                     jax.tree.map(lambda a: a,
                                  params["blocks"]["moe"][name])))
    return total - expert + int(expert * cfg.top_k / cfg.n_experts)


# ===================================================================== #
# Blocks (training / full-sequence)                                     #
# ===================================================================== #
def _moe_apply(p, h, cfg, shard):
    """MoE FFN dispatch: shard_map expert-parallel path when the §Perf
    variant is on and a mesh is available (and the batch divides the
    data axes); otherwise the GSPMD baseline."""
    mesh = getattr(shard, "mesh", None)
    if cfg.moe_ep_shard_map and mesh is not None:
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp = 1
        for a in axes:
            dp *= mesh.shape[a]
        if h.shape[0] % dp == 0 and cfg.n_experts % mesh.shape.get(
                "model", 1) == 0:
            return moe_ffn_ep(p, h, cfg, mesh)
    return moe_ffn(p, h, cfg, constrain=shard)


def _mlp_apply(p, x, cfg):
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"],
                  p.get("b_gate"), p.get("b_up"), p.get("b_down"))


def _attn_block(p, x, cfg, layer_idx, shard, memory=None):
    w = layer_window(cfg, layer_idx)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + self_attention(p["attn"], h, cfg, window=w, shard=shard)
    if memory is not None and "xattn" in p:
        h = rms_norm(x, p["lnx"], cfg.norm_eps)
        out, _ = cross_attention(p["xattn"], h, memory, cfg)
        x = x + out
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        x = x + _moe_apply(p["moe"], h, cfg, shard)
    else:
        x = x + _mlp_apply(p["mlp"], h, cfg)
    return shard(x, P(("pod", "data"), "model", None))


def _enc_block(p, x, cfg, shard):
    """Non-causal encoder block (whisper)."""
    from .attention import _sdpa, _qkv
    B, S, _ = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p["attn"], h, cfg, positions)
    out = _sdpa(q, k, v, scale=cfg.hd ** -0.5,
                attn_softcap=cfg.attn_softcap,
                bf16_math=cfg.attn_bf16_math)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd) @ p["attn"]["wo"]
    if cfg.use_bias:
        out = out + p["attn"]["bo"]
    x = x + out
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _mlp_apply(p["mlp"], h, cfg)
    return shard(x, P(("pod", "data"), "model", None))


def _ssm_block(p, x, cfg, shard):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + ssm_block_train(p["ssm"], h, cfg)
    return shard(x, P(("pod", "data"), "model", None))


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)


def _scan_blocks(stack, x, body, cfg, n: int):
    idxs = jnp.arange(n)

    def wrapped(carry, inp):
        lp, idx = inp
        return body(lp, carry, idx), None

    wrapped = _remat(wrapped, cfg)
    x, _ = jax.lax.scan(wrapped, x, (stack, idxs))
    return x


# ===================================================================== #
# Forward (train / prefill logits)                                      #
# ===================================================================== #
def _hidden(params, cfg: ArchConfig, tokens, extra: Optional[Dict] = None,
            shard=_noshard):
    """tokens: [B, S] int32 -> final-norm hidden states [B, S, D]."""
    extra = extra or {}
    x = embed(tokens, params["embed"])
    x = shard(x, P(("pod", "data"), "model", None))
    fam = cfg.family

    if fam in ("dense", "moe"):
        x = _scan_blocks(params["blocks"], x,
                         lambda p, h, i: _attn_block(p, h, cfg, i, shard),
                         cfg, cfg.n_layers)
    elif fam == "ssm":
        x = _scan_blocks(params["blocks"], x,
                         lambda p, h, i: _ssm_block(p, h, cfg, shard),
                         cfg, cfg.n_layers)
    elif fam == "hybrid":
        g = cfg.attn_every
        n_groups = cfg.n_layers // g
        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, g, *a.shape[1:]), params["blocks"])
        shared = params["shared"]

        def group_body(gp, h, gi):
            def inner(h2, lp):
                return _ssm_block(lp, h2, cfg, shard), None
            h, _ = jax.lax.scan(inner, h, gp)
            return _attn_block(shared, h, cfg, gi, shard)

        x = _scan_blocks(grouped, x, group_body, cfg, n_groups)
    elif fam == "vlm":
        memory = extra["image_embeds"]
        g = cfg.cross_attn_every
        n_groups = cfg.n_layers // g

        def group_body(gp, h, gi):
            def inner(h2, lp):
                return _attn_block(lp, h2, cfg, gi, shard), None
            h, _ = jax.lax.scan(inner, h, gp["plain"])
            return _attn_block(gp["cross"], h, cfg, gi, shard, memory=memory)

        stack = {"plain": params["plain"], "cross": params["cross"]}
        x = _scan_blocks(stack, x, group_body, cfg, n_groups)
    elif fam == "audio":
        frames = extra["frame_embeds"]
        mem = frames

        def enc_body(h, lp):
            return _enc_block(lp, h, cfg, shard), None
        mem, _ = jax.lax.scan(enc_body, mem, params["enc_blocks"])
        mem = rms_norm(mem, params["enc_norm"], cfg.norm_eps)
        x = _scan_blocks(params["blocks"], x,
                         lambda p, h, i: _attn_block(p, h, cfg, i, shard,
                                                     memory=mem),
                         cfg, cfg.n_layers)
    else:
        raise ValueError(fam)

    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def forward(params, cfg: ArchConfig, tokens, extra: Optional[Dict] = None,
            shard=_noshard):
    """tokens: [B, S] int32 -> logits [B, S, V]."""
    x = _hidden(params, cfg, tokens, extra, shard)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"].T
    logits = unembed(x, table, cfg.logit_softcap, cfg.vocab_size)
    return shard(logits, P(("pod", "data"), None, "model"))


LOSS_CHUNK = 2048  # sequence positions per cross-entropy chunk


def _ce_chunk(table, h, labels, cfg):
    """Cross entropy for one sequence chunk.  h: [B, c, D] -> [B, c].

    GSPMD-friendly vocab-parallel form: the max is stop-gradient'ed
    (exact — a constant shift) and the gold logit is a one-hot
    contraction rather than take_along_axis, so with logits sharded
    P(batch, None, 'model') the partitioner emits only [B, c]-sized
    all-reduces over the model axis — never a logits all-gather."""
    logits = unembed(h, table, cfg.logit_softcap,
                     cfg.vocab_size).astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    return lse - gold


def loss_fn(params, cfg: ArchConfig, batch: Dict[str, Any], shard=_noshard):
    """Next-token cross entropy, chunked over the sequence.

    The [B, S, V] f32 logits (and their cotangent) never materialize:
    the unembed + logsumexp run per LOSS_CHUNK positions under
    jax.checkpoint, so peak loss-head memory is [B, chunk, V/TP] instead
    of [B, S, V/TP] — at 152k-256k vocabularies this is the difference
    between fitting a v5e and not.
    """
    h = _hidden(params, cfg, batch["tokens"], batch.get("extra"), shard)
    labels = batch["labels"]
    table = params["embed"] if cfg.tie_embeddings else params["unembed"].T
    B, S, _ = h.shape
    chunk = LOSS_CHUNK if (S % LOSS_CHUNK == 0 and S > LOSS_CHUNK) else S

    if chunk == S:
        return jnp.mean(_ce_chunk(table, h, labels, cfg))

    nc = S // chunk
    hs = jnp.moveaxis(h.reshape(B, nc, chunk, -1), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(acc, inp):
        h_c, l_c = inp
        return acc + jnp.sum(_ce_chunk(table, h_c, l_c, cfg)), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (B * S)


# ===================================================================== #
# Prefill                                                               #
# ===================================================================== #
def _pad_kv(kv_stack, max_len: int):
    """kv_stack: [L, B, Hkv, S, hd] -> padded to max_len on axis 3."""
    S = kv_stack.shape[3]
    if S == max_len:
        return kv_stack
    pad = [(0, 0)] * kv_stack.ndim
    pad[3] = (0, max_len - S)
    return jnp.pad(kv_stack, pad)


def prefill(params, cfg: ArchConfig, tokens, extra: Optional[Dict] = None,
            shard=_noshard, max_len: Optional[int] = None):
    """Process a full prompt; return (last-position logits [B, V], state).

    ``max_len`` sizes the KV caches for subsequent decoding (default:
    prompt length — the dry-run prefill cell)."""
    extra = extra or {}
    B, S = tokens.shape
    max_len = max_len or S
    x = embed(tokens, params["embed"])
    x = shard(x, P(("pod", "data"), "model", None))
    fam = cfg.family
    st: Dict[str, Any] = {}
    pos = jnp.asarray(S, jnp.int32)

    def attn_prefill_body(p, h, i, memory=None):
        w = layer_window(cfg, i)
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        out, kvc = self_attention(p["attn"], hn, cfg, window=w,
                                  return_cache=True, shard=shard)
        h = h + out
        cross = None
        if memory is not None and "xattn" in p:
            hn = rms_norm(h, p["lnx"], cfg.norm_eps)
            out, cross = cross_attention(p["xattn"], hn, memory, cfg)
            h = h + out
        hn = rms_norm(h, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            h = h + _moe_apply(p["moe"], hn, cfg, shard)
        else:
            h = h + _mlp_apply(p["mlp"], hn, cfg)
        return shard(h, P(("pod", "data"), "model", None)), kvc, cross

    if fam in ("dense", "moe"):
        idxs = jnp.arange(cfg.n_layers)

        def body(h, inp):
            lp, i = inp
            h, kvc, _ = attn_prefill_body(lp, h, i)
            return h, (kvc.k, kvc.v)

        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], idxs))
        st["kv"] = KVCache(_pad_kv(ks, max_len), _pad_kv(vs, max_len), pos)
    elif fam == "ssm":
        from .ssm import ssm_block_prefill

        def body(h, lp):
            hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
            out, s = ssm_block_prefill(lp["ssm"], hn, cfg)
            return shard(h + out, P(("pod", "data"), "model", None)), s

        x, states = jax.lax.scan(body, x, params["blocks"])
        st["ssm"] = states
    elif fam == "hybrid":
        from .ssm import ssm_block_prefill
        g = cfg.attn_every
        n_groups = cfg.n_layers // g
        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, g, *a.shape[1:]), params["blocks"])
        shared = params["shared"]
        gidx = jnp.arange(n_groups)

        def gbody(h, inp):
            gp, gi = inp

            def inner(h2, lp):
                hn = rms_norm(h2, lp["ln1"], cfg.norm_eps)
                out, s = ssm_block_prefill(lp["ssm"], hn, cfg)
                return shard(h2 + out, P(("pod", "data"), "model", None)), s

            h, states_g = jax.lax.scan(inner, h, gp)
            h, kvc, _ = attn_prefill_body(shared, h, gi)
            return h, (states_g, kvc.k, kvc.v)

        x, (states, ks, vs) = jax.lax.scan(gbody, x, (grouped, gidx))
        st["ssm"] = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), states)
        st["kv"] = KVCache(_pad_kv(ks, max_len), _pad_kv(vs, max_len), pos)
    elif fam == "vlm":
        memory = extra["image_embeds"]
        g = cfg.cross_attn_every
        n_groups = cfg.n_layers // g
        gidx = jnp.arange(n_groups)

        def gbody(h, inp):
            gp_plain, gp_cross, gi = inp

            def inner(h2, lp):
                h2, kvc, _ = attn_prefill_body(lp, h2, gi)
                return h2, (kvc.k, kvc.v)

            h, (ks_p, vs_p) = jax.lax.scan(inner, h, gp_plain)
            h, kvc, cross = attn_prefill_body(gp_cross, h, gi, memory=memory)
            ks = jnp.concatenate([ks_p, kvc.k[None]], axis=0)
            vs = jnp.concatenate([vs_p, kvc.v[None]], axis=0)
            return h, (ks, vs, cross[0], cross[1])

        x, (ks, vs, cks, cvs) = jax.lax.scan(
            gbody, x, (params["plain"], params["cross"], gidx))
        ks = ks.reshape(cfg.n_layers, *ks.shape[2:])
        vs = vs.reshape(cfg.n_layers, *vs.shape[2:])
        st["kv"] = KVCache(_pad_kv(ks, max_len), _pad_kv(vs, max_len), pos)
        st["cross_kv"] = (cks, cvs)
        st["memory"] = memory
    elif fam == "audio":
        mem = extra["frame_embeds"]

        def enc_body(h, lp):
            return _enc_block(lp, h, cfg, shard), None

        mem, _ = jax.lax.scan(enc_body, mem, params["enc_blocks"])
        mem = rms_norm(mem, params["enc_norm"], cfg.norm_eps)
        idxs = jnp.arange(cfg.n_layers)

        def body(h, inp):
            lp, i = inp
            h, kvc, cross = attn_prefill_body(lp, h, i, memory=mem)
            return h, (kvc.k, kvc.v, cross[0], cross[1])

        x, (ks, vs, cks, cvs) = jax.lax.scan(body, x,
                                             (params["blocks"], idxs))
        st["kv"] = KVCache(_pad_kv(ks, max_len), _pad_kv(vs, max_len), pos)
        st["cross_kv"] = (cks, cvs)
        st["memory"] = mem
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"].T
    logits = unembed(x[:, -1, :], table, cfg.logit_softcap, cfg.vocab_size)
    state = DecodeState(kv=st.get("kv"), ssm=st.get("ssm"),
                        cross_kv=st.get("cross_kv"),
                        memory=st.get("memory"), pos=pos)
    return logits, state


# ===================================================================== #
# Decode                                                                #
# ===================================================================== #
class DecodeState(NamedTuple):
    """Family-generic decode state; unused fields are empty pytrees."""
    kv: Any = None        # stacked KVCache arrays
    ssm: Any = None       # stacked SSMState
    cross_kv: Any = None  # stacked cross-attn (k, v)
    memory: Any = None    # encoder output / image embeddings
    pos: Any = None       # current position, int32 scalar


def _empty_kv(cfg, n: int, batch: int, max_len: int, dtype=jnp.bfloat16):
    # heads-major cache layout [L, B, Hkv, S, hd] (see attention.KVCache)
    return KVCache(
        k=jnp.zeros((n, batch, cfg.n_kv_heads, max_len, cfg.hd), dtype),
        v=jnp.zeros((n, batch, cfg.n_kv_heads, max_len, cfg.hd), dtype),
        length=jnp.zeros((), jnp.int32))


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> DecodeState:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return DecodeState(kv=_empty_kv(cfg, cfg.n_layers, batch, max_len,
                                        dtype),
                           pos=jnp.zeros((), jnp.int32))
    if fam == "ssm":
        states = jax.vmap(lambda _: init_ssm_state(cfg, batch, dtype))(
            jnp.arange(cfg.n_layers))
        return DecodeState(ssm=states, pos=jnp.zeros((), jnp.int32))
    if fam == "hybrid":
        states = jax.vmap(lambda _: init_ssm_state(cfg, batch, dtype))(
            jnp.arange(cfg.n_layers))
        n_groups = cfg.n_layers // cfg.attn_every
        return DecodeState(ssm=states,
                           kv=_empty_kv(cfg, n_groups, batch, max_len, dtype),
                           pos=jnp.zeros((), jnp.int32))
    if fam == "vlm":
        n_groups = cfg.n_layers // cfg.cross_attn_every
        M = cfg.n_image_tokens
        cross = (jnp.zeros((n_groups, batch, M, cfg.n_kv_heads, cfg.hd), dtype),
                 jnp.zeros((n_groups, batch, M, cfg.n_kv_heads, cfg.hd), dtype))
        return DecodeState(kv=_empty_kv(cfg, cfg.n_layers, batch, max_len,
                                        dtype),
                           cross_kv=cross, pos=jnp.zeros((), jnp.int32))
    if fam == "audio":
        M = cfg.n_audio_frames
        cross = (jnp.zeros((cfg.n_layers, batch, M, cfg.n_kv_heads, cfg.hd),
                           dtype),
                 jnp.zeros((cfg.n_layers, batch, M, cfg.n_kv_heads, cfg.hd),
                           dtype))
        return DecodeState(kv=_empty_kv(cfg, cfg.n_layers, batch, max_len,
                                        dtype),
                           cross_kv=cross, pos=jnp.zeros((), jnp.int32))
    raise ValueError(fam)


def _attn_block_decode(p, x, cfg, layer_idx, cache: KVCache, shard,
                       cross_kv=None):
    w = layer_window(cfg, layer_idx)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    out, new_cache = self_attention(p["attn"], h, cfg, window=w, cache=cache)
    x = x + out
    if cross_kv is not None:
        h = rms_norm(x, p["lnx"], cfg.norm_eps)
        out, _ = cross_attention(p["xattn"], h, None, cfg, mem_cache=cross_kv)
        x = x + out
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        x = x + _moe_apply(p["moe"], h, cfg, shard)
    else:
        x = x + _mlp_apply(p["mlp"], h, cfg)
    return x, new_cache


def _ssm_block_decode(p, x, cfg, state: SSMState, shard):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    out, new_state = ssm_block_decode(p["ssm"], h, cfg, state)
    return x + out, new_state


def decode_step(params, cfg: ArchConfig, state: DecodeState, tokens,
                shard=_noshard):
    """tokens: [B] int32 -> (logits [B, V], new state)."""
    x = embed(tokens[:, None], params["embed"])
    x = shard(x, P(("pod", "data"), "model", None))
    fam = cfg.family
    new = {}

    if fam in ("dense", "moe"):
        idxs = jnp.arange(cfg.n_layers)

        def body(h, inp):
            lp, k_l, v_l, i = inp
            cache = KVCache(k_l, v_l, state.pos)
            h, nc = _attn_block_decode(lp, h, cfg, i, cache, shard)
            return h, (nc.k, nc.v)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["blocks"], state.kv.k, state.kv.v, idxs))
        new["kv"] = KVCache(ks, vs, state.pos + 1)
    elif fam == "ssm":
        def body(h, inp):
            lp, st_l = inp
            h, ns = _ssm_block_decode(lp, h, cfg, st_l, shard)
            return h, ns

        x, states = jax.lax.scan(body, x, (params["blocks"], state.ssm))
        new["ssm"] = states
    elif fam == "hybrid":
        g = cfg.attn_every
        n_groups = cfg.n_layers // g
        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, g, *a.shape[1:]), params["blocks"])
        sstates = jax.tree.map(
            lambda a: a.reshape(n_groups, g, *a.shape[1:]), state.ssm)
        shared = params["shared"]
        gidx = jnp.arange(n_groups)

        def gbody(h, inp):
            gp, st_g, k_g, v_g, gi = inp

            def inner(h2, inp2):
                lp, st_l = inp2
                h2, ns = _ssm_block_decode(lp, h2, cfg, st_l, shard)
                return h2, ns

            h, states_g = jax.lax.scan(inner, h, (gp, st_g))
            cache = KVCache(k_g, v_g, state.pos)
            h, nc = _attn_block_decode(shared, h, cfg, gi, cache, shard)
            return h, (states_g, nc.k, nc.v)

        x, (states, ks, vs) = jax.lax.scan(
            gbody, x, (grouped, sstates, state.kv.k, state.kv.v, gidx))
        new["ssm"] = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), states)
        new["kv"] = KVCache(ks, vs, state.pos + 1)
    elif fam == "vlm":
        g = cfg.cross_attn_every
        n_groups = cfg.n_layers // g
        kv = state.kv
        kv_g = jax.tree.map(
            lambda a: a.reshape(n_groups, g, *a.shape[1:]), (kv.k, kv.v))
        gidx = jnp.arange(n_groups)

        def gbody(h, inp):
            gp_plain, gp_cross, k_g, v_g, ck, cv, gi = inp
            # k_g, v_g: [g, B, S, Hkv, hd] — first g-1 for the plain layers,
            # last one for the cross layer's self-attention.

            def inner(h2, inp2):
                lp, k_l, v_l = inp2
                cache = KVCache(k_l, v_l, state.pos)
                h2, nc = _attn_block_decode(lp, h2, cfg, gi, cache, shard)
                return h2, (nc.k, nc.v)

            h, (ks_p, vs_p) = jax.lax.scan(
                inner, h, (gp_plain, k_g[:g - 1], v_g[:g - 1]))
            cache = KVCache(k_g[g - 1], v_g[g - 1], state.pos)
            h, nc = _attn_block_decode(gp_cross, h, cfg, gi, cache, shard,
                                       cross_kv=(ck, cv))
            ks = jnp.concatenate([ks_p, nc.k[None]], axis=0)
            vs = jnp.concatenate([vs_p, nc.v[None]], axis=0)
            return h, (ks, vs)

        x, (ks, vs) = jax.lax.scan(
            gbody, x, (params["plain"], params["cross"],
                       kv_g[0], kv_g[1],
                       state.cross_kv[0], state.cross_kv[1], gidx))
        new["kv"] = KVCache(ks.reshape(cfg.n_layers, *ks.shape[2:]),
                            vs.reshape(cfg.n_layers, *vs.shape[2:]),
                            state.pos + 1)
        new["cross_kv"] = state.cross_kv
        new["memory"] = state.memory
    elif fam == "audio":
        idxs = jnp.arange(cfg.n_layers)

        def body(h, inp):
            lp, k_l, v_l, ck, cv, i = inp
            cache = KVCache(k_l, v_l, state.pos)
            h, nc = _attn_block_decode(lp, h, cfg, i, cache, shard,
                                       cross_kv=(ck, cv))
            return h, (nc.k, nc.v)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["blocks"], state.kv.k, state.kv.v,
                      state.cross_kv[0], state.cross_kv[1], idxs))
        new["kv"] = KVCache(ks, vs, state.pos + 1)
        new["cross_kv"] = state.cross_kv
        new["memory"] = state.memory
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"].T
    logits = unembed(x[:, 0, :], table, cfg.logit_softcap, cfg.vocab_size)
    return logits, DecodeState(kv=new.get("kv"), ssm=new.get("ssm"),
                               cross_kv=new.get("cross_kv"),
                               memory=new.get("memory"),
                               pos=(new["kv"].length if "kv" in new
                                    else state.pos + 1))
