"""Shared model layers: RMSNorm, rotary embeddings, SwiGLU MLP, softcap,
embeddings.  Pure-functional JAX; parameters are plain dict pytrees."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with f32 accumulation for the variance only.

    The input is deliberately NOT upcast wholesale: an f32 copy of the
    residual stream is exactly the tensor XLA would hoist into the
    remat-saved layer stack (observed: a 14 GiB f32[L,B,S,D] buffer on
    the train dry-run).  Keeping x in its storage dtype and folding the
    f32 rsqrt back down keeps the saved stack in bf16.
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    w = (1.0 + weight.astype(jnp.float32)).astype(x.dtype)
    return x * inv * w


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    angles = angles[..., :, None, :]                    # [..., S, 1, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray,
           b_gate=None, b_up=None, b_down=None) -> jnp.ndarray:
    g = x @ w_gate
    u = x @ w_up
    if b_gate is not None:
        g = g + b_gate
        u = u + b_up
    h = jax.nn.silu(g) * u
    out = h @ w_down
    if b_down is not None:
        out = out + b_down
    return out


def embed(tokens: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jnp.ndarray, table: jnp.ndarray,
            cap: Optional[float] = None,
            valid: Optional[int] = None) -> jnp.ndarray:
    """x: [..., D]; table: [Vp, D] (tied) -> logits [..., Vp].

    ``valid``: real vocabulary size — embedding tables are padded to a
    multiple of 256 so the vocab axis shards evenly over the model axis;
    padded logit columns are masked to a large negative."""
    logits = x @ table.T
    logits = softcap(logits, cap)
    Vp = logits.shape[-1]
    if valid is not None and Vp > valid:
        ids = jax.lax.broadcasted_iota(jnp.int32, (Vp,), 0)
        logits = jnp.where(ids < valid, logits, -2.0 ** 30)
    return logits


# --------------------------------------------------------------------- #
# Parameter initialization helpers
# --------------------------------------------------------------------- #
def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16):
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.bfloat16):
    return jnp.zeros(shape, dtype=dtype)
