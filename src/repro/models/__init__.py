"""Composable model substrate for the assigned architectures."""

from .attention import KVCache, layer_window
from .model import (DecodeState, active_param_count, decode_step, forward,
                    init_decode_state, init_params, loss_fn, param_count,
                    prefill)
from .ssm import SSMState

__all__ = [
    "KVCache", "layer_window", "DecodeState", "active_param_count",
    "decode_step", "forward", "init_decode_state", "init_params", "loss_fn",
    "param_count", "prefill", "SSMState",
]
