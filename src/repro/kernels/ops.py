"""Jit'd public wrappers around the Pallas kernels.

Each op is differentiable via ``jax.custom_vjp``: the forward pass runs
the Pallas kernel; the backward pass recomputes through the pure-jnp
reference (flash-style recompute — no extra residuals beyond the inputs).
``interpret=True`` is threaded through for CPU validation; on TPU leave
it False.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .ssd_scan import ssd_scan


# --------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def attention_op(q, k, v, causal=True, window=None, softcap=None,
                 interpret=False):
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, interpret=interpret)


def _attn_fwd(q, k, v, causal, window, softcap, interpret):
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, interpret=interpret)
    return out, (q, k, v)


def _attn_bwd(causal, window, softcap, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(
            q_, k_, v_, causal=causal, window=window, softcap=softcap),
        q, k, v)
    return vjp(g)


attention_op.defvjp(_attn_fwd, _attn_bwd)


# --------------------------------------------------------------------- #
# SSD scan
# --------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssd_op(x, dt, A, Bm, Cm, interpret=False):
    return ssd_scan(x, dt, A, Bm, Cm, interpret=interpret)


def _ssd_fwd(x, dt, A, Bm, Cm, interpret):
    return ssd_scan(x, dt, A, Bm, Cm, interpret=interpret), \
        (x, dt, A, Bm, Cm)


def _ssd_bwd(interpret, res, g):
    x, dt, A, Bm, Cm = res
    _, vjp = jax.vjp(
        lambda *args: ref.ssd_ref(*args)[0], x, dt, A, Bm, Cm)
    return vjp(g)


ssd_op.defvjp(_ssd_fwd, _ssd_bwd)
