"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid: (B*H, L/chunk).  The chunk axis is innermost/sequential, so the
inter-chunk recurrent state [P, N] lives in f32 VMEM scratch across grid
steps (the standard TPU sequential-grid carry pattern).  Per chunk:

  intra-chunk  : (C B^T ⊙ L) X — two [cl x cl] / [cl x N] matmuls on the
                 MXU (cl = 128, N = ssm_state, hardware-aligned),
  inter-chunk  : C (decay ⊙ state) + state update via one outer-product
                 matmul.

VMEM per step (cl=128, P=64, N=128): x + B + C + L + att + state
≈ 200 KiB f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
            chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # [cl, P]
    dt = dt_ref[0].astype(jnp.float32)        # [cl, 1] (lane-collapsed)
    a = a_ref[0, 0]                           # scalar A_h (negative)
    Bm = b_ref[0].astype(jnp.float32)         # [cl, N]
    Cm = c_ref[0].astype(jnp.float32)         # [cl, N]

    dA = dt[:, 0] * a                         # [cl]
    dA_cs = jnp.cumsum(dA)                    # [cl]
    xs = x * dt                               # input scaling by dt

    # ---- intra-chunk quadratic term ----
    seg = dA_cs[:, None] - dA_cs[None, :]     # [cl, cl]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    att = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(att * L, xs, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # ---- inter-chunk contribution from the carried state ----
    state = state_ref[...]                    # [P, N]
    y_inter = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = y + y_inter * jnp.exp(dA_cs)[:, None]
    y_ref[0] = y.astype(y_ref.dtype)

    # ---- state update ----
    decay_end = jnp.exp(dA_cs[-1] - dA_cs)    # [cl]
    new_contrib = jax.lax.dot_general(
        xs * decay_end[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [P, N]
    state_ref[...] = state * jnp.exp(dA_cs[-1]) + new_contrib


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128,
             interpret: bool = False):
    """Chunked SSD scan.

    x: [B, L, H, P]; dt: [B, L, H] (softplus'ed); A: [H] (negative);
    Bm, Cm: [B, L, N] -> y: [B, L, H, P]."""
    Bsz, Ln, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, Ln)
    assert Ln % chunk == 0
    nc = Ln // chunk

    xt = jnp.moveaxis(x, 2, 1).reshape(Bsz * H, Ln, P)
    dtt = jnp.moveaxis(dt, 2, 1).reshape(Bsz * H, Ln, 1)
    a2 = jnp.broadcast_to(A[None, :], (Bsz, H)).reshape(Bsz * H, 1)
    a2 = a2.astype(jnp.float32)

    kernel = functools.partial(_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(Bsz * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci, H=H: (bh // H, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci, H=H: (bh // H, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz * H, Ln, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a2, Bm, Cm)
    return jnp.moveaxis(out.reshape(Bsz, H, Ln, P), 1, 2)
