"""Flash attention — Pallas TPU kernel with explicit BlockSpec VMEM tiling.

Streams KV blocks through VMEM with an online softmax; the (Sq x Sk)
logit matrix never materializes in HBM.  Supports causal masking, GQA
head grouping (via the k/v BlockSpec index maps), sliding windows
(gemma2 local layers) and attention-logit softcap.

Grid: (B*H, Sq/bq, Sk/bk) — the kv axis is innermost, so the f32
accumulator, row max and row sum live in VMEM scratch across kv steps.
Fully-masked (q, k) block pairs are skipped with ``pl.when`` (their grid
step still issues, but no MXU work runs — on TPU this prunes ~half the
FLOPs for causal attention and almost everything outside a sliding
window).

VMEM working set per step (bq = bk = 128, d = 128, f32 accum):
q (128x128x4) + k + v + acc + p ≈ 320 KiB — comfortably inside the
~16 MiB VMEM budget, with room for the double-buffered pipeline.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30
LANES = 128  # TPU lane width: scratch vectors are replicated to 2D


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], bq: int, bk: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    sq = pl.num_programs(1) * bq
    q_start = qi * bq + (sk - sq)          # right-aligned absolute position
    k_start = ki * bk

    run = True
    if causal:
        # skip blocks entirely above the diagonal
        run = jnp.logical_and(run, q_start + bq - 1 >= k_start)
    if window is not None:
        # skip blocks entirely older than the window
        run = jnp.logical_and(run, q_start < k_start + bk - 1 + window)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)   # [bq, d]
        k = k_ref[0].astype(jnp.float32)   # [bk, d]
        v = v_ref[0].astype(jnp.float32)   # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos < kpos + window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                                   # [bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])                        # [bq, bk]
        l_ref[...] = (l_ref[...] * alpha[:, None] +
                      jnp.sum(p, axis=1)[:, None])
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        # Rows with no valid key (possible only without causal/window) keep
        # l = 0; guard the division.
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [B, H, Sq, d]; k, v: [B, Hkv, Sk, d] -> [B, H, Sq, d]."""
    B, H, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert H % Hkv == 0, "GQA requires H % Hkv == 0"
    G = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    scale = scale if scale is not None else d ** -0.5

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=block_q, bk=block_k, sk=Sk)

    grid = (B * H, Sq // block_q, Sk // block_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d),
                         lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki: ((bh // H) * Hkv
                                             + (bh % H) // G, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki: ((bh // H) * Hkv
                                             + (bh % H) // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(B * H, Sq, d),
      k.reshape(B * Hkv, Sk, d),
      v.reshape(B * Hkv, Sk, d))
    return out.reshape(B, H, Sq, d)
