"""Flash-decode — single-token GQA attention against a KV cache.

The §Perf decode analysis (EXPERIMENTS.md cell 3) shows decode is bound
by cache streaming plus whatever the compiler materializes around it
(layout copies, f32 casts).  This kernel is the TPU endgame for that
term: it streams the heads-major cache [B, Hkv, S, hd] through VMEM in
blocks with an online softmax, reading each cache byte exactly once in
its storage dtype — no transposes, no f32 cache copies, no [S]-sized
logits in HBM.

Grid: (B * Hkv, S/block) — the cache-block axis is innermost, carrying
the f32 accumulator / running max / running sum for all G=H/Hkv query
heads of the group in VMEM scratch.  ``kv_len`` masks the padded tail.

q: [B, H, hd]; k, v: [B, Hkv, S, hd]; kv_len: [] -> out: [B, H, hd].
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30
LANES = 128


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_s: int, groups: int):
    si = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[0]
    s_start = si * block_s

    @pl.when(s_start < kv_len)
    def _step():
        q = q_ref[0].astype(jnp.float32)          # [G, hd]
        k = k_ref[0].astype(jnp.float32)          # [block_s, hd]
        v = v_ref[0].astype(jnp.float32)          # [block_s, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = s_start + jax.lax.broadcasted_iota(jnp.int32,
                                                 (groups, block_s), 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)   # mask padded tail
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = (l_ref[...] * alpha[:, None] +
                      jnp.sum(p, axis=1)[:, None])
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(si == ns - 1)
    def _finalize():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, kv_len, *, scale: Optional[float] = None,
                     block_s: int = 512, interpret: bool = False):
    """q: [B, H, hd]; k, v: [B, Hkv, S, hd] (heads-major cache);
    kv_len: [] int32 -> out: [B, H, hd]."""
    B, H, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    G = H // Hkv
    block_s = min(block_s, S)
    assert S % block_s == 0
    scale = scale if scale is not None else hd ** -0.5

    kernel = functools.partial(_kernel, scale=scale, block_s=block_s,
                               groups=G)
    grid = (B * Hkv, S // block_s)
    kv_len_arr = jnp.asarray(kv_len, jnp.int32).reshape(1)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, hd), lambda bh, si: (bh, 0, 0)),
            pl.BlockSpec((1, block_s, hd), lambda bh, si: (bh, si, 0)),
            pl.BlockSpec((1, block_s, hd), lambda bh, si: (bh, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda bh, si: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, LANES), jnp.float32),
            pltpu.VMEM((G, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len_arr,
      q.reshape(B, Hkv, G, hd).reshape(B * Hkv, G, hd),
      k.reshape(B * Hkv, S, hd),
      v.reshape(B * Hkv, S, hd))
    return out.reshape(B, Hkv, G, hd).reshape(B, H, hd)
