"""Jitted combiner-round bodies: the VectorApply seam's compute side.

The paper's combiner holds d announced requests when it commits a
round; the repo's thesis (ROADMAP "Combining-as-vectorization") is that
this batch should execute as ONE compiled kernel instead of d
interpreted Python calls.  This module holds those kernels: for each
array-valued sequential object (counter, heap, bounded queue/stack,
response log, checkpoint cell) the round body is a pure function over a
packed announcement array, compiled once per (kind, op) signature with
``jax.jit`` and driven by ``lax.scan`` in announcement order — the
haliax ``Stacked``/``hax.scan`` pattern (SNIPPETS.md §§2-3): compile
once, scan over homogeneous elements instead of unrolling.

Contract with ``SeqObject.vector_apply`` (core/objects.py):

  * Exactness: a kernel must produce byte-identical state words and
    responses to the per-op Python loop, or the caller must fall back.
    Kernels therefore run in 64-bit (``jax.experimental.enable_x64``
    scoped to this module's calls — the model substrate stays f32) and
    the packing guards reject anything that is not a plain Python int
    (or float, for the AtomicFloat kernel): rich payloads, huge ints,
    None — all take the eager path.  One documented wrinkle: ``bool``
    payloads pack as ints (bool subclasses int), so a ``True`` stored
    through the eager path decodes as ``1`` through the vector path;
    int-keyed workloads (every bench and property test) are unaffected.
  * NVM counters: kernels never touch NVM.  The caller gathers state
    with ``read_range`` and scatters with ``write_range`` — volatile
    accessors that cost zero persistence instructions and zero modeled
    time, so the round's persistence sentence (and the gated modeled
    trajectory) is untouched by vectorization.
  * Availability is gated: no jax in the environment means
    ``available()`` is False and every entry returns None (callers
    fall back to the per-op loop).

Kernels are cached in ``_KERNELS`` keyed by kind+op name; ``jax.jit``'s
own cache handles shape/dtype retraces (batch size d and state width
vary per instance).
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

_JAX = None          # None = not probed, False = unavailable, tuple = ok
_KERNELS: dict = {}


def _jx():
    global _JAX
    if _JAX is None:
        try:
            import jax
            import jax.numpy as jnp
            from jax import lax
            from jax.experimental import enable_x64
            _JAX = (jax, jnp, lax, enable_x64)
        except Exception:            # pragma: no cover - env without jax
            _JAX = False
    return _JAX


def available() -> bool:
    """True when the jitted round bodies can run (jax importable)."""
    return bool(_jx())


def kernel_calls() -> int:
    """Total jitted-round invocations so far (tests assert the vector
    path actually engaged rather than silently falling back)."""
    return _CALLS[0]


_CALLS = [0]


# ------------------------------------------------------------------ #
# packing guards                                                     #
# ------------------------------------------------------------------ #
def pack_ints(values: Sequence[Any]) -> Optional[np.ndarray]:
    """Batch args as int64, or None if any element is not a plain int
    (a bignum outside int64 range must decline, not raise)."""
    if not all(type(v) is int or type(v) is bool for v in values):
        return None
    try:
        return np.asarray(values, dtype=np.int64)
    except OverflowError:
        return None


def pack_floats(values: Sequence[Any]) -> Optional[np.ndarray]:
    if not all(type(v) is float for v in values):
        return None
    return np.asarray(values, dtype=np.float64)


def pack_state(words: Sequence[Any]) -> Optional[np.ndarray]:
    """State words as int64 — relies on numpy's inference: a list with
    any float/None/str/bignum element does not infer to int64."""
    try:
        arr = np.asarray(words)
    except (TypeError, ValueError, OverflowError):  # pragma: no cover
        return None
    return arr if arr.dtype == np.int64 else None


def pack_state_f64(words: Sequence[Any]) -> Optional[np.ndarray]:
    if not all(type(v) is float for v in words):
        return None
    return np.asarray(words, dtype=np.float64)


# ------------------------------------------------------------------ #
# kernel builders (pure functions of packed arrays)                  #
# ------------------------------------------------------------------ #
def _build(name: str, builder):
    fn = _KERNELS.get(name)
    if fn is None:
        jax, jnp, lax, x64 = _jx()
        with x64():
            fn = jax.jit(builder(jnp, lax))
        _KERNELS[name] = fn
    return fn


def _run(name: str, builder, *args):
    """Invoke a cached kernel under the x64 scope (dispatch must see the
    same dtypes tracing saw) and return numpy results."""
    jx = _jx()
    if not jx:
        return None
    _jax, _jnp, _lax, x64 = jx
    fn = _build(name, builder)
    with x64():
        out = fn(*args)
    _CALLS[0] += 1
    return tuple(np.asarray(o) for o in out)


def _faa_builder(jnp, lax):
    # int64 addition is associative and exact, so the sequential scan
    # collapses to a cumulative sum — each op's response is the value
    # before its own delta.  (MUL below must stay a true scan: float
    # products are order-sensitive and the contract is byte-exactness.)
    def k(v, xs):
        tot = jnp.cumsum(xs)
        return v + tot[-1], v + (tot - xs)
    return k


def _mul_builder(jnp, lax):
    def k(v, xs):
        def step(c, x):
            return c * x, c
        c, outs = lax.scan(step, v, xs)
        return c, outs
    return k


def _heap_insert_builder(jnp, lax):
    def k(arr, size, xs):
        cap = arr.shape[0]

        def sift_up(arr, i):
            def cond(c):
                a, j = c
                p = (j - 1) // 2
                return (j > 0) & (a[p] > a[j])

            def body(c):
                a, j = c
                p = (j - 1) // 2
                hi, lo = a[p], a[j]
                return a.at[p].set(lo).at[j].set(hi), p

            arr, _ = lax.while_loop(cond, body, (arr, i))
            return arr

        def step(carry, x):
            arr, size = carry
            full = size >= cap
            inserted = sift_up(arr.at[size].set(x), size)
            arr2 = jnp.where(full, arr, inserted)
            size2 = jnp.where(full, size, size + 1)
            return (arr2, size2), jnp.where(full, 0, 1)

        (arr, size), ok = lax.scan(step, (arr, size), xs)
        return arr, size, ok
    return k


def _heap_delete_builder(jnp, lax):
    def k(arr, size, xs):
        def step(carry, _x):
            arr, size = carry
            empty = size == 0
            top = arr[0]
            last = arr[jnp.maximum(size - 1, 0)]
            size2 = jnp.maximum(size - 1, 0)

            def smallest(a, i):
                l, r = 2 * i + 1, 2 * i + 2
                s = jnp.where((l < size2) & (a[l] < a[i]), l, i)
                s = jnp.where((r < size2) & (a[r] < a[s]), r, s)
                return s

            def cond(c):
                a, i = c
                return smallest(a, i) != i

            def body(c):
                a, i = c
                s = smallest(a, i)
                hi, lo = a[i], a[s]
                return a.at[i].set(lo).at[s].set(hi), s

            # the eager loop only moves `last` down when the heap stays
            # non-empty; size2 == 0 leaves the array words untouched
            sifted, _ = lax.while_loop(
                cond, body, (arr.at[0].set(last), jnp.int64(0)))
            arr2 = jnp.where(empty | (size2 == 0), arr, sifted)
            return (arr2, size2), (top, jnp.where(empty, 0, 1))

        (arr, size), (tops, ok) = lax.scan(step, (arr, size), xs)
        return arr, size, tops, ok
    return k


def _queue_builder(enq: bool):
    def builder(jnp, lax):
        if enq:
            def step_factory(cap):
                def step(carry, x):
                    arr, head, tail = carry
                    full = tail - head >= cap
                    arr2 = jnp.where(full, arr, arr.at[tail % cap].set(x))
                    tail2 = jnp.where(full, tail, tail + 1)
                    return (arr2, head, tail2), jnp.where(full, 0, 1)
                return step

            def k(arr, head, tail, xs):
                (arr, head, tail), ok = lax.scan(
                    step_factory(arr.shape[0]), (arr, head, tail), xs)
                return arr, head, tail, ok
        else:
            def k(arr, head, tail, xs):
                cap = arr.shape[0]

                def step(carry, _x):
                    arr, head, tail = carry
                    empty = head == tail
                    v = arr[head % cap]
                    head2 = jnp.where(empty, head, head + 1)
                    return (arr, head2, tail), (v, jnp.where(empty, 0, 1))

                (arr, head, tail), (vals, ok) = lax.scan(
                    step, (arr, head, tail), xs)
                return arr, head, tail, vals, ok
        return k
    return builder


def _stack_builder(push: bool):
    def builder(jnp, lax):
        if push:
            def k(arr, size, xs):
                cap = arr.shape[0]

                def step(carry, x):
                    arr, size = carry
                    full = size >= cap
                    arr2 = jnp.where(full, arr, arr.at[size].set(x))
                    size2 = jnp.where(full, size, size + 1)
                    return (arr2, size2), jnp.where(full, 0, 1)

                (arr, size), ok = lax.scan(step, (arr, size), xs)
                return arr, size, ok
        else:
            def k(arr, size, xs):
                def step(carry, _x):
                    arr, size = carry
                    empty = size == 0
                    v = arr[jnp.maximum(size - 1, 0)]
                    size2 = jnp.maximum(size - 1, 0)
                    return (arr, size2), (v, jnp.where(empty, 0, 1))

                (arr, size), (vals, ok) = lax.scan(step, (arr, size), xs)
                return arr, size, vals, ok
        return k
    return builder


def _log_builder(jnp, lax):
    # The log's resp words can hold rich (non-packable) payloads from
    # earlier eager RECORDs, so this kernel never reads existing state:
    # it scans the batch into dense last-write-wins (seq, resp, touched)
    # arrays and the caller scatters only the touched client words.
    def k(seqs, resps, touched, cs, ss, rs):
        def step(carry, x):
            seqs, resps, touched = carry
            c, s, r = x
            return (seqs.at[c].set(s), resps.at[c].set(r),
                    touched.at[c].set(1)), r

        (seqs, resps, touched), outs = lax.scan(
            step, (seqs, resps, touched), (cs, ss, rs))
        return seqs, resps, touched, outs
    return k


def _ckpt_builder(jnp, lax):
    # The existing payload word may be a rich (or None) object, so the
    # kernel never reads it: the caller only overwrites the pair when
    # some batch element advanced the step, and then the winning
    # payload comes from the batch itself.
    def k(step0, steps, payloads):
        def step(carry, x):
            st, pl, advanced = carry
            s, p = x
            adv = s > st
            st2 = jnp.where(adv, s, st)
            return (st2, jnp.where(adv, p, pl), advanced | adv), st2

        (st, pl, advanced), outs = lax.scan(
            step, (step0, jnp.int64(0), False), (steps, payloads))
        return st, pl, advanced, outs
    return k


# ------------------------------------------------------------------ #
# per-structure entry points (numpy in, numpy out, None = fall back) #
# ------------------------------------------------------------------ #
def faa_round(value: Any, deltas: Sequence[Any]):
    if type(value) is not int:
        return None
    xs = pack_ints(deltas)
    if xs is None:
        return None
    out = _run("counter.FAA", _faa_builder, np.int64(value), xs)
    if out is None:
        return None
    v, outs = out
    return int(v), outs.tolist()


def mul_round(value: Any, factors: Sequence[Any]):
    if type(value) is not float:
        return None
    xs = pack_floats(factors)
    if xs is None:
        return None
    out = _run("float.MUL", _mul_builder, np.float64(value), xs)
    if out is None:
        return None
    v, outs = out
    return float(v), outs.tolist()


def heap_round(arr_words: Sequence[Any], size: Any, func: str,
               args: Sequence[Any]):
    """One homogeneous heap round (HINSERT or HDELETEMIN) over the full
    key array.  Returns (new_words, new_size, responses) or None."""
    if type(size) is not int:
        return None
    arr = pack_state(arr_words)
    if arr is None:
        return None
    if func == "HINSERT":
        xs = pack_ints(args)
        if xs is None:
            return None
        out = _run("heap.HINSERT", _heap_insert_builder,
                   arr, np.int64(size), xs)
        if out is None:
            return None
        arr2, size2, ok = out
        return arr2.tolist(), int(size2), [bool(o) for o in ok]
    if func == "HDELETEMIN":
        xs = np.zeros(len(args), dtype=np.int64)
        out = _run("heap.HDELETEMIN", _heap_delete_builder,
                   arr, np.int64(size), xs)
        if out is None:
            return None
        arr2, size2, tops, ok = out
        resps = [int(t) if o else None for t, o in zip(tops, ok)]
        return arr2.tolist(), int(size2), resps
    return None


def queue_round(ring_words: Sequence[Any], head: Any, tail: Any,
                func: str, args: Sequence[Any]):
    if type(head) is not int or type(tail) is not int:
        return None
    arr = pack_state(ring_words)
    if arr is None:
        return None
    if func == "ENQ":
        xs = pack_ints(args)
        if xs is None:
            return None
        out = _run("queue.ENQ", _queue_builder(True),
                   arr, np.int64(head), np.int64(tail), xs)
        if out is None:
            return None
        arr2, h2, t2, ok = out
        resps: List[Any] = ["ACK" if o else False for o in ok]
        return arr2.tolist(), int(h2), int(t2), resps
    if func == "DEQ":
        xs = np.zeros(len(args), dtype=np.int64)
        out = _run("queue.DEQ", _queue_builder(False),
                   arr, np.int64(head), np.int64(tail), xs)
        if out is None:
            return None
        arr2, h2, t2, vals, ok = out
        resps = [int(v) if o else None for v, o in zip(vals, ok)]
        return arr2.tolist(), int(h2), int(t2), resps
    return None


def stack_round(arr_words: Sequence[Any], size: Any, func: str,
                args: Sequence[Any]):
    if type(size) is not int:
        return None
    arr = pack_state(arr_words)
    if arr is None:
        return None
    if func == "PUSH":
        xs = pack_ints(args)
        if xs is None:
            return None
        out = _run("stack.PUSH", _stack_builder(True),
                   arr, np.int64(size), xs)
        if out is None:
            return None
        arr2, s2, ok = out
        resps: List[Any] = ["ACK" if o else False for o in ok]
        return arr2.tolist(), int(s2), resps
    if func == "POP":
        xs = np.zeros(len(args), dtype=np.int64)
        out = _run("stack.POP", _stack_builder(False),
                   arr, np.int64(size), xs)
        if out is None:
            return None
        arr2, s2, vals, ok = out
        resps = [int(v) if o else None for v, o in zip(vals, ok)]
        return arr2.tolist(), int(s2), resps
    return None


def log_round(n_clients: int, triples: Sequence[Tuple[Any, Any, Any]]):
    """A batch of RECORD announcements as one last-write-wins scan.
    Returns ``(writes, responses)`` where writes is a list of
    ``(client, seq, resp)`` — one per client the batch touched — or
    None."""
    cs = pack_ints([t[0] for t in triples])
    ss = pack_ints([t[1] for t in triples])
    rs = pack_ints([t[2] for t in triples])
    if cs is None or ss is None or rs is None:
        return None
    if len(cs) and (cs.min() < 0 or cs.max() >= n_clients):
        return None                      # eager path raises — keep it
    zero = np.zeros(n_clients, dtype=np.int64)
    out = _run("log.RECORD", _log_builder, zero, zero, zero, cs, ss, rs)
    if out is None:
        return None
    seqs, resps, touched, outs = out
    writes = [(c, int(seqs[c]), int(resps[c]))
              for c in range(n_clients) if touched[c]]
    return writes, outs.tolist()


def ckpt_round(step: Any, pairs: Sequence[Tuple[Any, Any]]):
    """A batch of CKPT announcements (newest step wins).  Returns
    ``(new_step, new_payload_or_None_if_unchanged, responses)``."""
    if type(step) is not int:
        return None
    ss = pack_ints([p[0] for p in pairs])
    ps = pack_ints([p[1] for p in pairs])
    if ss is None or ps is None:
        return None
    out = _run("ckpt.CKPT", _ckpt_builder, np.int64(step), ss, ps)
    if out is None:
        return None
    st, pl, advanced, outs = out
    return int(st), (int(pl) if advanced else None), \
        [int(o) for o in outs]
