"""Pure-jnp oracles for the Pallas kernels.

Deliberately *different algorithms* from the kernels where possible, so a
match is meaningful:
  * ``attention_ref`` — materialized-logits softmax attention (the kernel
    streams kv blocks with an online softmax).
  * ``ssd_ref`` — token-by-token sequential recurrence (the kernel runs
    the chunked SSD formulation).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None):
    """q: [B, H, Sq, d]; k, v: [B, Hkv, Sk, d] -> [B, H, Sq, d]."""
    B, H, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(B, Hkv, G, Sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)   # right-aligned positions
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos < kpos + window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, d).astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm, init_state=None):
    """Sequential SSD recurrence (oracle for the chunked kernel).

    x: [B, L, H, P]; dt: [B, L, H] (already softplus'ed); A: [H] (negative);
    Bm, Cm: [B, L, N].  Returns (y [B, L, H, P], state [B, H, P, N])."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    s = jnp.zeros((Bsz, H, P, N), f32) if init_state is None \
        else init_state.astype(f32)

    def step(s, inp):
        x_t, dt_t, B_t, C_t = inp                 # [B,H,P], [B,H], [B,N] x2
        a = jnp.exp(dt_t.astype(f32) * A.astype(f32))           # [B,H]
        dx = x_t.astype(f32) * dt_t[..., None].astype(f32)      # [B,H,P]
        s = s * a[..., None, None] + jnp.einsum("bn,bhp->bhpn",
                                                B_t.astype(f32), dx)
        y = jnp.einsum("bn,bhpn->bhp", C_t.astype(f32), s)
        return s, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    s, ys = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s
