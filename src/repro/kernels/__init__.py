"""Pallas TPU kernels for the framework's compute hot-spots.

The paper's contribution is synchronization/persistence (no kernel-level
algorithm), so this package holds the TPU-native kernels for the model
substrate's hot paths, each with a jit'd wrapper (ops.py) and a pure-jnp
oracle (ref.py), validated in interpret mode:

  flash_attention  — fused causal/windowed/softcap GQA attention
                     (BlockSpec VMEM tiling, online softmax)
  ssd_scan         — Mamba2 SSD chunked scan (sequential-grid VMEM
                     state carry, MXU intra-chunk term)
  decode_attention — flash-decode: one token vs a heads-major KV cache,
                     streaming cache blocks with online softmax (the
                     §Perf decode cell's endgame)
"""

from .decode_attention import decode_attention
from .flash_attention import flash_attention
from .ops import attention_op, ssd_op
from .ssd_scan import ssd_scan

__all__ = ["decode_attention", "flash_attention", "attention_op",
           "ssd_op", "ssd_scan"]
