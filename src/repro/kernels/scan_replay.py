"""Periodic modeled-replay engine: ``lax.scan`` over taped clock events.

The deterministic modeled pass (``benchmarks/modeled.py``) drives every
registry cell single-threaded through the virtual-clock NVM.  Its cost
trajectory is a pure function of the op schedule, and the schedule is
periodic — so after a warmup the per-round clock/counter deltas settle
into an exactly repeating pattern.  This module exploits that:

  1. run a warmup window eagerly;
  2. attach a :class:`ClockTape` to the ``VClock`` and keep running
     eagerly while recording every clocked event — ``advance`` /
     ``merge`` / ``sync_device`` / ``now`` — with Lamport *provenance*:
     ``now()`` returns a :class:`TapedTime` (a float subclass tagged
     with its tape ordinal) so a later ``merge`` records *which* event
     produced its operand, not just the value;
  3. verify periodicity structurally: candidate periods ``P`` in
     ``{L, 2L, 4L, 8L}`` schedule lengths, accepted iff the last four
     ``P``-round chunks have byte-identical event tuples AND identical
     per-chunk NVM-counter deltas;
  4. replay the remaining ``k`` whole periods as arithmetic on the tape
     — a jitted f64 ``lax.scan`` over the period's event array inside a
     ``fori_loop`` over periods (pure-Python fallback when jax is
     absent) — then write the final clocks / device horizon / counters
     back and run any remainder rounds eagerly.

Exactness contract: the replay performs the *identical* IEEE-754 double
operations, in the identical order, that the eager simulator would have
performed (one add per ``advance``, one max per ``merge``, one max+add
per ``sync_device``), so the modeled columns are byte-identical to an
all-eager run — property-tested in ``tests/test_modeled_scan.py``.  Any
cell whose tape refuses to verify (aperiodic geometry, a non-no-op
constant merge, an audit NVM, or a run too short to amortize the taped
window) falls back to the eager loop for every round — honest, never
approximate.

Threading: tapes hook the clock's hot path and are not thread-safe.
Attach only from single-threaded drivers (the modeled pass); never
while workers run.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["TapedTime", "ClockTape", "periodic_run"]

# Event kinds (tape + replay encodings).
_ADV, _MRG, _DEV, _NOW, _MRGC_NOOP, _MRGC_LIVE = 0, 1, 2, 3, 4, 5


class TapedTime(float):
    """A clock reading tagged with the tape ordinal of the ``now()``
    that produced it — ``merge`` provenance for the replay engine."""

    __slots__ = ("idx",)

    def __new__(cls, value: float, idx: int) -> "TapedTime":
        self = float.__new__(cls, value)
        self.idx = idx
        return self


class ClockTape:
    """Recorder attached to ``VClock._tape`` by :func:`periodic_run`.

    Events are per-round lists of tuples ``(kind, lid, val, src)`` with
    clock keys densified to ``lid`` indices (stable across rounds);
    ``now()`` values are kept verbatim for ring seeding."""

    def __init__(self) -> None:
        self.rounds: List[List[Tuple[int, int, float, int]]] = []
        self._cur: List[Tuple[int, int, float, int]] = []
        self.now_vals: List[float] = []
        self.now_count = 0
        self._lids: Dict[Any, int] = {}

    def _lid(self, key: Any) -> int:
        lid = self._lids.get(key)
        if lid is None:
            lid = self._lids[key] = len(self._lids)
        return lid

    # ------------- hooks called from VClock ---------------------------- #
    def record_now(self, key: Any, t: float) -> TapedTime:
        idx = self.now_count
        self.now_count = idx + 1
        self.now_vals.append(float(t))
        self._cur.append((_NOW, self._lid(key), 0.0, 0))
        return TapedTime(t, idx)

    def record_adv(self, key: Any, ns: float) -> None:
        self._cur.append((_ADV, self._lid(key), float(ns), 0))

    def record_mrg(self, key: Any, value: float, cur: float) -> None:
        if type(value) is TapedTime:
            # src is relative in now-ordinal space: constant per period
            # when the schedule is periodic.
            self._cur.append((_MRG, self._lid(key), 0.0,
                              self.now_count - value.idx))
        else:
            # A stamp from before the tape attached.  A no-op merge
            # stays a no-op forever (clocks are monotone), so it can be
            # replayed as nothing; a live constant merge cannot be
            # extrapolated and poisons verification.
            kind = _MRGC_NOOP if value <= cur else _MRGC_LIVE
            self._cur.append((kind, self._lid(key), float(value), 0))

    def record_dev(self, key: Any, cost_ns: float) -> None:
        self._cur.append((_DEV, self._lid(key), float(cost_ns), 0))

    def mark_round(self) -> None:
        self.rounds.append(self._cur)
        self._cur = []


# --------------------------------------------------------------------- #
# Replay (python reference + jitted lax.scan)                           #
# --------------------------------------------------------------------- #
def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def _replay_python(times: List[float], device: float, ring: List[float],
                   nc: int, events, k: int
                   ) -> Tuple[List[float], float]:
    R = len(ring)
    for _ in range(k):
        for kind, lid, val, src in events:
            if kind == _ADV:
                times[lid] = times[lid] + val
            elif kind == _MRG:
                s = ring[(nc - src) % R]
                if s > times[lid]:
                    times[lid] = s
            elif kind == _DEV:
                t = times[lid]
                if device > t:
                    t = device
                t += val
                device = t
                times[lid] = t
            elif kind == _NOW:
                ring[nc % R] = times[lid]
                nc += 1
            # _MRGC_NOOP: nothing — verified no-op under monotone clocks
    return times, device


_SCAN_CACHE: Dict[Tuple[int, int, int], Any] = {}


def _jx():
    try:
        from . import vector_rounds
        if not vector_rounds.available():
            return None
        return vector_rounds._jx()
    except Exception:
        return None


def _replay_jax(jx, times, device, ring, nc, events, k):
    jax, jnp, lax, x64 = jx
    E, R, nlid = len(events), len(ring), len(times)

    with x64():
        fn = _SCAN_CACHE.get((E, R, nlid))
        if fn is None:
            def run(T, D, ring, nc, kinds, lids, vals, srcs, k):
                def per_event(carry, ev):
                    T, D, ring, nc = carry
                    kind, lid, val, src = ev
                    t = T[lid]
                    # all four candidate updates; `where` selects the
                    # one the eager simulator would have performed
                    t_adv = t + val
                    t_mrg = jnp.maximum(t, ring[(nc - src) % R])
                    t_dev = jnp.maximum(t, D) + val
                    new_t = jnp.where(kind == _ADV, t_adv,
                            jnp.where(kind == _MRG, t_mrg,
                            jnp.where(kind == _DEV, t_dev, t)))
                    is_now = kind == _NOW
                    return ((T.at[lid].set(new_t),
                             jnp.where(kind == _DEV, t_dev, D),
                             jnp.where(is_now, ring.at[nc % R].set(t),
                                       ring),
                             nc + jnp.where(is_now, 1, 0)), None)

                def per_period(_i, carry):
                    return lax.scan(per_event, carry,
                                    (kinds, lids, vals, srcs))[0]

                return lax.fori_loop(0, k, per_period, (T, D, ring, nc))

            fn = _SCAN_CACHE[(E, R, nlid)] = jax.jit(run)

        import numpy as np
        kinds = np.asarray([e[0] for e in events], dtype=np.int64)
        lids = np.asarray([e[1] for e in events], dtype=np.int64)
        vals = np.asarray([e[2] for e in events], dtype=np.float64)
        srcs = np.asarray([e[3] for e in events], dtype=np.int64)
        T, D, ring_o, _nc = fn(
            np.asarray(times, dtype=np.float64), np.float64(device),
            np.asarray(ring, dtype=np.float64), np.int64(nc),
            kinds, lids, vals, srcs, np.int64(k))
        return [float(x) for x in T], float(D)


# --------------------------------------------------------------------- #
# Driver                                                                #
# --------------------------------------------------------------------- #
def periodic_run(nvm, round_fn: Callable[[int], None], total_rounds: int,
                 sched_len: int = 1) -> Dict[str, Any]:
    """Run ``round_fn(r)`` for ``r in range(total_rounds)``, replaying
    the periodic middle through the tape engine when it verifies.

    Returns an info dict: ``engine`` is ``"scan"`` / ``"python"`` when
    periods were replayed (jax jitted vs pure-python arithmetic) or
    ``"eager"`` with a ``reason`` when every round ran the simulator.
    The NVM's modeled counters and virtual clocks end byte-identical to
    an all-eager run either way.
    """
    clk = getattr(nvm, "clock", None)
    L = max(1, int(sched_len))
    warm, taped = 8 * L, 32 * L
    if (clk is None or getattr(nvm, "audit", None) is not None
            or total_rounds < warm + taped + 2 * L):
        for r in range(total_rounds):
            round_fn(r)
        return {"engine": "eager", "reason": "short-or-unsupported"}

    for r in range(warm):
        round_fn(r)

    tape = ClockTape()
    snaps = [dict(nvm.counters)]
    clk._tape = tape
    try:
        for i in range(taped):
            round_fn(warm + i)
            tape.mark_round()
            snaps.append(dict(nvm.counters))
    finally:
        clk._tape = None

    chosen = None
    for P in (L, 2 * L, 4 * L, 8 * L):
        chunks = [sum((tape.rounds[i] for i in range(taped - c * P,
                                                     taped - (c - 1) * P)),
                      []) for c in (4, 3, 2, 1)]
        deltas = [{key: snaps[taped - (c - 1) * P].get(key, 0)
                   - snaps[taped - c * P].get(key, 0)
                   for key in snaps[taped]} for c in (4, 3, 2, 1)]
        if (all(ch == chunks[0] for ch in chunks[1:])
                and all(d == deltas[0] for d in deltas[1:])
                and not any(e[0] == _MRGC_LIVE for e in chunks[0])):
            chosen = (P, chunks[-1], deltas[-1])
            break

    consumed = warm + taped
    if chosen is None:
        for r in range(consumed, total_rounds):
            round_fn(r)
        return {"engine": "eager", "reason": "aperiodic"}

    P, events, delta = chosen
    k, tail = divmod(total_rounds - consumed, P)
    engine = "eager"
    if k and events:
        max_src = max((e[3] for e in events if e[0] == _MRG), default=0)
        R = _next_pow2(max_src + 1)
        nc = tape.now_count
        ring = [0.0] * R
        for j, v in enumerate(tape.now_vals[-R:]):
            ring[(nc - min(R, len(tape.now_vals)) + j) % R] = v
        keys = list(tape._lids)
        times = [float(clk._times.get(key, 0.0)) for key in keys]
        jx = _jx()
        if jx is not None:
            times, device = _replay_jax(jx, times, clk._device_free,
                                        ring, nc, events, k)
            engine = "scan"
        else:
            times, device = _replay_python(times, clk._device_free,
                                           ring, nc, events, k)
            engine = "python"
        for key, t in zip(keys, times):
            clk._times[key] = t
        clk._device_free = device
        for key, d in delta.items():
            if d:
                nvm.counters[key] = nvm.counters.get(key, 0) + k * d
    elif k:
        # clock-silent periods: only the counters move
        for key, d in delta.items():
            if d:
                nvm.counters[key] = nvm.counters.get(key, 0) + k * d
        engine = "python"

    for i in range(tail):
        round_fn(consumed + k * P + i)
    return {"engine": engine, "period_rounds": P, "replayed_periods": k,
            "events_per_period": len(events)}
