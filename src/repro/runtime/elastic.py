"""Elastic training runtime: heartbeats, straggler detection, rescale.

The coordinator runs the same combining pattern as everything else in
this framework: hosts *announce* liveness/progress into a flat slot
array; one coordinator (combiner) reads all announcements and produces a
single decision — a ``RescalePlan`` — instead of hosts negotiating
pairwise.  If the coordinator itself dies, any host notices the stale
lease and takes over (PWFComb).

A rescale never loses work: the plan's restore point is the PBComb
checkpointer's committed step (durable by construction), and the data
pipeline is a pure function of (seed, step), so the new data-axis
layout replays from exactly the committed step with no duplicate or
skipped batches (detectable recovery at the job level).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class HostStatus:
    host: int
    step: int = -1
    last_seen: float = 0.0
    alive: bool = True


@dataclass(frozen=True)
class RescalePlan:
    """A new data-parallel layout after failures/joins."""
    epoch: int                    # plan version (SC-style monotonic)
    hosts: Tuple[int, ...]        # surviving host ids, sorted
    data_shards: Dict[int, int]   # host -> data-shard index
    restore_step: int             # committed checkpoint step to resume

    @property
    def dp_size(self) -> int:
        return len(self.hosts)


class ElasticCoordinator:
    def __init__(self, n_hosts: int, *, heartbeat_timeout: float = 1.0,
                 lease_s: float = 2.0) -> None:
        self.n = n_hosts
        self.timeout = heartbeat_timeout
        self.lease_s = lease_s
        self.status: Dict[int, HostStatus] = {
            h: HostStatus(h, last_seen=time.monotonic())
            for h in range(n_hosts)}
        self.plan = RescalePlan(0, tuple(range(n_hosts)),
                                {h: h for h in range(n_hosts)}, -1)
        self.coordinator_host = 0
        self._last_coord_beat = time.monotonic()
        self._lock = threading.Lock()

    # ------------- announce path (any host) --------------------------- #
    def heartbeat(self, host: int, step: int) -> RescalePlan:
        """Host announces liveness + progress; returns the current plan
        (hosts notice rescales by the plan epoch changing)."""
        with self._lock:
            st = self.status.setdefault(host, HostStatus(host))
            st.step = step
            st.last_seen = time.monotonic()
            st.alive = True
            if host == self.coordinator_host:
                self._last_coord_beat = st.last_seen
            return self.plan

    def join(self, host: int) -> None:
        with self._lock:
            self.status[host] = HostStatus(host,
                                           last_seen=time.monotonic())

    def leave(self, host: int) -> None:
        """Voluntary departure (elastic scale-down): the host is
        excluded from the next rescale immediately instead of waiting
        out the heartbeat timeout.  ``join`` brings it back."""
        with self._lock:
            st = self.status.get(host)
            if st is not None:
                st.alive = False

    def alive_hosts(self) -> List[int]:
        with self._lock:
            return sorted(h for h, s in self.status.items() if s.alive)

    # ------------- combiner path --------------------------------------- #
    def stragglers(self) -> List[int]:
        now = time.monotonic()
        with self._lock:
            steps = [s.step for s in self.status.values() if s.alive]
            if not steps:
                return []
            lead = max(steps)
            out = []
            for s in self.status.values():
                stale = now - s.last_seen > self.timeout
                behind = s.step < lead - 2
                if s.alive and (stale or behind):
                    out.append(s.host)
            return out

    def detect_failures(self) -> List[int]:
        now = time.monotonic()
        with self._lock:
            return [s.host for s in self.status.values()
                    if s.alive and now - s.last_seen > self.timeout]

    def rescale(self, committed_step: int,
                failed: Optional[Sequence[int]] = None) -> RescalePlan:
        """Combine all announcements into ONE new plan."""
        with self._lock:
            failed = set(failed if failed is not None else [])
            now = time.monotonic()
            for s in self.status.values():
                if s.host in failed or now - s.last_seen > self.timeout:
                    s.alive = False
            alive = sorted(h for h, s in self.status.items() if s.alive)
            if not alive:
                raise RuntimeError("no hosts alive")
            plan = RescalePlan(
                epoch=self.plan.epoch + 1,
                hosts=tuple(alive),
                data_shards={h: i for i, h in enumerate(alive)},
                restore_step=committed_step)
            self.plan = plan
            return plan

    # ------------- coordinator takeover (PWFComb) ----------------------- #
    def coordinator_lease_expired(self) -> bool:
        return time.monotonic() - self._last_coord_beat > self.lease_s

    def take_over_coordination(self, host: int) -> bool:
        """Any live host may claim coordination when the lease lapses;
        the lock + epoch check arbitrate like an SC."""
        with self._lock:
            if time.monotonic() - self._last_coord_beat <= self.lease_s:
                return False
            self.coordinator_host = host
            self._last_coord_beat = time.monotonic()
            return True
