"""Multi-host sharded checkpointing with a single combining commit point.

Every host persists its OWN state shard (params/optimizer shards live
only on their owners under ZeRO/TP), but durability is committed by ONE
index flip — the PBComb structure lifted to the cluster:

  * host h announces "shard of step N written" after pwb+pfence of its
    slot file ``staterec.h<h>.<ind>``;
  * the coordinator (combiner) waits for all announcements of round
    ``ind``, then flips + psyncs the global index file.  One psync per
    round commits every host's shard (P1: persistence instructions per
    round O(1), not O(hosts));
  * recovery reads the index and loads every host's committed slot; a
    torn round (some shards written, index not flipped) is invisible;
  * if the coordinator misses its lease, any host performs the
    versioned takeover (PWFComb's SC) and commits the round itself.

The ``NaiveShardedCheckpointer`` is the non-combining baseline: every
host fsyncs its own shard AND its own index marker per round (O(hosts)
psyncs, scattered files) — benchmarked in
``benchmarks/checkpoint_bench.py``.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

from . import staterec
from .store import Store

INDEX_FILE = "shard_index"


class ShardedCheckpointer:
    def __init__(self, store: Store, n_hosts: int,
                 shard_templates: List[Any], *, lease_s: float = 5.0):
        self.store = store
        self.n = n_hosts
        self.templates = shard_templates
        self.lease_s = lease_s
        self._ready: Dict[int, Dict[int, int]] = {0: {}, 1: {}}  # ind->host->step
        self._lock = threading.Lock()
        self._mindex = 0
        self._committed_step = -1
        self._last_commit = time.monotonic()
        self._commit_version = 0      # the LL/SC version for takeover

    # ------------- per-host write path -------------------------------- #
    def slot_name(self, host: int, ind: int) -> str:
        return f"staterec.h{host}.{ind}"

    def write_shard(self, host: int, payload: Any, step: int) -> int:
        """pwb + pfence this host's shard into the non-current slot and
        announce readiness.  Returns the round index written."""
        with self._lock:
            ind = 1 - self._mindex
        buf = staterec.pack(payload, [step], [step % 2])
        self.store.pwb(self.slot_name(host, ind), buf)
        self.store.pfence()
        with self._lock:
            self._ready[ind][host] = step
        return ind

    # ------------- combining commit ------------------------------------ #
    def try_commit(self, step: int) -> bool:
        """Coordinator path: flip the index iff every host announced its
        step-``step`` shard for the pending round."""
        with self._lock:
            ind = 1 - self._mindex
            ready = self._ready[ind]
            if len(ready) < self.n or any(s != step for s in ready.values()):
                return False
            version = self._commit_version
        # one psync commits all n shards (P1)
        self.store.pwb(INDEX_FILE, f"{ind},{step}".encode())
        self.store.psync()
        with self._lock:
            if self._commit_version != version:   # lost the SC race
                return True
            self._commit_version += 1
            self._mindex = ind
            self._committed_step = step
            self._ready[1 - ind] = {}
            self._last_commit = time.monotonic()
        return True

    def lease_expired(self) -> bool:
        return time.monotonic() - self._last_commit > self.lease_s

    def takeover_commit(self, step: int) -> bool:
        """Any host may commit when the coordinator's lease lapses
        (PWFComb: everyone pretends to be the combiner; the version
        check arbitrates)."""
        return self.try_commit(step)

    # ------------- recovery -------------------------------------------- #
    def recover(self):
        raw = self.store.read(INDEX_FILE)
        if raw is None:
            return None, -1
        ind, step = (int(x) for x in raw.decode().split(","))
        shards = []
        for h in range(self.n):
            data = self.store.read(self.slot_name(h, ind))
            payload, _, _ = staterec.unpack(data, self.templates[h])
            shards.append(payload)
        with self._lock:
            self._mindex = ind
            self._committed_step = step
            self._ready = {0: {}, 1: {}}
        return shards, step

    @property
    def committed_step(self) -> int:
        return self._committed_step


class NaiveShardedCheckpointer:
    """Baseline: no combining — per-host index markers, one psync per
    host per round (the cost shape the paper argues against)."""

    def __init__(self, store: Store, n_hosts: int,
                 shard_templates: List[Any]):
        self.store = store
        self.n = n_hosts
        self.templates = shard_templates

    def write_shard(self, host: int, payload: Any, step: int) -> None:
        buf = staterec.pack(payload, [step], [step % 2])
        self.store.pwb(f"naive.h{host}.data", buf)
        self.store.pfence()
        self.store.pwb(f"naive.h{host}.idx", str(step).encode())
        self.store.psync()                 # every host syncs itself

    def recover(self):
        shards, steps = [], []
        for h in range(self.n):
            raw = self.store.read(f"naive.h{h}.idx")
            if raw is None:
                return None, -1
            steps.append(int(raw.decode()))
            data = self.store.read(f"naive.h{h}.data")
            payload, _, _ = staterec.unpack(data, self.templates[h])
            shards.append(payload)
        # hosts may have torn across steps — the caller detects mismatch
        return shards, min(steps) if len(set(steps)) == 1 else -abs(max(steps))
