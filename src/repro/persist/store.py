"""Persistent store with explicit epoch-persistency semantics.

Maps the paper's persistence instructions onto storage:

    pwb(name, data)  -> buffered write (staged, NOT durable)
    pfence()         -> ordering barrier: everything pwb'd before the
                        fence becomes durable before anything after it
    psync()          -> block until all prior pwbs are durable

``DirStore`` realizes this on a real directory (pwb = write to a staging
file, pfence/psync = fsync + atomic rename).  ``MemStore`` is an
in-memory twin with *adversarial crash resolution* — exactly like
core.nvm — used by the crash tests to enumerate every reachable
post-crash state of the checkpointer.
"""

from __future__ import annotations

import os
import random
import threading
from typing import Dict, List, Optional, Tuple


class Store:
    def pwb(self, name: str, data: bytes) -> None:
        raise NotImplementedError

    def pfence(self) -> None:
        raise NotImplementedError

    def psync(self) -> None:
        raise NotImplementedError

    def read(self, name: str) -> Optional[bytes]:
        raise NotImplementedError

    counters: Dict[str, int]


class MemStore(Store):
    """In-memory store with the paper's crash semantics (epoch queue).

    ``persist_latency``: seconds a psync blocks (models storage fsync
    cost for the checkpoint benchmarks); 0 keeps tests instant."""

    def __init__(self, persist_latency: float = 0.0) -> None:
        self._dur: Dict[str, bytes] = {}
        self._epochs: List[List[Tuple[str, bytes]]] = [[]]
        self._lock = threading.Lock()
        self.persist_latency = persist_latency
        self.counters = {"pwb": 0, "pfence": 0, "psync": 0, "crashes": 0}

    def pwb(self, name: str, data: bytes) -> None:
        with self._lock:
            self.counters["pwb"] += 1
            self._epochs[-1].append((name, bytes(data)))

    def pfence(self) -> None:
        with self._lock:
            self.counters["pfence"] += 1
            if self._epochs[-1]:
                self._epochs.append([])

    def psync(self) -> None:
        had = False
        with self._lock:
            self.counters["psync"] += 1
            had = any(self._epochs)
            for epoch in self._epochs:
                for name, data in epoch:
                    self._dur[name] = data
            self._epochs = [[]]
        if had and self.persist_latency:
            import time
            time.sleep(self.persist_latency)

    def read(self, name: str) -> Optional[bytes]:
        with self._lock:
            return self._dur.get(name)

    def crash(self, rng: Optional[random.Random] = None) -> None:
        """Adversarially resolve the write-back queue, then drop it."""
        with self._lock:
            self.counters["crashes"] += 1
            if rng is not None and self._epochs:
                cut = rng.randint(0, len(self._epochs) - 1)
                for epoch in self._epochs[:cut]:
                    for name, data in epoch:
                        self._dur[name] = data
                for name, data in self._epochs[cut]:
                    if rng.random() < 0.5:
                        self._dur[name] = data
            self._epochs = [[]]


class NVMStore(Store):
    """Store facade over a simulated NVM (thread or shm backed).

    Each name maps to ONE NVM word holding the file's bytes — the blob
    heap (shm) or the Python-object word (threads) carries arbitrary
    sizes — so ``pwb`` is a word write + ``nvm.pwb`` (charged with the
    payload's cache-line footprint on shm), ``pfence``/``psync`` are
    the NVM's own instructions, and ``read`` is a durable read.  This
    is what wires ``PBCombCheckpointer`` through
    ``CombiningRuntime(backend="shm")``: its slot files live in the
    shared segment, crash/recovery rides ``nvm.crash``, and its psyncs
    serialize through the owning segment's modeled device.

    The name -> word directory is volatile Python state in the creating
    process (the simulation's callers keep the store object across
    simulated crashes, exactly like MemStore keeps ``_dur``).
    """

    def __init__(self, nvm, segment: int = 0) -> None:
        self.nvm = nvm
        self.segment = segment
        self._words: Dict[str, int] = {}
        self._lock = threading.Lock()

    @property
    def counters(self) -> Dict[str, int]:           # type: ignore[override]
        c = self.nvm.counters
        return {k: c[k] for k in ("pwb", "pfence", "psync", "crashes")}

    def _word(self, name: str) -> int:
        with self._lock:
            addr = self._words.get(name)
            if addr is None:
                addr = self.nvm.alloc(1, segment=self.segment)
                self._words[name] = addr
            return addr

    def pwb(self, name: str, data: bytes) -> None:
        addr = self._word(name)
        self.nvm.write(addr, bytes(data))
        self.nvm.pwb(addr, 1)

    def pfence(self) -> None:
        self.nvm.pfence()

    def psync(self) -> None:
        self.nvm.psync()

    def read(self, name: str) -> Optional[bytes]:
        with self._lock:
            addr = self._words.get(name)
        if addr is None:
            return None
        data = self.nvm.durable_read(addr)
        return data if isinstance(data, bytes) else None

    def crash(self, rng: Optional[random.Random] = None) -> None:
        self.nvm.crash(rng)


class DirStore(Store):
    """Directory-backed store.

    pwb writes ``name.staged-k``; psync fsyncs every staged file and
    atomically renames it over ``name`` (rename-after-fsync gives the
    pfence ordering for free on POSIX).  A crash between pwb and psync
    leaves the old contents — the same guarantee the paper's pwb queue
    provides.
    """

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._staged: List[List[Tuple[str, str]]] = [[]]
        self._lock = threading.Lock()
        self._k = 0
        self.counters = {"pwb": 0, "pfence": 0, "psync": 0, "crashes": 0}

    def _path(self, name: str) -> str:
        return os.path.join(self.root, name)

    def pwb(self, name: str, data: bytes) -> None:
        with self._lock:
            self.counters["pwb"] += 1
            self._k += 1
            staged = self._path(f"{name}.staged-{self._k}")
            with open(staged, "wb") as f:
                f.write(data)
            self._staged[-1].append((name, staged))

    def pfence(self) -> None:
        with self._lock:
            self.counters["pfence"] += 1
            if self._staged[-1]:
                self._staged.append([])

    def psync(self) -> None:
        with self._lock:
            self.counters["psync"] += 1
            for epoch in self._staged:
                for name, staged in epoch:
                    with open(staged, "rb") as f:
                        os.fsync(f.fileno())
                    os.replace(staged, self._path(name))
            # fsync the directory so the renames are durable
            fd = os.open(self.root, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
            self._staged = [[]]

    def read(self, name: str) -> Optional[bytes]:
        try:
            with open(self._path(name), "rb") as f:
                return f.read()
        except FileNotFoundError:
            return None
