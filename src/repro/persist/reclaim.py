"""Epoch-based node reclamation for the combining structures
(DESIGN.md §13; ROADMAP "Memory reclamation for long-haul traffic").

The paper leaves PWFQueue node recycling open ("a solution would be
more complicated, due to the two parts"); the blocker is that helped
link writes and slow pretend-combiners may touch a node long after the
round that logically removed it.  MOD (PAPERS.md) shows the shape of a
fix: detach reclamation from the operation commit path so it adds
(almost) no persist ordering.  This module is that layer:

  * a global *epoch* word advances every successfully published
    combining round;
  * a removed node is *retired* into the retiring thread's limbo ring,
    stamped with the current epoch — retirement happens only after the
    round's S value is durable, so the node is unreachable from the
    durable state forever;
  * threads *pin* the epoch for the duration of one `_perform_request`
    (announce/help/combine/publish), so a slow helper that still holds
    a node address blocks its reuse;
  * a retired node re-enters the allocation path only once it is at
    least ``GRACE`` epochs old, no active pin predates its retirement,
    AND its limbo record is durable (see below) — the *free window*.

Persistence plan.  Every hot-path word here is VOLATILE-image only
(plain ``nvm.read``/``nvm.write`` — no pwb, no clock, no counters), so
the gated modeled trajectory is byte-identical with reclamation wired
in: a workload that never quiesces allocates exactly like the
unreclaimed baseline.  Durability happens only at explicit
``quiesce()`` calls (coordinator-side, workers idle — the fleet's wave
boundaries), in two persist stages:

  1. persist the new limbo records (ring spans) and the epoch, psync —
     records are durable BEFORE any boundary names them;
  2. advance ``dur_tail`` (durable-record boundary) and ``freed_head``
     (durable free boundary) and persist the per-thread header line,
     psync.  Both live on one line, so a crash cut sees either boundary
     move or neither — never a boundary past garbage records.

Recovery rule: the consumption cursor is volatile, so after a crash we
set ``alloc_cursor := freed_head`` — entries handed out before the
crash are never re-issued (no double allocation), at the cost of
leaking the unconsumed tail of the free window plus anything retired
since the last quiesce.  Both leaks are bounded by the ring capacity
per crash and are recorded in ``stats()``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from ..core.nvm import LINE

# per-thread header word offsets (one cache line, persisted as a unit)
_H_TAIL = 0          # monotone retire count (volatile; persisted @quiesce)
_H_DUR_TAIL = 1      # durable-record boundary (entries < this are durable)
_H_FREED = 2         # durable free boundary (entries < this may be reused)
_H_CURSOR = 3        # volatile consumption cursor inside the free window
_H_FRESH = 4         # stat: fresh chunk allocations (volatile)
_H_REUSED = 5        # stat: allocations served from the free window
_H_DROPS = 6         # stat: retirements dropped because the ring was full
_H_WORDS = 8         # header words (padded to a line boundary below)

_ENTRY_WORDS = 2     # limbo record: [node address, retire epoch]


def _as_int(v) -> int:
    """Coerce a possibly-never-persisted NVM word to an int: a fresh
    durable word decodes as None on the shm backend (tag 0) and as 0 on
    the thread backend."""
    return v if type(v) is int else 0


def _round_line(n: int) -> int:
    return (n + LINE - 1) // LINE * LINE


class EpochReclaimer:
    """Per-structure epoch-based limbo/free-window allocator seam.

    All state lives in NVM words allocated from the owning structure's
    segment; only thread ``p`` ever writes thread ``p``'s header and
    ring (no cross-thread retire coordination), and the coordinator
    reads everything at quiesce/recover time through the shared image.
    """

    #: a retired node must age at least this many epoch advances before
    #: the free window may hand it back out
    GRACE = 2

    def __init__(self, nvm, n_threads: int, cap: int = 512) -> None:
        self.nvm = nvm
        self.n = n_threads
        self.cap = cap
        self._block = _round_line(_H_WORDS + cap * _ENTRY_WORDS)
        # [E | pins (one line) | per-thread blocks]
        self._hdr = _round_line(1) + _round_line(n_threads)
        total = self._hdr + n_threads * self._block
        self.base = nvm.alloc(total, segment=nvm.current_segment())
        self._pins = self.base + _round_line(1)
        nvm.write(self.base, 0)                       # E
        for p in range(n_threads):
            nvm.write(self._pins + p, 0)
            h = self._thread_base(p)
            for f in range(_H_WORDS):
                nvm.write(h + f, 0)

    # ---------------- layout ------------------------------------------- #
    def _thread_base(self, p: int) -> int:
        return self.base + self._hdr + p * self._block

    def _ring_base(self, p: int) -> int:
        return self._thread_base(p) + _H_WORDS

    def _slot(self, p: int, idx: int) -> int:
        return self._ring_base(p) + (idx % self.cap) * _ENTRY_WORDS

    # ---------------- hot path (volatile-image only) ------------------- #
    def pin(self, p: int) -> None:
        """Enter a reclamation-protected section: any node reachable
        now stays allocated until after ``unpin``.  Stored as epoch+1 so
        0 means inactive."""
        nvm = self.nvm
        nvm.write(self._pins + p, _as_int(nvm.read(self.base)) + 1)

    def unpin(self, p: int) -> None:
        self.nvm.write(self._pins + p, 0)

    def advance(self) -> None:
        """One successfully published combining round = one epoch tick.
        The read-modify-write is racy across threads; lost increments
        only slow ageing down, never violate the grace period."""
        nvm = self.nvm
        nvm.write(self.base, _as_int(nvm.read(self.base)) + 1)

    def retire(self, p: int, addr: int) -> None:
        """Move ``addr`` into thread ``p``'s limbo ring, stamped with
        the current epoch.  If the ring is full the node is LEAKED (and
        counted) rather than overwritten — an overwrite could clobber a
        not-yet-durable record the next quiesce is about to persist."""
        nvm = self.nvm
        h = self._thread_base(p)
        tail = _as_int(nvm.read(h + _H_TAIL))
        cursor = _as_int(nvm.read(h + _H_CURSOR))
        if tail - cursor >= self.cap:
            nvm.write(h + _H_DROPS, _as_int(nvm.read(h + _H_DROPS)) + 1)
            return
        slot = self._slot(p, tail)
        nvm.write(slot, addr)
        nvm.write(slot + 1, _as_int(nvm.read(self.base)))
        nvm.write(h + _H_TAIL, tail + 1)

    def take(self, p: int) -> Optional[int]:
        """Pop one node address from the durable free window, or None.
        Only entries below ``freed_head`` (durable, aged, unpinned at
        the quiesce that freed them) are ever handed out."""
        nvm = self.nvm
        h = self._thread_base(p)
        cursor = _as_int(nvm.read(h + _H_CURSOR))
        if cursor >= _as_int(nvm.read(h + _H_FREED)):
            return None
        addr = _as_int(nvm.read(self._slot(p, cursor)))
        nvm.write(h + _H_CURSOR, cursor + 1)
        nvm.write(h + _H_REUSED, _as_int(nvm.read(h + _H_REUSED)) + 1)
        return addr if addr else None

    def count_fresh(self, p: int) -> None:
        nvm = self.nvm
        h = self._thread_base(p)
        nvm.write(h + _H_FRESH, _as_int(nvm.read(h + _H_FRESH)) + 1)

    # ---------------- quiesce (the only persisting path) --------------- #
    def _min_pinned_epoch(self) -> Optional[int]:
        nvm = self.nvm
        low = None
        for q in range(self.n):
            v = _as_int(nvm.read(self._pins + q))
            if v and (low is None or v - 1 < low):
                low = v - 1
        return low

    def quiesce(self) -> Dict[str, int]:
        """Persist new limbo records, then advance the durable
        boundaries (see the module doc for the two-stage crash-safety
        argument).  Call from the coordinator at a quiescent point —
        concurrent retire/take on OTHER threads is tolerated (their
        records simply wait for the next quiesce), but nodes freed here
        honor any still-active pin.  Costs two psyncs; never called on
        the gated bench paths."""
        nvm = self.nvm
        spans: List[Tuple[int, int]] = []
        tails = []
        for p in range(self.n):
            h = self._thread_base(p)
            tail = _as_int(nvm.read(h + _H_TAIL))
            dur = _as_int(nvm.read(h + _H_DUR_TAIL))
            tails.append(tail)
            for first, count in self._ring_runs(dur, tail):
                spans.append((self._slot(p, first),
                              count * _ENTRY_WORDS))
        spans.append((self.base, 1))                       # the epoch
        nvm.persist_lines(spans)
        nvm.psync()                       # stage 1: records durable
        epoch = _as_int(nvm.read(self.base))
        min_pin = self._min_pinned_epoch()
        hdr_spans = []
        freed_total = 0
        for p in range(self.n):
            h = self._thread_base(p)
            tail = tails[p]
            nvm.write(h + _H_DUR_TAIL, tail)
            freed = _as_int(nvm.read(h + _H_FREED))
            while freed < tail:
                e = _as_int(nvm.read(self._slot(p, freed) + 1))
                if e + self.GRACE > epoch:
                    break
                if min_pin is not None and min_pin <= e + 1:
                    break
                freed += 1
                freed_total += 1
            nvm.write(h + _H_FREED, freed)
            hdr_spans.append((h, _H_WORDS))
        nvm.persist_lines(hdr_spans)
        nvm.psync()                       # stage 2: boundaries durable
        return {"freed": freed_total, "epoch": epoch}

    def _ring_runs(self, lo: int, hi: int):
        """Contiguous slot runs covering entry indices [lo, hi) —
        at most two because the ring wraps once."""
        if hi - lo >= self.cap:           # full ring: one flat span
            yield 0, self.cap
            return
        while lo < hi:
            s = lo % self.cap
            count = min(hi - lo, self.cap - s)
            yield s, count
            lo += count

    # ---------------- recovery ----------------------------------------- #
    def recover(self) -> None:
        """Normalize after the backend restored vol := dur.  Entries
        consumed before the crash must never be re-issued, so the
        volatile cursor restarts at the durable free boundary — the
        unconsumed window plus anything retired since the last quiesce
        leaks (bounded by cap per thread per crash)."""
        nvm = self.nvm
        for p in range(self.n):
            h = self._thread_base(p)
            dur = _as_int(nvm.read(h + _H_DUR_TAIL))
            freed = min(_as_int(nvm.read(h + _H_FREED)), dur)
            nvm.write(h + _H_TAIL, dur)
            nvm.write(h + _H_FREED, freed)
            nvm.write(h + _H_CURSOR, freed)
            nvm.write(self._pins + p, 0)

    # ---------------- introspection ------------------------------------ #
    def stats(self) -> Dict[str, int]:
        nvm = self.nvm
        out = {"epoch": _as_int(nvm.read(self.base)), "retired": 0,
               "limbo": 0, "free_window": 0, "fresh": 0, "reused": 0,
               "drops": 0}
        for p in range(self.n):
            h = self._thread_base(p)
            tail = _as_int(nvm.read(h + _H_TAIL))
            freed = _as_int(nvm.read(h + _H_FREED))
            cursor = _as_int(nvm.read(h + _H_CURSOR))
            out["retired"] += tail
            out["limbo"] += tail - freed
            out["free_window"] += max(0, freed - cursor)
            out["fresh"] += _as_int(nvm.read(h + _H_FRESH))
            out["reused"] += _as_int(nvm.read(h + _H_REUSED))
            out["drops"] += _as_int(nvm.read(h + _H_DROPS))
        return out
