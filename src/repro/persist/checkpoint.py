"""PBComb checkpointer — the paper's protocol as the training-state
persistence engine.

Mapping (DESIGN.md §2):

  threads announcing requests  ->  announcers: trainer loop(s), data
                                   pipeline, eval hooks — anything that
                                   says "persist my state at step N"
  Request[p] (volatile)        ->  in-memory announce slots with the
                                   paper's activate/valid bits
  MemState[0..1] + MIndex      ->  slot-0 / slot-1 StateRec files +
                                   a tiny index file, flipped last
  combiner                     ->  one background thread: serves ALL
                                   active announcements with ONE slot
                                   write + pwb + pfence + index flip +
                                   psync (per combining round, not per
                                   request — persistence principle P1)
  Deactivate / ReturnVal       ->  inside the slot buffer (P3): on
                                   recovery every announcer learns
                                   whether its step-N request was
                                   captured, and its response
  PWFComb takeover             ->  lease: if the combiner stalls past
                                   its lease, any announcer performs the
                                   versioned take-over and combines

Torn checkpoints are impossible by construction: recovery always reads
the slot named by the durable index, and the index only flips after the
slot's psync (the paper's pfence-before-MIndex argument, Section 3).
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..api.adapters import OpSpec, StructureAdapter
from ..api.board import AnnounceBoard, Announcement
from . import staterec
from .store import Store

INDEX_FILE = "mindex"
SLOT_FILES = ("staterec.0", "staterec.1")

# The paper's RequestRec for this component is exactly an announcement
# slot; the dedicated dataclass became the shared AnnounceBoard record.
AnnounceRec = Announcement


class PBCombCheckpointer:
    """Detectably-recoverable, double-buffered, combining checkpointer."""

    @classmethod
    def over_nvm(cls, nvm, n_announcers: int, payload_template: Any, *,
                 segment: int = 0, lease_s: float = 5.0
                 ) -> "PBCombCheckpointer":
        """Checkpointer whose slot files live in simulated NVM words
        (``NVMStore``) instead of a file-like store — pass a runtime's
        ``ShmNVM`` to put the durable checkpoint state in the shared
        segment, with its psyncs accounted on ``segment``'s device
        (DESIGN.md §8)."""
        from .store import NVMStore
        ck = cls(NVMStore(nvm, segment=segment), n_announcers,
                 payload_template, lease_s=lease_s)
        return ck

    def __init__(self, store: Store, n_announcers: int,
                 payload_template: Any, *, lease_s: float = 5.0) -> None:
        self.store = store
        self.n = n_announcers
        self.template = payload_template
        self.lease_s = lease_s
        # volatile protocol state (rebuilt on recovery): the shared
        # announcement plumbing from repro.api instead of a private list
        self._kick = threading.Event()
        self.board = AnnounceBoard(n_announcers,
                                   on_announce=self._kick.set)
        self._lock = threading.Lock()         # the PBComb integer lock
        self._combine_count = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._last_combine = time.monotonic()
        # mirror of the durable deactivate/returnval (refreshed on combine)
        self._deactivate: List[int] = [0] * n_announcers
        self._returnval: List[Any] = [None] * n_announcers
        self._mindex = 0

    # ----------------- bootstrap / recovery --------------------------- #
    def initialize(self, payload: Any) -> None:
        """Write an initial durable state (both slots + index)."""
        buf = staterec.pack(payload, [None] * self.n, [0] * self.n)
        self.store.pwb(SLOT_FILES[0], buf)
        self.store.pwb(SLOT_FILES[1], buf)
        self.store.pfence()
        self.store.pwb(INDEX_FILE, b"0")
        self.store.psync()
        self._mindex = 0

    def recover(self) -> Any:
        """Reload the durable state; refresh the volatile mirrors.
        Returns the payload (callers then use ``was_applied`` /
        ``response`` per announcer for detectability)."""
        idx_raw = self.store.read(INDEX_FILE)
        self._mindex = int(idx_raw or b"0")
        data = self.store.read(SLOT_FILES[self._mindex])
        payload, retval, deact = staterec.unpack(data, self.template)
        self._returnval = list(retval)
        self._deactivate = list(deact)
        self.board.reset()                    # announcements are volatile
        return payload

    def was_applied(self, p: int, seq: int) -> bool:
        """Detectability: did announcer p's request with this seq take
        effect before the crash?  (paper Recover, line 4)"""
        return self._deactivate[p] == seq % 2

    def response(self, p: int) -> Any:
        return self._returnval[p]

    # ----------------- announce path ---------------------------------- #
    def announce(self, p: int, payload: Any, seq: int,
                 wait: bool = False, timeout: Optional[float] = None,
                 response: Any = None):
        """Announce "persist payload" for announcer p.

        ``seq`` must be p's CONSECUTIVE announcement number (the paper's
        system-support assumption, Section 2): activate is its parity, so
        detectability self-heals across crashes — the paper's Recover
        sets Request[p] := <func, args, seq mod 2, 1> with the same
        convention."""
        rec = self.board.announce(p, payload, seq=seq, response=response)
        if wait:
            if not rec.done_event.wait(timeout):
                # combiner stalled past its lease -> wait-free takeover
                if self.lease_expired():
                    self.takeover(p)
                rec.done_event.wait(timeout)
        return rec

    # ----------------- combiner ---------------------------------------- #
    def start(self) -> None:
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        self._kick.set()
        if self._thread:
            self._thread.join()

    def _run(self) -> None:
        while not self._stop.is_set():
            self._kick.wait(timeout=0.05)
            self._kick.clear()
            self.combine_once()

    def lease_expired(self) -> bool:
        return time.monotonic() - self._last_combine > self.lease_s

    def takeover(self, p: int) -> None:
        """PWFComb-style helping: announcer p becomes the combiner for
        one round (the lock arbitrates, like the SC on S)."""
        self.combine_once()

    def combine_once(self) -> int:
        """One combining round (paper Algorithm 2 lines 14-28).  Returns
        the number of requests served."""
        with self._lock:
            active = self.board.active_vs(self._deactivate)
            if not active:
                self._last_combine = time.monotonic()
                return 0
            # The object semantics of "persist(payload, seq)": the newest
            # announced payload wins; every served announcer's response is
            # the step/seq the round captured.
            newest = max(active, key=lambda pr: pr[1].seq)
            payload = newest[1].payload
            retval = list(self._returnval)
            deact = list(self._deactivate)
            for p, rec in active:
                retval[p] = rec.response if rec.response is not None \
                    else rec.seq
                deact[p] = rec.activate
            ind = 1 - self._mindex
            buf = staterec.pack(payload, retval, deact)  # one contiguous rec
            self.store.pwb(SLOT_FILES[ind], buf)         # line 22
            self.store.pfence()                          # line 23
            self.store.pwb(INDEX_FILE, str(ind).encode())  # lines 25-26
            self.store.psync()                           # line 27
            self._mindex = ind
            self._returnval = retval
            self._deactivate = deact
            self._combine_count += 1
            self._last_combine = time.monotonic()
            for _, rec in active:
                rec.done_event.set()
            return len(active)

    @property
    def stats(self) -> Dict[str, Any]:
        return {"combines": self._combine_count,
                **dict(self.store.counters)}


class CheckpointAdapter(StructureAdapter):
    """Registers a ``PBCombCheckpointer`` as a runtime structure.

    One op, ``record(slot, seq, response)``: announce "slot's request
    ``seq`` completed with ``response``" into the durable response log.
    The batched path (``Handle.invoke_many``) announces every record of
    a round first and runs ONE combining round — one contiguous slot
    write, one psync, for any number of completions.  This is what the
    serving engine's completion path rides on.
    """

    kind, protocol = "log", "pbcomb"
    detectable = True
    OPS = {"record": OpSpec("RECORD", "main")}

    def create(self, nvm, n_threads, counters=None, **kw):
        raise NotImplementedError(
            "build a PBCombCheckpointer explicitly and runtime.register it")

    @staticmethod
    def _announce(core: PBCombCheckpointer, args: Tuple[int, int, Any]):
        slot, seq, response = args
        core.announce(slot, {}, seq, response=response)

    def invoke(self, core, p, op, args, seq):
        self._spec(op)
        self._announce(core, args)
        core.combine_once()
        return args[2]

    def invoke_batch(self, core, p, calls):
        for _op, args, _hseq in calls:
            self._announce(core, args)
        core.combine_once()                   # one round, one psync
        return [args[2] for _op, args, _hseq in calls]

    def recover(self, core, p, op, args, seq):
        """Exactly-once replay: the announce parity (slot seq mod 2) is
        filtered against the durable deactivate bits, so an already-
        applied record is not re-persisted."""
        self._announce(core, args)
        core.combine_once()
        return core.response(args[0])

    def reset_volatile(self, core):
        core.recover()

    def snapshot(self, core):
        return list(core._returnval)
