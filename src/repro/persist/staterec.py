"""StateRec serialization — persistence principle P3 made literal.

A checkpoint "StateRec" mirrors the paper's record layout:

    [ st (the payload pytree) | ReturnVal[0..n-1] | Deactivate[0..n-1] ]

``pack`` flattens the payload pytree into ONE contiguous byte buffer
(header + leaf data back-to-back), so the combiner persists a slot with a
single sequential write + one fsync — the paper's "place data to be
persisted in consecutive memory addresses so they are persisted all
together".  Responses and deactivate bits ride in the same buffer.

No framework dependencies: leaves are numpy-convertible arrays or
scalars.
"""

from __future__ import annotations

import io
import json
import struct
from typing import Any, Dict, List, Sequence, Tuple

import jax
import numpy as np

_MAGIC = b"PSCR1\n"


def _tree_spec(tree) -> Tuple[Any, List[np.ndarray]]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrs = [np.asarray(l) for l in leaves]
    return treedef, arrs


def pack(payload, return_val: Sequence[Any], deactivate: Sequence[int]) -> bytes:
    """Serialize (payload pytree, ReturnVal, Deactivate) contiguously."""
    treedef, arrs = _tree_spec(payload)
    meta = {
        "treedef": str(treedef),
        "leaves": [{"shape": a.shape, "dtype": str(a.dtype)} for a in arrs],
        "return_val": list(return_val),
        "deactivate": list(int(d) for d in deactivate),
    }
    mbytes = json.dumps(meta).encode()
    out = io.BytesIO()
    out.write(_MAGIC)
    out.write(struct.pack("<Q", len(mbytes)))
    out.write(mbytes)
    for a in arrs:
        out.write(np.ascontiguousarray(a).tobytes())
    return out.getvalue()


def unpack(data: bytes, payload_template) -> Tuple[Any, List[Any], List[int]]:
    """Deserialize against a template pytree (for structure + dtypes)."""
    assert data[:len(_MAGIC)] == _MAGIC, "corrupt or torn StateRec"
    off = len(_MAGIC)
    (mlen,) = struct.unpack_from("<Q", data, off)
    off += 8
    meta = json.loads(data[off:off + mlen].decode())
    off += mlen
    leaves, treedef = jax.tree_util.tree_flatten(payload_template)
    arrs = []
    for spec in meta["leaves"]:
        dt = np.dtype(spec["dtype"]) if spec["dtype"] != "bfloat16" \
            else np.dtype("uint16")
        n = int(np.prod(spec["shape"])) if spec["shape"] else 1
        raw = np.frombuffer(data, dtype=dt, count=n, offset=off)
        off += n * dt.itemsize
        arrs.append(raw.reshape(spec["shape"]))
    if len(arrs) != len(leaves):
        raise ValueError("template/record leaf mismatch")
    restored = []
    for tmpl, arr in zip(leaves, arrs):
        tmpl_np = np.asarray(tmpl)
        if tmpl_np.dtype != arr.dtype:       # bf16 round-trip via uint16
            arr = arr.view(tmpl_np.dtype) if arr.itemsize == tmpl_np.itemsize \
                else arr.astype(tmpl_np.dtype)
        restored.append(arr.reshape(tmpl_np.shape))
    payload = jax.tree_util.tree_unflatten(treedef, restored)
    return payload, meta["return_val"], meta["deactivate"]


def payload_nbytes(payload) -> int:
    _, arrs = _tree_spec(payload)
    return sum(a.nbytes for a in arrs)
