"""Recoverable data structures built on the combining protocols
(paper Section 5) plus the baseline competitors used in Section 6.

The per-structure calling conventions (``PBQueue.enqueue(p, value,
seq)``, ``PBStack.push(p, value, seq)``, ...) were deprecated in the
runtime-API PR and are now removed.  All callers go through
``repro.api``: ``CombiningRuntime.make(kind, protocol)`` + per-thread
handles (``rt.attach(p).bind(obj)``) — see DESIGN.md §1.  The protocol
entry points themselves (``PBComb.op`` / ``PWFComb.op``, Algorithm 1/3)
remain: they are what the adapters call.
"""

from .baselines import (DFCStack, DurableMSQueue, LockDirectObject,
                        LockUndoLogObject)
from .nodes import (NODE_WORDS, NULL, ChunkAllocator, NodePool,
                    PerThreadFreeList, RecyclingStack)
from .pbheap import PBHeap
from .pbqueue import PBQueue
from .pbstack import PBStack
from .pwfqueue import PWFQueue
from .pwfstack import PWFStack

__all__ = [
    "DFCStack", "DurableMSQueue", "LockDirectObject", "LockUndoLogObject",
    "NODE_WORDS", "NULL", "ChunkAllocator", "NodePool", "PerThreadFreeList",
    "RecyclingStack", "PBHeap", "PBQueue", "PBStack", "PWFQueue", "PWFStack",
]
