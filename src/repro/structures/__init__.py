"""Recoverable data structures built on the combining protocols
(paper Section 5) plus the baseline competitors used in Section 6."""

from .baselines import (DFCStack, DurableMSQueue, LockDirectObject,
                        LockUndoLogObject)
from .nodes import (NODE_WORDS, NULL, ChunkAllocator, NodePool,
                    PerThreadFreeList, RecyclingStack)
from .pbheap import PBHeap
from .pbqueue import PBQueue
from .pbstack import PBStack
from .pwfqueue import PWFQueue
from .pwfstack import PWFStack

__all__ = [
    "DFCStack", "DurableMSQueue", "LockDirectObject", "LockUndoLogObject",
    "NODE_WORDS", "NULL", "ChunkAllocator", "NodePool", "PerThreadFreeList",
    "RecyclingStack", "PBHeap", "PBQueue", "PBStack", "PWFQueue", "PWFStack",
]
