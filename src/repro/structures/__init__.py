"""Recoverable data structures built on the combining protocols
(paper Section 5) plus the baseline competitors used in Section 6.

.. deprecated::
   The per-structure calling conventions exposed here (explicit thread
   ids and seq numbers: ``PBQueue.enqueue(p, value, seq)``,
   ``PBStack.push(p, value, seq)``, manual ``reset_volatile`` +
   ``recover`` dances) are shims kept for one PR cycle.  New code goes
   through ``repro.api``: ``CombiningRuntime.make(kind, protocol)`` +
   per-thread handles (``rt.attach(p).bind(obj)``) — see DESIGN.md §1
   for the migration table.
"""

from .baselines import (DFCStack, DurableMSQueue, LockDirectObject,
                        LockUndoLogObject)
from .nodes import (NODE_WORDS, NULL, ChunkAllocator, NodePool,
                    PerThreadFreeList, RecyclingStack)
from .pbheap import PBHeap
from .pbqueue import PBQueue
from .pbstack import PBStack
from .pwfqueue import PWFQueue
from .pwfstack import PWFStack

__all__ = [
    "DFCStack", "DurableMSQueue", "LockDirectObject", "LockUndoLogObject",
    "NODE_WORDS", "NULL", "ChunkAllocator", "NodePool", "PerThreadFreeList",
    "RecyclingStack", "PBHeap", "PBQueue", "PBStack", "PWFQueue", "PWFStack",
]
