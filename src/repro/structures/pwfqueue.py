"""PWFQueue — wait-free recoverable queue over two PWFComb instances
(paper Section 5, combining PBQueue's split with SimQueue's two-part list).

An enqueuing pretend-combiner builds a *local* list of new nodes for all
active enqueues and publishes EState = (tail = last new node,
link_from = previous tail, link_to = first new node).  The real linked
list temporarily consists of two parts; **every** thread applies the
pending link (an idempotent same-value write) before serving requests,
and persists the node it updated (paper: "an enqueuer that connects the
linked list has to persist the new values of the node it updated").

Persistence order on the enqueue side (paper's analysis):
  1. new nodes pwb'd (``_pre_publish``) — before S_E can move;
  2. the EStateRec (tail/link_from/link_to + responses) pwb'd + pfence —
     so after a crash the pending link can always be *redone* from the
     durable record (``recover_links``);
  3. SC, pwb(S_E), psync.

Dequeue side: before serving, a dequeue round (a) helps the pending
link, and (b) if the current E-publication is not yet flushed
(Flush parity odd), helps persist S_E — the wait-free analogue of
PBQueue's ``oldTail`` guard: no value is handed out whose enqueue could
fail to survive a crash.  Dequeued values are read through the durable
boundary ``tail_e`` captured at that point.

GC: none — the paper explicitly leaves PWFQueue node recycling for future
work ("a solution would be more complicated, due to the two parts"), and
recycling here would expose helped-link writes to reused nodes.  Nodes
come from per-thread contiguous chunks and are never reused.
"""

from __future__ import annotations

from typing import Any, List

from ..core.nvm import NVM
from ..core.objects import SeqObject
from ..core.pwfcomb import PWFComb
from .nodes import NODE_WORDS, NULL, NodePool


class _EnqCtx:
    """Per-pretend-combiner enqueue context: plain attributes, one per
    thread (no thread-local lookups on the application hot path)."""

    __slots__ = ("pool", "p", "alloc", "first", "last")

    def __init__(self, pool: NodePool, p: int) -> None:
        self.pool = pool
        self.p = p
        self.alloc: List[int] = []
        self.first = NULL
        self.last = NULL


class _EnqState(SeqObject):
    """st = [tail, link_from, link_to]."""

    state_words = 3

    def __init__(self, dummy: int) -> None:
        self.dummy = dummy

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, self.dummy)
        nvm.write(st_base + 1, NULL)
        nvm.write(st_base + 2, NULL)

    def apply(self, nvm, st_base, func, args, ctx=None):
        node = ctx.pool.alloc(ctx.p)
        nvm.write(node, args)
        nvm.write(node + 1, NULL)
        ctx.alloc.append(node)
        if ctx.first == NULL:
            # First enqueue of this round: the previous tail becomes
            # link_from, this node link_to.
            ctx.first = node
            nvm.write(st_base + 1, nvm.read(st_base))   # link_from := tail
            nvm.write(st_base + 2, node)                # link_to := first new
        else:
            nvm.write(ctx.last + 1, node)               # chain locally
        ctx.last = node
        nvm.write(st_base, node)                        # tail := node
        return "ACK"


class _DeqCtx:
    __slots__ = ("boundary",)

    def __init__(self, boundary: int) -> None:
        self.boundary = boundary


class _DeqState(SeqObject):
    """st = [head]."""

    state_words = 1

    def __init__(self, dummy: int) -> None:
        self.dummy = dummy

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, self.dummy)

    def apply(self, nvm, st_base, func, args, ctx=None):
        head = nvm.read(st_base)
        if head == ctx.boundary:                 # durable frontier
            return None
        nxt = nvm.read(head + 1)
        if nxt == NULL:
            return None
        nvm.write(st_base, nxt)
        return nvm.read(nxt)


class _EnqInstance(PWFComb):
    def __init__(self, nvm, n, obj, queue, counters=None, backoff=True):
        super().__init__(nvm, n, obj, counters=counters, backoff=backoff)
        self.queue = queue
        self.pool = queue.pool
        self._ctx = [_EnqCtx(queue.pool, p) for p in range(n)]

    def _apply(self, q, func, args, slot, combiner):
        return self.obj.apply(self.nvm, self._base(slot), func, args,
                              ctx=self._ctx[combiner])

    def _begin_attempt(self, slot: int, p: int) -> None:
        ctx = self._ctx[p]
        ctx.alloc = []
        ctx.first = NULL
        ctx.last = NULL
        self.queue.help_link()  # apply the previous round's pending link

    def _pre_publish(self, slot: int, p: int):
        alloc = self._ctx[p].alloc
        if alloc:
            return [(node, NODE_WORDS) for node in alloc]
        return None

    def _attempt_failed(self, slot: int, p: int) -> None:
        # No recycling (see module doc); just drop the bookkeeping.
        ctx = self._ctx[p]
        ctx.alloc = []
        ctx.first = NULL
        ctx.last = NULL


class _DeqInstance(PWFComb):
    def __init__(self, nvm, n, obj, queue, counters=None, backoff=True):
        super().__init__(nvm, n, obj, counters=counters, backoff=backoff)
        self.queue = queue
        self._ctx = [_DeqCtx(queue.dummy) for _ in range(n)]

    def _apply(self, q, func, args, slot, combiner):
        return self.obj.apply(self.nvm, self._base(slot), func, args,
                              ctx=self._ctx[combiner])

    def _begin_attempt(self, slot: int, p: int) -> None:
        # Help the pending link, then make the current enqueue publication
        # durable before adopting its tail as the dequeue frontier.
        self.queue.help_link()
        self._ctx[p].boundary = self.queue.durable_tail()


class PWFQueue:
    def __init__(self, nvm: NVM, n_threads: int, *, chunk_nodes: int = 256,
                 counters=None, backoff: bool = True) -> None:
        self.nvm = nvm
        self.n = n_threads
        self.dummy = nvm.alloc(NODE_WORDS)
        nvm.write(self.dummy, None)
        nvm.write(self.dummy + 1, NULL)
        nvm.pwb(self.dummy, NODE_WORDS)
        nvm.psync()
        self.pool = NodePool(nvm, n_threads, None, chunk_nodes)
        self.enq = _EnqInstance(nvm, n_threads, _EnqState(self.dummy), self,
                                counters=counters, backoff=backoff)
        self.deq = _DeqInstance(nvm, n_threads, _DeqState(self.dummy), self,
                                counters=counters, backoff=backoff)
        nvm.reset_counters()

    # ------------------ linking helpers --------------------------------- #
    def help_link(self) -> None:
        """Apply the currently pending two-part link (idempotent: all
        helpers write the same value) and persist the updated node."""
        nvm = self.nvm
        slot = self.enq.S.load()
        st = self.enq._base(slot)
        lf, lt = nvm.read(st + 1), nvm.read(st + 2)
        if lf != NULL and lt != NULL and nvm.read(lf + 1) != lt:
            nvm.write(lf + 1, lt)
            nvm.pwb(lf, NODE_WORDS)
            nvm.pfence()

    def durable_tail(self) -> int:
        """Make the current E-publication durable if needed, then return
        its tail — every node up to it is crash-safe to hand out."""
        nvm = self.nvm
        slot = self.enq.S.load()
        s_pid = nvm.read(self.enq._pid_addr(slot))
        lval = self.enq.flush[s_pid]
        if lval % 2 == 1:                       # publication not yet flushed
            nvm.pwb(self.enq.s_addr, 1)
            nvm.psync()
            self.enq._cas_flush(s_pid, lval, lval + 1)
        return nvm.read(self.enq._base(slot))

    # ------------------ recovery ----------------------------------------- #
    def reset_volatile(self) -> None:
        self.enq.reset_volatile()
        self.deq.reset_volatile()
        self.enq._ctx = [_EnqCtx(self.pool, p) for p in range(self.n)]
        self.deq._ctx = [_DeqCtx(self.dummy) for _ in range(self.n)]
        # Redo the pending link from the durable EState record, then
        # persist it (paper: links must be redoable after a crash).
        self.help_link()
        self.nvm.psync()

    def recover(self, p: int, func: str, args: Any, seq: int) -> Any:
        if func == "ENQ":
            return self.enq.recover(p, func, args, seq)
        return self.deq.recover(p, func, args, seq)

    # ------------------ introspection ------------------------------------ #
    def drain(self) -> List[Any]:
        self.help_link()
        out = []
        addr = self.nvm.read(self.deq._base(self.deq.S.load()))
        addr = self.nvm.read(addr + 1)
        while addr != NULL:
            out.append(self.nvm.read(addr))
            addr = self.nvm.read(addr + 1)
        return out
