"""PWFQueue — wait-free recoverable queue over two PWFComb instances
(paper Section 5, combining PBQueue's split with SimQueue's two-part list).

An enqueuing pretend-combiner builds a *local* list of new nodes for all
active enqueues and publishes EState = (tail = last new node,
link_from = previous tail, link_to = first new node).  The real linked
list temporarily consists of two parts; **every** thread applies the
pending link (an idempotent same-value write) before serving requests,
and persists the node it updated (paper: "an enqueuer that connects the
linked list has to persist the new values of the node it updated").

Persistence order on the enqueue side (paper's analysis):
  1. new nodes pwb'd (``_pre_publish``) — before S_E can move;
  2. the EStateRec (tail/link_from/link_to + responses) pwb'd + pfence —
     so after a crash the pending link can always be *redone* from the
     durable record (``recover_links``);
  3. SC, pwb(S_E), psync.

Dequeue side: before serving, a dequeue round (a) helps the pending
link, and (b) if the current E-publication is not yet flushed
(Flush parity odd), helps persist S_E — the wait-free analogue of
PBQueue's ``oldTail`` guard: no value is handed out whose enqueue could
fail to survive a crash.  Dequeued values are read through the durable
boundary ``tail_e`` captured at that point.

GC: the paper explicitly leaves PWFQueue node recycling for future work
("a solution would be more complicated, due to the two parts") — the
hazard being that helped-link writes and slow pretend-combiners may
touch a node long after the round that removed it.  This reproduction
closes the gap with the epoch-based limbo layer of
``repro.persist.reclaim`` (DESIGN.md §13): each successful dequeue
round retires the sentinel it buried (only after S_D is durable, so the
node is unreachable from any durable state), every ``_perform_request``
runs pinned (a stale helper that read link_from before the node was
retired blocks its reuse), and nodes re-enter allocation only from the
durable free window that ``quiesce()`` advances.  Workloads that never
quiesce allocate exactly like the unreclaimed original — the hot path
adds volatile-image bookkeeping only.  Pass ``reclaim=None`` for the
paper's never-reuse behavior.
"""

from __future__ import annotations

from typing import Any, List, Optional

from ..core.nvm import NVM
from ..core.objects import SeqObject
from ..core.pwfcomb import PWFComb
from ..persist.reclaim import EpochReclaimer
from .nodes import NODE_WORDS, NULL, NodePool


class _EnqCtx:
    """Per-pretend-combiner enqueue context: plain attributes, one per
    thread (no thread-local lookups on the application hot path)."""

    __slots__ = ("pool", "p", "alloc", "first", "last")

    def __init__(self, pool: NodePool, p: int) -> None:
        self.pool = pool
        self.p = p
        self.alloc: List[int] = []
        self.first = NULL
        self.last = NULL


class _EnqState(SeqObject):
    """st = [tail, link_from, link_to]."""

    state_words = 3

    def __init__(self, dummy: int) -> None:
        self.dummy = dummy

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, self.dummy)
        nvm.write(st_base + 1, NULL)
        nvm.write(st_base + 2, NULL)

    def apply(self, nvm, st_base, func, args, ctx=None):
        node = ctx.pool.alloc(ctx.p)
        nvm.write(node, args)
        nvm.write(node + 1, NULL)
        ctx.alloc.append(node)
        if ctx.first == NULL:
            # First enqueue of this round: the previous tail becomes
            # link_from, this node link_to.
            ctx.first = node
            nvm.write(st_base + 1, nvm.read(st_base))   # link_from := tail
            nvm.write(st_base + 2, node)                # link_to := first new
        else:
            nvm.write(ctx.last + 1, node)               # chain locally
        ctx.last = node
        nvm.write(st_base, node)                        # tail := node
        return "ACK"


class _DeqCtx:
    __slots__ = ("boundary", "retired")

    def __init__(self, boundary: int) -> None:
        self.boundary = boundary
        self.retired: List[int] = []


class _DeqState(SeqObject):
    """st = [head]."""

    state_words = 1

    def __init__(self, dummy: int) -> None:
        self.dummy = dummy

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, self.dummy)

    def apply(self, nvm, st_base, func, args, ctx=None):
        head = nvm.read(st_base)
        if head == ctx.boundary:                 # durable frontier
            return None
        nxt = nvm.read(head + 1)
        if nxt == NULL:
            return None
        nvm.write(st_base, nxt)
        # the dequeued node becomes the NEW sentinel; the node this
        # round buries is the PREVIOUS sentinel ``head`` — recorded now,
        # retired only if this attempt publishes (S_D durable)
        ctx.retired.append(head)
        return nvm.read(nxt)


class _EnqInstance(PWFComb):
    def __init__(self, nvm, n, obj, queue, counters=None, backoff=True):
        super().__init__(nvm, n, obj, counters=counters, backoff=backoff)
        self.queue = queue
        self.pool = queue.pool
        self._ctx = [_EnqCtx(queue.pool, p) for p in range(n)]

    def _apply(self, q, func, args, slot, combiner):
        return self.obj.apply(self.nvm, self._base(slot), func, args,
                              ctx=self._ctx[combiner])

    def _perform_request(self, p: int):
        rec = self.queue.reclaim
        if rec is None:
            return super()._perform_request(p)
        rec.pin(p)
        try:
            return super()._perform_request(p)
        finally:
            rec.unpin(p)

    def _begin_attempt(self, slot: int, p: int) -> None:
        ctx = self._ctx[p]
        ctx.alloc = []
        ctx.first = NULL
        ctx.last = NULL
        self.queue.help_link()  # apply the previous round's pending link

    def _pre_publish(self, slot: int, p: int):
        alloc = self._ctx[p].alloc
        if alloc:
            return [(node, NODE_WORDS) for node in alloc]
        return None

    def _on_publish_success(self, slot: int, p: int) -> None:
        rec = self.queue.reclaim
        if rec is not None:
            rec.advance()

    def _attempt_failed(self, slot: int, p: int) -> None:
        ctx = self._ctx[p]
        rec = self.queue.reclaim
        if rec is not None:
            # losing attempt: the fresh nodes were never published
            # (not reachable from any state), so they go straight into
            # limbo instead of leaking like the paper's original
            for node in ctx.alloc:
                rec.retire(p, node)
        ctx.alloc = []
        ctx.first = NULL
        ctx.last = NULL


class _DeqInstance(PWFComb):
    def __init__(self, nvm, n, obj, queue, counters=None, backoff=True):
        super().__init__(nvm, n, obj, counters=counters, backoff=backoff)
        self.queue = queue
        self._ctx = [_DeqCtx(queue.dummy) for _ in range(n)]

    def _apply(self, q, func, args, slot, combiner):
        return self.obj.apply(self.nvm, self._base(slot), func, args,
                              ctx=self._ctx[combiner])

    def _perform_request(self, p: int):
        rec = self.queue.reclaim
        if rec is None:
            return super()._perform_request(p)
        rec.pin(p)
        try:
            return super()._perform_request(p)
        finally:
            rec.unpin(p)

    def _begin_attempt(self, slot: int, p: int) -> None:
        # Help the pending link, then make the current enqueue publication
        # durable before adopting its tail as the dequeue frontier.
        self.queue.help_link()
        ctx = self._ctx[p]
        ctx.boundary = self.queue.durable_tail()
        ctx.retired = []

    def _on_publish_success(self, slot: int, p: int) -> None:
        ctx = self._ctx[p]
        rec = self.queue.reclaim
        if rec is not None:
            # S_D is durable past these sentinels: no durable state can
            # ever reach them again — safe to enter limbo
            for node in ctx.retired:
                rec.retire(p, node)
            rec.advance()
        ctx.retired = []

    def _attempt_failed(self, slot: int, p: int) -> None:
        # losing attempt: the buried-sentinel list was speculative
        self._ctx[p].retired = []


class PWFQueue:
    def __init__(self, nvm: NVM, n_threads: int, *, chunk_nodes: int = 256,
                 reclaim: Optional[str] = "epoch", reclaim_cap: int = 512,
                 counters=None, backoff: bool = True) -> None:
        if reclaim not in (None, "epoch"):
            raise ValueError(f"reclaim must be None or 'epoch', "
                             f"got {reclaim!r}")
        self.nvm = nvm
        self.n = n_threads
        self.dummy = nvm.alloc(NODE_WORDS)
        nvm.write(self.dummy, None)
        nvm.write(self.dummy + 1, NULL)
        nvm.pwb(self.dummy, NODE_WORDS)
        nvm.psync()
        # the reclaimer allocates its epoch/limbo words here, before the
        # trailing reset_counters — construction costs never reach the
        # gated modeled trajectory
        self.reclaim = (EpochReclaimer(nvm, n_threads, reclaim_cap)
                        if reclaim == "epoch" else None)
        self.pool = NodePool(nvm, n_threads, self.reclaim, chunk_nodes)
        self.enq = _EnqInstance(nvm, n_threads, _EnqState(self.dummy), self,
                                counters=counters, backoff=backoff)
        self.deq = _DeqInstance(nvm, n_threads, _DeqState(self.dummy), self,
                                counters=counters, backoff=backoff)
        nvm.reset_counters()

    # ------------------ reclamation -------------------------------------- #
    def quiesce(self):
        """Advance the durable limbo/free boundaries (coordinator-side,
        at a quiescent point).  No-op without a reclaimer."""
        if self.reclaim is None:
            return None
        return self.reclaim.quiesce()

    # ------------------ linking helpers --------------------------------- #
    def help_link(self) -> None:
        """Apply the currently pending two-part link (idempotent: all
        helpers write the same value) and persist the updated node."""
        nvm = self.nvm
        slot = self.enq.S.load()
        st = self.enq._base(slot)
        lf, lt = nvm.read(st + 1), nvm.read(st + 2)
        if lf != NULL and lt != NULL and nvm.read(lf + 1) != lt:
            nvm.write(lf + 1, lt)
            nvm.pwb(lf, NODE_WORDS)
            nvm.pfence()

    def durable_tail(self) -> int:
        """Make the current E-publication durable if needed, then return
        its tail — every node up to it is crash-safe to hand out."""
        nvm = self.nvm
        slot = self.enq.S.load()
        s_pid = nvm.read(self.enq._pid_addr(slot))
        lval = self.enq.flush[s_pid]
        if lval % 2 == 1:                       # publication not yet flushed
            nvm.pwb(self.enq.s_addr, 1)
            nvm.psync()
            self.enq._cas_flush(s_pid, lval, lval + 1)
        return nvm.read(self.enq._base(slot))

    # ------------------ recovery ----------------------------------------- #
    def reset_volatile(self) -> None:
        self.enq.reset_volatile()
        self.deq.reset_volatile()
        self.enq._ctx = [_EnqCtx(self.pool, p) for p in range(self.n)]
        self.deq._ctx = [_DeqCtx(self.dummy) for _ in range(self.n)]
        if self.reclaim is not None:
            self.reclaim.recover()
        # Redo the pending link from the durable EState record, then
        # persist it (paper: links must be redoable after a crash).
        self.help_link()
        self.nvm.psync()

    def recover(self, p: int, func: str, args: Any, seq: int) -> Any:
        if func == "ENQ":
            return self.enq.recover(p, func, args, seq)
        return self.deq.recover(p, func, args, seq)

    # ------------------ introspection ------------------------------------ #
    def drain(self) -> List[Any]:
        self.help_link()
        out = []
        addr = self.nvm.read(self.deq._base(self.deq.S.load()))
        addr = self.nvm.read(addr + 1)
        while addr != NULL:
            out.append(self.nvm.read(addr))
            addr = self.nvm.read(addr + 1)
        return out
