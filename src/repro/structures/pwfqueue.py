"""PWFQueue — wait-free recoverable queue over two PWFComb instances
(paper Section 5, combining PBQueue's split with SimQueue's two-part list).

An enqueuing pretend-combiner builds a *local* list of new nodes for all
active enqueues and publishes EState = (tail = last new node,
link_from = previous tail, link_to = first new node).  The real linked
list temporarily consists of two parts; **every** thread applies the
pending link (an idempotent same-value write) before serving requests,
and persists the node it updated (paper: "an enqueuer that connects the
linked list has to persist the new values of the node it updated").

Persistence order on the enqueue side (paper's analysis):
  1. new nodes pwb'd (``_pre_publish``) — before S_E can move;
  2. the EStateRec (tail/link_from/link_to + responses) pwb'd + pfence —
     so after a crash the pending link can always be *redone* from the
     durable record (``recover_links``);
  3. SC, pwb(S_E), psync.

Dequeue side: before serving, a dequeue round (a) helps the pending
link, and (b) if the current E-publication is not yet flushed
(Flush parity odd), helps persist S_E — the wait-free analogue of
PBQueue's ``oldTail`` guard: no value is handed out whose enqueue could
fail to survive a crash.  Dequeued values are read through the durable
boundary ``tail_e`` captured at that point.

GC: none — the paper explicitly leaves PWFQueue node recycling for future
work ("a solution would be more complicated, due to the two parts"), and
recycling here would expose helped-link writes to reused nodes.  Nodes
come from per-thread contiguous chunks and are never reused.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List

from ..core.nvm import NVM
from ..core.objects import SeqObject
from ..core.pwfcomb import PWFComb
from .nodes import NODE_WORDS, NULL, NodePool


class _EnqState(SeqObject):
    """st = [tail, link_from, link_to]."""

    state_words = 3

    def __init__(self, dummy: int) -> None:
        self.dummy = dummy

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, self.dummy)
        nvm.write(st_base + 1, NULL)
        nvm.write(st_base + 2, NULL)

    def apply(self, nvm, st_base, func, args, ctx=None):
        p = ctx.current_combiner
        node = ctx.pool.alloc(p)
        nvm.write(node, args)
        nvm.write(node + 1, NULL)
        ctx.attempt_alloc(p).append(node)
        local = ctx.attempt_local(p)
        if local["first"] == NULL:
            # First enqueue of this round: the previous tail becomes
            # link_from, this node link_to.
            local["first"] = node
            nvm.write(st_base + 1, nvm.read(st_base))   # link_from := tail
            nvm.write(st_base + 2, node)                # link_to := first new
        else:
            nvm.write(local["last"] + 1, node)          # chain locally
        local["last"] = node
        nvm.write(st_base, node)                        # tail := node
        return "ACK"


class _DeqState(SeqObject):
    """st = [head]."""

    state_words = 1

    def __init__(self, dummy: int) -> None:
        self.dummy = dummy

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, self.dummy)

    def apply(self, nvm, st_base, func, args, ctx=None):
        head = nvm.read(st_base)
        if head == ctx.boundary(ctx.current_combiner):  # durable frontier
            return None
        nxt = nvm.read(head + 1)
        if nxt == NULL:
            return None
        nvm.write(st_base, nxt)
        return nvm.read(nxt)


class _EnqInstance(PWFComb):
    def __init__(self, nvm, n, obj, queue, counters=None, backoff=True):
        super().__init__(nvm, n, obj, counters=counters, backoff=backoff)
        self.queue = queue
        self.pool = queue.pool
        self._tls = threading.local()
        self._allocs: Dict[int, List[int]] = {p: [] for p in range(n)}
        self._local: Dict[int, Dict[str, int]] = {
            p: {"first": NULL, "last": NULL} for p in range(n)}

    # context accessors used by _EnqState.apply
    @property
    def current_combiner(self):
        return self._tls.combiner

    def attempt_alloc(self, p):
        return self._allocs[p]

    def attempt_local(self, p):
        return self._local[p]

    def _apply(self, q, func, args, slot, combiner):
        self._tls.combiner = combiner
        return self.obj.apply(self.nvm, self._base(slot), func, args, ctx=self)

    def _begin_attempt(self, slot: int, p: int) -> None:
        self._allocs[p] = []
        self._local[p] = {"first": NULL, "last": NULL}
        self.queue.help_link()  # apply the previous round's pending link

    def _pre_publish(self, slot: int, p: int) -> None:
        for node in self._allocs[p]:
            self.nvm.pwb(node, NODE_WORDS)

    def _attempt_failed(self, slot: int, p: int) -> None:
        # No recycling (see module doc); just drop the bookkeeping.
        self._allocs[p] = []
        self._local[p] = {"first": NULL, "last": NULL}


class _DeqInstance(PWFComb):
    def __init__(self, nvm, n, obj, queue, counters=None, backoff=True):
        super().__init__(nvm, n, obj, counters=counters, backoff=backoff)
        self.queue = queue
        self._tls = threading.local()
        self._boundary: Dict[int, int] = {p: queue.dummy for p in range(n)}

    @property
    def current_combiner(self):
        return self._tls.combiner

    def boundary(self, p):
        return self._boundary[p]

    def _apply(self, q, func, args, slot, combiner):
        self._tls.combiner = combiner
        return self.obj.apply(self.nvm, self._base(slot), func, args, ctx=self)

    def _begin_attempt(self, slot: int, p: int) -> None:
        # Help the pending link, then make the current enqueue publication
        # durable before adopting its tail as the dequeue frontier.
        self.queue.help_link()
        self._boundary[p] = self.queue.durable_tail()


class PWFQueue:
    def __init__(self, nvm: NVM, n_threads: int, *, chunk_nodes: int = 256,
                 counters=None, backoff: bool = True) -> None:
        self.nvm = nvm
        self.n = n_threads
        self.dummy = nvm.alloc(NODE_WORDS)
        nvm.write(self.dummy, None)
        nvm.write(self.dummy + 1, NULL)
        nvm.pwb(self.dummy, NODE_WORDS)
        nvm.psync()
        self.pool = NodePool(nvm, n_threads, None, chunk_nodes)
        self.enq = _EnqInstance(nvm, n_threads, _EnqState(self.dummy), self,
                                counters=counters, backoff=backoff)
        self.deq = _DeqInstance(nvm, n_threads, _DeqState(self.dummy), self,
                                counters=counters, backoff=backoff)
        nvm.reset_counters()

    # ------------------ linking helpers --------------------------------- #
    def help_link(self) -> None:
        """Apply the currently pending two-part link (idempotent: all
        helpers write the same value) and persist the updated node."""
        nvm = self.nvm
        slot = self.enq.S.load()
        st = self.enq._base(slot)
        lf, lt = nvm.read(st + 1), nvm.read(st + 2)
        if lf != NULL and lt != NULL and nvm.read(lf + 1) != lt:
            nvm.write(lf + 1, lt)
            nvm.pwb(lf, NODE_WORDS)
            nvm.pfence()

    def durable_tail(self) -> int:
        """Make the current E-publication durable if needed, then return
        its tail — every node up to it is crash-safe to hand out."""
        nvm = self.nvm
        slot = self.enq.S.load()
        s_pid = nvm.read(self.enq._pid_addr(slot))
        lval = self.enq.flush[s_pid]
        if lval % 2 == 1:                       # publication not yet flushed
            nvm.pwb(self.enq.s_addr, 1)
            nvm.psync()
            self.enq._cas_flush(s_pid, lval, lval + 1)
        return nvm.read(self.enq._base(slot))

    # ---------- public API (deprecated shims — use repro.api) ------------ #
    def enqueue(self, p: int, value: Any, seq: int) -> Any:
        """.. deprecated:: use ``handle.bind(obj).enqueue(value)``."""
        return self.enq.op(p, "ENQ", value, seq)

    def dequeue(self, p: int, seq: int) -> Any:
        """.. deprecated:: use ``handle.bind(obj).dequeue()``."""
        return self.deq.op(p, "DEQ", None, seq)

    # ------------------ recovery ----------------------------------------- #
    def reset_volatile(self) -> None:
        self.enq.reset_volatile()
        self.deq.reset_volatile()
        self.enq._local = {p: {"first": NULL, "last": NULL}
                           for p in range(self.n)}
        self.enq._allocs = {p: [] for p in range(self.n)}
        self.deq._boundary = {p: self.dummy for p in range(self.n)}
        # Redo the pending link from the durable EState record, then
        # persist it (paper: links must be redoable after a crash).
        self.help_link()
        self.nvm.psync()

    def recover(self, p: int, func: str, args: Any, seq: int) -> Any:
        if func == "ENQ":
            return self.enq.recover(p, func, args, seq)
        return self.deq.recover(p, func, args, seq)

    # ------------------ introspection ------------------------------------ #
    def drain(self) -> List[Any]:
        self.help_link()
        out = []
        addr = self.nvm.read(self.deq._base(self.deq.S.load()))
        addr = self.nvm.read(addr + 1)
        while addr != NULL:
            out.append(self.nvm.read(addr))
            addr = self.nvm.read(addr + 1)
        return out
