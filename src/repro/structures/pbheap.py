"""PBHeap — the first recoverable concurrent heap (paper Section 5).

A single PBComb instance over a sequential bounded min-heap whose entire
array lives in the StateRec ``st`` field: the combiner's one contiguous
pwb covers the whole heap + responses + deactivate bits (P3).  The paper
measures good performance for small/medium heaps (64-1024 keys) — the
state-copy cost grows with capacity, which our heap benchmark reproduces.
"""

from __future__ import annotations

from typing import Any, Optional

from ..core.nvm import NVM
from ..core.objects import HeapObject
from ..core.pbcomb import PBComb


class PBHeap(PBComb):
    def __init__(self, nvm: NVM, n_threads: int, capacity: int = 256,
                 counters=None, vector_apply: bool = False) -> None:
        super().__init__(nvm, n_threads, HeapObject(capacity),
                         counters=counters, vector_apply=vector_apply)
        self.capacity = capacity

    def size(self) -> int:
        return self.nvm.read(self._st_base(self._mindex()))
