"""PBHeap — the first recoverable concurrent heap (paper Section 5).

A single PBComb instance over a sequential bounded min-heap whose entire
array lives in the StateRec ``st`` field: the combiner's one contiguous
pwb covers the whole heap + responses + deactivate bits (P3).  The paper
measures good performance for small/medium heaps (64-1024 keys) — the
state-copy cost grows with capacity, which our heap benchmark reproduces.
"""

from __future__ import annotations

from typing import Any, Optional

from ..core.nvm import NVM
from ..core.objects import HeapObject
from ..core.pbcomb import PBComb


class PBHeap(PBComb):
    def __init__(self, nvm: NVM, n_threads: int, capacity: int = 256,
                 counters=None) -> None:
        super().__init__(nvm, n_threads, HeapObject(capacity),
                         counters=counters)
        self.capacity = capacity

    # ------------- public API (deprecated shims — use repro.api) -------- #
    def insert(self, p: int, key: Any, seq: int) -> Any:
        """.. deprecated:: use ``handle.bind(obj).insert(key)``."""
        return self.op(p, "HINSERT", key, seq)

    def delete_min(self, p: int, seq: int) -> Any:
        """.. deprecated:: use ``handle.bind(obj).delete_min()``."""
        return self.op(p, "HDELETEMIN", None, seq)

    def get_min(self, p: int, seq: int) -> Any:
        """.. deprecated:: use ``handle.bind(obj).get_min()``."""
        return self.op(p, "HGETMIN", None, seq)

    def size(self) -> int:
        return self.nvm.read(self._st_base(self._mindex()))
