"""Baseline persistent algorithms the paper compares against (Section 6).

These are simplified but mechanism-faithful stand-ins for the published
competitors, reproducing their *persistence-cost shape* (where pwbs land,
how many per op, contention on persisted lines):

  * ``LockDirectObject`` — coarse lock, updates applied **directly** on
    the shared NVMM state, per-op pwb + pfence + psync (the design
    decision the paper argues against: scattered per-op persists).
  * ``LockUndoLogObject`` — PMDK-style: persist an undo-log entry, then
    the in-place update (2 rounds of pwb+pfence per op + psync) —
    log-based PTM cost shape (Romulus/PMDK class).
  * ``DurableMSQueue`` — FHMP-class durable Michael-Scott queue: per-op
    CAS on head/tail + pwbs of the touched node, next pointer, and the
    head/tail word; every thread persists its own operation.
  * ``DFCStack`` — detectable flat-combining stack (Rusanovsky et al.):
    combining, but (a) each thread persists its own announcement, (b) the
    combiner updates the shared state directly, and (c) each return value
    is persisted separately — all three decisions the paper's Section 6
    identifies as DFC's overhead sources.

All operate on the same simulated NVM so pwb/pfence/psync counters are
directly comparable; with ``pwb_nop``/``psync_nop`` they reproduce the
"no-pwb"/"no-psync" ablations (paper Figures 3/6).
"""

from __future__ import annotations

import time
from typing import Any, List

from ..core.nvm import NVM, SimulatedCrash
from ..core.objects import SeqObject
from .nodes import NODE_WORDS, NULL, NodePool


class LockDirectObject:
    """Global lock + direct in-place NVMM updates + per-op persistence."""

    def __init__(self, nvm: NVM, n_threads: int, obj: SeqObject) -> None:
        self.nvm = nvm
        self.obj = obj
        self.st_base = nvm.alloc(obj.state_words)
        obj.init_state(nvm, self.st_base)
        nvm.pwb(self.st_base, obj.state_words)
        nvm.psync()
        nvm.reset_counters()
        self._lock = nvm.backend.mutex()
        # Virtual-clock release time of the last critical section: the
        # next holder merges it, so modeled time reflects the full
        # serialization a coarse lock imposes (no amortization).
        self._lock_vt = 0.0

    def op(self, p: int, func: str, args: Any, seq: int) -> Any:
        nvm = self.nvm
        with self._lock:
            clk = nvm.clock
            if clk is not None:
                clk.advance(clk.profile.cas_ns)      # lock acquire
                clk.merge(self._lock_vt)             # serialized entry
            # persist only the touched lines when the object can name
            # them (the baselines' real scattered-persist cost shape);
            # small objects without a plan persist their whole state
            plan = getattr(self.obj, "touch_plan", None)
            ranges = plan(nvm, self.st_base, func, args) if plan else None
            ret = self.obj.apply(nvm, self.st_base, func, args)
            if func in self.obj.READ_ONLY:
                # declared read-only: nothing written, and the response
                # depends only on state already psync'd under this lock
                # — fencing an empty epoch would be pure waste
                if clk is not None:
                    self._lock_vt = clk.now()
                return ret
            if ranges is None:
                nvm.pwb_range(self.st_base, self.obj.state_words)
            elif ranges:
                base = self.st_base
                nvm.persist_lines((base + off, n) for off, n in ranges)
            nvm.pfence()
            nvm.psync()
            if clk is not None:
                self._lock_vt = clk.now()
            return ret

    def reset_volatile(self) -> None:
        """Post-crash re-initialization: only the lock is volatile.  No
        rollback is possible — a crash mid-update can leave torn state
        (the failure mode the paper's combining protocols remove)."""
        self._lock = self.nvm.backend.reset_mutex(self._lock)

    def recover(self, p: int, func: str, args: Any, seq: int) -> Any:
        """Not detectable: an in-flight op is simply re-executed
        (at-least-once semantics — the baseline's documented weakness)."""
        return self.op(p, func, args, seq)


class LockUndoLogObject:
    """Lock + undo log persisted before each in-place update (PMDK shape)."""

    def __init__(self, nvm: NVM, n_threads: int, obj: SeqObject) -> None:
        self.nvm = nvm
        self.obj = obj
        self.st_base = nvm.alloc(obj.state_words)
        self.log_base = nvm.alloc(obj.state_words + 1)  # snapshot + valid
        obj.init_state(nvm, self.st_base)
        nvm.pwb(self.st_base, obj.state_words)
        nvm.psync()
        nvm.reset_counters()
        self._lock = nvm.backend.mutex()
        self._lock_vt = 0.0   # see LockDirectObject

    def op(self, p: int, func: str, args: Any, seq: int) -> Any:
        nvm = self.nvm
        with self._lock:
            clk = nvm.clock
            if clk is not None:
                clk.advance(clk.profile.cas_ns)
                clk.merge(self._lock_vt)
            plan = getattr(self.obj, "touch_plan", None)
            ranges = plan(nvm, self.st_base, func, args) if plan else None
            if func in self.obj.READ_ONLY:
                # declared read-only: no stores to log or roll back (a
                # PMDK transaction with no stores writes no log), and
                # prior ops drained their epochs before releasing the
                # lock.  Ops that merely MAY be no-ops (stale CKPT) are
                # not exempt: this baseline's documented shape pays its
                # unconditional log + fence + psync there.
                ret = self.obj.apply(nvm, self.st_base, func, args)
                if clk is not None:
                    self._lock_vt = clk.now()
                return ret
            # 1. persist undo record: word-granular entries for the
            #    words about to change (PMDK logs ranges, not the whole
            #    object); objects without a plan snapshot full state.
            #    Ranged log layout: [count | (offset, old_value)* | valid]
            if ranges is None:
                nvm.copy_range(self.log_base, self.st_base,
                               self.obj.state_words)
                nvm.pwb_range(self.log_base, self.obj.state_words)
            else:
                entries: List[Any] = []
                for off, cnt in ranges:
                    vals = nvm.read_range(self.st_base + off, cnt)
                    for j in range(cnt):
                        entries.append(off + j)
                        entries.append(vals[j])
                n = len(entries) // 2
                nvm.write(self.log_base, n)
                nvm.write_range(self.log_base + 1, entries)
                nvm.pwb_range(self.log_base, 2 * n + 1)
            # the log entries' epoch must fully drain before the valid
            # flag can: without this fence a crash may persist valid=1
            # over a STALE log image, and recovery would roll back
            # acknowledged (psync'd) operations
            nvm.pfence()
            nvm.write(self.log_base + self.obj.state_words, 1)  # valid
            nvm.pwb(self.log_base + self.obj.state_words, 1)
            nvm.pfence()
            # 2. in-place update + persist touched lines (one coalesced
            #    line-set, like every other per-op persist in this file)
            ret = self.obj.apply(nvm, self.st_base, func, args)
            if ranges is None:
                nvm.pwb_range(self.st_base, self.obj.state_words)
            elif ranges:
                base = self.st_base
                nvm.persist_lines((base + off, cnt) for off, cnt in ranges)
            nvm.pfence()
            # 3. invalidate log
            nvm.write(self.log_base + self.obj.state_words, 0)
            nvm.pwb(self.log_base + self.obj.state_words, 1)
            nvm.psync()
            if clk is not None:
                self._lock_vt = clk.now()
            return ret

    def reset_volatile(self) -> None:
        """Post-crash: recreate the lock and roll back a torn in-place
        update from the persisted undo record (PMDK-style recovery).
        Both log layouts are handled: ranged entries for objects with a
        ``touch_plan``, full-state snapshot otherwise."""
        self._lock = self.nvm.backend.reset_mutex(self._lock)
        nvm = self.nvm
        if nvm.read(self.log_base + self.obj.state_words) == 1:
            if hasattr(self.obj, "touch_plan"):
                n = nvm.read(self.log_base)
                for i in range(n):
                    off = nvm.read(self.log_base + 1 + 2 * i)
                    val = nvm.read(self.log_base + 2 + 2 * i)
                    nvm.write(self.st_base + off, val)
                    nvm.pwb(self.st_base + off, 1)
            else:
                nvm.write_range(self.st_base,
                                nvm.read_range(self.log_base,
                                               self.obj.state_words))
                nvm.pwb(self.st_base, self.obj.state_words)
            nvm.pfence()
            nvm.write(self.log_base + self.obj.state_words, 0)
            nvm.pwb(self.log_base + self.obj.state_words, 1)
            nvm.psync()

    def recover(self, p: int, func: str, args: Any, seq: int) -> Any:
        """Not detectable: the log restores atomicity of the interrupted
        update, but whether the op took effect is unknowable — re-execute
        (at-least-once semantics)."""
        return self.op(p, func, args, seq)


class DurableMSQueue:
    """Durable Michael-Scott queue (FHMP-style persistence placement).

    Lock-free CAS loop; each operation persists the node it created, the
    predecessor's next pointer, and the head/tail word it swung — every
    thread runs its own persistence instructions (vs. one combiner),
    which is exactly the contrast the paper's Figures 4-5 measure.

    The volatile head/tail refs MIRROR into their NVM words *inside*
    the SC (``AtomicRef(mirror=...)``).  The seed mirrored with a plain
    store after the SC returned, which races under real parallelism:
    a loser of two back-to-back head swings could overwrite the
    winner's mirror with the older pointer, and the subsequent pwb then
    snapshots the REGRESSED head into NVMM — post-crash recovery
    rebuilds head pointing at an already-dequeued node (duplicate
    dequeue).  Same class as the PR 2 lost-link fix; found auditing the
    baselines under the multiprocess harness.
    """

    # Test-only seeded-bug fixture (repro.fuzz.bugs): when True,
    # dequeue re-introduces exactly the mirror race described above —
    # every second successful swing overwrites the durable head mirror
    # with the PRE-swing pointer before persisting it, so a later crash
    # recovers a regressed head and drains an already-returned value
    # again.  Never set directly; toggle via ``seeded_bug`` in tests.
    mirror_race_bug = False

    def __init__(self, nvm: NVM, n_threads: int, chunk_nodes: int = 256) -> None:
        self.nvm = nvm
        self.pool = NodePool(nvm, n_threads, None, chunk_nodes)
        dummy = self.pool.alloc(0)
        nvm.write(dummy, None)
        nvm.write(dummy + 1, NULL)
        nvm.pwb(dummy, NODE_WORDS)
        # head/tail words also mirrored in NVM for recovery — the initial
        # image must be durable or a pre-first-dequeue crash loses them.
        self.head_addr = nvm.alloc(1)
        self.tail_addr = nvm.alloc(1)
        nvm.write(self.head_addr, dummy)
        nvm.write(self.tail_addr, dummy)
        nvm.pwb(self.head_addr, 1)
        nvm.pwb(self.tail_addr, 1)
        nvm.psync()
        nvm.reset_counters()
        be = nvm.backend
        self.head = be.atomic_ref(dummy, shared=True, clock=nvm.clock,
                                  mirror=(nvm, self.head_addr))
        self.tail = be.atomic_ref(dummy, shared=True, clock=nvm.clock,
                                  mirror=(nvm, self.tail_addr))
        self._link_mutex = be.mutex()

    def enqueue(self, p: int, value: Any, seq: int) -> Any:
        nvm = self.nvm
        node = self.pool.alloc(p)
        nvm.write(node, value)
        nvm.write(node + 1, NULL)
        nvm.pwb(node, NODE_WORDS)
        nvm.pfence()
        while True:
            last, ver = self.tail.ll()
            nxt = nvm.read(last + 1)
            if nxt == NULL:
                # CAS on the next pointer (MS queue's linearization
                # point), emulated under a mutex.  Once the link lands
                # the node IS in the list; a failed tail SC only means
                # someone helped swing — never undo the link (an undo
                # can erase a concurrent enqueuer's successful link and
                # knot the list into a cycle).
                with self._link_mutex:
                    if nvm.clock is not None:
                        nvm.clock.advance(nvm.clock.profile.cas_ns)
                    linked = nvm.read(last + 1) == NULL
                    if linked:
                        nvm.write(last + 1, node)
                if linked:
                    nvm.pwb(last + 1, 1)
                    nvm.pfence()
                    if self.tail.sc(ver, node):
                        # mirror write happened inside the SC (no
                        # stale-overwrite window); persist it here
                        nvm.pwb(self.tail_addr, 1)
                    nvm.psync()
                    return "ACK"
            else:
                self.tail.sc(ver, nxt)         # help swing tail
            if nvm.halted:
                raise SimulatedCrash()
            time.sleep(0)

    def dequeue(self, p: int, seq: int) -> Any:
        nvm = self.nvm
        while True:
            first, ver = self.head.ll()
            nxt = nvm.read(first + 1)
            if nxt == NULL:
                return None
            if self.head.sc(ver, nxt):
                # head_addr mirrored inside the SC: mirror order always
                # matches swing order, so the pwb snapshot can never
                # regress the durable head (see class docstring)
                if DurableMSQueue.mirror_race_bug:
                    self._bug_deq = getattr(self, "_bug_deq", 0) + 1
                    if self._bug_deq % 2 == 0:
                        nvm.write(self.head_addr, first)
                nvm.pwb(self.head_addr, 1)
                nvm.psync()
                return nvm.read(nxt)
            if nvm.halted:
                raise SimulatedCrash()
            time.sleep(0)

    def drain(self) -> List[Any]:
        out, addr = [], self.head.load()
        addr = self.nvm.read(addr + 1)
        while addr != NULL:
            out.append(self.nvm.read(addr))
            addr = self.nvm.read(addr + 1)
        return out

    def reset_volatile(self) -> None:
        """Post-crash: rebuild the volatile head/tail refs from the
        durable mirrors.  The persisted tail word may lag the real list
        end (it is swung after the link pwb), so walk next pointers to
        the true tail — FHMP's recovery walk."""
        nvm = self.nvm
        head = nvm.read(self.head_addr)
        tail = nvm.read(self.tail_addr)
        while nvm.read(tail + 1) != NULL:
            tail = nvm.read(tail + 1)
        nvm.write(self.tail_addr, tail)
        nvm.pwb(self.tail_addr, 1)
        nvm.psync()
        be = nvm.backend
        self.head = be.reset_atomic_ref(self.head, head, shared=True,
                                        clock=nvm.clock,
                                        mirror=(nvm, self.head_addr))
        self.tail = be.reset_atomic_ref(self.tail, tail, shared=True,
                                        clock=nvm.clock,
                                        mirror=(nvm, self.tail_addr))
        self._link_mutex = be.reset_mutex(self._link_mutex)

    def recover(self, p: int, func: str, args: Any, seq: int) -> Any:
        """Not detectable (the FHMP-class queue has no announcement log):
        re-execute the in-flight op (at-least-once semantics)."""
        if func == "ENQ":
            return self.enqueue(p, args, seq)
        return self.dequeue(p, seq)


class DFCStack:
    """Detectable flat-combining stack, DFC-style cost shape.

    Differences from PBStack that the paper calls out:
      * announcements live in NVMM and each thread persists its own
        (pwb+pfence per announce, before the combiner may serve it);
      * the combiner applies updates directly to the shared top pointer
        and nodes (scattered per-op pwbs);
      * each response is persisted separately (one pwb per served op).
    """

    def __init__(self, nvm: NVM, n_threads: int, chunk_nodes: int = 256) -> None:
        self.nvm = nvm
        self.n = n_threads
        self.pool = NodePool(nvm, n_threads, None, chunk_nodes)
        self.top_addr = nvm.alloc(1)
        nvm.write(self.top_addr, NULL)
        # announce array in NVMM: per thread [func, arg, seq, resp, done_seq]
        self.ann_base = [nvm.alloc(5) for _ in range(n_threads)]
        nvm.pwb(self.top_addr, 1)
        nvm.psync()
        nvm.reset_counters()
        self.lock = nvm.backend.atomic_int(0, shared=True, clock=nvm.clock)
        # Virtual-clock announce times + last round's commit time (the
        # combiner merges announces, served threads merge the commit).
        self._ann_vt = [0.0] * n_threads
        self._round_end_vt = 0.0
        # measured degree: DFC combines too — its cost difference vs
        # PBComb is WHERE it persists, not whether it batches
        self.stats = nvm.backend.degree_stats()

    def op(self, p: int, func: str, args: Any, seq: int) -> Any:
        nvm = self.nvm
        a = self.ann_base[p]
        nvm.write(a, func)
        nvm.write(a + 1, args)
        nvm.write(a + 2, seq)
        nvm.pwb(a, 3)                       # persist own announcement
        nvm.pfence()
        if nvm.clock is not None:
            self._ann_vt[p] = nvm.clock.now()
        return self.perform(p)

    def perform(self, p: int) -> Any:
        """Serve p's already-persisted announcement (spin / combine) —
        never re-announces, so the announce/perform split pays exactly
        one announcement persist per op."""
        nvm = self.nvm
        clk = nvm.clock
        a = self.ann_base[p]
        seq = nvm.read(a + 2)
        while True:
            if nvm.read(a + 4) == seq:      # served?
                if clk is not None:
                    clk.merge(self._round_end_vt)
                return nvm.read(a + 3)
            lval = self.lock.load()
            if lval % 2 == 0 and self.lock.cas(lval, lval + 1):
                self._combine()
                self.lock.store(self.lock.load() + 1)
                if nvm.read(a + 4) == seq:
                    return nvm.read(a + 3)
            if nvm.halted:
                raise SimulatedCrash()
            time.sleep(0)

    def _combine(self) -> None:
        nvm = self.nvm
        clk = nvm.clock
        if clk is not None:
            clk.advance(clk.profile.round_ns)
        served = 0
        for q in range(self.n):
            a = self.ann_base[q]
            seq = nvm.read(a + 2)
            if seq and nvm.read(a + 4) != seq:
                if clk is not None:
                    clk.merge(self._ann_vt[q])
                func, args = nvm.read(a), nvm.read(a + 1)
                if func == "PUSH":
                    node = self.pool.alloc(q)
                    nvm.write(node, args)
                    nvm.write(node + 1, nvm.read(self.top_addr))
                    nvm.write(self.top_addr, node)
                    nvm.pwb(node, NODE_WORDS)       # scattered per-op pwbs
                    nvm.pwb(self.top_addr, 1)
                    ret = "ACK"
                else:
                    top = nvm.read(self.top_addr)
                    if top == NULL:
                        ret = None
                    else:
                        nvm.write(self.top_addr, nvm.read(top + 1))
                        nvm.pwb(self.top_addr, 1)
                        ret = nvm.read(top)
                nvm.write(a + 3, ret)
                nvm.write(a + 4, seq)
                nvm.pwb(a + 3, 2)                   # persist response alone
                nvm.pfence()
                served += 1
        nvm.psync()
        self.stats.record(served)
        if clk is not None:
            self._round_end_vt = clk.now()

    def drain(self) -> List[Any]:
        out, addr = [], self.nvm.read(self.top_addr)
        while addr != NULL:
            out.append(self.nvm.read(addr))
            addr = self.nvm.read(addr + 1)
        return out

    def reset_volatile(self) -> None:
        """Post-crash: only the combiner lock is volatile — announcements,
        responses and done-marks live in NVMM (DFC's design).  The
        virtual-clock timestamps survive (logical time is monotone
        across crashes; stale merges only ever charge more)."""
        self.lock = self.nvm.backend.reset_atomic_int(
            self.lock, 0, shared=True, clock=self.nvm.clock)

    def recover(self, p: int, func: str, args: Any, seq: int) -> Any:
        """Done-mark fast path: if the persisted done-mark carries this
        op's seq, its response was recorded before the crash — return it
        instead of re-executing.  Note this is only exactly-once for ops
        served in a *psync'd* round: DFC psyncs once per round, so a
        mid-round crash can persist the done-mark and the structural
        update independently (the runtime adapter reports
        ``detectable=False`` for this reason)."""
        a = self.ann_base[p]
        if self.nvm.read(a + 4) == seq:
            return self.nvm.read(a + 3)
        return self.op(p, func, args, seq)
