"""PBStack — recoverable stack over PBComb (paper Section 5).

The stack is a linked list of NVM nodes; the combined state is just the
``top`` pointer (one word), so StateRec stays tiny and one contiguous pwb
persists top + all responses + all deactivate bits.

Extras from the paper:
  * the combiner persists the fields of all newly allocated nodes before
    persisting the StateRec (``toPersist``, flushed in one pass — nodes
    come from per-thread contiguous chunks, P3);
  * **elimination** [32]: concurrent Push/Pop pairs are served against
    each other without touching the state — fewer allocated nodes to
    persist (paper Figure 7a);
  * **recycling stack** GC: one shared LIFO free list so recycled nodes
    re-enter the stack in original reservation order (P3).
"""

from __future__ import annotations

from typing import Any, List, Optional

from ..core.nvm import NVM
from ..core.objects import SeqObject
from ..core.pbcomb import PBComb
from .nodes import NODE_WORDS, NULL, NodePool, RecyclingStack


class _StackState(SeqObject):
    """st = [top] (node address, NULL = empty)."""

    state_words = 1

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, NULL)

    def apply(self, nvm, st_base, func, args, ctx=None):
        if func == "PUSH":
            node = ctx.pool.alloc(ctx.current_combiner)
            nvm.write(node, args)                    # data
            nvm.write(node + 1, nvm.read(st_base))   # next := top
            nvm.write(st_base, node)                 # top := node
            ctx.to_persist.append(node)
            return "ACK"
        if func == "POP":
            top = nvm.read(st_base)
            if top == NULL:
                return None
            nvm.write(st_base, nvm.read(top + 1))    # top := top.next
            ctx.popped.append(top)
            return nvm.read(top)                     # data
        raise ValueError(func)


class PBStack(PBComb):
    def __init__(self, nvm: NVM, n_threads: int, *, elimination: bool = True,
                 recycle: bool = True, chunk_nodes: int = 256,
                 counters=None) -> None:
        super().__init__(nvm, n_threads, _StackState(), counters=counters)
        self.pool = NodePool(nvm, n_threads,
                             RecyclingStack() if recycle else None,
                             chunk_nodes)
        self.elimination = elimination
        self.current_combiner = 0
        self.to_persist: List[int] = []
        self.popped: List[int] = []

    # -------------------- combiner hooks -------------------------------- #
    def _begin_round(self, ind: int, combiner: int) -> None:
        self.current_combiner = combiner
        self.to_persist.clear()
        self.popped.clear()
        if not self.elimination:
            return
        # Elimination: pair each active PUSH with an active POP and serve
        # both without touching the state (the pop linearizes immediately
        # after the push).  Responses/deactivate bits are recorded in the
        # working StateRec, so they persist with the round as usual.
        nvm = self.nvm
        deacts = nvm.read_range(self._deact_addr(ind, 0), self.n)
        pushes, pops = [], []
        for q in range(self.n):
            req = self.request[q]
            if req.valid == 1 and req.activate != deacts[q]:
                (pushes if req.func == "PUSH" else pops).append(q)
        if not pushes or not pops:
            return
        for qp, qo in zip(pushes, pops):
            req_push, req_pop = self.request[qp], self.request[qo]
            nvm.write(self._retval_addr(ind, qp), "ACK")
            nvm.write(self._deact_addr(ind, qp), req_push.activate)
            nvm.write(self._retval_addr(ind, qo), req_push.args)
            nvm.write(self._deact_addr(ind, qo), req_pop.activate)
            # eliminated pairs are served by this round too: the main
            # simulation loop skips them, so count them here
            self._round_served += 2

    def _post_simulation(self, ind: int, combiner: int):
        # The round's new nodes persist before the StateRec as ONE
        # coalesced line-set (chunk allocation keeps them contiguous, so
        # the union collapses to a few runs — P3 made visible).
        if self.to_persist:
            return [(node, NODE_WORDS) for node in self.to_persist]
        return None

    def _pre_unlock(self, ind: int, combiner: int) -> None:
        # Recycle popped nodes only after the round took effect (psync).
        free = self.pool.free
        for node in self.popped:
            free(combiner, node)
        self.to_persist.clear()
        self.popped.clear()

    # -------------------- introspection --------------------------------- #
    def drain(self) -> List[Any]:
        """Read out the stack contents (top first) — test helper."""
        out, addr = [], self.nvm.read(self._st_base(self._mindex()))
        while addr != NULL:
            out.append(self.nvm.read(addr))
            addr = self.nvm.read(addr + 1)
        return out
