"""NVM node allocation for the linked-list structures (paper Section 5,
"Memory Management").

Each thread pre-allocates fixed-size *chunks* of nodes in NVMM and
reserves nodes from its chunk, so a combiner's freshly allocated nodes sit
in consecutive memory addresses (persistence principle P3 — one pwb covers
several nodes).

Recycling:
  * ``RecyclingStack`` — the PBStack scheme: one shared LIFO free list for
    all threads, so recycled nodes re-enter the structure in the same
    order they originally left their chunk (preserves P3).
  * ``PerThreadFreeList`` — the PBQueue scheme: each thread keeps its own
    free list of nodes it removed while combining (the paper notes this
    does NOT preserve P3, and measures the cost).

A node occupies NODE_WORDS consecutive NVM words: [data, next].
``next`` is an NVM word address, 0 = null.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from ..core.nvm import NVM

NODE_WORDS = 2
NULL = 0  # address 0 is reserved (never allocated to a node)


class ChunkAllocator:
    """Per-thread bump allocation from contiguous NVM chunks."""

    def __init__(self, nvm: NVM, n_threads: int,
                 chunk_nodes: int = 256) -> None:
        self.nvm = nvm
        self.chunk_nodes = chunk_nodes
        # segment affinity is captured at construction (the runtime's
        # placement context is active while the structure builds), so
        # chunks refilled lazily mid-workload stay on the structure's
        # modeled device (DESIGN.md §8)
        self.segment = nvm.current_segment()
        self._cursor: List[int] = [0] * n_threads
        self._limit: List[int] = [0] * n_threads

    def alloc(self, p: int) -> int:
        if self._cursor[p] >= self._limit[p]:
            base = self.nvm.alloc(self.chunk_nodes * NODE_WORDS,
                                  segment=self.segment)
            self._cursor[p] = base
            self._limit[p] = base + self.chunk_nodes * NODE_WORDS
        addr = self._cursor[p]
        self._cursor[p] += NODE_WORDS
        return addr


class RecyclingStack:
    """Shared volatile LIFO free list (PBStack GC scheme).

    ``list.append`` and ``list.pop`` are single atomic bytecodes under
    the GIL, so the shared LIFO needs no lock — the empty case is an
    exception branch instead of a guarded check (which WOULD race)."""

    def __init__(self) -> None:
        self._stack: List[int] = []

    def push(self, addr: int) -> None:
        self._stack.append(addr)

    def pop(self) -> Optional[int]:
        try:
            return self._stack.pop()
        except IndexError:
            return None

    def __len__(self) -> int:
        return len(self._stack)


class PerThreadFreeList:
    """Per-thread volatile free lists (PBQueue GC scheme), with a
    bounded overflow into a shared ``RecyclingStack``.

    The pure per-thread scheme recycles a node only to the thread that
    freed it — under asymmetric produce/consume (A only pushes, B only
    pops) B's list grows without bound while A allocates fresh chunks
    forever.  Above ``cap`` entries a freeing thread overflows into the
    shared stack, and an allocating thread whose own list is empty
    steals from it, so steady-state ``allocs_per_op`` reaches 0 for any
    role split.  ``cap`` is sized so balanced workloads (the gated
    benches) never overflow: their allocation order is unchanged."""

    def __init__(self, n_threads: int, cap: int = 4096) -> None:
        self._free: Dict[int, List[int]] = {p: [] for p in range(n_threads)}
        self.cap = cap
        self.shared = RecyclingStack()

    def push(self, p: int, addr: int) -> None:
        lst = self._free[p]
        if len(lst) >= self.cap:
            self.shared.push(addr)
        else:
            lst.append(addr)

    def pop(self, p: int) -> Optional[int]:
        lst = self._free[p]
        return lst.pop() if lst else self.shared.pop()


class NodePool:
    """Chunk allocator + optional recycler, the paper's full scheme.
    The recycling strategy is bound once at construction — the hot
    alloc/free path carries no isinstance dispatch."""

    def __init__(self, nvm: NVM, n_threads: int, recycler=None,
                 chunk_nodes: int = 256) -> None:
        from ..persist.reclaim import EpochReclaimer
        self.nvm = nvm
        self.chunks = ChunkAllocator(nvm, n_threads, chunk_nodes)
        self.recycler = recycler
        if recycler is None:
            self.alloc = self.chunks.alloc
            self.free = self._free_noop
        elif isinstance(recycler, EpochReclaimer):
            # epoch-based limbo path (DESIGN.md §13): free = retire into
            # the limbo ring; alloc prefers the durable free window
            self.alloc = self._alloc_epoch
            self.free = recycler.retire
        elif isinstance(recycler, PerThreadFreeList):
            self.alloc = self._alloc_per_thread
            self.free = recycler.push
        else:
            self.alloc = self._alloc_shared
            self.free = self._free_shared

    def _alloc_per_thread(self, p: int) -> int:
        addr = self.recycler.pop(p)
        return addr if addr is not None else self.chunks.alloc(p)

    def _alloc_epoch(self, p: int) -> int:
        addr = self.recycler.take(p)
        if addr is not None:
            return addr
        self.recycler.count_fresh(p)
        return self.chunks.alloc(p)

    def _alloc_shared(self, p: int) -> int:
        addr = self.recycler.pop()
        return addr if addr is not None else self.chunks.alloc(p)

    def _free_shared(self, p: int, addr: int) -> None:
        self.recycler.push(addr)

    @staticmethod
    def _free_noop(p: int, addr: int) -> None:
        return None
