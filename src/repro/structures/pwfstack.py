"""PWFStack — wait-free recoverable stack over PWFComb (paper Section 5).

Same linked-list representation and elimination as PBStack, but every
thread pretends to be the combiner on its private StateRec copy.  Node
management differs because losing pretend-combiners must roll back:

  * allocations are attempt-local: on a failed VL/SC the freshly
    allocated nodes return to the thread's own free list;
  * new nodes are persisted *before* the SC (``_pre_publish``) — S must
    never point to a StateRec whose reachable nodes are not durable;
  * popped nodes are recycled only after the winning round's S value is
    durable (``_on_publish_success`` fires post-psync), which is the
    simplified stand-in for the validation scheme of [11] cited by the
    paper: threads never *reuse* a node while it can still be reached
    from the durable S.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List

from ..core.nvm import NVM
from ..core.pwfcomb import PWFComb
from .nodes import NODE_WORDS, NULL, NodePool, PerThreadFreeList
from .pbstack import _StackState


class PWFStack(PWFComb):
    def __init__(self, nvm: NVM, n_threads: int, *, elimination: bool = True,
                 recycle: bool = True, chunk_nodes: int = 256,
                 counters=None, backoff: bool = True) -> None:
        super().__init__(nvm, n_threads, _StackState(), counters=counters,
                         backoff=backoff)
        self.pool = NodePool(nvm, n_threads,
                             PerThreadFreeList(n_threads) if recycle else None,
                             chunk_nodes)
        self.elimination = elimination
        # attempt-local bookkeeping, keyed by thread id
        self._alloc: Dict[int, List[int]] = {p: [] for p in range(n_threads)}
        self._popped: Dict[int, List[int]] = {p: [] for p in range(n_threads)}
        self._tls = threading.local()  # which logical thread runs here

    # ------------- public API (deprecated shims — use repro.api) -------- #
    def push(self, p: int, value: Any, seq: int) -> Any:
        """.. deprecated:: use ``handle.bind(obj).push(value)``."""
        return self.op(p, "PUSH", value, seq)

    def pop(self, p: int, seq: int) -> Any:
        """.. deprecated:: use ``handle.bind(obj).pop()``."""
        return self.op(p, "POP", None, seq)

    # -------------------- combining hooks ------------------------------- #
    def _apply(self, q, func, args, slot, combiner):
        self._tls.combiner = combiner
        return self.obj.apply(self.nvm, self._base(slot), func, args, ctx=self)

    @property
    def current_combiner(self) -> int:  # _StackState allocates under this id
        return self._tls.combiner

    @property
    def to_persist(self):  # _StackState records allocations here
        return self._alloc[self._tls.combiner]

    @property
    def popped(self):
        return self._popped[self._tls.combiner]

    def _begin_attempt(self, slot: int, p: int) -> None:
        self._alloc[p] = []
        self._popped[p] = []
        if not self.elimination:
            return
        nvm = self.nvm
        pushes, pops = [], []
        for q in range(self.n):
            req = self.request[q]
            if req.valid == 1 and req.activate != nvm.read(self._deact_addr(slot, q)):
                (pushes if req.func == "PUSH" else pops).append(q)
        for qp, qo in zip(pushes, pops):
            req_push, req_pop = self.request[qp], self.request[qo]
            nvm.write(self._retval_addr(slot, qp), "ACK")
            nvm.write(self._deact_addr(slot, qp), req_push.activate)
            nvm.write(self._retval_addr(slot, qo), req_push.args)
            nvm.write(self._deact_addr(slot, qo), req_pop.activate)

    def _pre_publish(self, slot: int, p: int) -> None:
        for node in self._alloc[p]:
            self.nvm.pwb(node, NODE_WORDS)

    def _on_publish_success(self, slot: int, p: int) -> None:
        for node in self._popped[p]:
            self.pool.free(p, node)
        self._alloc[p] = []
        self._popped[p] = []

    def _attempt_failed(self, slot: int, p: int) -> None:
        for node in self._alloc[p]:
            self.pool.free(p, node)
        self._alloc[p] = []
        self._popped[p] = []

    # -------------------- introspection --------------------------------- #
    def drain(self) -> List[Any]:
        out, addr = [], self.nvm.read(self._base(self.S.load()))
        while addr != NULL:
            out.append(self.nvm.read(addr))
            addr = self.nvm.read(addr + 1)
        return out
