"""PWFStack — wait-free recoverable stack over PWFComb (paper Section 5).

Same linked-list representation and elimination as PBStack, but every
thread pretends to be the combiner on its private StateRec copy.  Node
management differs because losing pretend-combiners must roll back:

  * allocations are attempt-local: on a failed VL/SC the freshly
    allocated nodes return to the thread's own free list;
  * new nodes are persisted *before* the SC (``_pre_publish``) — S must
    never point to a StateRec whose reachable nodes are not durable;
  * popped nodes are recycled only after the winning round's S value is
    durable (``_on_publish_success`` fires post-psync), which is the
    simplified stand-in for the validation scheme of [11] cited by the
    paper: threads never *reuse* a node while it can still be reached
    from the durable S.
"""

from __future__ import annotations

from typing import Any, List, Optional

from ..core.nvm import NVM
from ..core.pwfcomb import PWFComb
from ..persist.reclaim import EpochReclaimer
from .nodes import NODE_WORDS, NULL, NodePool, PerThreadFreeList
from .pbstack import _StackState


class _AttemptCtx:
    """Per-pretend-combiner context handed to ``_StackState.apply`` —
    one object per thread, plain attributes (no thread-local lookups on
    the application hot path; concurrent attempts never share one)."""

    __slots__ = ("pool", "current_combiner", "to_persist", "popped")

    def __init__(self, pool: NodePool, p: int) -> None:
        self.pool = pool
        self.current_combiner = p
        self.to_persist: List[int] = []
        self.popped: List[int] = []


class PWFStack(PWFComb):
    def __init__(self, nvm: NVM, n_threads: int, *, elimination: bool = True,
                 recycle: bool = True, reclaim: Optional[str] = None,
                 reclaim_cap: int = 512, chunk_nodes: int = 256,
                 counters=None, backoff: bool = True) -> None:
        if reclaim not in (None, "epoch"):
            raise ValueError(f"reclaim must be None or 'epoch', "
                             f"got {reclaim!r}")
        super().__init__(nvm, n_threads, _StackState(), counters=counters,
                         backoff=backoff)
        # default: the paper's immediate per-thread recycling (the gated
        # baselines reflect its allocation order); ``reclaim="epoch"``
        # opts into the crash-safe limbo layer (DESIGN.md §13) used by
        # long-haul workloads
        if reclaim == "epoch":
            self.reclaim = EpochReclaimer(nvm, n_threads, reclaim_cap)
            recycler = self.reclaim
        else:
            self.reclaim = None
            recycler = PerThreadFreeList(n_threads) if recycle else None
        self.pool = NodePool(nvm, n_threads, recycler, chunk_nodes)
        self.elimination = elimination
        # attempt-local bookkeeping, one context per thread id
        self._ctx = [_AttemptCtx(self.pool, p) for p in range(n_threads)]

    # -------------------- combining hooks ------------------------------- #
    def _apply(self, q, func, args, slot, combiner):
        return self.obj.apply(self.nvm, self._base(slot), func, args,
                              ctx=self._ctx[combiner])

    def _perform_request(self, p: int):
        rec = self.reclaim
        if rec is None:
            return super()._perform_request(p)
        rec.pin(p)
        try:
            return super()._perform_request(p)
        finally:
            rec.unpin(p)

    def _begin_attempt(self, slot: int, p: int) -> None:
        ctx = self._ctx[p]
        ctx.to_persist = []
        ctx.popped = []
        if not self.elimination:
            return
        nvm = self.nvm
        deacts = nvm.read_range(self._deact_addr(slot, 0), self.n)
        pushes, pops = [], []
        for q in range(self.n):
            req = self.request[q]
            if req.valid == 1 and req.activate != deacts[q]:
                (pushes if req.func == "PUSH" else pops).append(q)
        if not pushes or not pops:
            return
        for qp, qo in zip(pushes, pops):
            req_push, req_pop = self.request[qp], self.request[qo]
            nvm.write(self._retval_addr(slot, qp), "ACK")
            nvm.write(self._deact_addr(slot, qp), req_push.activate)
            nvm.write(self._retval_addr(slot, qo), req_push.args)
            nvm.write(self._deact_addr(slot, qo), req_pop.activate)
            # eliminated pairs are served by this attempt too: the main
            # scan skips them, so count them for the measured degree
            self._attempt_served[p] += 2

    def _pre_publish(self, slot: int, p: int):
        alloc = self._ctx[p].to_persist
        if alloc:
            return [(node, NODE_WORDS) for node in alloc]
        return None

    def _on_publish_success(self, slot: int, p: int) -> None:
        ctx = self._ctx[p]
        for node in ctx.popped:
            self.pool.free(p, node)
        if self.reclaim is not None:
            self.reclaim.advance()
        ctx.to_persist = []
        ctx.popped = []

    def _attempt_failed(self, slot: int, p: int) -> None:
        ctx = self._ctx[p]
        for node in ctx.to_persist:
            self.pool.free(p, node)
        ctx.to_persist = []
        ctx.popped = []

    # -------------------- reclamation ------------------------------------ #
    def quiesce(self):
        """Advance the durable limbo/free boundaries (epoch mode only)."""
        if self.reclaim is None:
            return None
        return self.reclaim.quiesce()

    def reset_volatile(self) -> None:
        super().reset_volatile()
        if self.reclaim is not None:
            self.reclaim.recover()

    # -------------------- introspection --------------------------------- #
    def drain(self) -> List[Any]:
        out, addr = [], self.nvm.read(self._base(self.S.load()))
        while addr != NULL:
            out.append(self.nvm.read(addr))
            addr = self.nvm.read(addr + 1)
        return out
