"""PBQueue — recoverable FIFO queue over two PBComb instances
(paper Section 5 + Appendix A, Algorithms 5-7).

Parallelism trick: enqueuers synchronize through instance ``I_E`` (whose
combined state is just the ``Tail`` pointer) and dequeuers through
``I_D`` (just ``Head``), so an enqueue combiner and a dequeue combiner
run concurrently.  The first list node is a dummy.

Persistence subtleties implemented exactly as the appendix:
  * an enqueue combiner collects modified/created nodes in ``toPersist``
    (Alg 5 lines 19/23) and pwbs them *before* pwb(EStateRec) (line 24);
  * the volatile ``oldTail`` pointer is advanced only after the enqueue
    round's psync (line 31), and a dequeue combiner never removes nodes
    past ``oldTail`` (lines 57-59) — so a dequeuer can never hand out a
    value whose enqueue is not yet durable (that would break
    detectability, as analyzed in the appendix);
  * on recovery, ``oldTail`` is re-seeded from the durable tail
    (Alg 7 lines 73-74).

GC: per-thread free lists — a dequeue combiner banks removed nodes after
its round took effect; enqueuing threads draw from their own bank first
(the paper measures that this scheme does *not* preserve P3 and costs a
bit of performance — reproduced in benchmarks).
"""

from __future__ import annotations

from typing import Any, List, Optional

from ..core.nvm import NVM
from ..core.objects import SeqObject
from ..core.pbcomb import PBComb
from .nodes import NODE_WORDS, NULL, NodePool, PerThreadFreeList


class _EnqState(SeqObject):
    """st = [Tail]."""

    state_words = 1

    def __init__(self, dummy: int) -> None:
        self.dummy = dummy

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, self.dummy)

    def apply(self, nvm, st_base, func, args, ctx=None):
        # Alg 5 lines 19-23 (sequential Enqueue, lines 35-39)
        tail = nvm.read(st_base)
        ctx.to_persist.append(tail)          # node whose .next changes
        node = ctx.pool.alloc(ctx.current_combiner)
        nvm.write(node, args)                # data
        nvm.write(node + 1, NULL)            # next
        nvm.write(tail + 1, node)            # (*Tail).next := node
        nvm.write(st_base, node)             # Tail := node
        return "ACK"


class _DeqState(SeqObject):
    """st = [Head]."""

    state_words = 1

    def __init__(self, dummy: int) -> None:
        self.dummy = dummy

    def init_state(self, nvm: NVM, st_base: int) -> None:
        nvm.write(st_base, self.dummy)

    def apply(self, nvm, st_base, func, args, ctx=None):
        # Alg 6 lines 56-61 with the oldTail guard.
        head = nvm.read(st_base)
        if ctx.queue.old_tail == head:       # line 57: nothing durable left
            return None
        nxt = nvm.read(head + 1)             # sequential Dequeue, lines 70-72
        if nxt == NULL:
            return None
        nvm.write(st_base, nxt)              # Head := head.next
        ctx.removed.append(head)             # old dummy becomes free
        return nvm.read(nxt)                 # data of the new dummy


class _EnqInstance(PBComb):
    def __init__(self, nvm, n, obj, queue, counters=None):
        super().__init__(nvm, n, obj, counters=counters)
        self.queue = queue
        self.pool = queue.pool
        self.current_combiner = 0
        self.to_persist: List[int] = []

    def _begin_round(self, ind: int, combiner: int) -> None:
        self.current_combiner = combiner
        self.to_persist.clear()

    def _post_simulation(self, ind: int, combiner: int):
        tail = self.nvm.read(self.mem_base[ind])
        self.to_persist.append(tail)                  # Alg 5 line 23
        # Alg 5 line 24: all modified/created nodes in one coalesced
        # line-set (duplicate lines — e.g. tail sharing a line with the
        # node it links to — persist once).
        return [(node, NODE_WORDS) for node in self.to_persist]

    def _pre_unlock(self, ind: int, combiner: int) -> None:
        self.queue.old_tail = self.nvm.read(self.mem_base[ind])  # line 31
        self.to_persist.clear()                                  # line 32


class _DeqInstance(PBComb):
    def __init__(self, nvm, n, obj, queue, counters=None):
        super().__init__(nvm, n, obj, counters=counters)
        self.queue = queue
        self.removed: List[int] = []

    def _begin_round(self, ind: int, combiner: int) -> None:
        self.removed.clear()

    def _pre_unlock(self, ind: int, combiner: int) -> None:
        # Removal took effect (psync done): bank nodes for reuse.
        free = self.queue.pool.free
        for node in self.removed:
            free(combiner, node)
        self.removed.clear()


class PBQueue:
    def __init__(self, nvm: NVM, n_threads: int, *, recycle: bool = True,
                 chunk_nodes: int = 256, counters=None) -> None:
        self.nvm = nvm
        self.n = n_threads
        # Shared non-volatile dummy node.
        self.dummy = nvm.alloc(NODE_WORDS)
        nvm.write(self.dummy, None)
        nvm.write(self.dummy + 1, NULL)
        nvm.pwb(self.dummy, NODE_WORDS)
        nvm.psync()
        self.pool = NodePool(nvm, n_threads,
                             PerThreadFreeList(n_threads) if recycle else None,
                             chunk_nodes)
        # Shared volatile variable (Alg 7 re-seeds it on recovery) — a
        # backend cell: the enqueue combiner that advances it and the
        # dequeue combiner that reads it may live in different processes.
        self._old_tail = nvm.backend.cell(self.dummy)
        self.enq = _EnqInstance(nvm, n_threads, _EnqState(self.dummy), self,
                                counters=counters)
        self.deq = _DeqInstance(nvm, n_threads, _DeqState(self.dummy), self,
                                counters=counters)
        nvm.reset_counters()

    @property
    def old_tail(self) -> int:
        return self._old_tail.value

    @old_tail.setter
    def old_tail(self, v: int) -> None:
        self._old_tail.value = v

    # -------------------- recovery (Algorithm 7) ------------------------ #
    def reset_volatile(self) -> None:
        self.enq.reset_volatile()
        self.deq.reset_volatile()
        # lines 73-74: conservatively re-seed oldTail from the durable tail
        # (everything reachable in the durable state is, by construction,
        # persisted).
        self.old_tail = self.nvm.read(self.enq._st_base(self.enq._mindex()))

    def recover(self, p: int, func: str, args: Any, seq: int) -> Any:
        if func == "ENQ":
            return self.enq.recover(p, func, args, seq)
        return self.deq.recover(p, func, args, seq)

    # -------------------- introspection --------------------------------- #
    def drain(self) -> List[Any]:
        """Queue contents head-to-tail (excluding the dummy) — test helper."""
        out = []
        addr = self.nvm.read(self.deq._st_base(self.deq._mindex()))
        addr = self.nvm.read(addr + 1)
        while addr != NULL:
            out.append(self.nvm.read(addr))
            addr = self.nvm.read(addr + 1)
        return out
