"""CLI: ``python -m repro.fuzz <replay|explore|run|selftest>``.

* ``replay``   — deterministic corpus replay (the PR smoke gate):
                 re-runs every ``tests/fuzz_corpus/`` entry with its
                 recorded cell pinned, prints the per-class table, and
                 exits 1 on any verdict change.
* ``explore``  — wall-clock-budgeted fresh-seed search (the nightly /
                 workflow_dispatch job): time-derived base seed, every
                 failure shrunk to a minimal seed and printed as a
                 ready-to-commit corpus line.
* ``run``      — one scenario from its replay tuple (the command every
                 checker failure prints).
* ``selftest`` — seeded-bug calibration: asserts the fuzzer rediscovers
                 the torn-announce and mirror-race fixtures within a
                 bounded seed budget.
"""

from __future__ import annotations

import argparse
import sys
import time

from .bugs import BUG_HUNTS, SEEDED_BUGS, seeded_bug
from .corpus import (append_entries, class_table, default_corpus_path,
                     dump_entry, load_corpus, replay_corpus)
from .scenarios import MASK64, SCENARIO_CLASSES, run_scenario
from .shrink import shrink_seed


def _parse_seed(s: str) -> int:
    return int(s, 16 if s.lower().startswith("0x") else 10) & MASK64


def cmd_replay(args) -> int:
    results, mismatches = replay_corpus(args.corpus)
    table = class_table(results, mismatches)
    if args.summary:
        print(table)
    for m in mismatches:
        print(f"MISMATCH: {m}", file=sys.stderr)
    unexpected = [r for r in results if r.verdict.startswith("error:")]
    for r in unexpected:
        print(f"ERROR: (class={r.cls} seed={r.seed:#018x}) "
              f"{r.verdict}", file=sys.stderr)
    if not results:
        print("corpus is empty — nothing replayed", file=sys.stderr)
    print(f"replayed {len(results)} corpus entries: "
          f"{len(mismatches)} mismatches")
    return 1 if (mismatches or unexpected) else 0


def cmd_explore(args) -> int:
    # the ONE place wall-clock derives a seed: explore hunts fresh
    # schedules by design, and prints every find as a replayable line
    base = args.base_seed if args.base_seed is not None \
        else (time.time_ns() & MASK64)
    deadline = time.monotonic() + args.budget_s
    classes = args.cls or sorted(SCENARIO_CLASSES)
    ran = 0
    found = []
    i = 0
    while time.monotonic() < deadline:
        cls = classes[i % len(classes)]
        seed = (base + 0x9E3779B97F4A7C15 * i) & MASK64
        i += 1
        res = run_scenario(cls, seed)
        ran += 1
        if not res.failed:
            continue

        def fails(cand, _cls=cls, _v=res.verdict):
            return run_scenario(_cls, cand).verdict == _v

        small = shrink_seed(fails, seed, budget=args.shrink_budget)
        found.append(run_scenario(cls, small))
        print(f"FOUND ({cls}): seed {seed:#018x} -> shrunk "
              f"{small:#018x}: {found[-1].verdict}")
    print(f"explored {ran} scenarios across {len(classes)} classes "
          f"in {args.budget_s:.0f}s: {len(found)} failures")
    if found:
        print("ready-to-commit corpus lines "
              f"(append to {default_corpus_path()}):")
        for res in found:
            print(dump_entry(res))
        if args.out:
            with open(args.out, "w", encoding="utf-8") as fh:
                for res in found:
                    fh.write(dump_entry(res) + "\n")
            print(f"wrote {len(found)} entries to {args.out}")
    return 1 if found else 0


def cmd_run(args) -> int:
    res = run_scenario(args.cls, _parse_seed(args.seed),
                       cell=args.cell, backend=args.backend)
    print(f"class    {res.cls}")
    print(f"seed     {res.seed:#018x}")
    print(f"cell     {res.cell}")
    print(f"backend  {res.backend}")
    print(f"verdict  {res.verdict}")
    if res.stats:
        print(f"stats    {res.stats}")
    if res.detail and res.failed:
        print(res.detail)
    return 1 if res.failed else 0


def cmd_selftest(args) -> int:
    ok = True
    for bug in SEEDED_BUGS:
        cls, cell = BUG_HUNTS[bug]
        hit = None
        with seeded_bug(bug):
            for i in range(args.budget):
                seed = (args.base_seed + i) & MASK64
                res = run_scenario(cls, seed, cell=cell)
                if res.failed:
                    hit = res
                    break
        if hit is None:
            ok = False
            print(f"MISSED: seeded bug {bug!r} not found by class "
                  f"{cls} on {cell} within {args.budget} seeds")
        else:
            print(f"found {bug!r} at seed {hit.seed:#018x} "
                  f"({cls}/{cell}): {hit.verdict}")
            clean = run_scenario(cls, hit.seed, cell=cell)
            if clean.failed:
                ok = False
                print(f"  but the same seed fails with the bug OFF "
                      f"({clean.verdict}) — not the seeded bug")
    print("selftest:", "ok" if ok else "FAILED")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.fuzz")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("replay", help="deterministic corpus replay")
    p.add_argument("--corpus", default=None)
    p.add_argument("--summary", action="store_true",
                   help="print the per-class markdown table")
    p.set_defaults(fn=cmd_replay)

    p = sub.add_parser("explore", help="budgeted fresh-seed search")
    p.add_argument("--budget-s", type=float, default=60.0)
    p.add_argument("--base-seed", type=_parse_seed, default=None,
                   help="override the time-derived base seed")
    p.add_argument("--cls", action="append",
                   choices=sorted(SCENARIO_CLASSES),
                   help="restrict to these classes (repeatable)")
    p.add_argument("--shrink-budget", type=int, default=48)
    p.add_argument("--out", default=None,
                   help="also write found entries to this file")
    p.set_defaults(fn=cmd_explore)

    p = sub.add_parser("run", help="replay one scenario by its tuple")
    p.add_argument("--cls", required=True,
                   choices=sorted(SCENARIO_CLASSES))
    p.add_argument("--seed", required=True)
    p.add_argument("--cell", default=None)
    p.add_argument("--backend", default=None)
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("selftest",
                       help="seeded-bug rediscovery calibration")
    p.add_argument("--budget", type=int, default=64,
                   help="seeds to try per bug")
    p.add_argument("--base-seed", type=_parse_seed, default=0)
    p.set_defaults(fn=cmd_selftest)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
