"""Seeded-bug fixtures: re-introduce two REAL historical bugs on demand.

Fuzzer calibration: a fuzzer that has never found a bug proves nothing
about the bugs it fails to find.  These context managers flip test-only
class flags that re-enable, behind emulation, two defects this codebase
actually shipped and fixed:

* ``torn-announce`` — the PR 5 torn announcement read: PBComb's scan
  adopting a request record mixed across two announce generations
  (fixed by the seqlock stamp re-check).  The flag makes the combiner
  apply stale args on a schedule, which only a fuzz schedule that
  reuses a thread's slot across rounds and then crashes/drains can
  observe.

* ``mirror-race`` — the PR 4 durable-MS head-mirror race: the durable
  head word persisted from a pre-swing snapshot, regressing the
  recovered head to an already-dequeued node (fixed by mirroring
  inside the SC).  Only a post-crash drain sees the duplicate.

``tests/test_fuzz.py`` asserts the fuzzer rediscovers BOTH within a
bounded seed budget — the acceptance bar for the whole subsystem.
"""

from __future__ import annotations

from contextlib import contextmanager

from ..core.pbcomb import PBComb
from ..structures.baselines import DurableMSQueue

SEEDED_BUGS = ("torn-announce", "mirror-race")

#: bug name -> (class, flag attribute); cell where each bug is visible
BUG_FLAGS = {
    "torn-announce": (PBComb, "torn_announce_bug"),
    "mirror-race": (DurableMSQueue, "mirror_race_bug"),
}

#: the scenario class + pinned cell the selftest hunts each bug with
BUG_HUNTS = {
    "torn-announce": ("schedule", "queue/pbcomb"),
    "mirror-race": ("instr-crash", "queue/durable-ms"),
}


@contextmanager
def seeded_bug(name: str):
    """Enable one seeded bug for the duration of the block."""
    if name not in BUG_FLAGS:
        raise ValueError(f"unknown seeded bug {name!r} "
                         f"(have: {SEEDED_BUGS})")
    cls, attr = BUG_FLAGS[name]
    prev = getattr(cls, attr)
    setattr(cls, attr, True)
    try:
        yield
    finally:
        setattr(cls, attr, prev)
