"""Replayable seed corpus: one JSON line per scenario.

``tests/fuzz_corpus/corpus.jsonl`` is the regression ledger: every
entry is ``{"class", "seed", "cell", "backend", "verdict"}`` with the
seed as a zero-padded hex string.  ``replay_corpus`` re-runs every
entry with the recorded cell PINNED (so a registry reshuffle cannot
silently retarget an entry) and reports any verdict or resolution
mismatch — the CI smoke gate fails on the first one.  Entries are
written in canonical key order so two consecutive replays (and two
checkouts) are byte-identical.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Optional, Tuple

from .scenarios import ScenarioResult, run_scenario

ENTRY_KEYS = ("class", "seed", "cell", "backend", "verdict")


def default_corpus_path() -> str:
    """tests/fuzz_corpus/corpus.jsonl relative to the repo root (three
    levels above this package)."""
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    return os.path.join(root, "tests", "fuzz_corpus", "corpus.jsonl")


def dump_entry(res: ScenarioResult) -> str:
    """Canonical one-line JSON for one scenario result."""
    entry = {"class": res.cls, "seed": f"{res.seed:#018x}",
             "cell": res.cell, "backend": res.backend,
             "verdict": res.verdict}
    return json.dumps(entry, separators=(", ", ": "))


def load_corpus(path: Optional[str] = None) -> List[Dict[str, Any]]:
    path = path or default_corpus_path()
    entries: List[Dict[str, Any]] = []
    if not os.path.exists(path):
        return entries
    with open(path, "r", encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            entry = json.loads(line)
            missing = [k for k in ENTRY_KEYS if k not in entry]
            if missing:
                raise ValueError(
                    f"{path}:{lineno}: corpus entry missing {missing}")
            entries.append(entry)
    return entries


def append_entries(results: Iterable[ScenarioResult],
                   path: Optional[str] = None) -> int:
    """Append results not already present (keyed by class+seed)."""
    path = path or default_corpus_path()
    have = {(e["class"], int(e["seed"], 16))
            for e in load_corpus(path)}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    wrote = 0
    with open(path, "a", encoding="utf-8") as fh:
        for res in results:
            if res.key() in have:
                continue
            fh.write(dump_entry(res) + "\n")
            have.add(res.key())
            wrote += 1
    return wrote


def replay_corpus(path: Optional[str] = None
                  ) -> Tuple[List[ScenarioResult], List[str]]:
    """Re-run every corpus entry; returns (results, mismatches).

    A mismatch is any divergence from the recorded entry — verdict,
    resolved cell, or backend — each described as one line carrying the
    replay tuple."""
    results: List[ScenarioResult] = []
    mismatches: List[str] = []
    for e in load_corpus(path):
        seed = int(e["seed"], 16)
        res = run_scenario(e["class"], seed, cell=e["cell"])
        results.append(res)
        for field_name, want, got in (
                ("verdict", e["verdict"], res.verdict),
                ("cell", e["cell"], res.cell),
                ("backend", e["backend"], res.backend)):
            if want != got:
                mismatches.append(
                    f"{field_name} changed for (class={e['class']} "
                    f"seed={e['seed']} cell={e['cell']} "
                    f"backend={e['backend']}): recorded {want!r}, "
                    f"replay got {got!r}")
    return results, mismatches


def class_table(results: Iterable[ScenarioResult],
                mismatches: Iterable[str] = ()) -> str:
    """Per-class markdown table for the CI job summary."""
    by_cls: Dict[str, Dict[str, int]] = {}
    for r in results:
        row = by_cls.setdefault(r.cls, {"entries": 0, "ok": 0,
                                        "fail": 0})
        row["entries"] += 1
        row["ok" if r.verdict == "ok" else "fail"] += 1
    lines = ["| scenario class | entries | ok | fail |",
             "|---|---|---|---|"]
    for cls in sorted(by_cls):
        row = by_cls[cls]
        lines.append(f"| {cls} | {row['entries']} | {row['ok']} "
                     f"| {row['fail']} |")
    n_mis = len(list(mismatches))
    lines.append("")
    lines.append(f"verdict mismatches: **{n_mis}**")
    return "\n".join(lines)
