"""Seeded crash-schedule fuzzer (DESIGN.md §12, docs/FUZZING.md).

Turns the durable-linearizability checker into a scenario fuzzer: every
scenario is a PURE FUNCTION of a 64-bit seed plus a scenario-class tag,
so any discovery replays byte-for-byte from its seed.  The classes
compose three ingredients the fixed sweeps cannot reach:

  * randomized logical-thread schedules — a deterministic scheduler
    drives the staged announce/perform seam, choosing announcer
    subsets, op mixes and the performing thread from the seed;
  * crash points between INDIVIDUAL persistence instructions — a
    kind-aware injector on the pwb/pfence/psync tick seam (the same
    accessor seam the persist audit uses), so a crash can land at "the
    3rd psync" instead of the aggregate countdown's Nth event;
  * partial failures — losing one segment of a multi-segment ShmNVM,
    killing a worker subset mid-round, crash DURING recover, and
    cross-version recovery across an elastic reshape.

Failures shrink to a minimal seed (``repro.fuzz.shrink``) and land as
one JSON line each in ``tests/fuzz_corpus/`` which CI replays
deterministically on every PR (``python -m repro.fuzz replay``).
"""

from .crashpoints import CrashPointInjector
from .scenarios import (SCENARIO_CLASSES, ScenarioResult, run_scenario)
from .shrink import shrink_seed
from .corpus import (load_corpus, dump_entry, append_entries,
                     replay_corpus, class_table)

__all__ = [
    "CrashPointInjector", "SCENARIO_CLASSES", "ScenarioResult",
    "run_scenario", "shrink_seed", "load_corpus", "dump_entry",
    "append_entries", "replay_corpus", "class_table",
]
