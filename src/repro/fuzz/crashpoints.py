"""Instruction-kind crash-point injector.

The countdown the sweeps use (``nvm.arm_crash(n)``) counts pwb, pfence
and psync ticks in aggregate; related work (the detectability machinery
in Rusanovsky et al.'s flat-combining persistence and MOD's
per-instruction persist-cost accounting) shows bugs that only surface
when the crash lands between two SPECIFIC instructions.  The injector
rides the same ``_tick_crash_point`` seam but filters by instruction
kind, so a scenario can say "crash at the 3rd psync from now".

Armed via ``nvm.arm_injector(...)``; the NVM consults it at every tick
and disarms it the moment it fires.  Unlike the countdown it survives
``disarm_crash`` — which is what lets a scenario crash INSIDE
``recover`` (recover's first act is disarming the countdown).
"""

from __future__ import annotations

import random
from typing import Optional

KINDS = ("pwb", "pfence", "psync", "any")


class CrashPointInjector:
    """Crash at the ``nth`` next persistence instruction of ``kind``.

    ``rng`` governs the adversarial write-back drain at the crash
    (None = drain nothing, the most adversarial loss).  ``fired`` and
    ``seen`` expose what happened for scenario bookkeeping.
    """

    __slots__ = ("kind", "remaining", "rng", "fired", "seen")

    def __init__(self, kind: str, nth: int,
                 rng: Optional[random.Random] = None) -> None:
        if kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
        if nth < 1:
            raise ValueError(f"nth is 1-based, got {nth}")
        self.kind = kind
        self.remaining = nth
        self.rng = rng
        self.fired = False
        self.seen = 0

    def tick(self, kind: str) -> bool:
        """Called by the NVM at each persistence instruction; True means
        crash NOW (the NVM then disarms this injector)."""
        if self.fired or (self.kind != "any" and kind != self.kind):
            return False
        self.seen += 1
        self.remaining -= 1
        if self.remaining <= 0:
            self.fired = True
            return True
        return False

    def __repr__(self) -> str:
        return (f"CrashPointInjector(kind={self.kind!r}, "
                f"remaining={self.remaining}, fired={self.fired})")
