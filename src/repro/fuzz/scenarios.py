"""Scenario classes: each is a pure function of (class tag, 64-bit seed).

Every class derives ALL its parameters — registry cell, thread count,
round count, crash countdowns, injector kinds, kill subsets — from
``random.Random(seed ^ class_salt)`` in a fixed draw order, runs the
scenario under the history checker, and returns a ``ScenarioResult``
whose ``verdict`` is ``"ok"`` or ``"fail: <first violated invariant>"``
(or ``"error: ..."`` for harness-level exceptions).  Replaying the same
(class, seed) therefore reproduces the same verdict byte-for-byte —
the property the corpus gate relies on.

``cell`` may be pinned (corpus replay passes the recorded cell; the
seeded-bug selftest pins the cell the bug lives in).  Pinning happens
AFTER the derivation draw so the RNG stream — and with it every other
decision — is identical whether or not the pin matches the derivation.
"""

from __future__ import annotations

import random
import sys
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..api import CombiningRuntime
from ..core import SimulatedCrash
from ..runtime.elastic import ElasticCoordinator
from .crashpoints import CrashPointInjector
from .scheduler import StagedScheduler, drain_all, PAD, STAGE_OPS

MASK64 = (1 << 64) - 1

#: per-class RNG salts: two classes never see the same stream for one seed
_SALTS = {"schedule": 0x5C4ED0_01,
          "instr-crash": 0x1457C2_A5,
          "segment-loss": 0x5E97_055,
          "worker-kill": 0x3072415,
          "crash-during-recover": 0xC4A54EC0,
          "reshape-recovery": 0x4E54A9E}

#: detectable announce/perform cells (staged classes)
ANNOUNCE_CELLS = [(k, p) for k in ("queue", "stack", "heap")
                  for p in ("pbcomb", "pwfcomb")]
#: invoke-path cells incl. the non-detectable baselines (at-least-once)
INVOKE_CELLS = ANNOUNCE_CELLS + [("queue", "durable-ms"),
                                 ("queue", "lock-direct"),
                                 ("stack", "dfc"),
                                 ("stack", "lock-undo"),
                                 ("heap", "lock-direct")]


def _checker_mod():
    """tests/checker.py is the single source of truth for history
    verdicts; it lives beside the tests, not in the package, so resolve
    it the way the test-suite does (tests/ on sys.path) with a
    repo-root fallback for CLI runs."""
    try:
        import checker
        return checker
    except ImportError:
        import os
        here = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        tests = os.path.join(here, "tests")
        if os.path.isdir(tests) and tests not in sys.path:
            sys.path.insert(0, tests)
        import checker
        return checker


@dataclass
class ScenarioResult:
    cls: str
    seed: int
    cell: str                 # "kind/protocol"
    backend: str              # "threads" | "shm"
    verdict: str              # "ok" | "fail: ..." | "error: ..."
    detail: str = ""
    stats: Dict[str, Any] = field(default_factory=dict)

    @property
    def failed(self) -> bool:
        return self.verdict != "ok"

    def key(self) -> Tuple[str, int]:
        return (self.cls, self.seed)


def _pick_cell(rng: random.Random, cells, pin: Optional[str]
               ) -> Tuple[str, str]:
    drawn = cells[rng.randrange(len(cells))]
    if pin is None:
        return drawn
    kind, _, proto = pin.partition("/")
    if (kind, proto) not in cells:
        raise ValueError(f"cell {pin!r} not valid for this class "
                         f"(choices: {cells})")
    return kind, proto


def _first_failure(exc: AssertionError) -> str:
    for ln in str(exc).splitlines():
        ln = ln.strip()
        if ln.startswith("- "):
            return ln[2:]
    return str(exc).splitlines()[0]


def _result(cls: str, seed: int, cell: str, backend: str,
            body: Callable[[], Dict[str, Any]]) -> ScenarioResult:
    """Run ``body`` (which ends in a checker call) to a verdict."""
    try:
        stats = body() or {}
    except AssertionError as e:
        return ScenarioResult(cls, seed, cell, backend,
                              f"fail: {_first_failure(e)}",
                              detail=str(e))
    except Exception as e:                      # noqa: BLE001
        return ScenarioResult(cls, seed, cell, backend,
                              f"error: {type(e).__name__}: {e}")
    return ScenarioResult(cls, seed, cell, backend, "ok", stats=stats)


# --------------------------------------------------------------------- #
# schedule: randomized staged rounds + countdown crashes (threads)      #
# --------------------------------------------------------------------- #
def _sc_schedule(seed: int, cell: Optional[str] = None) -> ScenarioResult:
    chk_mod = _checker_mod()
    rng = random.Random(seed ^ _SALTS["schedule"])
    kind, proto = _pick_cell(rng, ANNOUNCE_CELLS, cell)
    cellstr = f"{kind}/{proto}"
    n = rng.randint(2, 4)
    rounds = rng.randint(3, 7)
    banner = chk_mod.replay_banner("schedule", seed, cellstr, "threads")

    def body():
        rt = CombiningRuntime(n_threads=n)
        try:
            chk = chk_mod.HistoryChecker(kind, replay=banner)
            obj = rt.make(kind, proto)
            sched = StagedScheduler(rt, obj, chk, rng, n)
            for _ in range(rounds):
                arm = (rng.randint(1, 20) if rng.random() < 0.7
                       else None)
                arng = random.Random(rng.randrange(1 << 30))
                sched.round(arm_cd=arm, arm_rng=arng)
            sched.finish()
            return {"rounds": rounds, "crashes": sched.crashes}
        finally:
            rt.close()

    return _result("schedule", seed, cellstr, "threads", body)


# --------------------------------------------------------------------- #
# instr-crash: kind-aware injector on the invoke path (threads)         #
# --------------------------------------------------------------------- #
def _sc_instr_crash(seed: int, cell: Optional[str] = None
                    ) -> ScenarioResult:
    chk_mod = _checker_mod()
    rng = random.Random(seed ^ _SALTS["instr-crash"])
    kind, proto = _pick_cell(rng, INVOKE_CELLS, cell)
    cellstr = f"{kind}/{proto}"
    n = rng.randint(2, 3)
    rounds = rng.randint(3, 6)
    banner = chk_mod.replay_banner("instr-crash", seed, cellstr,
                                   "threads")

    def body():
        rt = CombiningRuntime(n_threads=n)
        try:
            chk = chk_mod.HistoryChecker(kind, replay=banner)
            obj = rt.make(kind, proto)
            detectable = obj.adapter.detectable
            handles = [rt.attach(p) for p in range(n)]
            add_op, rem_op = STAGE_OPS[kind]
            idx = [0] * n
            crashes = 0
            for _ in range(rounds):
                order = rng.sample(range(n), n)
                arm_at = (rng.choice(order)
                          if rng.random() < 0.8 else None)
                for p in order:
                    if p == arm_at:
                        rt.nvm.arm_injector(CrashPointInjector(
                            rng.choice(("pwb", "pfence", "psync")),
                            rng.randint(1, 6),
                            random.Random(rng.randrange(1 << 30))))
                    if rng.random() < 0.6:
                        op, a = add_op, (p, idx[p], PAD)
                        idx[p] += 1
                    else:
                        op, a = rem_op, None
                    try:
                        if a is None:
                            ret = handles[p].invoke(obj, op)
                        else:
                            ret = handles[p].invoke(obj, op, a)
                        chk.extend(p, [(op, a, ret)])
                    except SimulatedCrash:
                        crashes += 1
                        records = [
                            (nm, t, op_, a_, s_)
                            for (nm, t), (op_, a_, s_)
                            in rt._inflight.items()]
                        rt.nvm.disarm_injector()
                        replies = rt.recover()
                        chk.apply_replay(records, replies)
                        if not detectable:
                            chk.note_at_least_once(records)
            rt.nvm.disarm_injector()
            rt.crash(random.Random(rng.randrange(1 << 30)))
            rt.recover()
            chk.check(drain_all(rt, obj))
            return {"rounds": rounds, "crashes": crashes,
                    "detectable": detectable}
        finally:
            rt.close()

    return _result("instr-crash", seed, cellstr, "threads", body)


# --------------------------------------------------------------------- #
# segment-loss: one DIMM loses its pending write-backs (shm, in-parent) #
# --------------------------------------------------------------------- #
def _sc_segment_loss(seed: int, cell: Optional[str] = None
                     ) -> ScenarioResult:
    chk_mod = _checker_mod()
    rng = random.Random(seed ^ _SALTS["segment-loss"])
    kind, proto = _pick_cell(rng, ANNOUNCE_CELLS, cell)
    cellstr = f"{kind}/{proto}"
    segments = rng.randint(2, 3)
    n = rng.randint(2, 3)
    rounds = rng.randint(2, 5)
    banner = chk_mod.replay_banner("segment-loss", seed, cellstr, "shm")

    def body():
        rt = CombiningRuntime(n_threads=n, backend="shm",
                              segments=segments)
        try:
            chk = chk_mod.HistoryChecker(kind, replay=banner)
            obj = rt.make(kind, proto,
                          segment=rng.randrange(segments))
            sched = StagedScheduler(rt, obj, chk, rng, n)
            for _ in range(rounds):
                arm = (rng.randint(1, 16) if rng.random() < 0.8
                       else None)
                lose = rng.randrange(segments)
                sched.round(arm_cd=arm, arm_rng=None,
                            lose_segment=lose if arm else None)
            sched.finish()
            return {"rounds": rounds, "crashes": sched.crashes,
                    "segments": segments}
        finally:
            rt.close()

    return _result("segment-loss", seed, cellstr, "shm", body)


# --------------------------------------------------------------------- #
# worker-kill: a worker subset dies with its journal (shm, real procs)  #
# --------------------------------------------------------------------- #
def _sc_worker_kill(seed: int, cell: Optional[str] = None
                    ) -> ScenarioResult:
    chk_mod = _checker_mod()
    rng = random.Random(seed ^ _SALTS["worker-kill"])
    kind, proto = _pick_cell(rng, ANNOUNCE_CELLS, cell)
    cellstr = f"{kind}/{proto}"
    workers = rng.randint(3, 4)
    pairs = rng.randint(4, 8)
    waves = rng.randint(1, 2)
    banner = chk_mod.replay_banner("worker-kill", seed, cellstr, "shm")

    def body():
        rt = CombiningRuntime(n_threads=workers, backend="shm",
                              segments=2)
        try:
            chk = chk_mod.HistoryChecker(kind, replay=banner)
            obj = rt.make(kind, proto)
            pool = rt.spawn_workers(workers)
            kills = 0
            for wave in range(waves):
                rt.nvm.arm_crash(rng.randint(8, 40),
                                 random.Random(rng.randrange(1 << 30)))
                res = pool.run_pairs(obj, pairs, collect=True,
                                     rich=True,
                                     index_base=wave * pairs)
                if not res.crashed:
                    chk.extend_pool(res)
                    rt.nvm.disarm_crash()
                    continue
                # the kill: a seeded worker subset dies WITH its
                # journal — every response it acked (or would have
                # received from the replay) is lost with its clients.
                # The SYSTEM still replays every in-flight record
                # (Section 2's system-support assumption: dropping a
                # record would desync that thread's seq/announce
                # parity and corrupt LATER recoveries for its tid) —
                # the partial failure is losing the ACKS, not the
                # replay.
                tids = sorted(r.tid for r in res.reports)
                killed = set(rng.sample(tids,
                                        rng.randint(1, len(tids) - 1)))
                kills += len(killed)
                survivors, lost = res.partition_inflight(killed)
                for rep in res.reports:
                    if rep.tid in killed:
                        chk.note_lost(rep.results or [])
                    else:
                        chk.extend(rep.tid, rep.results)
                chk.note_lost(
                    [(op, a, None) for _n, _t, op, a, _s in lost])
                replies = rt.recover(inflight=survivors + lost)
                chk.apply_replay(survivors, replies)
            rt.crash(random.Random(rng.randrange(1 << 30)))
            rt.recover()
            chk.check(drain_all(rt, obj))
            return {"waves": waves, "killed": kills}
        finally:
            rt.close()

    return _result("worker-kill", seed, cellstr, "shm", body)


# --------------------------------------------------------------------- #
# crash-during-recover: a second crash lands inside the replay          #
# --------------------------------------------------------------------- #
def _sc_crash_during_recover(seed: int, cell: Optional[str] = None
                             ) -> ScenarioResult:
    chk_mod = _checker_mod()
    rng = random.Random(seed ^ _SALTS["crash-during-recover"])
    kind, proto = _pick_cell(rng, ANNOUNCE_CELLS, cell)
    cellstr = f"{kind}/{proto}"
    n = rng.randint(2, 4)
    rounds = rng.randint(2, 5)
    banner = chk_mod.replay_banner("crash-during-recover", seed,
                                   cellstr, "threads")

    def body():
        rt = CombiningRuntime(n_threads=n)
        try:
            chk = chk_mod.HistoryChecker(kind, replay=banner)
            obj = rt.make(kind, proto)
            sched = StagedScheduler(rt, obj, chk, rng, n)
            for _ in range(rounds):
                # small countdown: the first crash is near-certain, so
                # most rounds exercise the recover-crash path
                arm = rng.randint(1, 10)
                arng = random.Random(rng.randrange(1 << 30))
                ik = rng.choice(("pwb", "pfence", "psync", "any"))
                nth = rng.randint(1, 4)
                irng = random.Random(rng.randrange(1 << 30))
                sched.round(
                    arm_cd=arm, arm_rng=arng,
                    recover_injector=lambda k=ik, t=nth, r=irng:
                        CrashPointInjector(k, t, r))
            sched.finish()
            return {"rounds": rounds, "crashes": sched.crashes,
                    "recover_crashes": sched.recover_crashes}
        finally:
            rt.close()

    return _result("crash-during-recover", seed, cellstr, "threads",
                   body)


# --------------------------------------------------------------------- #
# reshape-recovery: checkpoint at step N, recovered after join/leave    #
# --------------------------------------------------------------------- #
def _sc_reshape_recovery(seed: int, cell: Optional[str] = None
                         ) -> ScenarioResult:
    chk_mod = _checker_mod()
    rng = random.Random(seed ^ _SALTS["reshape-recovery"])
    proto = ("pbcomb", "pwfcomb")[rng.randrange(2)]
    if cell is not None:
        kind, _, proto = cell.partition("/")
        if kind != "ckpt":
            raise ValueError("reshape-recovery runs on the ckpt cell")
    cellstr = f"ckpt/{proto}"
    n = rng.randint(2, 4)
    steps = rng.randint(3, 6)
    words = 4
    banner = chk_mod.replay_banner("reshape-recovery", seed, cellstr,
                                   "threads")

    def body():
        from ..api.mp import checkpoint_payload
        rt = CombiningRuntime(n_threads=n)
        try:
            chk = chk_mod.HistoryChecker("ckpt", replay=banner)
            ck = rt.make("ckpt", proto)
            # wall-clock-free coordinator: failures only via explicit
            # leave(), so the plan is a pure function of the seed
            coord = ElasticCoordinator(n, heartbeat_timeout=1e9)
            step = 0
            for _ in range(steps):
                step += 1
                writer = rng.randrange(n)
                payload = checkpoint_payload(writer, step, words)
                if rng.random() < 0.5:
                    rt.arm_crash(rng.randint(1, 12),
                                 random.Random(rng.randrange(1 << 30)))
                h = rt.attach(writer)
                try:
                    ret = h.invoke(ck, "persist", (step, payload))
                    chk.extend(writer,
                               [("persist", (step, payload), ret)])
                except SimulatedCrash:
                    records = [(nm, t, op_, a_, s_)
                               for (nm, t), (op_, a_, s_)
                               in rt._inflight.items()]
                    replies = rt.recover()
                    chk.apply_replay(records, replies)
                rt.nvm.disarm_crash()
                coord.heartbeat(writer, step)
            committed = ck.snapshot()["step"]

            # elastic reshape: one host leaves, maybe a new one joins
            leaver = rng.randrange(n)
            coord.leave(leaver)
            joiner = None
            if rng.random() < 0.7:
                joiner = n + rng.randrange(2)
                coord.join(joiner)
            plan = coord.rescale(committed)

            # cross-version recovery: full power loss, then the NEW
            # host set resumes from the plan's restore point
            rt.crash(random.Random(rng.randrange(1 << 30)))
            rt.recover()
            snap = ck.snapshot()
            assert plan.restore_step == committed, (
                f"  - plan restore_step {plan.restore_step} != "
                f"committed durable step {committed}\n"
                + banner)
            assert leaver not in plan.hosts, (
                f"  - departed host {leaver} still in plan "
                f"{plan.hosts}\n" + banner)
            if joiner is not None:
                assert joiner in plan.hosts, (
                    f"  - joined host {joiner} missing from plan "
                    f"{plan.hosts}\n" + banner)
            assert snap["step"] >= committed, (
                f"  - durable step {snap['step']} regressed below "
                f"committed {committed} across the reshape\n" + banner)

            # the reshaped fleet continues from restore_step + 1
            step = max(snap["step"], plan.restore_step)
            for host in plan.hosts[:2]:
                step += 1
                tid = host % n
                payload = checkpoint_payload(tid, step, words)
                ret = rt.attach(tid).invoke(ck, "persist",
                                            (step, payload))
                chk.extend(tid, [("persist", (step, payload), ret)])
                coord.heartbeat(host, step)
            chk_mod.check_ckpt(chk.events, ck.snapshot(), words,
                               replay=banner)
            return {"steps": steps, "committed": committed,
                    "dp_size": plan.dp_size}
        finally:
            rt.close()

    return _result("reshape-recovery", seed, cellstr, "threads", body)


# --------------------------------------------------------------------- #
SCENARIO_CLASSES: Dict[str, Callable[..., ScenarioResult]] = {
    "schedule": _sc_schedule,
    "instr-crash": _sc_instr_crash,
    "segment-loss": _sc_segment_loss,
    "worker-kill": _sc_worker_kill,
    "crash-during-recover": _sc_crash_during_recover,
    "reshape-recovery": _sc_reshape_recovery,
}


def run_scenario(cls: str, seed: int, cell: Optional[str] = None,
                 backend: Optional[str] = None) -> ScenarioResult:
    """Run one scenario; pure function of (cls, seed [, cell pin]).

    ``backend`` is informational/validated — each class determines its
    backend; passing a mismatching one is an error, not a knob."""
    if cls not in SCENARIO_CLASSES:
        raise ValueError(f"unknown scenario class {cls!r} "
                         f"(have: {sorted(SCENARIO_CLASSES)})")
    res = SCENARIO_CLASSES[cls](seed & MASK64, cell)
    if backend is not None and backend != res.backend:
        raise ValueError(f"class {cls} runs on backend "
                         f"{res.backend!r}, not {backend!r}")
    return res
