"""Deterministic logical-thread scheduler for staged fuzz rounds.

One OS thread drives n logical threads through the staged
announce/perform seam (the only way to enumerate in-round crash points
deterministically in one process): every scheduling decision — which
threads announce this round, in what order, with which op, who
performs, whether and when the machine crashes — is drawn from the
scenario's seeded RNG, so the whole interleaving replays from the seed.

The round protocol mirrors the fixed staged sweeps
(tests/test_linearizability.py): announce a subset, one announcer
performs (combining the others), a crash may land anywhere inside the
round, and ``recover`` replays every announced request.  On top of
that, rounds can crash AGAIN inside recover (a kind-aware injector
fires during the replay — the countdown can't, recover disarms it
first) and re-recover from the retained in-flight records, which is
exactly the crash-during-recover coverage the fixed sweeps never had.
"""

from __future__ import annotations

import random
from typing import Any, Callable, List, Optional, Tuple

from ..core import SimulatedCrash

#: add/remove op names per structure kind (pair-workload shape)
STAGE_OPS = {"queue": ("enqueue", "dequeue"),
             "stack": ("push", "pop"),
             "heap": ("insert", "delete_min")}

DRAIN_OP = {"queue": "dequeue", "stack": "pop", "heap": "delete_min"}

#: payload pad: long enough that rich values exercise the blob path on
#: the shm backend, short enough to keep corpus replay fast
PAD = "fuzz-blob-pad-" * 2


def drain_all(rt, obj) -> List[Any]:
    """Quiescent post-recovery drain (the structure's own remove op
    until empty) — the final-state input the checker wants."""
    fn = rt.attach(0).invoker(obj, DRAIN_OP[obj.kind], arity=0)
    out = []
    while True:
        v = fn()
        if v is None:
            break
        out.append(v)
    return out


class StagedScheduler:
    """Drives seeded announce/perform rounds against one structure.

    ``chk`` is the scenario's ``HistoryChecker``; every completed or
    replayed response is journaled here.  ``rng`` is the scenario RNG —
    the scheduler consumes draws in a fixed order so runs are pure
    functions of the seed.
    """

    def __init__(self, rt, obj, chk, rng: random.Random, n: int) -> None:
        self.rt = rt
        self.obj = obj
        self.chk = chk
        self.rng = rng
        self.n = n
        self.handles = [rt.attach(p) for p in range(n)]
        self.add_op, self.rem_op = STAGE_OPS[obj.kind]
        self._idx = [0] * n              # per-producer value index
        self.crashes = 0
        self.recover_crashes = 0

    # ------------------------------------------------------------------ #
    def round(self, *, arm_cd: Optional[int] = None,
              arm_rng: Optional[random.Random] = None,
              lose_segment: Optional[int] = None,
              recover_injector: Optional[Callable[[], Any]] = None
              ) -> bool:
        """One staged round; returns True iff a crash landed in it.

        ``arm_cd``/``arm_rng``: crash countdown + drain adversary.
        ``lose_segment``: shm partial-failure policy for that crash.
        ``recover_injector``: factory for a ``CrashPointInjector`` armed
        over the FIRST recover when the round crashed — a second crash
        then lands inside the replay and a second recover finishes from
        the retained in-flight records.
        """
        rng = self.rng
        k = rng.randint(1, self.n)
        announcers = rng.sample(range(self.n), k)
        staged: List[Tuple[int, str, Any, int]] = []
        for p in announcers:
            if rng.random() < 0.65:
                op, args = self.add_op, (p, self._idx[p], PAD)
                self._idx[p] += 1
            else:
                op, args = self.rem_op, None
            if args is None:
                seq = self.handles[p].announce(self.obj, op)
            else:
                seq = self.handles[p].announce(self.obj, op, args)
            staged.append((p, op, args, seq))

        if arm_cd is not None:
            if lose_segment is not None:
                self.rt.nvm.arm_crash(arm_cd, arm_rng,
                                      lose_segment=lose_segment)
            else:
                self.rt.nvm.arm_crash(arm_cd, arm_rng)

        performer = rng.choice(announcers)
        crashed = False
        performed = False
        try:
            ret = self.handles[performer].perform(self.obj)
            performed = True
            p_op, p_args = next((op, a) for q, op, a, _s in staged
                                if q == performer)
            self.chk.extend(performer, [(p_op, p_args, ret)])
        except SimulatedCrash:
            crashed = True
            self.crashes += 1

        records = [(self.obj.name, p, op, a, seq)
                   for p, op, a, seq in staged]
        nvm = self.rt.nvm
        nvm.disarm_crash()
        if crashed and recover_injector is not None:
            inj = recover_injector()
            nvm.arm_injector(inj)
            try:
                replies = self.rt.recover(inflight=records)
                nvm.disarm_injector()
            except SimulatedCrash:
                # crash DURING recover: the caller retains the records,
                # so a second recover replays everything idempotently
                self.recover_crashes += 1
                nvm.disarm_injector()
                nvm.disarm_crash()
                replies = self.rt.recover(inflight=records)
        else:
            replies = self.rt.recover(inflight=records)

        for p, op, a, _seq in staged:
            if p == performer and performed:
                continue        # journaled at perform time
            key = (self.obj.name, p)
            if key in replies:
                self.chk.extend(p, [(op, a, replies[key])])
        return crashed

    # ------------------------------------------------------------------ #
    def finish(self) -> None:
        """Final full crash + recovery, then drain and check."""
        self.rt.crash(random.Random(self.rng.randrange(1 << 30)))
        self.rt.recover()
        self.chk.check(drain_all(self.rt, self.obj))
