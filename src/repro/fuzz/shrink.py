"""Seed shrinking: reduce a failing 64-bit seed to a minimal one.

There is no structured input to delta-debug — the scenario IS the seed —
so shrinking means searching nearby seeds that still fail and preferring
"simpler" ones.  Simplicity is (popcount, value): fewer set bits first
(sparse seeds are easier to eyeball and diff), then numerically smaller.
The search is greedy over single-bit clears plus a few shift/mask jumps,
re-running the scenario for each candidate, bounded by ``budget``
evaluations so a slow scenario class cannot stall CI's explore job.
"""

from __future__ import annotations

from typing import Callable, Tuple

MASK64 = (1 << 64) - 1


def _cost(seed: int) -> Tuple[int, int]:
    return (bin(seed).count("1"), seed)


def shrink_seed(fails: Callable[[int], bool], seed: int,
                budget: int = 64) -> int:
    """Greedy seed minimization.

    ``fails(candidate)`` must re-run the scenario and return True iff it
    still reproduces the failure.  ``seed`` must itself fail (the caller
    just observed it); it is returned unchanged if nothing simpler
    reproduces within ``budget`` evaluations."""
    best = seed & MASK64
    tried = {best}
    evals = 0
    improved = True
    while improved and evals < budget:
        improved = False
        candidates = [best & ~(1 << b) for b in range(64)
                      if best & (1 << b)]
        candidates += [best >> 1, best >> 8,
                       best & 0xFFFFFFFF, best & 0xFFFF]
        for cand in candidates:
            cand &= MASK64
            if cand in tried or _cost(cand) >= _cost(best):
                continue
            tried.add(cand)
            evals += 1
            if fails(cand):
                best = cand
                improved = True
                break               # restart the scan from the new best
            if evals >= budget:
                break
    return best
