"""KV-cache slot allocator — the paper's recycling-stack memory manager.

Serving keeps a fixed pool of per-sequence KV slots (TPU memory is
pre-allocated; slots are indices into the batched cache arrays).  Freed
slots go onto a LIFO *recycling stack* — the PBStack GC scheme — so slot
reuse is as contiguous as the original reservation order (persistence
principle P3 transplanted to HBM locality: recently-touched cache lines
get reused first).
"""

from __future__ import annotations

import threading
from typing import List, Optional


class SlotAllocator:
    def __init__(self, n_slots: int) -> None:
        self.n_slots = n_slots
        self._bump = 0
        self._recycled: List[int] = []        # the recycling stack
        self._lock = threading.Lock()
        self.stats = {"alloc": 0, "free": 0, "recycled_hits": 0}

    def alloc(self) -> Optional[int]:
        with self._lock:
            self.stats["alloc"] += 1
            if self._recycled:
                self.stats["recycled_hits"] += 1
                return self._recycled.pop()
            if self._bump < self.n_slots:
                s = self._bump
                self._bump += 1
                return s
            self.stats["alloc"] -= 1
            return None                       # pool exhausted

    def free(self, slot: int) -> None:
        with self._lock:
            self.stats["free"] += 1
            self._recycled.append(slot)

    def available(self) -> int:
        with self._lock:
            return (self.n_slots - self._bump) + len(self._recycled)
