"""Priority scheduler — PBHeap applied to request admission.

Requests carry a deadline/priority; the combiner admits the most urgent
first when the batch or KV pool is contended.  The heap is the paper's
PBHeap shape: a bounded sequential min-heap mutated only by the combiner
(so no internal locking is needed beyond the combiner's own mutual
exclusion), and its state can ride inside the engine's persisted
StateRec if admission order must survive crashes.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Any, List, Optional, Tuple


class RequestHeap:
    def __init__(self, capacity: int = 4096) -> None:
        self.capacity = capacity
        self._heap: List[Tuple[float, int, Any]] = []
        self._tie = itertools.count()

    def insert(self, priority: float, item: Any) -> bool:
        if len(self._heap) >= self.capacity:
            return False
        heapq.heappush(self._heap, (priority, next(self._tie), item))
        return True

    def delete_min(self) -> Optional[Any]:
        if not self._heap:
            return None
        return heapq.heappop(self._heap)[2]

    def get_min(self) -> Optional[Any]:
        return self._heap[0][2] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)
