"""Priority scheduler — PBHeap applied to request admission.

Requests carry a deadline/priority; the combiner admits the most urgent
first when the batch or KV pool is contended.  The heap is the paper's
PBHeap shape: a bounded sequential min-heap mutated only by the combiner
(so no internal locking is needed beyond the combiner's own mutual
exclusion), and its state can ride inside the engine's persisted
StateRec if admission order must survive crashes.

``PriorityAdmission`` is the fleet wiring (DESIGN.md §9): each fleet
worker pulls a small window of requests off its shard's ingress queue
per tick, offers them here, and serves them earliest-deadline-first —
the KV-cache serving engine's admission policy applied at the
open-loop harness's dequeue point.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Any, Iterator, List, Optional, Tuple


class RequestHeap:
    def __init__(self, capacity: int = 4096) -> None:
        self.capacity = capacity
        self._heap: List[Tuple[float, int, Any]] = []
        self._tie = itertools.count()

    def insert(self, priority: float, item: Any) -> bool:
        if len(self._heap) >= self.capacity:
            return False
        heapq.heappush(self._heap, (priority, next(self._tie), item))
        return True

    def delete_min(self) -> Optional[Any]:
        if not self._heap:
            return None
        return heapq.heappop(self._heap)[2]

    def get_min(self) -> Optional[Any]:
        return self._heap[0][2] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)


class PriorityAdmission:
    """Deadline-priority admission window over a shard ingress queue.

    Fleet requests are ``(client, seq, t_intended, priority)`` tuples
    whose priority is an absolute deadline (intended arrival + latency
    budget, seconds from the window epoch).  ``offer`` stages a
    dequeued request; ``admit`` yields everything staged, most urgent
    (smallest deadline) first — so when a worker pulls several pending
    requests out of a backed-up ingress, interactive-class requests
    overtake batch-class ones at the serve point."""

    def __init__(self, window: int = 4, capacity: int = 4096) -> None:
        self.window = window
        self._heap = RequestHeap(capacity)

    def offer(self, request: Tuple) -> bool:
        return self._heap.insert(float(request[3]), request)

    def admit(self) -> Iterator[Tuple]:
        while True:
            r = self._heap.delete_min()
            if r is None:
                return
            yield r

    def __len__(self) -> int:
        return len(self._heap)
