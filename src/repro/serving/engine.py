"""Combining batch-serving engine.

Continuous batching IS software combining (DESIGN.md §2): clients
announce generate/cancel requests onto a shared ``AnnounceBoard`` (the
runtime's announcement plumbing — the same component every combining
protocol in this repo announces through) and wait; two combiner
instances — mirroring PBQueue's enqueue/dequeue split — do all the
work:

  * the PREFILL combiner batches every active prefill announcement, runs
    one batched prefill, allocates KV slots, and appends the sequences to
    the shared sequence table;
  * the DECODE combiner batches every *committed* live sequence and runs
    one decode step for all of them per round.

The ``oldTail`` rule: the decode combiner only adopts sequences whose
prefill round has been committed (response-log StateRec persisted) —
PBQueue's "never dequeue past the durable tail", here "never generate
from (or complete) state that a crash would un-happen".

Detectability: client requests carry (client_id, seq).  Completed
responses are recorded in the engine's response log — a
``PBCombCheckpointer`` registered with the shared ``CombiningRuntime``
and written through the batched ``Handle.invoke_many`` path: all
completions of a round are announced together and persisted by ONE
combining round (one contiguous StateRec write + one psync).  After a
crash, a client re-announcing (client_id, seq) receives its cached
response instead of recomputing — exactly the paper's Recover path.

Elimination: a CANCEL announcement is paired with its target GENERATE
announcement inside the combiner *before* touching engine state — both
complete in one pass (the paper's push/pop elimination).

The model is pluggable: ``prefill_batch_fn(prompts) -> (first_tok, kv)``
and ``decode_batch_fn(kv_list, last_toks) -> next_toks`` — a real JAX
model adapter lives in examples/serve_combining.py; tests use a toy LM.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..api import AnnounceBoard, Announcement, CombiningRuntime
from ..core.atomics import AtomicInt
from ..persist.checkpoint import CheckpointAdapter, PBCombCheckpointer
from ..persist.store import MemStore, Store
from .kv_cache import SlotAllocator
from .scheduler import RequestHeap


def _live_key(client: int, seq: int) -> int:
    """Sequence-table key for a (client, request-seq) pair."""
    return (client << 32) | (seq & 0xffffffff)


@dataclass
class GenRequest:
    """Announcement payload — pure request data; the announcement record
    (activate/valid bits, done event, response) lives on the board."""
    client: int
    seq: int
    prompt: Tuple[int, ...]
    max_tokens: int
    priority: float = 0.0
    cancel_target: Optional[Tuple[int, int]] = None  # (client, seq) to cancel


@dataclass
class LiveSeq:
    client: int
    seq: int
    slot: int
    tokens: List[int]
    max_tokens: int
    committed: bool = False   # oldTail rule: decode may not touch until True


class CombiningEngine:
    def __init__(self, n_clients: int, *,
                 prefill_batch_fn: Callable,
                 decode_batch_fn: Callable,
                 n_kv_slots: int = 64,
                 max_batch: int = 32,
                 store: Optional[Store] = None,
                 eos_token: int = 0,
                 runtime: Optional[CombiningRuntime] = None,
                 response_log: str = "auto") -> None:
        """``response_log`` selects where completions persist:

          * ``"store"`` — the file-like ``Store`` path (default for
            thread runtimes): a ``PBCombCheckpointer`` whose StateRec
            slot files live in ``store``.
          * ``"nvm"`` — a registry ``log/pbcomb`` structure living in
            the runtime's NVM words: on a shared-memory runtime the
            durable response log (rich token payloads included — blob
            heap, DESIGN.md §8) is then shared with forked worker
            processes, and its psyncs account on its segment's device.
          * ``"auto"`` — ``"nvm"`` iff the runtime's NVM is shm-backed.
        """
        self.n = n_clients
        self.prefill_batch_fn = prefill_batch_fn
        self.decode_batch_fn = decode_batch_fn
        self.max_batch = max_batch
        self.eos = eos_token
        # shared runtime: announce board (volatile — dies with the
        # process) + the durable response log, both under one
        # crash/recovery umbrella.
        self.runtime = runtime or CombiningRuntime(n_threads=n_clients)
        self.board: AnnounceBoard = self.runtime.board("engine", n_clients)
        if response_log == "auto":
            response_log = "nvm" if self.runtime._backend_kind == "shm" \
                or getattr(getattr(self.runtime.nvm, "backend", None),
                           "kind", None) == "shm" else "store"
        if response_log == "nvm":
            self.store = None
            self.ckpt = None
            self.log = self.runtime.make("log", "pbcomb",
                                         name="engine/response-log",
                                         n_clients=n_clients)
        elif response_log == "store":
            self.store = store or MemStore()
            # The engine's durable state is exactly the response log,
            # which lives in the StateRec's ReturnVal/Deactivate fields
            # — the payload pytree is empty.
            self.ckpt = PBCombCheckpointer(self.store, n_clients,
                                           payload_template={})
            self.ckpt.initialize({})
            self.log = self.runtime.register("engine/response-log",
                                             self.ckpt,
                                             CheckpointAdapter())
        else:
            raise ValueError(f"unknown response_log {response_log!r}; "
                             "expected 'auto', 'store' or 'nvm'")
        self._log_handle = self.runtime.attach(0)
        # sequence table (the shared linked structure)
        self.live: Dict[int, LiveSeq] = {}
        self.kv: Dict[int, Any] = {}
        self.slots = SlotAllocator(n_kv_slots)
        self.heap = RequestHeap()
        self.prefill_lock = AtomicInt(0)
        self.decode_lock = AtomicInt(0)
        self._table_lock = threading.Lock()
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self.stats = {"prefill_rounds": 0, "decode_rounds": 0,
                      "prefill_batched": 0, "decode_batched": 0,
                      "eliminated": 0, "persists": 0}

    # ------------------ client API ------------------------------------ #
    def submit(self, client: int, prompt: Sequence[int], max_tokens: int,
               seq: int, priority: float = 0.0,
               timeout: float = 30.0) -> Any:
        req = GenRequest(client, seq, tuple(prompt), max_tokens, priority)
        rec = self.board.announce(client, req)
        if not rec.done.wait(timeout):
            raise TimeoutError(f"client {client} seq {seq}")
        return rec.response

    def cancel(self, client: int, target: Tuple[int, int], seq: int,
               timeout: float = 30.0) -> Any:
        """Cancel the pending request ``target = (client, seq)``."""
        req = GenRequest(client, seq, (), 0, cancel_target=tuple(target))
        rec = self.board.announce(client, req)
        if not rec.done.wait(timeout):
            raise TimeoutError(f"cancel {client}/{seq}")
        return rec.response

    def cached_response(self, client: int, seq: int) -> Tuple[bool, Any]:
        """(was_applied, response) for (client, seq) from the durable
        response log, whichever backing it has."""
        if self.ckpt is not None:
            if self.ckpt.was_applied(client, seq):
                return True, self.ckpt.response(client)
            return False, None
        logged_seq, resp = self.log.adapter.last_record(self.log.core,
                                                        client)
        return logged_seq == seq, resp

    def recover_request(self, client: int, prompt: Sequence[int],
                        max_tokens: int, seq: int,
                        timeout: float = 30.0) -> Any:
        """The paper's Recover: if (client, seq) completed before the
        crash, return the logged response; else re-execute."""
        applied, resp = self.cached_response(client, seq)
        if applied:
            return resp
        return self.submit(client, prompt, max_tokens, seq,
                           timeout=timeout)

    # ------------------ lifecycle -------------------------------------- #
    def start(self) -> None:
        for fn in (self._prefill_loop, self._decode_loop):
            t = threading.Thread(target=fn, daemon=True)
            t.start()
            self._threads.append(t)

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5)

    def restart_after_crash(self) -> None:
        """Simulated process restart: volatile state (announce board,
        sequence table, KV) is lost; the durable response log survives.
        One runtime call resets every volatile component it owns."""
        with self._table_lock:
            for s in self.live.values():
                self.slots.free(s.slot)
            self.live.clear()
            self.kv.clear()
        self.runtime.recover()

    # ------------------ combiner loops --------------------------------- #
    def _prefill_loop(self) -> None:
        while not self._stop.is_set():
            if not self._combine_prefill():
                time.sleep(0.001)

    def _decode_loop(self) -> None:
        while not self._stop.is_set():
            if not self._combine_decode():
                time.sleep(0.001)

    def _active(self, want_cancel: bool) -> List[Announcement]:
        return [rec for _c, rec in self.board.pending()
                if (rec.payload.cancel_target is not None) == want_cancel]

    def _combine_prefill(self) -> int:
        lval = self.prefill_lock.load()
        if lval % 2 == 1 or not self.prefill_lock.cas(lval, lval + 1):
            return 0
        try:
            served = 0
            gens = self._active(False)
            cancels = self._active(True)
            # --- elimination: pair cancels with waiting generates ------ #
            by_seq = {(r.payload.client, r.payload.seq): r for r in gens}
            for c in cancels:
                tgt = by_seq.get(c.payload.cancel_target)
                if tgt is not None and not tgt.done.is_set():
                    self.board.serve(tgt, {"cancelled": True, "tokens": []})
                    self.board.serve(c, {"cancelled_ok": True})
                    self.stats["eliminated"] += 1
                    served += 2
                else:
                    self.board.serve(c, {"cancelled_ok": False})
                    served += 1
            # --- admission by priority (PBHeap) ------------------------ #
            # skip requests already admitted (their LiveSeq is decoding):
            # re-admitting would re-run prefill and orphan the earlier
            # KV slot when the duplicate LiveSeq overwrites the table key
            with self._table_lock:
                admitted = set(self.live.keys())
            gens = [g for g in gens if not g.done.is_set()
                    and _live_key(g.payload.client,
                                  g.payload.seq) not in admitted]
            for g in gens:
                self.heap.insert(g.payload.priority, g)
            batch: List[Announcement] = []
            slot_of: Dict[int, int] = {}          # round-local: id -> slot
            while len(batch) < self.max_batch and len(self.heap):
                if self.slots.available() == 0:
                    break
                g = self.heap.delete_min()
                if g.done.is_set():
                    continue
                key = _live_key(g.payload.client, g.payload.seq)
                if key in admitted:      # stale duplicate heap entry
                    continue
                slot = self.slots.alloc()
                if slot is None:
                    break
                admitted.add(key)
                slot_of[id(g)] = slot
                batch.append(g)
            if not batch:
                return served
            # --- one batched prefill for the whole round --------------- #
            toks, kvs = self.prefill_batch_fn(
                [g.payload.prompt for g in batch])
            round_seqs: List[LiveSeq] = []
            with self._table_lock:
                for g, t0, kv in zip(batch, toks, kvs):
                    req = g.payload
                    ls = LiveSeq(req.client, req.seq, slot_of[id(g)], [t0],
                                 req.max_tokens)
                    self.live[_live_key(req.client, req.seq)] = ls
                    self.kv[ls.slot] = kv
                    round_seqs.append(ls)
            # commit marker (oldTail): decode may now adopt these
            with self._table_lock:
                for ls in round_seqs:
                    ls.committed = True
            self.stats["prefill_rounds"] += 1
            self.stats["prefill_batched"] += len(batch)
            return served + len(batch)
        finally:
            self.prefill_lock.store(self.prefill_lock.load() + 1)

    def _combine_decode(self) -> int:
        lval = self.decode_lock.load()
        if lval % 2 == 1 or not self.decode_lock.cas(lval, lval + 1):
            return 0
        try:
            with self._table_lock:
                batch = [s for s in self.live.values() if s.committed]
            if not batch:
                return 0
            kvs = [self.kv[s.slot] for s in batch]
            last = [s.tokens[-1] for s in batch]
            nxt = self.decode_batch_fn(kvs, last)
            finished: List[LiveSeq] = []
            for s, t in zip(batch, nxt):
                s.tokens.append(int(t))
                if int(t) == self.eos or len(s.tokens) >= s.max_tokens:
                    finished.append(s)
            if finished:
                self._complete(finished)
            self.stats["decode_rounds"] += 1
            self.stats["decode_batched"] += len(batch)
            return len(batch)
        finally:
            self.decode_lock.store(self.decode_lock.load() + 1)

    def _complete(self, finished: List[LiveSeq]) -> None:
        """Persist ALL completions of the round through the runtime's
        batched ``invoke_many`` path — one combining round, one
        contiguous StateRec write, one psync — then release waiters and
        recycle slots (the paper's 'respond only after psync' rule)."""
        responses = {s.slot: {"tokens": list(s.tokens), "seq": s.seq}
                     for s in finished}
        self._log_handle.invoke_many(
            [(self.log, "record", s.client, s.seq, responses[s.slot])
             for s in finished])
        self.stats["persists"] += 1
        with self._table_lock:
            for s in finished:
                self.live.pop(_live_key(s.client, s.seq), None)
                self.kv.pop(s.slot, None)
                self.slots.free(s.slot)            # recycling stack
        for s in finished:
            rec = self.board.slots[s.client]
            if rec is not None and rec.payload.seq == s.seq:
                self.board.serve(rec, responses[s.slot])
