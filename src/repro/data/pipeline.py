"""Deterministic, recoverable synthetic data pipeline.

The iterator state is a single integer step: batch ``i`` is a pure
function of ``(seed, i)`` via ``jax.random.fold_in``, so restoring the
step counter from a checkpoint resumes the exact token stream — the data
pipeline's contribution to detectable recovery (the step lives inside the
checkpointer's StateRec).

``input_specs`` produces ShapeDtypeStruct stand-ins for every model input
of an (arch x shape) cell — the dry-run lowers against these without
allocating anything.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig


def _extra_shapes(cfg: ArchConfig, batch: int) -> Dict[str, Tuple]:
    extra = {}
    if cfg.family == "vlm":
        extra["image_embeds"] = (batch, cfg.n_image_tokens, cfg.d_model)
    if cfg.family == "audio":
        extra["frame_embeds"] = (batch, cfg.n_audio_frames, cfg.d_model)
    return extra


def make_batch(cfg: ArchConfig, shape: ShapeConfig, seed: int, step: int,
               batch_override: Optional[int] = None) -> Dict[str, Any]:
    """Materialize training batch ``step`` (CPU smoke / example drivers)."""
    B = batch_override or shape.global_batch
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (B, shape.seq_len), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)
    extra = {name: jax.random.normal(k2, shp, jnp.bfloat16) * 0.02
             for name, shp in _extra_shapes(cfg, B).items()}
    return {"tokens": tokens, "labels": labels, "extra": extra}


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                batch_override: Optional[int] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for a train/prefill batch."""
    B = batch_override or shape.global_batch
    sds = jax.ShapeDtypeStruct
    extra = {name: sds(shp, jnp.bfloat16)
             for name, shp in _extra_shapes(cfg, B).items()}
    return {"tokens": sds((B, shape.seq_len), jnp.int32),
            "labels": sds((B, shape.seq_len), jnp.int32),
            "extra": extra}


def decode_token_specs(shape: ShapeConfig,
                       batch_override: Optional[int] = None):
    B = batch_override or shape.global_batch
    return jax.ShapeDtypeStruct((B,), jnp.int32)
