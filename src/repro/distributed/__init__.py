"""Distribution: sharding rules, pipeline parallelism, compression."""

from .compression import compressed_psum, dequantize, ef_quantize, quantize
from .pipeline import pipeline_apply
from .sharding import (NOSHARD, Sharder, batch_pspec, decode_state_pspecs,
                       param_pspecs, param_shardings, zero1_pspecs,
                       zero1_spec)

__all__ = [
    "compressed_psum", "dequantize", "ef_quantize", "quantize",
    "pipeline_apply", "NOSHARD", "Sharder", "batch_pspec",
    "decode_state_pspecs", "param_pspecs", "param_shardings",
    "zero1_pspecs", "zero1_spec",
]
