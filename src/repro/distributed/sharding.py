"""Sharding rules: parameter PartitionSpecs, activation constraints, and
ZeRO-1 optimizer-state sharding.

Parallelism map (DESIGN.md §5):
  * TP over 'model': attention heads (wq/wk/wv out-dim, wo in-dim), MLP
    hidden (w_gate/w_up out, w_down in), expert dim E, mamba d_inner,
    vocab dim of embedding/unembedding.
  * DP over 'data' (+ 'pod' on the multi-pod mesh): batch dimension of
    every activation; ZeRO-1 additionally shards AdamW/Adafactor state
    over 'data'.
  * EP: expert-parallel weights (E, D, F) put E on 'model'.
  * SP: long_500k decode shards the KV-cache length over 'data'
    (batch=1 leaves the data axis free).

``param_pspecs`` walks the param pytree by key path; rules are name-based
so they survive the stacked-layer layout (leading layer axis is always
unsharded).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass
class Sharder:
    """Activation-constraint helper passed through the model code.

    ``shard(x, spec)`` pins ``x`` to ``spec`` (axes absent from the mesh
    are dropped, so model code can always mention ('pod','data')).
    """

    mesh: Optional[Mesh] = None
    seq_shard_kv: bool = False   # long_500k: shard cache length over 'data'

    def _filter(self, spec: P, shape) -> P:
        """Drop axes absent from the mesh or not dividing the dimension
        (e.g. batch=1 long-context decode cannot batch-shard)."""
        names = self.mesh.axis_names
        sizes = dict(self.mesh.shape)
        out = []
        for i, entry in enumerate(spec):
            dim = shape[i] if i < len(shape) else 1
            if entry is None:
                out.append(None)
                continue
            was_tuple = isinstance(entry, (tuple, list))
            entries = entry if was_tuple else (entry,)
            kept = []
            prod = 1
            for a in entries:
                if a in names and dim % (prod * sizes[a]) == 0:
                    kept.append(a)
                    prod *= sizes[a]
            if not kept:
                out.append(None)
            elif not was_tuple:
                # a plain axis name came in: hand it back unwrapped —
                # wrapping the lone survivor as ('model',) changes the
                # spec's identity even though it means the same sharding
                out.append(kept[0])
            else:
                out.append(tuple(kept))
        return P(*out)

    def __call__(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self._filter(spec, x.shape)))


NOSHARD = Sharder(None)


# --------------------------------------------------------------------- #
# Parameter specs
# --------------------------------------------------------------------- #
_MODEL_OUT = {"wq", "wk", "wv", "w_gate", "w_up", "wz", "wx",
              "bq", "bk", "bv", "b_gate", "b_up"}
_MODEL_IN = {"wo", "w_down", "out_proj"}
_VOCAB = {"embed", "unembed"}
_SSM_HEAD = {"A_log", "D", "dt_bias", "wdt"}
_REPLICATED = {"ln1", "ln2", "lnx", "final_norm", "enc_norm", "q_norm",
               "k_norm", "norm", "router", "bo", "b_down", "wB", "wC",
               "conv_B", "conv_B_b", "conv_C", "conv_C_b", "dt_bias"}


def _spec_for(path: Tuple[str, ...], shape: Tuple[int, ...],
              model_size: int, data_axes: Tuple[str, ...] = (),
              data_size: int = 1) -> P:
    name = path[-1]
    nd = len(shape)
    n_elems = 1
    for d in shape:
        n_elems *= d

    def last_axis_spec(axis_from_end: int) -> P:
        out = [None] * nd
        idx = nd - 1 - axis_from_end
        if shape[idx] % model_size == 0 and shape[idx] >= model_size:
            out[idx] = "model"
        return P(*out)

    if name in ("w_gate", "w_up", "w_down") and nd >= 3 \
            and any(p in ("moe",) for p in path):
        # expert weights [L?, E, D, F]: E on 'model' (expert parallelism).
        # Huge expert stacks (llama4: 772B of experts = 97 GiB/device at
        # TP-16 alone) additionally shard FSDP-style over the data axes
        # on their last dim — each layer all-gathers its own experts at
        # use, the standard large-MoE memory/bandwidth trade.
        out = [None] * nd
        e_idx = nd - 3
        if shape[e_idx] % model_size == 0:
            out[e_idx] = "model"
        if n_elems >= (1 << 31) and data_axes and \
                shape[-1] % data_size == 0 and shape[-1] >= data_size:
            out[-1] = data_axes if len(data_axes) > 1 else data_axes[0]
        return P(*out)
    if name in _VOCAB:
        # [V, D] or [D, V]: vocab axis on 'model'
        out = [None] * nd
        v_idx = nd - 2 if name == "embed" else nd - 1
        if shape[v_idx] % model_size == 0:
            out[v_idx] = "model"
        return P(*out)
    if name in _MODEL_OUT:
        return last_axis_spec(0)
    if name in _MODEL_IN:
        # [..., F_in, D_out]: shard the in (hidden/head) axis
        return last_axis_spec(1)
    if name in ("conv_x", "conv_x_b"):
        return last_axis_spec(0)     # d_inner channels
    if name in _SSM_HEAD:
        return last_axis_spec(0)     # per-ssm-head vectors
    return P(*([None] * nd))


def param_pspecs(params, mesh: Mesh):
    """PartitionSpec pytree matching ``params``."""
    model_size = mesh.shape.get("model", 1)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    data_size = 1
    for a in data_axes:
        data_size *= mesh.shape[a]

    def assign(path, leaf):
        keys = tuple(getattr(p, "key", getattr(p, "name", str(p)))
                     for p in path)
        return _spec_for(keys, leaf.shape, model_size, data_axes,
                         data_size)

    return jax.tree_util.tree_map_with_path(assign, params)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params, mesh))


# --------------------------------------------------------------------- #
# ZeRO-1: optimizer-state sharding
# --------------------------------------------------------------------- #
def zero1_spec(spec: P, shape: Tuple[int, ...], data_size: int,
               axes=("data",)) -> P:
    """Extend a param spec with 'data' on the first free divisible axis —
    optimizer state m/v shards over the data axis in addition to the
    param's own model-axis sharding (ZeRO stage 1).  No-op when the spec
    already uses the data axes (e.g. FSDP-sharded expert stacks)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        if e is None:
            continue
        for a in (e if isinstance(e, (tuple, list)) else (e,)):
            used.add(a)
    if any(a in used for a in axes):
        return P(*entries)            # already data-sharded (FSDP)
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % data_size == 0 and dim >= data_size:
            entries[i] = axes if len(axes) > 1 else axes[0]
            return P(*entries)
    return P(*entries)   # too small to shard further — replicate


def zero1_pspecs(params, mesh: Mesh):
    base = param_pspecs(params, mesh)
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def assign(spec, leaf):
        return zero1_spec(spec, leaf.shape, dp, axes)

    return jax.tree.map(assign, base, params)


# --------------------------------------------------------------------- #
# Batch / decode-state specs
# --------------------------------------------------------------------- #
def batch_pspec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes, None)


def decode_state_pspecs(state, mesh: Mesh, *, seq_shard: bool = False):
    """Specs for the DecodeState pytree.

    KV caches [L, B, S, Hkv, hd]: batch on data, kv heads on model; with
    ``seq_shard`` (long_500k, B=1) the length axis shards on 'data'
    instead (sequence parallelism for the half-terabyte cache).  SSM
    recurrent states [L, B, H, P, N]: ssm heads on 'model'.
    """
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model = mesh.shape.get("model", 1)
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]

    def assign(path, leaf):
        if leaf is None:
            return P()
        keys = tuple(str(getattr(p, "key", getattr(p, "name", p)))
                     for p in path)
        in_ssm = "ssm" in keys
        is_memory = "memory" in keys
        nd = leaf.ndim

        def b(dim):   # batch axes only if the dim divides
            return axes if (leaf.shape[dim] % dp == 0
                            and leaf.shape[dim] >= dp) else None

        def m_ok(dim):
            return leaf.shape[dim] % model == 0 and leaf.shape[dim] >= model

        if is_memory:                # [B, M, D] encoder/image memory
            return P(b(0), None, None)
        if nd == 5 and not in_ssm:   # stacked KV cache [L, B, S, H, hd]
            # GQA often has Hkv < model_size (e.g. kv=8, TP=16): then the
            # cache length shards over 'model' instead (flash-decoding
            # style split-KV; the partial softmax reduces over 'model').
            if seq_shard and m_ok(2):  # long-context, batch too small
                if m_ok(3):
                    return P(None, None, axes, "model", None)
                return P(None, None, axes + ("model",), None, None)
            if m_ok(3):
                return P(None, b(1), None, "model", None)
            if m_ok(2):
                return P(None, b(1), "model", None, None)
            return P(None, b(1), None, None, None)
        if nd == 5 and in_ssm:       # recurrent state [L, B, H, P, N]
            return P(None, b(1), "model" if m_ok(2) else None, None, None)
        if nd == 4:                  # conv windows / cross-kv pieces
            if in_ssm:               # conv windows [L, B, W-1, C]
                return P(None, b(1), None, "model" if m_ok(3) else None)
            return P(None, b(1), None, None)
        if nd == 3:                  # stacked [L, B, *]
            return P(None, b(1), None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(assign, state)
