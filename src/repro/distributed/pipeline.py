"""Optional GPipe-style pipeline parallelism (shard_map + ppermute).

Stages live on a 'stage' mesh axis; microbatches stream through with the
classic (n_micro + S - 1)-step schedule.  The communication pattern is a
single ppermute per step — jax-native collective-permute rather than
emulated send/recv.  Used for the PP feature demonstration + tests; the
production configs default to DP x TP (+ ZeRO/SP), where PP is not
required to fit any assigned architecture.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(block_fn: Callable, stage_weights, x, mesh: Mesh,
                   n_microbatches: int):
    """Apply ``block_fn(w_s, h)`` for stages s = 0..S-1 in pipeline.

    stage_weights: [S, ...] (stage-major stacked weights, sharded on
    'stage'); x: [B, ...] input batch (replicated).  Returns the output
    of the final stage for the whole batch.
    """
    S = mesh.shape["stage"]
    B = x.shape[0]
    assert B % n_microbatches == 0
    mb = B // n_microbatches
    xr = x.reshape(n_microbatches, mb, *x.shape[1:])
    n_steps = n_microbatches + S - 1
    perm = [(i, (i + 1) % S) for i in range(S)]

    def stage_fn(w, xs):
        w = w[0]                                   # local stage's weights
        sid = jax.lax.axis_index("stage")
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def step(carry, t):
            buf, outs = carry
            inp = jnp.where(sid == 0,
                            xs[jnp.clip(t, 0, n_microbatches - 1)], buf)
            h = block_fn(w, inp)
            nxt = jax.lax.ppermute(h, "stage", perm)
            m = t - (S - 1)                        # microbatch finishing now
            upd = jax.lax.dynamic_update_index_in_dim(
                outs, h, jnp.clip(m, 0, n_microbatches - 1), 0)
            take = jnp.logical_and(sid == S - 1, m >= 0)
            outs = jnp.where(take, upd, outs)
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(step, (buf, outs),
                                      jnp.arange(n_steps))
        # replicate the last stage's result to all stages
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), "stage")
        return outs

    f = shard_map(stage_fn, mesh=mesh,
                  in_specs=(P("stage"), P()),
                  out_specs=P(), check_rep=False)
    outs = f(stage_weights, xr)
    return outs.reshape(B, *x.shape[1:])
