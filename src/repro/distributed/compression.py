"""Gradient compression: int8-quantized cross-pod all-reduce with error
feedback.

The expensive collective at multi-pod scale is the once-per-step
gradient reduction over the 'pod' axis (DCN-class links).  Quantizing
the summand to int8 with per-chunk scales cuts that traffic 2x vs bf16 /
4x vs f32; the residual (quantization error) is fed back into the next
step's gradient so the *accumulated* update stays unbiased (standard
error-feedback/EF-SGD argument — convergence is preserved while each
individual step is approximate).

``quantize``/``dequantize`` are pure and tested numerically;
``compressed_psum`` wires them around a shard_map psum over a named
axis.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

CHUNK = 1024


def quantize(x: jnp.ndarray, chunk: int = CHUNK
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: flat f32 [N] -> (int8 [N], per-chunk scales [N/chunk])."""
    n = x.shape[0]
    pad = (-n) % chunk
    xp = jnp.pad(x, (0, pad)).reshape(-1, chunk)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, n: int,
               chunk: int = CHUNK) -> jnp.ndarray:
    xq = q.reshape(-1, chunk).astype(jnp.float32) * scale[:, None]
    return xq.reshape(-1)[:n]


def ef_quantize(x: jnp.ndarray, error: jnp.ndarray,
                chunk: int = CHUNK):
    """Error-feedback quantization: compress (x + carried error); return
    (q, scale, new_error)."""
    target = x + error
    q, scale = quantize(target, chunk)
    recon = dequantize(q, scale, x.shape[0], chunk)
    return q, scale, target - recon


def compressed_psum(x: jnp.ndarray, error: jnp.ndarray, mesh: Mesh,
                    axis: str = "pod", chunk: int = CHUNK):
    """Mean-reduce flat f32 x over ``axis`` with int8 wire payload +
    error feedback.  Returns (reduced_mean, new_error).

    Members quantize independently (per-chunk scales), so payloads are
    not summable in transit; the collective is an int8 all-gather —
    (g-1)/g x N x 1B on the wire vs 2 (g-1)/g x N x 4B for an f32
    all-reduce, a ~8x traffic cut — followed by a local dequantize-sum.
    """
    n = x.shape[0]

    def f(xl, el):
        q, scale, new_err = ef_quantize(xl, el, chunk)
        qg = jax.lax.all_gather(q, axis)          # int8 on the wire
        sg = jax.lax.all_gather(scale, axis)      # tiny f32 scales
        deq = jax.vmap(lambda qi, si: dequantize(qi, si, n, chunk))(qg, sg)
        g = deq.shape[0]
        return jnp.sum(deq, axis=0) / g, new_err

    spec = P()
    return shard_map(f, mesh=mesh, in_specs=(spec, spec),
                     out_specs=(spec, spec), check_rep=False)(x, error)
