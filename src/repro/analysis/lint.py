"""Static protocol-invariant lint (DESIGN.md §10) + CLI.

Run::

    PYTHONPATH=src python -m repro.analysis.lint [paths...]
        [--allowlist PATH] [--summary PATH]

Scope (when no paths are given): the protocol/structure modules —
``core/pbcomb.py``, ``core/pwfcomb.py``, ``structures/*.py``,
``api/*.py``.  Pure stdlib (``ast``); rules:

``raw-lock``
    Constructing ``threading.Lock``/``RLock``/``Condition``/``Event``/
    ``Semaphore``/``Barrier`` directly.  Shared mutable state must come
    from the ``nvm.backend`` seam (DESIGN.md §7) so the same protocol
    code runs on the thread AND shared-memory backends; a raw lock is
    invisible to the shm backend and silently breaks process mode.

``module-global``
    A module-level assignment of a mutable container (list/dict/set or
    their constructors).  Module globals are shared across every
    runtime in the process and survive crash/recover — exactly the
    hidden channel the seam exists to eliminate.

``wall-clock``
    ``time.time``/``monotonic``/``perf_counter``/``datetime.now`` and
    friends in modeled paths: the virtual clock is the only time
    source the deterministic perf gate tolerates.  (``time.sleep`` is
    allowed — backoff changes scheduling, never modeled results.)

``unseeded-random``
    Module-level ``random.*`` calls (the interpreter-global RNG) or
    ``random.Random()`` with no seed: modeled trajectories must be
    byte-identical across runs, so every RNG must be explicitly
    seeded.

``unflushed-store``
    A function body performs a raw durable store (``nvm.write`` /
    ``write_range`` / ``copy_range``, directly or via a local alias)
    with NO persistence call (pwb family, ``persist_lines``, fused
    sentences, ``psync``) in the same body.  Methods named ``apply`` /
    ``init_state`` are exempt by contract: a ``SeqObject`` mutates the
    combiner's PRIVATE copy and the enclosing round's commit persists
    it (persistence principle P3).

Justified exceptions live in ``allowlist.txt`` next to this module:
``<rule> <site-glob>  # one-line justification`` — the glob matches
``file.py::qualname`` (same key the dynamic audit uses), so one file
documents every exception of both passes.
"""

from __future__ import annotations

import argparse
import ast
import fnmatch
import glob
import os
import sys
from typing import Iterable, List, Optional, Sequence, Tuple

#: Default lint scope, relative to the ``repro`` package directory.
DEFAULT_SCOPE = ("core/pbcomb.py", "core/pwfcomb.py",
                 "structures/*.py", "api/*.py")

_LOCK_NAMES = {"Lock", "RLock", "Condition", "Event", "Semaphore",
               "BoundedSemaphore", "Barrier"}
_WALL_CLOCK_TIME = {"time", "time_ns", "monotonic", "monotonic_ns",
                    "perf_counter", "perf_counter_ns"}
_WALL_CLOCK_DT = {"now", "utcnow", "today"}
_RANDOM_FNS = {"random", "randint", "randrange", "choice", "choices",
               "shuffle", "sample", "uniform", "getrandbits", "gauss"}
_MUTABLE_CTORS = {"list", "dict", "set", "deque", "defaultdict",
                  "OrderedDict", "Counter", "bytearray"}
_WRITE_FNS = {"write", "write_range", "copy_range"}
_PERSIST_FNS = {"pwb", "pwb_range", "persist_lines", "pwb_fence",
                "pwb_sync", "commit_round", "psync"}
#: SeqObject contract: these methods mutate the combiner's private
#: copy; the round's commit persists it (see module docstring).
_EXEMPT_METHODS = {"apply", "init_state"}


class LintFinding:
    __slots__ = ("rule", "path", "lineno", "qual", "site_key", "message")

    def __init__(self, rule: str, path: str, lineno: int, qual: str,
                 message: str) -> None:
        self.rule = rule
        self.path = path
        self.lineno = lineno
        self.qual = qual
        self.site_key = f"{os.path.basename(path)}::{qual}"
        self.message = message

    def __repr__(self) -> str:
        return (f"<{self.rule} {self.path}:{self.lineno} "
                f"[{self.site_key}] {self.message}>")


# --------------------------------------------------------------------- #
# Allowlist (shared with the dynamic audit)                             #
# --------------------------------------------------------------------- #
class Allowlist:
    """Parsed ``allowlist.txt``: (rule, site-glob, justification)."""

    def __init__(self, entries: Sequence[Tuple[str, str, str]]) -> None:
        self.entries = list(entries)

    def allowed(self, rule: str, site_key: str) -> bool:
        return any(r == rule and fnmatch.fnmatch(site_key, pat)
                   for r, pat, _j in self.entries)


def load_allowlist(path: Optional[str] = None) -> Allowlist:
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "allowlist.txt")
    entries: List[Tuple[str, str, str]] = []
    if os.path.exists(path):
        with open(path, encoding="utf-8") as fh:
            for raw in fh:
                line = raw.strip()
                if not line or line.startswith("#"):
                    continue
                body, _, just = line.partition("#")
                parts = body.split()
                if len(parts) != 2:
                    raise ValueError(
                        f"malformed allowlist line (want "
                        f"'<rule> <site-glob>  # why'): {raw!r}")
                entries.append((parts[0], parts[1], just.strip()))
    return Allowlist(entries)


# --------------------------------------------------------------------- #
# The linter                                                            #
# --------------------------------------------------------------------- #
class _Linter(ast.NodeVisitor):
    def __init__(self, path: str) -> None:
        self.path = path
        self.findings: List[LintFinding] = []
        self._qual: List[str] = []
        self._from_threading: set = set()

    # -------- helpers -------------------------------------------------- #
    def _q(self, name: str = "") -> str:
        parts = self._qual + ([name] if name else [])
        return ".".join(parts) or "<module>"

    def _flag(self, rule: str, node: ast.AST, message: str,
              qual: Optional[str] = None) -> None:
        self.findings.append(LintFinding(
            rule, self.path, getattr(node, "lineno", 0),
            qual if qual is not None else self._q(), message))

    # -------- structure ------------------------------------------------ #
    def visit_Module(self, node: ast.Module) -> None:
        for stmt in node.body:
            self._check_module_global(stmt)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "threading":
            self._from_threading.update(
                a.asname or a.name for a in node.names
                if a.name in _LOCK_NAMES)
        self.generic_visit(node)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._qual.append(node.name)
        self.generic_visit(node)
        self._qual.pop()

    def visit_FunctionDef(self, node) -> None:
        self._qual.append(node.name)
        self._check_unflushed_store(node)
        self.generic_visit(node)
        self._qual.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # -------- module-global -------------------------------------------- #
    def _is_mutable_value(self, value: ast.AST) -> bool:
        if isinstance(value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                              ast.DictComp, ast.SetComp)):
            return True
        if isinstance(value, ast.Call):
            fn = value.func
            name = fn.id if isinstance(fn, ast.Name) else \
                fn.attr if isinstance(fn, ast.Attribute) else None
            return name in _MUTABLE_CTORS
        return False

    def _check_module_global(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        else:
            return
        if not self._is_mutable_value(value):
            return
        for t in targets:
            if isinstance(t, ast.Name) and t.id != "__all__":
                self._flag("module-global", stmt,
                           f"module-level mutable global '{t.id}' — "
                           "shared state must come from the "
                           "nvm.backend seam", qual=t.id)

    # -------- call-pattern rules --------------------------------------- #
    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute):
            base = fn.value
            base_name = base.id if isinstance(base, ast.Name) else None
            if base_name == "threading" and fn.attr in _LOCK_NAMES:
                self._flag("raw-lock", node,
                           f"threading.{fn.attr}() — use the "
                           "nvm.backend seam")
            elif base_name == "time" and fn.attr in _WALL_CLOCK_TIME:
                self._flag("wall-clock", node,
                           f"time.{fn.attr}() in a modeled path — the "
                           "virtual clock is the only tolerated time "
                           "source")
            elif fn.attr in _WALL_CLOCK_DT and (
                    base_name == "datetime"
                    or (isinstance(base, ast.Attribute)
                        and base.attr == "datetime")):
                self._flag("wall-clock", node,
                           f"datetime {fn.attr}() in a modeled path")
            elif base_name == "random" and fn.attr in _RANDOM_FNS:
                self._flag("unseeded-random", node,
                           f"random.{fn.attr}() uses the interpreter-"
                           "global RNG — seed an explicit "
                           "random.Random(seed)")
            elif fn.attr == "Random" and base_name == "random" \
                    and not node.args and not node.keywords:
                self._flag("unseeded-random", node,
                           "random.Random() without a seed")
        elif isinstance(fn, ast.Name):
            if fn.id in self._from_threading:
                self._flag("raw-lock", node,
                           f"{fn.id}() (from threading) — use the "
                           "nvm.backend seam")
            elif fn.id == "Random" and not node.args \
                    and not node.keywords:
                self._flag("unseeded-random", node,
                           "Random() without a seed")
        self.generic_visit(node)

    # -------- unflushed-store ------------------------------------------ #
    def _check_unflushed_store(self, fn_node) -> None:
        if fn_node.name in _EXEMPT_METHODS:
            return
        aliases: dict = {}
        first_write: Optional[ast.AST] = None
        write_attr = ""
        has_persist = False

        def body_nodes():
            # the function body WITHOUT descending into nested defs
            # (each nested def is linted on its own visit)
            stack = list(fn_node.body)
            while stack:
                n = stack.pop()
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                    continue
                yield n
                stack.extend(ast.iter_child_nodes(n))

        for n in body_nodes():
            if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                    and isinstance(n.targets[0], ast.Name) \
                    and isinstance(n.value, ast.Attribute):
                attr = n.value.attr
                if attr in _WRITE_FNS or attr in _PERSIST_FNS:
                    aliases[n.targets[0].id] = attr
        for n in body_nodes():
            if not isinstance(n, ast.Call):
                continue
            f = n.func
            attr = f.attr if isinstance(f, ast.Attribute) else \
                aliases.get(f.id) if isinstance(f, ast.Name) else None
            if attr in _PERSIST_FNS:
                has_persist = True
            elif attr in _WRITE_FNS:
                if first_write is None or \
                        n.lineno < first_write.lineno:
                    first_write, write_attr = n, attr
        if first_write is not None and not has_persist:
            self._flag("unflushed-store", first_write,
                       f".{write_attr}(...) with no pwb/psync in the "
                       "same body — a raw durable store must be paired "
                       "with its flush in the round that issues it")


def lint_file(path: str) -> List[LintFinding]:
    with open(path, encoding="utf-8") as fh:
        tree = ast.parse(fh.read(), filename=path)
    linter = _Linter(path)
    linter.visit(tree)
    return linter.findings


def default_scope(root: Optional[str] = None) -> List[str]:
    """Expand the default scope globs under the repro package dir."""
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out: List[str] = []
    for pat in DEFAULT_SCOPE:
        out.extend(sorted(glob.glob(os.path.join(root, pat))))
    return out


def lint_paths(paths: Optional[Iterable[str]] = None,
               root: Optional[str] = None) -> List[LintFinding]:
    files = list(paths) if paths else default_scope(root)
    findings: List[LintFinding] = []
    for path in files:
        findings.extend(lint_file(path))
    return findings


# --------------------------------------------------------------------- #
# CLI                                                                   #
# --------------------------------------------------------------------- #
def render_summary(findings: List[LintFinding],
                   allow: Allowlist) -> List[str]:
    lines = ["## repro.analysis.lint", "",
             "| rule | site | status | message |",
             "|---|---|---|---|"]
    for f in findings:
        status = ("allowlisted" if allow.allowed(f.rule, f.site_key)
                  else "**VIOLATION**")
        lines.append(f"| {f.rule} | `{f.path.split('/repro/')[-1]}:"
                     f"{f.lineno}` ({f.qual}) | {status} | "
                     f"{f.message} |")
    if not findings:
        lines.append("| - | - | clean | no findings |")
    return lines


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Protocol-invariant AST lint (DESIGN.md §10)")
    ap.add_argument("paths", nargs="*",
                    help="files to lint (default: the protocol scope)")
    ap.add_argument("--root", default=None,
                    help="repro package dir the default scope globs "
                         "resolve under")
    ap.add_argument("--allowlist", default=None,
                    help="allowlist file (default: the package's "
                         "allowlist.txt)")
    ap.add_argument("--summary", default=None,
                    help="append a markdown findings table here "
                         "(e.g. $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args(argv)

    allow = load_allowlist(args.allowlist)
    findings = lint_paths(args.paths or None, root=args.root)
    bad = 0
    for f in findings:
        ok = allow.allowed(f.rule, f.site_key)
        bad += 0 if ok else 1
        tag = "allow" if ok else "FAIL "
        print(f"[{tag}] {f.rule:16s} {f.path}:{f.lineno} "
              f"({f.qual}) — {f.message}")
    print(f"lint: {len(findings)} finding(s), {bad} non-allowlisted, "
          f"{len(allow.entries)} allowlist entr(y/ies)")
    if args.summary:
        with open(args.summary, "a", encoding="utf-8") as fh:
            fh.write("\n".join(render_summary(findings, allow)) + "\n")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
