"""repro.analysis — persist-ordering race detector + protocol lint.

Two cooperating passes over the repo's correctness invariants
(DESIGN.md §10):

* :mod:`repro.analysis.audit` — the dynamic persist-ordering detector.
  ``NVM(..., audit=True)`` / ``ShmNVM(..., audit=True)`` attach a
  :class:`PersistAudit` that tracks every cache line through the
  flush-state lattice (CLEAN -> DIRTY -> PENDING -> CLEAN) and checks
  happens-before via the existing VClock: unflushed-dirty-at-commit,
  psync-order races, post-crash reads of un-ordered lines, and the
  minimality metric (redundant pwbs / pfences).

* :mod:`repro.analysis.lint` — the static AST lint over the protocol
  and structure modules: shared mutable state must come from the
  ``nvm.backend`` seam, modeled paths must be wall-clock and
  unseeded-randomness free, and raw durable stores must be paired with
  a flush in the same round body.

* :mod:`repro.analysis.sweep` — drives the detector over the full
  registry (kind, protocol) matrix on both backends; the CI
  ``analysis-smoke`` job fails on any non-allowlisted finding.

Both passes share one allowlist file (``allowlist.txt`` next to this
package) so every justified exception is written down exactly once.
"""

from .audit import Finding, PersistAudit
from .lint import lint_paths, load_allowlist

__all__ = ["Finding", "PersistAudit", "lint_paths", "load_allowlist"]
