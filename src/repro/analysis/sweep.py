"""Detector sweep over the full (kind, protocol) registry matrix.

Drives every registry entry through a staged deterministic workload —
announce from the non-combining logical threads, invoke from thread 0,
then an adversarial crash, recovery, a snapshot, and post-crash rounds —
on an audited NVM (``audit=True``), on both execution backends:

* ``threads``: the in-process NVM with the ``optane`` cost profile, so
  the VClock is engaged and the happens-before (psync-order) checks run.
* ``shm``: the shared-memory NVM driven in-process.  It has no virtual
  clock, so the sweep checks the flush-state classes only (the audit
  disables order checks by stamping everything 0) — but it exercises
  the completely separate ShmNVM write-back ring / drain plumbing.

The staged schedule is single-OS-thread deterministic: every finding it
raises is reproducible and triagable, which is what lets the CI
``analysis-smoke`` job FAIL on any non-allowlisted gating finding
instead of merely reporting it.  (Free-running threaded workloads can
interleave helping patterns into one-off apparent races; those belong
in the threaded stress tests, not in a gate.)

CLI::

    python -m repro.analysis.sweep [--quick] [--backend threads|shm|both]
                                   [--json PATH] [--summary PATH]
                                   [--allowlist PATH]

Exit status 1 when any cell raises a non-allowlisted gating finding (or
fails to drive at all).
"""

from __future__ import annotations

import argparse
import sys
from typing import Any, Callable, Dict, List, Optional, Tuple

from .lint import Allowlist, load_allowlist

N_THREADS = 4
ROUNDS = 8
POST_CRASH_ROUNDS = 2
CRASH_SEED = 1234

#: per-kind op schedule: round r runs sched[r % len] on every thread
SCHEDULES: Dict[str, List[Tuple[str, Optional[Callable[[int, int], Any]]]]] = {
    "queue": [("enqueue", lambda p, r: p * 1_000_000 + r),
              ("dequeue", None)],
    "stack": [("push", lambda p, r: p * 1_000_000 + r),
              ("pop", None)],
    "heap": [("insert", lambda p, r: (p * 31 + r) % 1_000_000),
             ("delete_min", None)],
    "counter": [("fetch_add", lambda p, r: 1)],
    "log": [("record", lambda p, r: (p, r + 1, ("resp", p, r + 1))),
            ("lookup", lambda p, r: p)],
    "ckpt": [("persist", lambda p, r: (r + 1, {"step": r + 1, "w": p})),
             ("latest", None)],
}


def _make_nvm(backend: str):
    if backend == "shm":
        from ..core.shm import ShmNVM
        return ShmNVM(1 << 18, audit=True)
    from ..core.nvm import NVM
    return NVM(1 << 22, profile="optane", audit=True)


def sweep_cell(kind: str, protocol: str, backend: str = "threads",
               rounds: int = ROUNDS,
               post_crash_rounds: int = POST_CRASH_ROUNDS) -> Dict[str, Any]:
    """Drive one (kind, protocol) cell on an audited NVM and return its
    audit report plus op accounting.  Deterministic: one OS thread,
    combining rounds staged via announce + a single invoke."""
    import random

    from ..api import CombiningRuntime

    nvm = _make_nvm(backend)
    rt = CombiningRuntime(nvm=nvm, n_threads=N_THREADS)
    ops = 0
    try:
        obj = rt.make(kind, protocol)
        handles = [rt.attach(p) for p in range(N_THREADS)]
        bounds = [h.bind(obj) for h in handles]
        combining = obj.adapter.can_announce
        sched = SCHEDULES[kind]

        def run_round(r: int, staged: bool) -> None:
            nonlocal ops
            op, argfn = sched[r % len(sched)]
            if staged:
                for p in range(1, N_THREADS):
                    if argfn is None:
                        handles[p].announce(obj, op)
                    else:
                        handles[p].announce(obj, op, argfn(p, r))
                fn = getattr(bounds[0], op)
                fn(*(() if argfn is None else (argfn(0, r),)))
            else:
                for p in range(N_THREADS):
                    fn = getattr(bounds[p], op)
                    fn(*(() if argfn is None else (argfn(p, r),)))
            ops += N_THREADS

        for r in range(rounds):
            run_round(r, combining)
        rt.crash(random.Random(CRASH_SEED))
        rt.recover()
        obj.snapshot()
        for r in range(rounds, rounds + post_crash_rounds):
            run_round(r, False)

        aud = nvm.audit
        return {
            "kind": kind, "protocol": protocol, "backend": backend,
            "ops": ops,
            "findings": list(aud.findings),
            "redundant_pwbs": aud.redundant_pwbs,
            "redundant_pfences": aud.redundant_pfences,
            "error": None,
        }
    except Exception as e:                         # driver failure: hard
        return {
            "kind": kind, "protocol": protocol, "backend": backend,
            "ops": ops, "findings": [], "redundant_pwbs": 0,
            "redundant_pfences": 0,
            "error": f"{type(e).__name__}: {e}",
        }
    finally:
        rt.close()
        if backend == "shm":
            nvm.close()        # rt only closes NVMs it created itself


def run_sweep(backends: Tuple[str, ...] = ("threads", "shm"),
              quick: bool = False,
              allow: Optional[Allowlist] = None) -> Dict[str, Any]:
    """Sweep every registry entry on each backend; classify findings
    against the allowlist.  Returns ``{"cells": [...], "failures": N}``
    where ``failures`` counts non-allowlisted gating findings plus
    driver errors."""
    from ..api import entries

    rounds = 4 if quick else ROUNDS
    post = 1 if quick else POST_CRASH_ROUNDS
    cells: List[Dict[str, Any]] = []
    failures = 0
    for backend in backends:
        for kind, protocol in entries():
            cell = sweep_cell(kind, protocol, backend,
                              rounds=rounds, post_crash_rounds=post)
            gating, allowed = [], []
            for f in cell.pop("findings"):
                if not f.gating:
                    continue
                if allow is not None and allow.allowed(f.rule, f.site_key):
                    allowed.append(f)
                else:
                    gating.append(f)
            cell["gating"] = gating
            cell["allowed"] = allowed
            if cell["error"] is not None or gating:
                failures += 1
            cells.append(cell)
    return {"cells": cells, "failures": failures}


# ---------------- rendering ------------------------------------------- #
def _finding_row(cell: Dict[str, Any], f) -> str:
    return (f"| {cell['kind']}/{cell['protocol']} | {cell['backend']} "
            f"| {f.rule} | `{f.site}` | `{f.site_key}` | {f.count} "
            f"| {f.detail} |")


def render_summary(result: Dict[str, Any]) -> str:
    """GitHub-flavored markdown: a violations table (if any) plus the
    per-cell matrix with the minimality metric."""
    out = ["## Persist-ordering sweep", ""]
    viol = [(c, f) for c in result["cells"] for f in c["gating"]]
    errs = [c for c in result["cells"] if c["error"]]
    if viol or errs:
        out += ["### Violations (non-allowlisted)", "",
                "| cell | backend | rule | site | site key | hits "
                "| detail |",
                "|---|---|---|---|---|---|---|"]
        out += [_finding_row(c, f) for c, f in viol]
        out += [f"| {c['kind']}/{c['protocol']} | {c['backend']} "
                f"| driver-error | — | — | — | {c['error']} |"
                for c in errs]
        out += ["", "Triage guide — reproduce, read the rule, decide "
                "bug/allowlist/detector-gap: docs/ANALYSIS.md", ""]
    else:
        out += ["No non-allowlisted violations.", ""]
    allowed = [(c, f) for c in result["cells"] for f in c["allowed"]]
    if allowed:
        out += ["### Allowlisted findings", "",
                "| cell | backend | rule | site | site key | hits "
                "| detail |",
                "|---|---|---|---|---|---|---|"]
        out += [_finding_row(c, f) for c, f in allowed]
        out.append("")
    out += ["### Matrix", "",
            "| cell | backend | ops | gating | redundant pwbs "
            "| redundant pfences |",
            "|---|---|---|---|---|---|"]
    for c in result["cells"]:
        out.append(f"| {c['kind']}/{c['protocol']} | {c['backend']} "
                   f"| {c['ops']} | {len(c['gating'])} "
                   f"| {c['redundant_pwbs']} | {c['redundant_pfences']} |")
    out.append("")
    return "\n".join(out)


def _to_json(result: Dict[str, Any]) -> Dict[str, Any]:
    def fd(f):
        return {"rule": f.rule, "site": f.site, "site_key": f.site_key,
                "line": f.line, "count": f.count, "detail": f.detail}

    return {
        "schema": "analysis.sweep.v1",
        "failures": result["failures"],
        "cells": [{**{k: c[k] for k in ("kind", "protocol", "backend",
                                        "ops", "redundant_pwbs",
                                        "redundant_pfences", "error")},
                   "gating": [fd(f) for f in c["gating"]],
                   "allowed": [fd(f) for f in c["allowed"]]}
                  for c in result["cells"]],
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.sweep",
        description="persist-ordering detector sweep over the registry "
                    "matrix (fails on non-allowlisted gating findings)")
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds per cell (CI smoke)")
    ap.add_argument("--backend", choices=["threads", "shm", "both"],
                    default="both")
    ap.add_argument("--json", metavar="PATH",
                    help="write the machine-readable report here")
    ap.add_argument("--summary", metavar="PATH",
                    help="append the markdown summary here "
                    "(e.g. $GITHUB_STEP_SUMMARY)")
    ap.add_argument("--allowlist", metavar="PATH",
                    help="override the package allowlist file")
    args = ap.parse_args(argv)

    backends = (("threads", "shm") if args.backend == "both"
                else (args.backend,))
    allow = load_allowlist(args.allowlist)
    result = run_sweep(backends=backends, quick=args.quick, allow=allow)

    text = render_summary(result)
    print(text)
    if args.summary:
        with open(args.summary, "a", encoding="utf-8") as fh:
            fh.write(text + "\n")
    if args.json:
        import json as _json
        with open(args.json, "w", encoding="utf-8") as fh:
            _json.dump(_to_json(result), fh, indent=2, sort_keys=True)
            fh.write("\n")
    return 1 if result["failures"] else 0


if __name__ == "__main__":                         # pragma: no cover
    sys.exit(main())
