"""Dynamic persist-ordering detector (DESIGN.md §10).

``NVM(..., audit=True)`` attaches a :class:`PersistAudit` to the
simulated NVMM.  Every cache line is tracked through the flush-state
lattice::

    CLEAN --write--> DIRTY --pwb--> PENDING --drain(psync)--> CLEAN

and every transition is stamped with the issuing thread plus its
virtual-clock time (when the NVM has a :class:`~repro.core.nvm.VClock`
engaged).  From those stamps the audit flags:

``unflushed-at-commit``  (gating)
    At a psync by thread T, a line is still DIRTY and its last writer
    is T: the thread "committed" durable state it never covered with a
    pwb.  (Lines dirtied by *other* threads are judged at those
    threads' own commits — flushing another thread's line is legal,
    hardware write-backs are per-line, not per-writer.)

``psync-order-race``  (gating)
    A psync drains a pwb issued by another thread whose issue stamp is
    LARGER than the syncer's clock.  The VClock is a Lamport clock, so
    ``stamp > now`` proves no happens-before path orders the pwb
    before the sync: the "durability" of that line is a race outcome,
    not a guarantee.  The line is tainted until it is rewritten or
    drained with proper ordering.  (Sound, not complete: requires a
    clock; the clockless shm NVM audits the flush-state classes only.)

``post-crash-unordered-read``  (gating)
    After a crash, a read of a line whose durability was tainted by a
    psync-order-race: recovery is consuming state that was persisted
    by luck.

``redundant-pwb`` / ``redundant-pfence``  (metric, non-gating)
    The paper's minimality claim, machine-checked: a pwb on a CLEAN
    line whose previous pwb came from the same thread (an intra-thread
    duplicate — re-flushing after another thread's flush is the normal
    helping pattern and is NOT counted), and a pfence with no pwb
    pending in the current epoch.  Surfaced per protocol as
    ``redundant_pwbs_per_op`` in bench.v2 / bench.mp.v2 rows.

The audit never mutates NVM state and is consulted only behind
``if nvm._audit is not None`` branches plus instance-level wrappers for
the hot volatile accessors — with ``audit=False`` (the default) the
modeled trajectory is byte-identical to an un-instrumented run, and
with ``audit=True`` the NVM pins ``force_discrete`` so the fused
persistence sentences take their counter-identical discrete fallbacks
(the equivalence the property tests already gate).
"""

from __future__ import annotations

import os
import sys
import threading
from typing import Any, Dict, Iterable, List, Optional, Tuple

from ..core.nvm import LINE

#: Frames inside these files are simulator/primitive internals — the
#: offending *protocol* site is the first frame outside them.
_INTERNAL_FILES = ("nvm.py", "shm.py", "atomics.py", "audit.py")
_INTERNAL_DIRS = (os.sep + "core" + os.sep, os.sep + "analysis" + os.sep)


def _site() -> Tuple[str, str]:
    """(``file.py:lineno``, ``file.py::qualname``) of the nearest frame
    outside the simulator internals — the call site a finding blames."""
    f = sys._getframe(2)
    while f is not None:
        fn = f.f_code.co_filename
        base = os.path.basename(fn)
        if not (base in _INTERNAL_FILES
                and any(d in fn for d in _INTERNAL_DIRS)):
            break
        f = f.f_back
    if f is None:                                  # pragma: no cover
        return "<unknown>", "<unknown>"
    code = f.f_code
    base = os.path.basename(code.co_filename)
    qual = getattr(code, "co_qualname", code.co_name)
    return f"{base}:{f.f_lineno}", f"{base}::{qual}"


class Finding:
    """One detector finding, deduped on (rule, site_key)."""

    __slots__ = ("rule", "site", "site_key", "line", "thread", "detail",
                 "gating", "count")

    def __init__(self, rule: str, site: str, site_key: str, line: int,
                 thread: Any, detail: str, gating: bool) -> None:
        self.rule = rule
        self.site = site            # file.py:lineno of the first hit
        self.site_key = site_key    # file.py::qualname (allowlist key)
        self.line = line            # cache line of the first hit
        self.thread = thread
        self.detail = detail
        self.gating = gating
        self.count = 1

    def __repr__(self) -> str:
        return (f"<{self.rule} at {self.site} [{self.site_key}] "
                f"line={self.line} x{self.count}: {self.detail}>")


class PersistAudit:
    """Per-NVM flush-state tracker; all hooks are thread-safe."""

    def __init__(self, nvm: Any) -> None:
        self._nvm = nvm
        self._lock = threading.Lock()
        # line -> [writer_key, write_site, write_site_key, reported]
        self._dirty: Dict[int, list] = {}
        # line -> (issuer_key, issue_stamp_ns, pwb_site)
        self._pending: Dict[int, Tuple[Any, float, str]] = {}
        # line -> issuer_key of the most recent pwb covering it
        self._last_pwb: Dict[int, Any] = {}
        # line -> detail of the order race that "durabilized" it
        self._tainted: Dict[int, str] = {}
        self._post_crash = False
        self.redundant_pwbs = 0
        self.redundant_pfences = 0
        self.findings: List[Finding] = []
        self._dedup: Dict[Tuple[str, str], Finding] = {}

    # ---------------- identity / time ---------------------------------- #
    def _key(self) -> Any:
        clock = self._nvm.clock
        if clock is not None:
            return clock._key()      # honors VClock.bind(logical_id)
        return threading.get_ident()

    def _now(self) -> float:
        clock = self._nvm.clock
        return clock.now() if clock is not None else 0.0

    # ---------------- finding plumbing --------------------------------- #
    def _flag(self, rule: str, site: str, site_key: str, line: int,
              detail: str, gating: bool) -> None:
        k = (rule, site_key)
        f = self._dedup.get(k)
        if f is not None:
            f.count += 1
            return
        f = Finding(rule, site, site_key, line, self._key(), detail,
                    gating)
        self._dedup[k] = f
        self.findings.append(f)

    # ---------------- hooks (called by NVM / ShmNVM) -------------------- #
    def on_write(self, addr: int, n_words: int) -> None:
        site, site_key = _site()
        key = self._key()
        first = addr // LINE
        last = (addr + max(n_words, 1) - 1) // LINE
        with self._lock:
            dirty = self._dirty
            for line in range(first, last + 1):
                d = dirty.get(line)
                if d is None or d[0] != key:
                    dirty[line] = [key, site, site_key, False]
                if self._tainted:
                    self._tainted.pop(line, None)   # rewritten: untainted

    def on_read(self, addr: int, n_words: int = 1) -> None:
        if not self._post_crash or not self._tainted:
            return
        first = addr // LINE
        last = (addr + max(n_words, 1) - 1) // LINE
        hits: List[Tuple[int, str]] = []
        with self._lock:
            for line in range(first, last + 1):
                detail = self._tainted.pop(line, None)
                if detail is not None:
                    hits.append((line, detail))
        if hits:
            site, site_key = _site()
            for line, detail in hits:
                self._flag("post-crash-unordered-read", site, site_key,
                           line,
                           f"recovery read of a line whose durability "
                           f"was a race outcome ({detail})", gating=True)

    def on_pwb(self, runs: Iterable[Tuple[int, int]]) -> None:
        site, site_key = _site()
        key = self._key()
        stamp = self._now()
        with self._lock:
            dirty, pending, last = \
                self._dirty, self._pending, self._last_pwb
            for first, n_lines in runs:
                for line in range(first, first + n_lines):
                    if dirty.pop(line, None) is None \
                            and last.get(line) == key:
                        self.redundant_pwbs += 1
                        self._flag(
                            "redundant-pwb", site, site_key, line,
                            "pwb of a clean line this thread already "
                            "flushed (minimality P2 miss)", gating=False)
                    pending[line] = (key, stamp, site)
                    last[line] = key

    def on_spill(self, runs: Iterable[Tuple[int, int]]) -> None:
        """Ring-overflow early write-back completion: the hardware may
        drain a pwb'd line any time before the psync, so this clears
        PENDING without any ordering judgment."""
        with self._lock:
            for first, n_lines in runs:
                for line in range(first, first + n_lines):
                    self._pending.pop(line, None)

    def on_pfence(self, had_pending: bool) -> None:
        if had_pending:
            return
        site, site_key = _site()
        with self._lock:
            self.redundant_pfences += 1
        self._flag("redundant-pfence", site, site_key, -1,
                   "pfence with no pwb pending in the current epoch",
                   gating=False)

    def on_psync(self, drained: Iterable[Tuple[int, int]],
                 sync_now: float) -> None:
        """``sync_now`` is the syncer's clock BEFORE the drain advance —
        comparing post-advance time would hide every race behind the
        psync's own device cost."""
        site, site_key = _site()
        key = self._key()
        races: List[Tuple[int, Tuple[Any, float, str]]] = []
        stale: List[list] = []
        with self._lock:
            pending, tainted = self._pending, self._tainted
            for first, n_lines in drained:
                for line in range(first, first + n_lines):
                    p = pending.pop(line, None)
                    if p is None:
                        continue
                    if p[0] != key and p[1] > sync_now:
                        races.append((line, p))
                        tainted[line] = (f"pwb at {p[2]} (t={p[1]:.0f}ns)"
                                         f" vs psync at {site} "
                                         f"(t={sync_now:.0f}ns)")
                    else:
                        tainted.pop(line, None)     # ordered: clean bill
            for line, d in self._dirty.items():
                if d[0] == key and not d[3]:
                    d[3] = True
                    stale.append([line] + d)
        for line, p in races:
            self._flag("psync-order-race", site, site_key, line,
                       f"drains pwb issued at {p[2]} with stamp "
                       f"{p[1]:.0f}ns > syncer clock {sync_now:.0f}ns — "
                       "no happens-before orders the flush before this "
                       "sync", gating=True)
        for line, _key, wsite, wsite_key, _rep in stale:
            self._flag("unflushed-at-commit", wsite, wsite_key, line,
                       f"durable word written here was never pwb'd "
                       f"before the committing psync at {site}",
                       gating=True)

    def on_crash(self) -> None:
        with self._lock:
            self._dirty.clear()      # volatile image is lost
            self._pending.clear()    # queue resolved by the adversary
            self._post_crash = True  # taints now fail reads

    # ---------------- reporting ---------------------------------------- #
    def gating_findings(self, allow=None) -> List[Finding]:
        """Findings that should fail a sweep: gating rules minus the
        allowlist (``allow`` is a loaded allowlist, see lint.py)."""
        out = []
        for f in self.findings:
            if not f.gating:
                continue
            if allow is not None and allow.allowed(f.rule, f.site_key):
                continue
            out.append(f)
        return out

    def reset_metrics(self) -> None:
        """Zero the minimality counters and drop their (non-gating)
        findings — benches call this with ``nvm.reset_counters`` so the
        metric covers the measured window only.  Gating findings and
        the line-state tables survive: correctness findings from any
        phase stay reported."""
        with self._lock:
            self.redundant_pwbs = 0
            self.redundant_pfences = 0
            kept = [f for f in self.findings if f.gating]
            self.findings = kept
            self._dedup = {(f.rule, f.site_key): f for f in kept}

    def report(self) -> Dict[str, Any]:
        return {
            "findings": list(self.findings),
            "gating": [f for f in self.findings if f.gating],
            "redundant_pwbs": self.redundant_pwbs,
            "redundant_pfences": self.redundant_pfences,
        }
