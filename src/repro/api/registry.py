"""Protocol registry: the (kind x protocol) matrix behind
``make_recoverable``.

Kinds:      queue | stack | heap | counter | log | ckpt
Protocols:  pbcomb | pwfcomb | lock-direct | lock-undo | dfc | durable-ms

Not every cell exists (DFC is a stack algorithm, the durable MS queue is
a queue); ``entries()`` enumerates the supported pairs so benchmarks and
tests iterate protocols generically instead of hard-coding class lists.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from .adapters import (DFCStackAdapter, DurableMSQueueAdapter, LockAdapter,
                       PBCkptAdapter, PBCounterAdapter, PBHeapAdapter,
                       PBLogAdapter, PBQueueAdapter, PBStackAdapter,
                       PWFCkptAdapter, PWFCounterAdapter, PWFHeapAdapter,
                       PWFLogAdapter, PWFQueueAdapter, PWFStackAdapter,
                       StructureAdapter)

# (kind, protocol) -> zero-arg adapter factory
REGISTRY: Dict[Tuple[str, str], Callable[[], StructureAdapter]] = {
    ("queue", "pbcomb"): PBQueueAdapter,
    ("queue", "pwfcomb"): PWFQueueAdapter,
    ("queue", "durable-ms"): DurableMSQueueAdapter,
    ("queue", "lock-direct"): lambda: LockAdapter("queue", undo=False),
    ("queue", "lock-undo"): lambda: LockAdapter("queue", undo=True),
    ("stack", "pbcomb"): PBStackAdapter,
    ("stack", "pwfcomb"): PWFStackAdapter,
    ("stack", "dfc"): DFCStackAdapter,
    ("stack", "lock-direct"): lambda: LockAdapter("stack", undo=False),
    ("stack", "lock-undo"): lambda: LockAdapter("stack", undo=True),
    ("heap", "pbcomb"): PBHeapAdapter,
    ("heap", "pwfcomb"): PWFHeapAdapter,
    ("heap", "lock-direct"): lambda: LockAdapter("heap", undo=False),
    ("heap", "lock-undo"): lambda: LockAdapter("heap", undo=True),
    ("counter", "pbcomb"): PBCounterAdapter,
    ("counter", "pwfcomb"): PWFCounterAdapter,
    ("counter", "lock-direct"): lambda: LockAdapter("counter", undo=False),
    ("counter", "lock-undo"): lambda: LockAdapter("counter", undo=True),
    # serving/checkpoint workload structures (DESIGN.md §8): the
    # response log and the checkpoint cell, combinable like any kind
    ("log", "pbcomb"): PBLogAdapter,
    ("log", "pwfcomb"): PWFLogAdapter,
    ("log", "lock-direct"): lambda: LockAdapter("log", undo=False),
    ("log", "lock-undo"): lambda: LockAdapter("log", undo=True),
    ("ckpt", "pbcomb"): PBCkptAdapter,
    ("ckpt", "pwfcomb"): PWFCkptAdapter,
    ("ckpt", "lock-direct"): lambda: LockAdapter("ckpt", undo=False),
    ("ckpt", "lock-undo"): lambda: LockAdapter("ckpt", undo=True),
}


def entries(kind: str = None) -> List[Tuple[str, str]]:
    """All supported (kind, protocol) pairs, optionally filtered."""
    return sorted(k for k in REGISTRY if kind is None or k[0] == kind)


def kinds() -> List[str]:
    return sorted({k for k, _ in REGISTRY})


def protocols_for(kind: str) -> List[str]:
    return sorted(p for k, p in REGISTRY if k == kind)


def get_adapter(kind: str, protocol: str) -> StructureAdapter:
    try:
        factory = REGISTRY[(kind, protocol)]
    except KeyError:
        raise ValueError(
            f"no recoverable implementation for kind={kind!r} "
            f"protocol={protocol!r}; supported: {entries()}") from None
    return factory()
