"""Structure adapters: one calling convention over every recoverable
structure and baseline.

The paper's claim is a *universal* recipe — any sequential data
structure becomes a recoverable concurrent one — but the seed exposed
each implementation through an ad-hoc convention (``PBComb.op(p, func,
args, seq)``, ``PBQueue.enqueue(p, value, seq)``, per-class recovery
dances).  An adapter normalizes exactly four things per structure:

  * **ops** — sugar-name -> (protocol func tag, seq group, default arg),
    e.g. ``enqueue -> ("ENQ", "enq", None)``.  The *seq group* matters
    for the split-instance queues: detectability parity is per combining
    instance, so the runtime keeps one seq counter per (object, group).
  * **invoke / recover** — the normal path and the paper's Recover path
    with identical signatures.
  * **reset_volatile / snapshot** — post-crash volatile rebuild and a
    comparable view of the logical state (for crash/recovery checks).
  * **announce / perform** — optional (detectable combining protocols
    only): split an op into its announcement and the combining phase so
    crash-point tests can enumerate crashes *inside* a round that is
    serving many announced requests.

Adapters are stateless; all state lives in the wrapped core object.
"""

from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

from ..core.atomics import Counters
from ..core.nvm import NVM
from ..core.objects import (CheckpointObject, FetchAddObject, HeapObject,
                            ResponseLogObject, SeqQueueObject,
                            SeqStackObject)
from ..core.pbcomb import PBComb, RequestRec
from ..core.pwfcomb import PWFComb
from ..structures import (DFCStack, DurableMSQueue, LockDirectObject,
                          LockUndoLogObject, PBHeap, PBQueue, PBStack,
                          PWFQueue, PWFStack)


class OpSpec(NamedTuple):
    func: str               # protocol func tag ("ENQ", "PUSH", "FAA", ...)
    group: str              # seq-counter group (parity is per instance)
    default: Any = None     # args value for zero-arg sugar ("read" -> 0)


QUEUE_OPS = {"enqueue": OpSpec("ENQ", "enq"),
             "dequeue": OpSpec("DEQ", "deq")}
STACK_OPS = {"push": OpSpec("PUSH", "main"),
             "pop": OpSpec("POP", "main")}
HEAP_OPS = {"insert": OpSpec("HINSERT", "main"),
            "delete_min": OpSpec("HDELETEMIN", "main"),
            "get_min": OpSpec("HGETMIN", "main")}
COUNTER_OPS = {"fetch_add": OpSpec("FAA", "main", 1),
               "read": OpSpec("FAA", "main", 0)}
LOG_OPS = {"record": OpSpec("RECORD", "main"),
           "lookup": OpSpec("LOOKUP", "main")}
CKPT_OPS = {"persist": OpSpec("CKPT", "main"),
            "latest": OpSpec("CKPTGET", "main")}


class StructureAdapter:
    """Base adapter: subclasses set ``kind``/``protocol``/``OPS`` and
    implement the structure-specific pieces."""

    kind: str = ""
    protocol: str = ""
    detectable: bool = False     # exactly-once recovery of in-flight ops
    can_announce: bool = False   # announce/perform split available
    OPS: Dict[str, OpSpec] = {}

    # ---------------- construction ------------------------------------ #
    def create(self, nvm: NVM, n_threads: int,
               counters: Optional[Counters] = None, **kw) -> Any:
        raise NotImplementedError

    # ---------------- normal + recovery paths ------------------------- #
    def _spec(self, op: str) -> OpSpec:
        try:
            return self.OPS[op]
        except KeyError:
            raise ValueError(
                f"{self.kind}/{self.protocol} has no op {op!r}; "
                f"supported: {sorted(self.OPS)}") from None

    def _args(self, op: str, args: Any) -> Any:
        return self._spec(op).default if args is None else args

    def invoke(self, core: Any, p: int, op: str, args: Any,
               seq: int) -> Any:
        raise NotImplementedError

    def bind_op(self, core: Any, op: str):
        """Pre-resolved ``fn(p, args, seq)`` for one (core, op) pair —
        handles cache these so the hot invoke path stops re-resolving op
        strings and OpSpecs per call.  The default wraps ``invoke``;
        adapters whose cores expose a direct entry override it to bind
        the core method itself."""
        self._spec(op)                  # validate (raises ValueError)
        invoke = self.invoke

        def fn(p: int, args: Any, seq: int) -> Any:
            return invoke(core, p, op, args, seq)
        return fn

    def bind_parts(self, core: Any, op: str):
        """Optional deeper binding: ``(entry, func, default)`` such that
        ``entry(p, func, args-or-default, seq)`` IS the operation — lets
        the handle skip one wrapper frame per call.  None means "use
        bind_op"."""
        return None

    def recover(self, core: Any, p: int, op: str, args: Any,
                seq: int) -> Any:
        spec = self._spec(op)
        return core.recover(p, spec.func, self._args(op, args), seq)

    def recover_batch(self, core: Any, p: int,
                      calls: List[Tuple[str, Any, int]]) -> List[Any]:
        return [self.recover(core, p, op, args, seq)
                for op, args, seq in calls]

    # ---------------- optional paths ----------------------------------- #
    invoke_batch = None   # type: Optional[Any]  # set by batching adapters

    def announce(self, core: Any, p: int, op: str, args: Any,
                 seq: int) -> None:
        raise NotImplementedError(f"{self.protocol} cannot pre-announce")

    def perform(self, core: Any, p: int, op: str) -> Any:
        raise NotImplementedError(f"{self.protocol} cannot pre-announce")

    # ---------------- crash plumbing ----------------------------------- #
    def reset_volatile(self, core: Any) -> None:
        core.reset_volatile()

    # ---------------- reclamation -------------------------------------- #
    def quiesce(self, core: Any) -> Optional[dict]:
        """Advance the structure's durable reclamation boundaries at a
        quiescent point (no requests in flight).  Structures without a
        reclaimer return None."""
        return None

    def snapshot(self, core: Any) -> Any:
        raise NotImplementedError

    # ---------------- measured-degree accounting ------------------------ #
    def degree_stats(self, core: Any) -> Optional[dict]:
        """Measured combining-degree counters (rounds / ops_combined /
        degree_mean / degree_max) accumulated by the core since creation
        (or the last ``reset_degree_stats``), or None for protocols that
        do not combine (the per-op-persist baselines)."""
        return None

    def reset_degree_stats(self, core: Any) -> None:
        """Zero the degree counters (benchmarks call this after their
        warmup so degree_max reflects only the measured window)."""


# --------------------------------------------------------------------- #
# Combining-protocol adapters (PBComb / PWFComb families)               #
# --------------------------------------------------------------------- #
class _CombiningAdapter(StructureAdapter):
    """Shared logic for cores built from PBComb/PWFComb instances."""

    detectable = True
    can_announce = True

    def _instance(self, core: Any, op: str) -> Any:
        """The combining instance serving ``op`` (split queues override)."""
        return core

    def invoke(self, core, p, op, args, seq):
        spec = self._spec(op)
        return self._instance(core, op).op(p, spec.func,
                                           self._args(op, args), seq)

    def bind_op(self, core, op):
        spec = self._spec(op)
        inst_op = self._instance(core, op).op
        func, default = spec.func, spec.default

        def fn(p: int, args: Any, seq: int) -> Any:
            return inst_op(p, func, default if args is None else args, seq)
        return fn

    def bind_parts(self, core, op):
        spec = self._spec(op)
        return (self._instance(core, op).op, spec.func, spec.default)

    def announce(self, core, p, op, args, seq):
        spec = self._spec(op)
        inst = self._instance(core, op)
        rec = RequestRec(spec.func, self._args(op, args),
                         1 - inst.request[p].activate, 1)
        clk = inst.nvm.clock
        if clk is not None:
            rec.vtime = clk.now()   # combiner merges this (Lamport)
        inst.request[p] = rec

    def perform(self, core, p, op):
        return self._instance(core, op)._perform_request(p)

    def _instances(self, core):
        """The distinct combining instances behind this core (split
        queues have two; everything else one)."""
        return list({id(self._instance(core, op)): self._instance(core, op)
                     for op in self.OPS}.values())

    def degree_stats(self, core):
        from ..core.backend import merge_degree_stats
        return merge_degree_stats(
            [inst.stats.snapshot() for inst in self._instances(core)])

    def reset_degree_stats(self, core):
        for inst in self._instances(core):
            inst.stats.reset()


def _pb_st(core: PBComb) -> int:
    return core._st_base(core._mindex())


def _pwf_st(core: PWFComb) -> int:
    return core._base(core.S.load())


class PBQueueAdapter(_CombiningAdapter):
    kind, protocol, OPS = "queue", "pbcomb", QUEUE_OPS

    def create(self, nvm, n_threads, counters=None, **kw):
        return PBQueue(nvm, n_threads, counters=counters, **kw)

    def _instance(self, core, op):
        return core.enq if op == "enqueue" else core.deq

    def snapshot(self, core):
        return core.drain()


class PWFQueueAdapter(PBQueueAdapter):
    protocol = "pwfcomb"

    def create(self, nvm, n_threads, counters=None, **kw):
        return PWFQueue(nvm, n_threads, counters=counters, **kw)

    def quiesce(self, core):
        return core.quiesce()


class PBStackAdapter(_CombiningAdapter):
    kind, protocol, OPS = "stack", "pbcomb", STACK_OPS

    def create(self, nvm, n_threads, counters=None, **kw):
        return PBStack(nvm, n_threads, counters=counters, **kw)

    def snapshot(self, core):
        return core.drain()


class PWFStackAdapter(PBStackAdapter):
    protocol = "pwfcomb"

    def create(self, nvm, n_threads, counters=None, **kw):
        return PWFStack(nvm, n_threads, counters=counters, **kw)

    def quiesce(self, core):
        return core.quiesce()


class PBHeapAdapter(_CombiningAdapter):
    kind, protocol, OPS = "heap", "pbcomb", HEAP_OPS

    def create(self, nvm, n_threads, counters=None, capacity=256,
               vector_apply=False, **kw):
        return PBHeap(nvm, n_threads, capacity=capacity, counters=counters,
                      vector_apply=vector_apply)

    def snapshot(self, core):
        base = _pb_st(core)
        size = core.nvm.read(base)
        return sorted(core.nvm.read(base + 1 + i) for i in range(size))


class PWFHeapAdapter(_CombiningAdapter):
    """The wait-free heap the paper leaves implicit: HeapObject is a
    SeqObject, so PWFComb transforms it exactly like PBComb does."""

    kind, protocol, OPS = "heap", "pwfcomb", HEAP_OPS

    def create(self, nvm, n_threads, counters=None, capacity=256, **kw):
        return PWFComb(nvm, n_threads, HeapObject(capacity),
                       counters=counters, **kw)

    def snapshot(self, core):
        base = _pwf_st(core)
        size = core.nvm.read(base)
        return sorted(core.nvm.read(base + 1 + i) for i in range(size))


class _ObjSnapshotMixin:
    """Snapshot through the wrapped SeqObject's own ``snapshot`` (the
    log/checkpoint objects define one; the combining cores expose the
    current StateRec base)."""

    _st = staticmethod(_pb_st)

    def snapshot(self, core):
        return core.obj.snapshot(core.nvm, self._st(core))


class PBLogAdapter(_ObjSnapshotMixin, _CombiningAdapter):
    """Durable response log under PBComb — the serving engine's
    completion path as a registry structure (DESIGN.md §8).

    Crash replay is IDEMPOTENT re-execution instead of the per-thread
    announce-parity Recover: a batched RECORD_MANY advances the handle
    seq by the batch size, so seq parity no longer mirrors the announce
    bit — but re-applying a RECORD with identical (client, seq,
    response) is a no-op in effect, which gives the same exactly-once
    *effect* guarantee the parity path provides."""

    kind, protocol, OPS = "log", "pbcomb", LOG_OPS

    def create(self, nvm, n_threads, counters=None, n_clients=None,
               vector_apply=False, **kw):
        return PBComb(nvm, n_threads,
                      ResponseLogObject(n_clients or n_threads),
                      counters=counters, vector_apply=vector_apply)

    def recover(self, core, p, op, args, seq):
        spec = self._spec(op)
        return self._instance(core, op).op(p, spec.func,
                                           self._args(op, args), seq)

    def recover_batch(self, core, p, calls):
        triples = tuple(self._args(op, args) for op, args, _seq in calls)
        return list(core.op(p, "RECORD_MANY", triples, calls[-1][2]))

    def invoke_batch(self, core, p, calls):
        """All completions of a round in ONE combining round — one
        contiguous StateRec write, one psync (what the serving engine's
        ``invoke_many`` completion path rides on)."""
        if any(op != "record" for op, _a, _s in calls):
            return [self.invoke(core, p, op, a, s) for op, a, s in calls]
        triples = tuple(a for _op, a, _s in calls)
        return list(core.op(p, "RECORD_MANY", triples, calls[-1][2]))

    def last_record(self, core, client: int):
        """(seq, response) currently logged for ``client`` — the
        paper's Recover reads this to answer re-announced requests
        without re-executing them."""
        base = self._st(core)
        return (core.nvm.read(base + 2 * client),
                core.nvm.read(base + 2 * client + 1))


class PWFLogAdapter(PBLogAdapter):
    protocol = "pwfcomb"
    _st = staticmethod(_pwf_st)

    def create(self, nvm, n_threads, counters=None, n_clients=None, **kw):
        return PWFComb(nvm, n_threads,
                       ResponseLogObject(n_clients or n_threads),
                       counters=counters, **kw)


class PBCkptAdapter(_ObjSnapshotMixin, _CombiningAdapter):
    """Checkpoint cell under PBComb: d announcers' persist requests ride
    one combining round/psync; newest step wins.  Replay is idempotent
    (the step guard), same reasoning as PBLogAdapter."""

    kind, protocol, OPS = "ckpt", "pbcomb", CKPT_OPS

    def create(self, nvm, n_threads, counters=None, vector_apply=False, **kw):
        return PBComb(nvm, n_threads, CheckpointObject(),
                      counters=counters, vector_apply=vector_apply)

    def recover(self, core, p, op, args, seq):
        spec = self._spec(op)
        return self._instance(core, op).op(p, spec.func,
                                           self._args(op, args), seq)


class PWFCkptAdapter(PBCkptAdapter):
    protocol = "pwfcomb"
    _st = staticmethod(_pwf_st)

    def create(self, nvm, n_threads, counters=None, **kw):
        return PWFComb(nvm, n_threads, CheckpointObject(),
                       counters=counters, **kw)


class PBCounterAdapter(_CombiningAdapter):
    kind, protocol, OPS = "counter", "pbcomb", COUNTER_OPS

    def create(self, nvm, n_threads, counters=None, vector_apply=False, **kw):
        return PBComb(nvm, n_threads, FetchAddObject(), counters=counters,
                      vector_apply=vector_apply)

    def snapshot(self, core):
        return core.nvm.read(_pb_st(core))


class PWFCounterAdapter(_CombiningAdapter):
    kind, protocol, OPS = "counter", "pwfcomb", COUNTER_OPS

    def create(self, nvm, n_threads, counters=None, **kw):
        return PWFComb(nvm, n_threads, FetchAddObject(),
                       counters=counters, **kw)

    def snapshot(self, core):
        return core.nvm.read(_pwf_st(core))


# --------------------------------------------------------------------- #
# Baseline adapters (Section 6 competitors)                             #
# --------------------------------------------------------------------- #
_SEQ_OBJ = {"queue": SeqQueueObject, "stack": SeqStackObject,
            "heap": HeapObject, "counter": FetchAddObject,
            "log": ResponseLogObject, "ckpt": CheckpointObject}
_KIND_OPS = {"queue": QUEUE_OPS, "stack": STACK_OPS,
             "heap": HEAP_OPS, "counter": COUNTER_OPS,
             "log": LOG_OPS, "ckpt": CKPT_OPS}


class _DirectOpAdapter(StructureAdapter):
    """Shared dispatch for cores exposing ``core.op(p, func, args, seq)``
    directly (lock baselines, DFC)."""

    def invoke(self, core, p, op, args, seq):
        spec = self._spec(op)
        return core.op(p, spec.func, self._args(op, args), seq)

    def bind_op(self, core, op):
        spec = self._spec(op)
        core_op = core.op
        func, default = spec.func, spec.default

        def fn(p: int, args: Any, seq: int) -> Any:
            return core_op(p, func, default if args is None else args, seq)
        return fn

    def bind_parts(self, core, op):
        spec = self._spec(op)
        return (core.op, spec.func, spec.default)


class LockAdapter(_DirectOpAdapter):
    """Coarse-lock baselines over any SeqObject (direct or undo-log)."""

    detectable = False

    def __init__(self, kind: str, undo: bool) -> None:
        self.kind = kind
        self.protocol = "lock-undo" if undo else "lock-direct"
        self.OPS = _KIND_OPS[kind]
        self._cls = LockUndoLogObject if undo else LockDirectObject
        self._obj_cls = _SEQ_OBJ[kind]

    def create(self, nvm, n_threads, counters=None, capacity=1024,
               n_clients=None, **kw):
        cls = self._obj_cls
        if cls is FetchAddObject or cls is CheckpointObject:
            obj = cls()
        elif cls is ResponseLogObject:
            obj = cls(n_clients or n_threads)
        else:
            obj = cls(capacity)
        return self._cls(nvm, n_threads, obj)

    def snapshot(self, core):
        nvm, base, obj = core.nvm, core.st_base, core.obj
        if hasattr(obj, "snapshot"):
            return obj.snapshot(nvm, base)
        if self.kind == "counter":
            return nvm.read(base)
        size = nvm.read(base)                    # HeapObject layout
        return sorted(nvm.read(base + 1 + i) for i in range(size))


class DurableMSQueueAdapter(StructureAdapter):
    kind, protocol, OPS = "queue", "durable-ms", QUEUE_OPS
    detectable = False

    def create(self, nvm, n_threads, counters=None, **kw):
        return DurableMSQueue(nvm, n_threads, **kw)

    def invoke(self, core, p, op, args, seq):
        if op == "enqueue":
            return core.enqueue(p, self._args(op, args), seq)
        return core.dequeue(p, seq)

    def bind_op(self, core, op):
        self._spec(op)
        if op == "enqueue":
            enq = core.enqueue
            return lambda p, args, seq: enq(p, args, seq)
        deq = core.dequeue
        return lambda p, args, seq: deq(p, seq)

    def snapshot(self, core):
        return core.drain()


class DFCStackAdapter(_DirectOpAdapter):
    kind, protocol, OPS = "stack", "dfc", STACK_OPS
    # DFC persists announcements and done-marks, and recover() uses them
    # as a fast path — but the combiner psyncs once per ROUND, so under
    # the explicit-epoch model a mid-round crash can drain the structural
    # update while dropping the done-mark (or vice versa).  Exactly-once
    # replay of in-flight ops is therefore not guaranteed; don't claim it.
    detectable = False
    # DFC announcements live in NVMM, so the announce/perform split is
    # natural: announce persists the request record (pwb+pfence — the
    # per-thread persistence DFC pays that PBComb avoids), perform runs
    # the combiner loop.  The modeled bench pass uses this to stage
    # rounds of a fixed combining degree deterministically.
    can_announce = True

    def create(self, nvm, n_threads, counters=None, **kw):
        return DFCStack(nvm, n_threads, **kw)

    def announce(self, core, p, op, args, seq):
        spec = self._spec(op)
        nvm = core.nvm
        base = core.ann_base[p]
        nvm.write(base, spec.func)
        nvm.write(base + 1, self._args(op, args))
        nvm.write(base + 2, seq)
        nvm.pwb(base, 3)
        nvm.pfence()
        if nvm.clock is not None:
            core._ann_vt[p] = nvm.clock.now()

    def perform(self, core, p, op):
        return core.perform(p)

    def degree_stats(self, core):
        from ..core.backend import merge_degree_stats
        return merge_degree_stats([core.stats.snapshot()])

    def reset_degree_stats(self, core):
        core.stats.reset()

    def snapshot(self, core):
        return core.drain()
