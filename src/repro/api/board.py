"""AnnounceBoard — the paper's ``Request[0..n-1]`` announcement array as
a reusable component.

Every combining-style component in this repo used to re-implement the
same plumbing: a per-slot announcement record carrying (payload, seq,
activate, valid), a done event the announcer waits on, and parity
bookkeeping against some persisted deactivate array.  The board owns
exactly that volatile state and nothing else — *where* the deactivate
bits and responses persist stays with the component (a StateRec in NVMM
for the protocols, a slot file for the checkpointer), which is what
makes the board reusable by ``PBCombCheckpointer`` and
``CombiningEngine`` alike.

A crash wipes the board (it is volatile by design, persistence principle
P1): ``reset()`` models that, and ``CombiningRuntime.recover`` calls it
for every board it handed out.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional, Sequence, Tuple


class Announcement:
    """One announcement slot: the paper's RequestRec plus a done event
    the announcing thread can block on."""

    def __init__(self, payload: Any = None, seq: int = 0, activate: int = 0,
                 valid: int = 0, response: Any = None) -> None:
        self.payload = payload
        self.seq = seq
        self.activate = activate
        self.valid = valid
        self.response = response
        self.done = threading.Event()

    # Backwards-compatible alias (the checkpointer's AnnounceRec exposed
    # the event as ``done_event``).
    @property
    def done_event(self) -> threading.Event:
        return self.done


class AnnounceBoard:
    """Volatile announcement array shared by combiner-style components."""

    def __init__(self, n_slots: int,
                 on_announce: Optional[Callable[[], None]] = None) -> None:
        self.n = n_slots
        self.slots: List[Optional[Announcement]] = [None] * n_slots
        self._on_announce = on_announce

    # ------------------ announcer side -------------------------------- #
    def announce(self, p: int, payload: Any, *, seq: Optional[int] = None,
                 response: Any = None) -> Announcement:
        """Publish an announcement in slot ``p``.

        With an explicit ``seq`` the activate bit is its parity (the
        paper's detectability convention — recovery re-announces the same
        seq and the parities line up).  Without one, the activate bit
        simply flips relative to the previous announcement in the slot.
        """
        prev = self.slots[p]
        if seq is None:
            seq = (prev.seq + 1) if prev else 1
            activate = 1 - (prev.activate if prev else 0)
        else:
            activate = seq % 2
        rec = Announcement(payload, seq, activate, 1, response)
        self.slots[p] = rec
        if self._on_announce is not None:
            self._on_announce()
        return rec

    # ------------------ combiner side --------------------------------- #
    def pending(self) -> List[Tuple[int, Announcement]]:
        """Valid announcements nobody has served yet (done-event view —
        used by combiners whose served-detection is the event itself)."""
        out = []
        for p in range(self.n):
            rec = self.slots[p]
            if rec is not None and rec.valid == 1 and not rec.done.is_set():
                out.append((p, rec))
        return out

    def active_vs(self, deactivate: Sequence[int]) \
            -> List[Tuple[int, Announcement]]:
        """Valid announcements whose activate parity differs from the
        caller's (persisted) deactivate bits — the paper's line 17."""
        out = []
        for p in range(self.n):
            rec = self.slots[p]
            if rec is not None and rec.valid == 1 \
                    and rec.activate != deactivate[p]:
                out.append((p, rec))
        return out

    def serve(self, rec: Announcement, response: Any) -> None:
        rec.response = response
        rec.done.set()

    # ------------------ crash ----------------------------------------- #
    def reset(self) -> None:
        """A crash wiped DRAM: all announcements are gone (P1)."""
        self.slots = [None] * self.n
