"""Per-thread handles: the paper's "system support" made explicit.

Section 2 of the paper assumes the system hands every operation a
per-thread *consecutive* sequence number and re-supplies the in-flight
(func, args, seq) to the recovery function after a crash.  A ``Handle``
is that system: it owns the seq counters (one per (object, seq-group) —
parity is per combining instance, so the split queues get independent
enqueue/dequeue counters), records every in-flight call with the runtime
so ``CombiningRuntime.recover`` can replay it, and exposes the typed
sugar (``q.enqueue(x)``, ``stack.pop()``, ``heap.insert(k)``) so callers
stop hand-threading thread ids and seq numbers.
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

from ..core.nvm import SimulatedCrash

BATCH = "__batch__"   # runtime in-flight marker for invoke_many records


class Handle:
    """One logical thread attached to a CombiningRuntime."""

    def __init__(self, runtime: Any, tid: int) -> None:
        self.runtime = runtime
        self.tid = tid
        self._seq: Dict[Tuple[str, str], int] = {}

    # ------------------ seq management -------------------------------- #
    def _next_seq(self, obj: Any, op: str) -> int:
        group = obj.adapter._spec(op).group
        key = (obj.name, group)
        self._seq[key] = self._seq.get(key, 0) + 1
        return self._seq[key]

    @staticmethod
    def _norm(args: tuple) -> Any:
        if not args:
            return None
        if len(args) == 1:
            return args[0]
        return tuple(args)

    # ------------------ invocation ------------------------------------ #
    def invoke(self, obj: Any, op: str, *args: Any) -> Any:
        """Run one operation; the runtime replays it on recovery if a
        crash lands mid-call."""
        a = self._norm(args)
        seq = self._next_seq(obj, op)
        key = (obj.name, self.tid)
        self.runtime._inflight[key] = (op, a, seq)
        try:
            ret = obj.adapter.invoke(obj.core, self.tid, op, a, seq)
        except SimulatedCrash:
            raise                       # stays in-flight -> replayed
        except BaseException:
            self.runtime._inflight.pop(key, None)
            raise
        self.runtime._inflight.pop(key, None)
        return ret

    def invoke_many(self, calls: Sequence[Sequence[Any]]) -> List[Any]:
        """Batched invocation: ``calls`` is ``[(obj, op, *args), ...]``.

        When every call targets the same object and its adapter supports
        a batch path (``invoke_batch``), all calls are announced together
        and served by ONE combining round (one contiguous persist, one
        psync) — this is the path the serving engine's completion log
        rides on.  Otherwise the calls run sequentially; batching then
        comes from cross-thread combining, as in the paper.
        """
        calls = [tuple(c) for c in calls]
        if not calls:
            return []
        first = calls[0][0]
        same = all(c[0] is first for c in calls)
        if same and first.adapter.invoke_batch is not None:
            batch = [(c[1], self._norm(c[2:]), self._next_seq(first, c[1]))
                     for c in calls]
            key = (first.name, self.tid)
            self.runtime._inflight[key] = (BATCH, batch, 0)
            try:
                rets = first.adapter.invoke_batch(first.core, self.tid,
                                                  batch)
            except SimulatedCrash:
                raise
            except BaseException:
                self.runtime._inflight.pop(key, None)
                raise
            self.runtime._inflight.pop(key, None)
            return rets
        return [self.invoke(c[0], c[1], *c[2:]) for c in calls]

    # ------------------ announce / perform ---------------------------- #
    def announce(self, obj: Any, op: str, *args: Any) -> int:
        """Publish the request without serving it (detectable combining
        protocols only).  Used by crash tests to stage a round serving
        many announced requests; returns the seq the runtime will replay
        with."""
        a = self._norm(args)
        seq = self._next_seq(obj, op)
        obj.adapter.announce(obj.core, self.tid, op, a, seq)
        self.runtime._inflight[(obj.name, self.tid)] = (op, a, seq)
        return seq

    def perform(self, obj: Any) -> Any:
        """Serve this handle's announced request (possibly combining
        every other announced request along the way)."""
        key = (obj.name, self.tid)
        if key not in self.runtime._inflight:
            raise RuntimeError(f"nothing announced on {obj.name} "
                               f"by thread {self.tid}")
        op, _a, _seq = self.runtime._inflight[key]
        ret = obj.adapter.perform(obj.core, self.tid, op)
        self.runtime._inflight.pop(key, None)
        return ret

    # ------------------ typed sugar ----------------------------------- #
    def bind(self, obj: Any) -> "Bound":
        return bind(self, obj)


class Bound:
    """Base typed proxy: an object + the handle operating on it."""

    def __init__(self, handle: Handle, obj: Any) -> None:
        self._h = handle
        self._obj = obj

    def snapshot(self) -> Any:
        return self._obj.snapshot()


class BoundQueue(Bound):
    def enqueue(self, value: Any) -> Any:
        return self._h.invoke(self._obj, "enqueue", value)

    def dequeue(self) -> Any:
        return self._h.invoke(self._obj, "dequeue")

    def drain(self) -> List[Any]:
        return self._obj.snapshot()


class BoundStack(Bound):
    def push(self, value: Any) -> Any:
        return self._h.invoke(self._obj, "push", value)

    def pop(self) -> Any:
        return self._h.invoke(self._obj, "pop")

    def drain(self) -> List[Any]:
        return self._obj.snapshot()


class BoundHeap(Bound):
    def insert(self, key: Any) -> Any:
        return self._h.invoke(self._obj, "insert", key)

    def delete_min(self) -> Any:
        return self._h.invoke(self._obj, "delete_min")

    def get_min(self) -> Any:
        return self._h.invoke(self._obj, "get_min")


class BoundCounter(Bound):
    def fetch_add(self, delta: int = 1) -> Any:
        return self._h.invoke(self._obj, "fetch_add", delta)

    def read(self) -> Any:
        return self._h.invoke(self._obj, "read")


_BOUND_BY_KIND = {"queue": BoundQueue, "stack": BoundStack,
                  "heap": BoundHeap, "counter": BoundCounter}


def bind(handle: Handle, obj: Any) -> Bound:
    return _BOUND_BY_KIND.get(obj.kind, Bound)(handle, obj)
